//! Sensitivity study driver (Figs. 13/14 + threshold/NVM-latency studies
//! from §IV-F): sweeps sampling interval, top-N, and migration threshold
//! for Rainbow on a chosen app. The interval and top-N sweeps run as
//! parallel spec matrices on the sweep orchestrator; the threshold sweep
//! patches a `Config` knob `RunSpec` cannot express, so it stays a local
//! serial loop.
//!
//! ```sh
//! cargo run --release --example sensitivity [app]
//! ```

use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::RunSpec;
use rainbow::util::tables::Table;

fn base_spec(app: &str) -> RunSpec {
    let mut s = RunSpec::new(app, "rainbow");
    s.instructions = 800_000;
    s
}

fn main() {
    let app = std::env::args().nth(1).unwrap_or_else(|| "soplex".into());

    // Fig. 13: sampling interval sweep (paper: 1e5..1e9 full-scale).
    let base_interval = base_spec(&app).config().interval_cycles;
    let interval_specs: Vec<RunSpec> = [0.01, 0.1, 1.0, 10.0]
        .iter()
        .map(|f| {
            let mut s = base_spec(&app);
            s.interval_cycles =
                ((base_interval as f64 * f) as u64).max(10_000);
            s
        })
        .collect();
    let metrics =
        sweep::run_parallel(&interval_specs, &SweepConfig::default());
    let mut t = Table::new(
        &format!("Fig 13 (sensitivity): {app}, interval sweep"),
        &["interval", "migrations", "traffic MB", "IPC"]);
    for (s, m) in interval_specs.iter().zip(&metrics) {
        t.row(&[format!("{:.0e}", s.interval_cycles as f64),
                m.migrations.to_string(),
                format!("{:.1}", (m.migrated_bytes + m.writeback_bytes)
                        as f64 / (1 << 20) as f64),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(None);

    // Fig. 14: top-N sweep.
    let topn_specs: Vec<RunSpec> = [4usize, 10, 25, 50, 100]
        .iter()
        .map(|&n| {
            let mut s = base_spec(&app);
            s.top_n = n;
            s
        })
        .collect();
    let metrics = sweep::run_parallel(&topn_specs, &SweepConfig::default());
    let mut t = Table::new(
        &format!("Fig 14 (sensitivity): {app}, top-N sweep"),
        &["top-N", "migrations", "traffic MB", "IPC"]);
    for (s, m) in topn_specs.iter().zip(&metrics) {
        t.row(&[s.top_n.to_string(), m.migrations.to_string(),
                format!("{:.1}", (m.migrated_bytes + m.writeback_bytes)
                        as f64 / (1 << 20) as f64),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(None);

    // §IV-F threshold study (described in text, no figure): higher
    // threshold -> fewer migrations.
    let mut t = Table::new(
        &format!("§IV-F: {app}, migration-threshold sweep"),
        &["threshold", "migrations", "IPC"]);
    for mult in [0.25, 1.0, 4.0, 16.0] {
        let s = base_spec(&app);
        let threshold = s.config().migration_threshold * mult;
        let m = run_with_threshold(&s, threshold);
        t.row(&[format!("{threshold:.0}"),
                m.migrations.to_string(), format!("{:.4}", m.ipc())]);
    }
    t.emit(None);
}

/// Run a spec with an overridden migration threshold (bypasses the cache).
fn run_with_threshold(spec: &RunSpec, threshold: f64)
                      -> rainbow::sim::RunMetrics {
    use rainbow::policies::{self, Policy};
    use rainbow::sim::{engine, EngineConfig};
    use rainbow::workloads::Workload;

    let mut cfg = spec.config();
    cfg.migration_threshold = threshold;
    let mut w = Workload::by_name(&spec.workload, cfg.cores, spec.scale,
                                  spec.seed).unwrap();
    let mut p: Box<dyn Policy> =
        policies::by_name(&spec.policy, &cfg, false).unwrap();
    engine::run(p.as_mut(), &mut w,
                &EngineConfig::new(spec.instructions, cfg.interval_cycles))
        .metrics
}
