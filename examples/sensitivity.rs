//! Sensitivity study driver (Figs. 13/14 + the §IV-F threshold and
//! NVM-latency studies): sweeps sampling interval, top-N, migration
//! threshold, and NVM read/write latency for Rainbow on a chosen app.
//! Every sweep — including the config-level knobs `RunSpec` historically
//! could not express — is an override-bearing spec matrix, and ALL of
//! them run as ONE batch on the parallel sweep orchestrator.
//!
//! ```sh
//! cargo run --release --example sensitivity [app]
//! ```

use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::RunSpec;
use rainbow::sim::RunMetrics;
use rainbow::util::tables::Table;

fn base_spec(app: &str) -> RunSpec {
    RunSpec::new(app, "rainbow").with_instructions(800_000)
}

fn traffic_mb(m: &RunMetrics) -> String {
    format!("{:.1}",
            (m.migrated_bytes + m.writeback_bytes) as f64 / (1 << 20) as f64)
}

fn main() {
    let app = std::env::args().nth(1).unwrap_or_else(|| "soplex".into());
    let base_cfg = base_spec(&app).config();

    // Build each §IV-F sweep as its own override-bearing spec chunk...
    let interval_specs: Vec<RunSpec> = [0.01, 0.1, 1.0, 10.0]
        .iter()
        .map(|f| base_spec(&app).with(
            "rainbow.interval_cycles",
            ((base_cfg.interval_cycles as f64 * f) as u64).max(10_000)))
        .collect();
    let topn_specs: Vec<RunSpec> = [4usize, 10, 25, 50, 100]
        .iter()
        .map(|&n| base_spec(&app).with("rainbow.top_n", n))
        .collect();
    let threshold_specs: Vec<RunSpec> = [0.25, 1.0, 4.0, 16.0]
        .iter()
        .map(|m| base_spec(&app).with(
            "rainbow.migration_threshold",
            base_cfg.migration_threshold * m))
        .collect();
    let nvm_specs: Vec<RunSpec> = [0.5, 1.0, 2.0, 4.0]
        .iter()
        .map(|m| base_spec(&app)
            .with("nvm.read_cycles",
                  ((base_cfg.nvm.read_cycles as f64 * m) as u64).max(1))
            .with("nvm.write_cycles",
                  ((base_cfg.nvm.write_cycles as f64 * m) as u64).max(1)))
        .collect();

    // ...run them all concurrently as one batch (any specs sharing a
    // fingerprint would be simulated once), then split the metrics back
    // into the same chunks for rendering.
    let all: Vec<RunSpec> = interval_specs.iter()
        .chain(&topn_specs)
        .chain(&threshold_specs)
        .chain(&nvm_specs)
        .cloned()
        .collect();
    let metrics = sweep::run_parallel(&all, &SweepConfig::default());
    let (m_interval, rest) = metrics.split_at(interval_specs.len());
    let (m_topn, rest) = rest.split_at(topn_specs.len());
    let (m_threshold, m_nvm) = rest.split_at(threshold_specs.len());

    // Fig. 13: sampling interval sweep (paper: 1e5..1e9 full-scale).
    let mut t = Table::new(
        &format!("Fig 13 (sensitivity): {app}, interval sweep"),
        &["interval", "migrations", "traffic MB", "IPC"]);
    for (s, m) in interval_specs.iter().zip(m_interval) {
        t.row(&[format!("{:.0e}", s.config().interval_cycles as f64),
                m.migrations.to_string(), traffic_mb(m),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(None);

    // Fig. 14: top-N sweep.
    let mut t = Table::new(
        &format!("Fig 14 (sensitivity): {app}, top-N sweep"),
        &["top-N", "migrations", "traffic MB", "IPC"]);
    for (s, m) in topn_specs.iter().zip(m_topn) {
        t.row(&[s.config().top_n.to_string(), m.migrations.to_string(),
                traffic_mb(m), format!("{:.4}", m.ipc())]);
    }
    t.emit(None);

    // §IV-F threshold study (described in text, no figure): higher
    // threshold -> fewer migrations.
    let mut t = Table::new(
        &format!("§IV-F: {app}, migration-threshold sweep"),
        &["threshold", "migrations", "IPC"]);
    for (s, m) in threshold_specs.iter().zip(m_threshold) {
        t.row(&[format!("{:.0}", s.config().migration_threshold),
                m.migrations.to_string(), format!("{:.4}", m.ipc())]);
    }
    t.emit(None);

    // §IV-F NVM-latency study: slower NVM widens Rainbow's benefit from
    // serving hot pages out of DRAM.
    let mut t = Table::new(
        &format!("§IV-F: {app}, NVM latency sweep"),
        &["NVM rd/wr cycles", "migrations", "traffic MB", "IPC"]);
    for (s, m) in nvm_specs.iter().zip(m_nvm) {
        let cfg = s.config();
        t.row(&[format!("{}/{}", cfg.nvm.read_cycles, cfg.nvm.write_cycles),
                m.migrations.to_string(), traffic_mb(m),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(None);
}
