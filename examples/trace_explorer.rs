//! Trace explorer: record a workload trace, persist it, reload it, and
//! print the paper's motivation analytics over it — a Fig.-1-style CDF of
//! touched 4 KB pages per superpage and a Table-II-style hot-page
//! distribution — exercising the trace substrate end to end.
//!
//! ```sh
//! cargo run --release --example trace_explorer [app] [n_accesses]
//! ```

use std::collections::HashMap;

use rainbow::config::{PAGES_PER_SP, PAGE_SIZE};
use rainbow::util::stats::{cdf_at, Histogram};
use rainbow::util::tables::Table;
use rainbow::workloads::{AppProfile, Synth, Trace, HOT_HIST_BOUNDS};

fn main() {
    let app = std::env::args().nth(1).unwrap_or_else(|| "mcf".into());
    let n: usize = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(300_000);

    let profile = AppProfile::by_name(&app)
        .unwrap_or_else(|| panic!("unknown app {app}"))
        .scaled(8);
    println!("{app}: footprint {} MB, working set {} MB (1/8 scale)",
             profile.footprint >> 20, profile.working_set >> 20);

    // Record + persist + reload (round-trip through the binary format).
    let mut synth = Synth::new(profile, 0, 7);
    let trace = Trace::record(|| synth.next_op(), n);
    let path = std::env::temp_dir().join(format!("{app}.trace"));
    trace.save(&path).unwrap();
    let trace = Trace::load(&path).unwrap();
    println!("trace: {} memory records, {} instructions, saved to {}\n",
             trace.len(), trace.instructions(), path.display());

    // Per-page access counts from the reloaded trace.
    let mut counts: HashMap<u64, u64> = HashMap::new();
    let mut writes = 0u64;
    for r in &trace.recs {
        *counts.entry(r.vaddr / PAGE_SIZE).or_default() += 1;
        writes += r.is_write as u64;
    }
    println!("write ratio: {:.1}%  touched pages: {}",
             100.0 * writes as f64 / trace.len() as f64, counts.len());

    // Fig. 1: CDF of touched pages per superpage.
    let mut per_sp: HashMap<u64, u64> = HashMap::new();
    for &pg in counts.keys() {
        *per_sp.entry(pg / PAGES_PER_SP).or_default() += 1;
    }
    let touched: Vec<u64> = per_sp.values().copied().collect();
    let points = [1u64, 8, 32, 64, 128, 256, 384, 512];
    let cdf = cdf_at(&touched, &points);
    let mut t = Table::new(
        &format!("Fig 1 (from trace): {app} — CDF of touched 4KB pages/superpage"),
        &["<= pages", "fraction of superpages"]);
    for (p, c) in points.iter().zip(cdf.iter()) {
        t.row(&[p.to_string(), format!("{c:.3}")]);
    }
    t.emit(None);

    // Table II: hot pages (top pages carrying 70% of accesses) per sp.
    let mut by_count: Vec<(u64, u64)> =
        counts.iter().map(|(&p, &c)| (p, c)).collect();
    by_count.sort_by(|a, b| b.1.cmp(&a.1));
    let target = (trace.len() as u64 * 7) / 10;
    let mut acc = 0;
    let mut hot_per_sp: HashMap<u64, u64> = HashMap::new();
    for (pg, c) in by_count {
        if acc >= target {
            break;
        }
        acc += c;
        *hot_per_sp.entry(pg / PAGES_PER_SP).or_default() += 1;
    }
    let mut h = Histogram::with_bounds(&HOT_HIST_BOUNDS);
    for (_, c) in hot_per_sp {
        h.add(c);
    }
    let fr = h.fractions();
    let mut t = Table::new(
        &format!("Table II (from trace): {app} — hot 4KB pages per superpage"),
        &["1-32", "33-64", "65-128", "129-256", "257-384", "385-512"]);
    t.row(&(0..6).map(|i| format!("{:.1}%", 100.0 * fr[i]))
        .collect::<Vec<_>>());
    t.emit(None);
}
