//! Backend-matrix driver: the Rainbow-vs-baselines comparison replayed
//! across the NVM design space (PCM, STT-RAM, Optane-DCPMM-class,
//! CXL-remote-class) by swapping the slow tier's device profile through
//! the `nvm.profile` knob — every cell is one override-bearing spec on
//! the parallel sweep orchestrator.
//!
//! ```sh
//! cargo run --release --example backends [app ...]
//! ```

use rainbow::config::profiles;
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::RunSpec;
use rainbow::sim::metrics::hit_rate;
use rainbow::util::stats::geomean;
use rainbow::util::tables::Table;

const POLICIES: [&str; 3] = ["flat", "hscc4k", "rainbow"];

fn main() {
    let mut apps: Vec<String> = std::env::args().skip(1).collect();
    if apps.is_empty() {
        apps = ["mcf", "DICT", "GUPS"].iter().map(|s| s.to_string()).collect();
    }
    let profs = profiles::slow_tier_names();

    // One spec per (profile, app, policy) cell, all simulated as a
    // single concurrent batch.
    let mut specs = Vec::with_capacity(
        profs.len() * apps.len() * POLICIES.len());
    for prof in &profs {
        for app in &apps {
            for pol in &POLICIES {
                specs.push(RunSpec::new(app, pol)
                    .with_instructions(600_000)
                    .with_raw("nvm.profile", prof));
            }
        }
    }
    let t0 = std::time::Instant::now();
    let metrics = sweep::run_parallel(&specs, &SweepConfig::default());

    // Does Rainbow's win over HSCC-4KB survive on every backend? The
    // last column is the answer the paper's Fig. 10 gives for PCM.
    let mut t = Table::new(
        &format!("Backend matrix: geomean IPC over {} (by NVM profile)",
                 apps.join(", ")),
        &["NVM profile", "tech", "Flat-static", "HSCC-4KB", "Rainbow",
          "Rainbow/HSCC-4KB", "NVM row-hit"]);
    let (na, np) = (apps.len(), POLICIES.len());
    for (pi, prof) in profs.iter().enumerate() {
        let p = profiles::by_name(prof).unwrap();
        let ipc = |poli: usize| -> f64 {
            let xs: Vec<f64> = (0..na)
                .map(|ai| metrics[(pi * na + ai) * np + poli].ipc()
                    .max(1e-12))
                .collect();
            geomean(&xs)
        };
        let (mut nh, mut nm) = (0u64, 0u64);
        for ai in 0..na {
            // Row-buffer locality of the slow tier under Rainbow.
            let m = &metrics[(pi * na + ai) * np + 2];
            nh += m.nvm_row_hits;
            nm += m.nvm_row_misses;
        }
        let (flat, hscc, rb) = (ipc(0), ipc(1), ipc(2));
        t.row(&[prof.to_string(), p.tech.name().to_string(),
                format!("{flat:.4}"), format!("{hscc:.4}"),
                format!("{rb:.4}"),
                format!("{:.3}", rb / hscc.max(1e-12)),
                format!("{:.2}%", 100.0 * hit_rate(nh, nm))]);
    }
    t.emit(Some("target/figures/backends_example.csv"));
    println!("backend matrix: {} runs in {:.1}s",
             specs.len(), t0.elapsed().as_secs_f64());
}
