//! END-TO-END driver (DESIGN.md deliverable): record a real workload
//! trace once, persist it, run the identically-seeded stream through all
//! five systems — concurrently, on the sweep orchestrator's scoped
//! workers — and report the paper's headline metric (normalized IPC,
//! Fig. 10) plus MPKI, migration traffic, and energy, proving workload
//! generation, trace record/replay, every policy, the parallel harness,
//! and the metrics stack compose.
//!
//! ```sh
//! cargo run --release --example policy_compare [app] [instructions]
//! ```

use rainbow::config::Config;
use rainbow::policies;
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::RunSpec;
use rainbow::util::tables::Table;
use rainbow::workloads::{Trace, Workload};

fn main() {
    let app = std::env::args().nth(1).unwrap_or_else(|| "soplex".into());
    let instructions: u64 = std::env::args()
        .nth(2)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_500_000);
    let cfg = Config::scaled(8);

    // 1. Record a replayable trace (one per core) so the input is a
    //    persisted, inspectable artifact.
    println!("recording {app} trace ({instructions} instructions, \
              {} cores)...", cfg.cores);
    let mut source = Workload::by_name(&app, cfg.cores, 8, 0xE2E)
        .unwrap_or_else(|| panic!("unknown workload {app}"));
    let per_core_mem =
        (instructions / cfg.cores as u64 / 3).max(10_000) as usize;
    let traces: Vec<Trace> = (0..cfg.cores)
        .map(|c| Trace::record(|| source.next_op(c), per_core_mem))
        .collect();
    let trace_dir = std::path::Path::new("target/e2e_traces");
    std::fs::create_dir_all(trace_dir).unwrap();
    for (c, t) in traces.iter().enumerate() {
        t.save(&trace_dir.join(format!("{app}_{c}.trace"))).unwrap();
    }
    let total_recs: usize = traces.iter().map(|t| t.len()).sum();
    println!("traces saved to {} ({} memory records)\n",
             trace_dir.display(), total_recs);

    // 2. All five policies over the identically-seeded stream, as one
    //    parallel sweep matrix (each cell re-derives the same workload
    //    stream from the shared seed).
    let base = RunSpec::new(&app, "flat")
        .with_scale(8)
        .with_instructions(instructions)
        .with_seed(0xE2E);
    let policy_names: Vec<String> =
        policies::all_names().iter().map(|s| s.to_string()).collect();
    let specs = sweep::matrix(&base, &[app.clone()], &policy_names);
    let t0 = std::time::Instant::now();
    let out = sweep::run(&specs, &SweepConfig::default());
    println!("{} systems simulated concurrently on {} workers in {:.1} ms",
             specs.len(), out.workers_used,
             t0.elapsed().as_secs_f64() * 1e3);
    let flat_ipc = out.metrics[0].ipc(); // all_names()[0] == "flat"

    // 3. Report (Fig. 10-style).
    let mut t = Table::new(
        &format!("End-to-end: {app} x 5 systems ({instructions} instr)"),
        &["system", "IPC", "norm IPC", "MPKI", "mig traffic MB",
          "shootdowns", "energy mJ"]);
    for (s, m) in specs.iter().zip(&out.metrics) {
        t.row(&[s.policy.clone(),
                format!("{:.4}", m.ipc()),
                format!("{:.2}", m.ipc() / flat_ipc.max(1e-12)),
                format!("{:.3}", m.mpki()),
                format!("{:.1}",
                        (m.migrated_bytes + m.writeback_bytes) as f64
                            / (1 << 20) as f64),
                m.shootdowns.to_string(),
                format!("{:.1}", m.energy_mj())]);
    }
    t.emit(Some("target/figures/e2e_policy_compare.csv"));

    let rb_at = policies::all_names()
        .iter()
        .position(|&n| n == "rainbow")
        .unwrap();
    println!("Rainbow/Flat-static speedup: {:.2}x \
              (paper: up to 2.9x, 1.727x average)",
             out.metrics[rb_at].ipc() / flat_ipc.max(1e-12));
}
