//! Quickstart: simulate one workload under Rainbow and the Flat-static
//! baseline — both runs in parallel on the sweep orchestrator — and
//! print the headline comparison.
//!
//! ```sh
//! cargo run --release --example quickstart [app]
//! ```
//!
//! Results are cached on disk so a re-run is instant: the results
//! store is threaded explicitly through `SweepConfig::store` (the same
//! mechanism the CLI's `--cache-dir`/`--store` and the shard
//! orchestrator use — a directory store here; `Store::net` would point
//! the same code at a `rainbow cache-server`. Nothing mutates the
//! environment; `default_cache_dir()` only *reads* `RAINBOW_CACHE` as
//! a fallback default). See docs/MANUAL.md §1.

use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::{default_cache_dir, RunSpec, Store};
use rainbow::util::tables::Table;

fn main() {
    let app = std::env::args().nth(1).unwrap_or_else(|| "DICT".to_string());
    println!("simulating {app} under Flat-static and Rainbow \
              (1/8-scale Table IV machine, parallel workers)...\n");

    let spec = RunSpec::new(&app, "flat").with_instructions(3_000_000);
    let rb_spec = spec.clone().with_policy("rainbow");
    let cache_dir = default_cache_dir();
    let cfg = SweepConfig {
        disk_cache: true,
        store: Some(Store::fs(cache_dir.clone())),
        ..SweepConfig::default()
    };
    let metrics = sweep::run_parallel(&[spec, rb_spec], &cfg);
    let (flat, rb) = (&metrics[0], &metrics[1]);
    println!("(results cached in {}; re-runs load from there)\n",
             cache_dir.display());

    let mut t = Table::new(
        &format!("{app}: Rainbow vs Flat-static"),
        &["metric", "Flat-static", "Rainbow", "ratio"]);
    let ratio = |a: f64, b: f64| {
        if b == 0.0 { "-".to_string() } else { format!("{:.2}x", a / b) }
    };
    t.row(&["IPC".into(), format!("{:.4}", flat.ipc()),
            format!("{:.4}", rb.ipc()), ratio(rb.ipc(), flat.ipc())]);
    t.row(&["MPKI".into(), format!("{:.2}", flat.mpki()),
            format!("{:.3}", rb.mpki()), ratio(flat.mpki(), rb.mpki())]);
    t.row(&["TLB-miss cycles %".into(),
            format!("{:.1}%", 100.0 * flat.tlb_miss_cycle_frac()),
            format!("{:.2}%", 100.0 * rb.tlb_miss_cycle_frac()),
            "".into()]);
    t.row(&["energy (mJ)".into(), format!("{:.1}", flat.energy_mj()),
            format!("{:.1}", rb.energy_mj()),
            ratio(flat.energy_mj(), rb.energy_mj())]);
    t.row(&["pages migrated".into(), "0".into(),
            rb.migrations.to_string(), "".into()]);
    t.row(&["TLB shootdowns".into(), "0".into(),
            rb.shootdowns.to_string(),
            "(zero by design: §III-F)".into()]);
    t.emit(None);

    println!("Rainbow speedup over Flat-static: {:.2}x \
              (paper: 1.727x average across its suite)",
             rb.ipc() / flat.ipc());
}
