#!/usr/bin/env python3
"""Reference generator for rust/schemas.lock.

Replicates rust/src/analysis/{lexer,schema}.rs exactly (tokenization,
field descriptors, FNV-1a fingerprint, lock rendering) so the lock can
be (re)generated without a Rust toolchain. The canonical generator is
`rainbow lint --update-schemas`; CI asserts both agree by linting the
committed tree.
"""

import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "rust" / "src"
LOCK = Path(__file__).resolve().parent.parent / "rust" / "schemas.lock"
LOCK_VERSION = 1

TRACKED = [
    ("sim/metrics.rs", "RunMetrics", "report/serde_kv.rs", "METRICS_VERSION"),
    ("sim/metrics.rs", "XlatBreakdown", "report/serde_kv.rs",
     "METRICS_VERSION"),
    ("sim/metrics.rs", "RuntimeBreakdown", "report/serde_kv.rs",
     "METRICS_VERSION"),
    ("report/spec.rs", "RunSpec", "report/serde_kv.rs", "SPEC_VERSION"),
    ("workloads/trace.rs", "TraceRec", "workloads/trace.rs", "VERSION"),
    ("perf.rs", "PerfConfig", "perf.rs", "SCHEMA"),
    ("perf.rs", "BenchEntry", "perf.rs", "SCHEMA"),
    ("perf.rs", "PerfReport", "perf.rs", "SCHEMA"),
    ("report/queue.rs", "LeaseRequest", "report/serde_kv.rs",
     "QUEUE_WIRE_VERSION"),
    ("report/queue.rs", "LeaseReply", "report/serde_kv.rs",
     "QUEUE_WIRE_VERSION"),
    ("report/queue.rs", "CompleteRequest", "report/serde_kv.rs",
     "QUEUE_WIRE_VERSION"),
    ("report/queue.rs", "QueueStat", "report/serde_kv.rs",
     "QUEUE_WIRE_VERSION"),
    ("report/wal.rs", "LogRecord", "report/serde_kv.rs",
     "CACHE_LOG_VERSION"),
    ("telemetry/mod.rs", "Event", "telemetry/mod.rs", "TRACE_VERSION"),
    ("telemetry/mod.rs", "EpochSample", "telemetry/mod.rs",
     "TRACE_VERSION"),
    ("telemetry/trace.rs", "TraceMeta", "telemetry/mod.rs",
     "TRACE_VERSION"),
    ("report/netstore.rs", "ServerStats", "report/serde_kv.rs",
     "STATS_WIRE_VERSION"),
]


def is_ident_start(c):
    return c == "_" or c.isalpha()


def is_ident_continue(c):
    return c == "_" or c.isalnum()


def lex(src):
    """Port of analysis::lexer::lex — returns (kind, text) tokens."""
    cs = list(src)
    toks = []
    i = 0
    n = len(cs)

    def raw_open(i):
        if i >= n or cs[i] != "r":
            return None
        j = i + 1
        while j < n and cs[j] == "#":
            j += 1
        return (j - (i + 1)) if j < n and cs[j] == '"' else None

    while i < n:
        c = cs[i]
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "/":
            j = i + 2
            while j < n and cs[j] != "\n":
                j += 1
            i = j
            continue
        if c == "/" and i + 1 < n and cs[i + 1] == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if cs[j] == "/" and j + 1 < n and cs[j + 1] == "*":
                    depth += 1
                    j += 2
                    continue
                if cs[j] == "*" and j + 1 < n and cs[j + 1] == "/":
                    depth -= 1
                    j += 2
                    continue
                j += 1
            i = j
            continue
        if c in ("r", "b"):
            after_b = i + 1 if c == "b" else i
            raw_at = i + 1 if (c == "b" and i + 1 < n
                               and cs[i + 1] == "r") else i
            hashes = raw_open(raw_at)
            if hashes is not None:
                j = raw_at + 1 + hashes + 1
                while j < n:
                    if cs[j] == '"' and cs[j + 1:j + 1 + hashes] == \
                            ["#"] * hashes:
                        j += 1 + hashes
                        break
                    j += 1
                toks.append(("Str", ""))
                i = j
                continue
            if c == "b" and after_b < n and cs[after_b] == '"':
                i = after_b
                continue
            if (c == "r" and i + 1 < n and cs[i + 1] == "#"
                    and i + 2 < n and is_ident_start(cs[i + 2])):
                j = i + 2
                while j < n and is_ident_continue(cs[j]):
                    j += 1
                toks.append(("Ident", "".join(cs[i + 2:j])))
                i = j
                continue
        if c == '"':
            j = i + 1
            body = []
            while j < n:
                if cs[j] == "\\":
                    j += 2
                    continue
                if cs[j] == '"':
                    j += 1
                    break
                body.append(cs[j])
                j += 1
            toks.append(("Str", "".join(body)))
            i = j
            continue
        if c == "'":
            j = i + 1
            if j < n and is_ident_start(cs[j]):
                k = j + 1
                while k < n and is_ident_continue(cs[k]):
                    k += 1
                if k >= n or cs[k] != "'":
                    toks.append(("Lifetime", "".join(cs[j:k])))
                    i = k
                    continue
            while j < n:
                if cs[j] == "\\":
                    j += 2
                    continue
                if cs[j] == "'":
                    j += 1
                    break
                j += 1
            toks.append(("Char", ""))
            i = j
            continue
        if is_ident_start(c):
            j = i + 1
            while j < n and is_ident_continue(cs[j]):
                j += 1
            toks.append(("Ident", "".join(cs[i:j])))
            i = j
            continue
        if c.isdigit() and c.isascii():
            j = i + 1
            while j < n:
                d = cs[j]
                if d == ".":
                    if j + 1 < n and cs[j + 1].isdigit() \
                            and cs[j + 1].isascii():
                        j += 2
                        continue
                    break
                if is_ident_continue(d):
                    j += 1
                    continue
                break
            toks.append(("Num", "".join(cs[i:j])))
            i = j
            continue
        if c == ":" and i + 1 < n and cs[i + 1] == ":":
            toks.append(("Punct", "::"))
            i += 2
            continue
        if c == "-" and i + 1 < n and cs[i + 1] == ">":
            toks.append(("Punct", "->"))
            i += 2
            continue
        toks.append(("Punct", c))
        i += 1
    return toks


def is_punct(t, s):
    return t[0] == "Punct" and t[1] == s


def is_ident(t, s):
    return t[0] == "Ident" and t[1] == s


def struct_fields(toks, name):
    """Port of analysis::schema::struct_fields."""
    k = 0
    while k + 1 < len(toks):
        if is_ident(toks[k], "struct") and is_ident(toks[k + 1], name):
            break
        k += 1
    if k + 1 >= len(toks):
        return None
    j = k + 2
    angle = 0
    while True:
        if j >= len(toks):
            return None
        t = toks[j]
        if is_punct(t, "<"):
            angle += 1
        elif is_punct(t, ">"):
            angle -= 1
        elif angle == 0 and (is_punct(t, "{") or is_punct(t, "(")):
            break
        elif angle == 0 and is_punct(t, ";"):
            return []
        j += 1
    tuple_struct = is_punct(toks[j], "(")
    close = ")" if tuple_struct else "}"
    open_p = "(" if tuple_struct else "{"
    j += 1

    fields = []
    cur = []
    depth = 0
    idx = [0]

    def flush():
        parts = cur[:]
        while parts and parts[0] == "pub":
            parts = parts[1:]
            if parts and parts[0] == "(":
                if ")" in parts:
                    parts = parts[parts.index(")") + 1:]
        if not parts:
            cur.clear()
            return
        if tuple_struct:
            fields.append(f"{idx[0]}:{' '.join(parts)}")
        else:
            fields.append(" ".join(parts))
        idx[0] += 1
        cur.clear()

    while j < len(toks):
        t = toks[j]
        if is_punct(t, "#"):
            nest = 0
            j += 1
            while j < len(toks):
                a = toks[j]
                if is_punct(a, "["):
                    nest += 1
                elif is_punct(a, "]"):
                    nest -= 1
                    if nest == 0:
                        break
                j += 1
            j += 1
            continue
        if depth == 0 and is_punct(t, close):
            if cur:
                flush()
            return fields
        if is_punct(t, "<") or is_punct(t, "[") or is_punct(t, "(") \
                or is_punct(t, open_p):
            depth += 1
        elif is_punct(t, ">") or is_punct(t, "]") or is_punct(t, ")"):
            depth -= 1
        elif depth == 0 and is_punct(t, ","):
            flush()
            j += 1
            continue
        cur.append(t[1])
        j += 1
    return None


def fingerprint(fields):
    h = 0xCBF29CE484222325
    for f in fields:
        for b in (f + ";").encode("utf-8"):
            h ^= b
            h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def const_value(toks, name):
    k = 0
    while k + 1 < len(toks):
        if is_ident(toks[k], "const") and is_ident(toks[k + 1], name):
            j = k + 2
            while j < len(toks):
                t = toks[j]
                if is_punct(t, "="):
                    v = toks[j + 1]
                    if v[0] in ("Num", "Ident", "Str"):
                        return v[1]
                    return None
                if is_punct(t, ";"):
                    break
                j += 1
        k += 1
    return None


def main():
    lexed = {}

    def toks_of(rel):
        if rel not in lexed:
            lexed[rel] = lex((SRC / rel).read_text())
        return lexed[rel]

    out = [
        "# rainbow lint wire-format lock — generated by "
        "`rainbow lint --update-schemas`.",
        "# A tracked struct's layout may not change unless its version "
        "constant changes too.",
        f"schemalockversion={LOCK_VERSION}",
    ]
    for sf, sn, vf, vc in TRACKED:
        fields = struct_fields(toks_of(sf), sn)
        if fields is None:
            sys.exit(f"struct {sn} not found in {sf}")
        value = const_value(toks_of(vf), vc)
        if value is None:
            sys.exit(f"const {vc} not found in {vf}")
        fp = fingerprint(fields)
        out.append(f"struct={sf}::{sn} fields={len(fields)} fp={fp:016x} "
                   f"version={vf}::{vc} value={value}")
        print(f"{sf}::{sn}: {len(fields)} fields, fp {fp:016x}, "
              f"{vc}={value}")
        for f in fields:
            print(f"    {f}")
    LOCK.write_text("\n".join(out) + "\n")
    print(f"wrote {LOCK}")


if __name__ == "__main__":
    main()
