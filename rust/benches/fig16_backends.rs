//! Regenerates the Fig. 16 backend matrix (policies × NVM device
//! profiles) through the cached experiment harness — a three-app subset
//! keeps the profile × policy × workload cube bench-sized.
mod common;

use rainbow::config::profiles;
use rainbow::report::figures::{self, FigureCtx};
use rainbow::report::RunSpec;

fn main() {
    let base = RunSpec::new("", "")
        .with_scale(8)
        .with_instructions(common::bench_instructions().min(800_000));
    let ctx = FigureCtx::new(
        ["mcf", "DICT", "GUPS"].iter().map(|s| s.to_string()).collect(),
        base);
    let profs: Vec<String> = profiles::slow_tier_names()
        .iter().map(|s| s.to_string()).collect();
    let pols: Vec<String> = figures::BACKEND_POLICIES
        .iter().map(|s| s.to_string()).collect();
    common::figure_bench("fig16_backends",
                         || figures::fig16_backends(&ctx, &profs, &pols));
}
