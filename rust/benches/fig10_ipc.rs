//! Regenerates the paper's fig10_ipc (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig10_ipc", || figures::fig10_ipc(&ctx));
}
