//! Regenerates the paper's fig15_runtime (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig15_runtime", || figures::fig15_runtime(&ctx));
}
