//! §III-E analytic model: Rainbow DRAM-page addressing vs 4-level PTW,
//! including the R_hit ≈ 67% crossover the paper derives.
mod common;
use rainbow::config::Config;
use rainbow::report::figures;

fn main() {
    common::figure_bench("ana_remap_cost",
        || figures::ana_remap_cost(&Config::paper()));
}
