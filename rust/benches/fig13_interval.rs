//! Fig. 13: sensitivity of migration traffic + IPC to the sampling
//! interval (paper sweeps 1e5..1e9; we sweep the same factors around the
//! scaled default).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig13_interval",
        || figures::fig13_interval(&ctx, &["mcf", "soplex", "GUPS"]));
}
