//! Regenerates the paper's fig08_tlbcycles (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig08_tlbcycles", || figures::fig08_tlbcycles(&ctx));
}
