//! Fig. 14: sensitivity to the number of monitored top-N hot superpages.
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig14_topn",
        || figures::fig14_topn(&ctx, &["mcf", "soplex", "GUPS"]));
}
