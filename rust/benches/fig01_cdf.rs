//! Regenerates the paper's fig01_cdf (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig01_cdf", || figures::fig01_cdf(&ctx));
}
