//! Regenerates the paper's fig12_energy (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig12_energy", || figures::fig12_energy(&ctx));
}
