//! Regenerates the paper's fig07_mpki (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig07_mpki", || figures::fig07_mpki(&ctx));
}
