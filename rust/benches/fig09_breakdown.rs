//! Regenerates the paper's fig09_breakdown (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig09_breakdown", || figures::fig09_breakdown(&ctx));
}
