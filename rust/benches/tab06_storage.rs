//! Table VI: storage overhead of Rainbow with 1 TB PCM (analytic model).
mod common;
use rainbow::report::figures;

fn main() {
    common::figure_bench("tab06_storage", figures::tab06_storage);
}
