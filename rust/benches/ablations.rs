//! Ablations beyond the paper (DESIGN.md §6): bitmap-cache geometry,
//! write-weighting of superpage counters, and the migration threshold —
//! each expressed as config-knob overrides on `RunSpec`s and executed as
//! one override-bearing spec matrix on the parallel sweep orchestrator
//! (the same path the figures and the `sweep` CLI use).
mod common;

use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::RunSpec;
use rainbow::util::tables::Table;

const APP: &str = "DICT";

fn base_spec() -> RunSpec {
    RunSpec::new(APP, "rainbow")
        .with_instructions(common::bench_instructions().min(800_000))
}

fn main() {
    let t0 = std::time::Instant::now();
    let base_cfg = base_spec().config();

    // Build each ablation as its own override-bearing spec chunk...
    let geometry_specs: Vec<RunSpec> =
        [(256u64, 8u64), (1000, 8), (4000, 8), (4000, 2), (4000, 16)]
            .iter()
            .map(|&(entries, assoc)| base_spec()
                .with("rainbow.bitmap_cache_entries", entries)
                .with("rainbow.bitmap_cache_assoc", assoc))
            .collect();
    let weight_specs: Vec<RunSpec> = [0.0f64, 1.0, 3.0, 8.0]
        .iter()
        .map(|&w| base_spec().with("rainbow.write_weight", w))
        .collect();
    let threshold_specs: Vec<RunSpec> = [0.25f64, 1.0, 4.0, 16.0]
        .iter()
        .map(|m| base_spec().with("rainbow.migration_threshold",
                                  base_cfg.migration_threshold * m))
        .collect();

    // ...simulate them all concurrently, then split the metrics back
    // into the same chunks for rendering.
    let all: Vec<RunSpec> = geometry_specs.iter()
        .chain(&weight_specs)
        .chain(&threshold_specs)
        .cloned()
        .collect();
    let metrics = sweep::run_parallel(&all, &SweepConfig::default());
    let (m_geometry, rest) = metrics.split_at(geometry_specs.len());
    let (m_weight, m_threshold) = rest.split_at(weight_specs.len());

    // Bitmap-cache size/associativity vs hit rate (the regime behind
    // Fig. 9's "trivial misses" claim), measured on full simulations.
    let mut t = Table::new(
        &format!("Ablation: bitmap cache geometry ({APP}, full sim)"),
        &["entries", "assoc", "bitmap hit rate", "IPC"]);
    for (s, m) in geometry_specs.iter().zip(m_geometry) {
        let cfg = s.config();
        t.row(&[cfg.bitmap_cache_entries.to_string(),
                cfg.bitmap_cache_assoc.to_string(),
                format!("{:.4}", m.bitmap_hit_rate()),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(Some("target/figures/ablation_bitmap.csv"));

    // Write weighting in stage-1 scoring: PCM writes are the expensive
    // resource (§III-B), so up-weighting write-hot superpages shifts
    // which pages migrate and what traffic results.
    let mut t = Table::new(
        &format!("Ablation: write weighting in superpage selection ({APP})"),
        &["write_weight", "migrations", "NVM writes", "IPC"]);
    for (s, m) in weight_specs.iter().zip(m_weight) {
        t.row(&[format!("{}", s.config().write_weight),
                m.migrations.to_string(),
                m.nvm_writes.to_string(),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(Some("target/figures/ablation_wweight.csv"));

    // Static migration-threshold sweep (Eq. 1): higher thresholds
    // suppress marginal migrations, bounding churn at some IPC cost.
    let mut t = Table::new(
        &format!("Ablation: migration threshold ({APP})"),
        &["threshold", "migrations", "migrated MB", "IPC"]);
    for (s, m) in threshold_specs.iter().zip(m_threshold) {
        t.row(&[format!("{:.0}", s.config().migration_threshold),
                m.migrations.to_string(),
                format!("{:.1}", m.migrated_bytes as f64 / (1 << 20) as f64),
                format!("{:.4}", m.ipc())]);
    }
    t.emit(Some("target/figures/ablation_threshold.csv"));

    println!("bench ablations: generated in {:.2}s\n",
             t0.elapsed().as_secs_f64());
}
