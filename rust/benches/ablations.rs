//! Ablations beyond the paper (DESIGN.md §6): bitmap-cache geometry,
//! write-weighting of superpage counters, and dynamic-vs-static
//! migration threshold.
mod common;

use rainbow::rainbow::bitmap::BitmapCache;
use rainbow::rainbow::counters::TwoStageCounters;
use rainbow::rainbow::migration::{ThresholdCtl, UtilityParams};
use rainbow::runtime::HotPageIdentifier;
use rainbow::util::rng::{Rng, Zipf};
use rainbow::util::tables::Table;

fn main() {
    bitmap_cache_sweep();
    write_weighting();
    dynamic_threshold();
}

/// Bitmap-cache size/associativity vs hit rate under a zipfian superpage
/// reference stream (the regime behind Fig. 9's "trivial misses" claim).
fn bitmap_cache_sweep() {
    let mut t = Table::new(
        "Ablation: bitmap cache geometry vs hit rate (zipf over 16Ki superpages)",
        &["entries", "assoc", "SRAM KB", "hit rate"]);
    let z = Zipf::new(16384, 0.9);
    for &(entries, assoc) in &[(256usize, 8usize), (1000, 8), (4000, 8),
                               (4000, 2), (4000, 16), (16384, 8)] {
        let mut c = BitmapCache::new(entries, assoc, 9);
        let mut rng = Rng::new(7);
        for _ in 0..300_000 {
            c.touch(z.sample(&mut rng) as u32);
        }
        t.row(&[entries.to_string(), assoc.to_string(),
                format!("{:.0}", c.sram_bytes() as f64 / 1000.0),
                format!("{:.4}", c.stats.hit_rate())]);
    }
    t.emit(Some("target/figures/ablation_bitmap.csv"));
}

/// Write weighting in stage-1 scoring: with weighting, a write-hot
/// superpage outranks a read-hot one of equal traffic (the paper's
/// §III-B design choice — PCM writes are the expensive resource).
fn write_weighting() {
    let mut t = Table::new(
        "Ablation: write weighting in superpage selection",
        &["write_weight", "write-hot sp rank", "read-hot sp rank"]);
    for weight in [0.0f64, 1.0, 3.0, 8.0] {
        let mut c = TwoStageCounters::new(256, 8);
        // sp 10: 600 reads. sp 20: 300 writes (less total traffic).
        for _ in 0..600 {
            c.record(10, 0, false);
        }
        for _ in 0..300 {
            c.record(20, 0, true);
        }
        let mut p =
            UtilityParams::from_config(&rainbow::config::Config::paper());
        p.write_weight = weight;
        let top = HotPageIdentifier::native().select_top(&c, &p);
        let rank = |sp: u32| {
            top.iter().position(|&x| x == sp)
                .map(|i| i.to_string()).unwrap_or("-".into())
        };
        t.row(&[format!("{weight}"), rank(20), rank(10)]);
    }
    t.emit(Some("target/figures/ablation_wweight.csv"));
}

/// Dynamic threshold controller vs a static threshold under a thrashing
/// traffic pattern: the controller must rise under bidirectional traffic
/// and decay when it stops (bounding migration churn).
fn dynamic_threshold() {
    let mut t = Table::new(
        "Ablation: dynamic migration threshold under thrash",
        &["phase", "interval", "threshold"]);
    let mut ctl = ThresholdCtl::new(2000.0);
    for i in 0..4 {
        ctl.update(1 << 20, 900 << 10); // heavy writeback: thrash
        t.row(&["thrash".into(), i.to_string(),
                format!("{:.0}", ctl.threshold())]);
    }
    for i in 4..8 {
        ctl.update(1 << 20, 0); // calm
        t.row(&["calm".into(), i.to_string(),
                format!("{:.0}", ctl.threshold())]);
    }
    t.emit(Some("target/figures/ablation_threshold.csv"));
}
