//! Regenerates the paper's tab01_hotstats (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("tab01_hotstats", || figures::tab01_hotstats(&ctx));
}
