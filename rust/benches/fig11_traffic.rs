//! Regenerates the paper's fig11_traffic (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("fig11_traffic", || figures::fig11_traffic(&ctx));
}
