//! Microbenchmarks of the simulation hot paths (EXPERIMENTS.md §Perf):
//! raw policy access throughput per policy, the interval analytics
//! (native vs PJRT when artifacts exist), and the workload generator.
mod common;

use std::time::Duration;

use rainbow::config::Config;
use rainbow::policies::{self, Policy};
use rainbow::rainbow::counters::TwoStageCounters;
use rainbow::rainbow::migration::UtilityParams;
use rainbow::rainbow::RemapTable;
use rainbow::runtime::{native, HotPageIdentifier, PjrtRuntime};
use rainbow::util::bench::{black_box, Bencher};
use rainbow::util::rng::Rng;
use rainbow::workloads::{AppProfile, Synth};

fn main() {
    let b = Bencher::new().warmup(Duration::from_millis(200)).samples(10);

    // Workload generator throughput.
    let p = AppProfile::by_name("mcf").unwrap().scaled(8);
    let mut synth = Synth::new(p, 0, 1);
    b.run("synth::next_mem", || {
        black_box(synth.next_mem());
    });

    // End-to-end access throughput per policy (the L3 hot path).
    let cfg = Config::scaled(8);
    for name in policies::all_names() {
        let mut pol = policies::by_name(name, &cfg, false).unwrap();
        let prof = AppProfile::by_name("DICT").unwrap().scaled(8);
        let mut s = Synth::new(prof, 0, 2);
        let mut now = 0u64;
        b.run(&format!("policy::{name}::access"), || {
            let (vaddr, w) = s.next_mem();
            now += pol.access(0, vaddr, w, now) + 1;
            black_box(now);
        });
    }

    // Flat remap table: the per-access structure behind every
    // superpage-TLB hit with a set bitmap bit (lookup-dominated mix).
    let n_pages = 1usize << 20;
    let n_frames = 1usize << 17;
    let mut remap = RemapTable::with_capacity(n_pages, n_frames);
    for f in 0..(n_frames as u64 / 2) {
        remap.insert(f * 8, f); // every 8th page migrated
    }
    let mut rrng = Rng::new(0x51EE9);
    b.run("remap::lookup(1Mi pages, 1/16 mapped)", || {
        black_box(remap.lookup(rrng.below(n_pages as u64)));
    });
    b.run("remap::insert+remove", || {
        let page = n_pages as u64 - 1;
        let frame = n_frames as u64 - 1;
        remap.insert(page, frame);
        black_box(remap.remove(page));
    });

    // Interval analytics: native stage1+stage2 at artifact shapes.
    let mut rng = Rng::new(3);
    let reads: Vec<i32> =
        (0..16384).map(|_| rng.below(0x8000) as i32).collect();
    let writes: Vec<i32> =
        (0..16384).map(|_| rng.below(0x8000) as i32).collect();
    let params = [62.0f32, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0];
    b.run("native::stage1(16384)", || {
        black_box(native::stage1(&reads, &writes, &params, 128));
    });
    let pr: Vec<i32> = (0..128 * 512).map(|_| rng.below(0x8000) as i32).collect();
    let pw: Vec<i32> = (0..128 * 512).map(|_| rng.below(0x8000) as i32).collect();
    b.run("native::stage2(128x512)", || {
        black_box(native::stage2(&pr, &pw, &params));
    });

    // PJRT path if artifacts exist.
    if let Ok(rt) = PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        b.run("pjrt::stage1(16384)", || {
            black_box(rt.stage1(&reads, &writes, &params).unwrap());
        });
        b.run("pjrt::stage2(128x512)", || {
            black_box(rt.stage2(&pr, &pw, &params).unwrap());
        });
    } else {
        println!("pjrt benches skipped (no artifacts)");
    }

    // Full identifier pipeline through the facade.
    let id = HotPageIdentifier::native();
    let mut counters = TwoStageCounters::new(2048, 50);
    for _ in 0..100_000 {
        counters.record(rng.below(2048) as u32, rng.below(512) as u16,
                        rng.chance(0.3));
    }
    let up = UtilityParams::from_config(&cfg);
    b.run("identifier::select_top(2048)", || {
        black_box(id.select_top(&counters, &up));
    });
}
