//! Microbenchmarks of the simulation hot paths (EXPERIMENTS.md §Perf),
//! driving the shared [`rainbow::perf`] harness — the same stages,
//! measurement, and `rainbow-bench-v1` JSON as the `rainbow perf`
//! subcommand, so a cargo-bench run and a committed `BENCH_<n>.json`
//! are directly comparable. Honors the `RAINBOW_BENCH_*` env caps;
//! prints the per-stage lines as they complete, then the JSON report.
//!
//! The PJRT analytics path (when AOT artifacts exist) is benched here
//! as an extra, outside the stable report schema.

use rainbow::perf::{run_suite, PerfConfig};
use rainbow::runtime::PjrtRuntime;
use rainbow::util::bench::{black_box, Bencher};
use rainbow::util::rng::Rng;

fn main() {
    let cfg = PerfConfig::from_env();
    let report = run_suite(&cfg);

    // PJRT path if artifacts exist (not part of the report: artifact
    // availability would make the schema's bench list machine-dependent).
    if let Ok(rt) = PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        let b = Bencher::from_env();
        let mut rng = Rng::new(3);
        let reads: Vec<i32> =
            (0..16384).map(|_| rng.below(0x8000) as i32).collect();
        let writes: Vec<i32> =
            (0..16384).map(|_| rng.below(0x8000) as i32).collect();
        let params = [62.0f32, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0];
        b.run("pjrt.stage1(16384)", || {
            black_box(rt.stage1(&reads, &writes, &params).unwrap());
        });
        let pr: Vec<i32> =
            (0..128 * 512).map(|_| rng.below(0x8000) as i32).collect();
        let pw: Vec<i32> =
            (0..128 * 512).map(|_| rng.below(0x8000) as i32).collect();
        b.run("pjrt.stage2(128x512)", || {
            black_box(rt.stage2(&pr, &pw, &params).unwrap());
        });
    } else {
        println!("pjrt benches skipped (no artifacts)");
    }

    print!("{}", report.to_json().pretty());
}
