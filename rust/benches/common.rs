//! Shared bench-harness glue: every `benches/*.rs` binary regenerates one
//! paper table/figure (DESIGN.md §4) through the cached experiment
//! harness, then reports wall time. Results cache lives under
//! target/rainbow_results, so the first bench populates it and the rest
//! reuse it.
#![allow(dead_code)]

use rainbow::report::figures::FigureCtx;
use rainbow::report::{self, RunSpec};

/// Standard bench context: the default workload subset at 1/8 scale.
pub fn ctx() -> FigureCtx {
    let base = RunSpec::new("", "")
        .with_scale(8)
        .with_instructions(bench_instructions());
    FigureCtx::new(
        report::default_workloads().iter().map(|s| s.to_string()).collect(),
        base,
    )
}

pub fn bench_instructions() -> u64 {
    std::env::var("RAINBOW_BENCH_INSTR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_500_000)
}

/// Time a figure generator and emit its table.
pub fn figure_bench<F>(name: &str, f: F)
where
    F: FnOnce() -> rainbow::util::tables::Table,
{
    let t0 = std::time::Instant::now();
    let table = f();
    let dt = t0.elapsed();
    table.emit(Some(&format!("target/figures/{name}.csv")));
    println!("bench {name}: generated in {:.2}s\n", dt.as_secs_f64());
}
