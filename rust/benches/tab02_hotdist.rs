//! Regenerates the paper's tab02_hotdist (see DESIGN.md §4).
mod common;
use rainbow::report::figures;

fn main() {
    let ctx = common::ctx();
    common::figure_bench("tab02_hotdist", || figures::tab02_hotdist(&ctx));
}
