//! # Rainbow — superpages + lightweight page migration for hybrid memory
//!
//! A full reproduction of *"Supporting Superpages and Lightweight Page
//! Migration in Hybrid Memory Systems"* (Wang, 2018): the Rainbow memory
//! management mechanism, its zsim/NVMain-equivalent simulation substrate,
//! the paper's baseline policies, workload generators matching the paper's
//! published access statistics, and a bench harness that regenerates every
//! table and figure of the evaluation. See DESIGN.md for the architecture
//! and EXPERIMENTS.md for paper-vs-measured results.

// The unsafe audit (ISSUE 7): the crate is 100% safe code today, and
// the lint rule `unsafe-audit` requires any future site to carry a
// per-site `#[allow(unsafe_code)]` plus a SAFETY: justification.
#![deny(unsafe_code)]

pub mod analysis;
pub mod cache;
pub mod config;
pub mod mem;
pub mod os;
pub mod perf;
pub mod policies;
pub mod rainbow;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod tlb;
pub mod util;
pub mod workloads;
