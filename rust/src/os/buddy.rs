//! Binary buddy allocator over physical page frames.
//!
//! Orders 0..=9 cover 4 KB base pages up to 2 MB superpages (order 9 =
//! 512 contiguous frames), matching the OS module the paper added to zsim.
//! Frames are identified by PFN relative to the managed region's base.

use std::collections::HashSet;

pub const MAX_ORDER: usize = 9; // 2^9 * 4 KB = 2 MB

/// Buddy allocator state.
#[derive(Clone, Debug)]
pub struct Buddy {
    /// free[k] holds base PFNs of free 2^k-frame blocks.
    free: Vec<HashSet<u64>>,
    /// Live allocations (base, order) — catches double/mismatched frees.
    allocated: HashSet<(u64, usize)>,
    total_frames: u64,
    free_frames: u64,
}

impl Buddy {
    /// Manage `total_frames` frames (must be a multiple of 512 so 2 MB
    /// blocks tile the region exactly).
    pub fn new(total_frames: u64) -> Buddy {
        assert!(total_frames > 0 && total_frames % (1 << MAX_ORDER) == 0,
                "frames {total_frames} must be a multiple of 512");
        let mut free: Vec<HashSet<u64>> =
            (0..=MAX_ORDER).map(|_| HashSet::new()).collect();
        let mut pfn = 0;
        while pfn < total_frames {
            free[MAX_ORDER].insert(pfn);
            pfn += 1 << MAX_ORDER;
        }
        Buddy { free, allocated: HashSet::new(), total_frames,
                free_frames: total_frames }
    }

    pub fn total_frames(&self) -> u64 {
        self.total_frames
    }

    pub fn free_frames(&self) -> u64 {
        self.free_frames
    }

    /// Allocate a 2^order-frame block; returns its base PFN.
    pub fn alloc(&mut self, order: usize) -> Option<u64> {
        assert!(order <= MAX_ORDER);
        // Find the smallest order with a free block.
        let mut k = order;
        while k <= MAX_ORDER && self.free[k].is_empty() {
            k += 1;
        }
        if k > MAX_ORDER {
            return None;
        }
        // Take one and split down to the requested order.
        let base = *self.free[k].iter().next().unwrap();
        self.free[k].remove(&base);
        while k > order {
            k -= 1;
            // Keep the upper half free, continue splitting the lower.
            self.free[k].insert(base + (1u64 << k));
        }
        self.free_frames -= 1u64 << order;
        self.allocated.insert((base, order));
        Some(base)
    }

    /// Free a block previously returned by `alloc(order)`; merges buddies.
    pub fn free(&mut self, base: u64, order: usize) {
        assert!(order <= MAX_ORDER);
        assert_eq!(base % (1u64 << order), 0, "misaligned free");
        assert!(self.allocated.remove(&(base, order)),
                "double free or mismatched order: pfn {base} order {order}");
        let mut base = base;
        let mut k = order;
        while k < MAX_ORDER {
            let buddy = base ^ (1u64 << k);
            if self.free[k].remove(&buddy) {
                base = base.min(buddy);
                k += 1;
            } else {
                break;
            }
        }
        let inserted = self.free[k].insert(base);
        debug_assert!(inserted, "free-list corruption at pfn {base} order {k}");
        self.free_frames += 1u64 << order;
    }

    /// Largest currently-allocatable order (fragmentation probe).
    pub fn max_free_order(&self) -> Option<usize> {
        (0..=MAX_ORDER).rev().find(|&k| !self.free[k].is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_free_roundtrip() {
        let mut b = Buddy::new(1024);
        let p = b.alloc(0).unwrap();
        assert_eq!(b.free_frames(), 1023);
        b.free(p, 0);
        assert_eq!(b.free_frames(), 1024);
        // Full merge back to two 2 MB blocks.
        assert_eq!(b.max_free_order(), Some(MAX_ORDER));
    }

    #[test]
    fn superpage_alloc_is_aligned() {
        let mut b = Buddy::new(2048);
        for _ in 0..4 {
            let p = b.alloc(MAX_ORDER).unwrap();
            assert_eq!(p % 512, 0);
        }
        assert_eq!(b.alloc(MAX_ORDER), None, "region exhausted");
        assert_eq!(b.free_frames(), 0);
    }

    #[test]
    fn split_and_remerge() {
        let mut b = Buddy::new(512);
        let a = b.alloc(0).unwrap();
        // One 4 KB allocation fragments the single 2 MB block...
        assert!(b.alloc(MAX_ORDER).is_none());
        b.free(a, 0);
        // ...and freeing it restores superpage allocability.
        assert!(b.alloc(MAX_ORDER).is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_detected() {
        let mut b = Buddy::new(512);
        let p = b.alloc(3).unwrap();
        b.free(p, 3);
        b.free(p, 3);
    }

    #[test]
    fn exhaustion_returns_none_not_panic() {
        let mut b = Buddy::new(512);
        let mut n = 0;
        while b.alloc(0).is_some() {
            n += 1;
        }
        assert_eq!(n, 512);
    }

    /// Property: any interleaving of allocs/frees conserves frames and
    /// never hands out overlapping blocks.
    #[test]
    fn prop_no_overlap_and_conservation() {
        forall(
            "buddy-no-overlap",
            0xB0DD7,
            40,
            |r: &mut Rng| {
                (0..64)
                    .map(|_| (r.below(5) as usize, r.below(3) == 0))
                    .collect::<Vec<(usize, bool)>>()
            },
            |ops| {
                let mut b = Buddy::new(1024);
                let mut live: Vec<(u64, usize)> = Vec::new();
                let mut owned = vec![false; 1024];
                for &(order, do_free) in ops {
                    if do_free && !live.is_empty() {
                        let (base, o) = live.pop().unwrap();
                        for f in base..base + (1 << o) {
                            owned[f as usize] = false;
                        }
                        b.free(base, o);
                    } else if let Some(base) = b.alloc(order) {
                        for f in base..base + (1 << order) {
                            if owned[f as usize] {
                                return Err(format!("overlap at frame {f}"));
                            }
                            owned[f as usize] = true;
                        }
                        live.push((base, order));
                    }
                    let held: u64 =
                        live.iter().map(|&(_, o)| 1u64 << o).sum();
                    if b.free_frames() + held != 1024 {
                        return Err(format!(
                            "frame leak: free={} held={held}",
                            b.free_frames()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
