//! DRAM page manager with the HSCC-style three-list scheme (§III-A):
//! a free list of unused 4 KB frames, a clean list (unmodified cached
//! pages, reclaimable without writeback), and a dirty list (must be
//! written back to NVM before reuse). Replacement preference:
//! free -> clean (FIFO) -> dirty (FIFO).
//!
//! Hot-path note (§Perf optimization #2): `mark_dirty` runs on every
//! DRAM write, so the clean/dirty queues are *lazy* — entries are not
//! removed on state changes; `take`/pops revalidate entries against the
//! authoritative `resident` map and skip stale ones. This makes
//! `mark_dirty` O(1) instead of an O(n) queue scan.

use std::collections::{HashMap, VecDeque};

/// Why a frame was handed out by `take()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reclaim {
    /// A free frame: no victim.
    Free,
    /// A clean cached page was dropped; its owner (nvm 4 KB page number)
    /// is returned so the caller can clear bookkeeping.
    Clean { victim_owner: u64 },
    /// A dirty cached page was evicted; the caller must write it back.
    Dirty { victim_owner: u64 },
}

/// Allocation result: the DRAM frame plus what had to be reclaimed.
#[derive(Clone, Copy, Debug)]
pub struct Grant {
    pub frame: u64,
    pub reclaim: Reclaim,
}

#[derive(Clone, Debug, Default)]
pub struct DramMgrStats {
    pub grants_free: u64,
    pub grants_clean: u64,
    pub grants_dirty: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Meta {
    owner: u64,
    dirty: bool,
    /// Generation stamp: queue entries carry the stamp they were pushed
    /// with; a mismatch on pop means the entry is stale.
    gen: u64,
}

/// The three-list DRAM frame manager (lazy queues, exact counts).
#[derive(Clone, Debug)]
pub struct DramMgr {
    free: VecDeque<u64>,
    /// (frame, gen) entries; validated against `resident` on pop.
    clean: VecDeque<(u64, u64)>,
    dirty: VecDeque<(u64, u64)>,
    resident: HashMap<u64, Meta>,
    clean_count: u64,
    dirty_count: u64,
    next_gen: u64,
    total: u64,
    pub stats: DramMgrStats,
}

impl DramMgr {
    pub fn new(total_frames: u64) -> DramMgr {
        DramMgr {
            free: (0..total_frames).collect(),
            clean: VecDeque::new(),
            dirty: VecDeque::new(),
            resident: HashMap::new(),
            clean_count: 0,
            dirty_count: 0,
            next_gen: 0,
            total: total_frames,
            stats: DramMgrStats::default(),
        }
    }

    pub fn total_frames(&self) -> u64 {
        self.total
    }

    pub fn free_count(&self) -> u64 {
        self.free.len() as u64
    }

    pub fn clean_count(&self) -> u64 {
        self.clean_count
    }

    pub fn dirty_count(&self) -> u64 {
        self.dirty_count
    }

    fn stamp(&mut self) -> u64 {
        self.next_gen += 1;
        self.next_gen
    }

    /// Pop the oldest *valid* clean frame (skipping stale entries).
    fn pop_clean(&mut self) -> Option<u64> {
        while let Some((f, g)) = self.clean.pop_front() {
            if let Some(m) = self.resident.get(&f) {
                if !m.dirty && m.gen == g {
                    return Some(f);
                }
            }
        }
        None
    }

    fn pop_dirty(&mut self) -> Option<u64> {
        while let Some((f, g)) = self.dirty.pop_front() {
            if let Some(m) = self.resident.get(&f) {
                if m.dirty && m.gen == g {
                    return Some(f);
                }
            }
        }
        None
    }

    /// Take a frame for caching `owner` (an NVM 4 KB page number),
    /// reclaiming in free -> clean -> dirty order.
    pub fn take(&mut self, owner: u64) -> Grant {
        let (frame, reclaim) = if let Some(f) = self.free.pop_front() {
            self.stats.grants_free += 1;
            (f, Reclaim::Free)
        } else if self.clean_count > 0 {
            let f = self.pop_clean().expect("clean_count out of sync");
            self.stats.grants_clean += 1;
            let m = self.resident.remove(&f).unwrap();
            self.clean_count -= 1;
            (f, Reclaim::Clean { victim_owner: m.owner })
        } else {
            let f = self.pop_dirty().expect("DRAM has zero frames configured");
            self.stats.grants_dirty += 1;
            let m = self.resident.remove(&f).unwrap();
            self.dirty_count -= 1;
            (f, Reclaim::Dirty { victim_owner: m.owner })
        };
        let gen = self.stamp();
        self.resident.insert(frame, Meta { owner, dirty: false, gen });
        self.clean.push_back((frame, gen));
        self.clean_count += 1;
        Grant { frame, reclaim }
    }

    /// Mark a resident frame dirty (first write to the cached page). O(1).
    pub fn mark_dirty(&mut self, frame: u64) {
        let gen = self.stamp();
        if let Some(m) = self.resident.get_mut(&frame) {
            if !m.dirty {
                m.dirty = true;
                m.gen = gen;
                self.clean_count -= 1;
                self.dirty_count += 1;
                self.dirty.push_back((frame, gen));
            }
        }
    }

    /// Release a frame entirely (page written back / invalidated).
    pub fn release(&mut self, frame: u64) {
        if let Some(m) = self.resident.remove(&frame) {
            if m.dirty {
                self.dirty_count -= 1;
            } else {
                self.clean_count -= 1;
            }
            self.free.push_back(frame);
        }
    }

    pub fn is_dirty(&self, frame: u64) -> bool {
        self.resident.get(&frame).map(|m| m.dirty).unwrap_or(false)
    }

    pub fn owner_of(&self, frame: u64) -> Option<u64> {
        self.resident.get(&frame).map(|m| m.owner)
    }

    /// Fraction of frames in use.
    pub fn utilization(&self) -> f64 {
        1.0 - self.free.len() as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn prefers_free_then_clean_then_dirty() {
        let mut m = DramMgr::new(2);
        let g1 = m.take(100);
        let g2 = m.take(101);
        assert_eq!(g1.reclaim, Reclaim::Free);
        assert_eq!(g2.reclaim, Reclaim::Free);
        // Dirty one of them.
        m.mark_dirty(g1.frame);
        // Next take must reclaim the CLEAN frame (g2), not the dirty one.
        let g3 = m.take(102);
        assert_eq!(g3.reclaim, Reclaim::Clean { victim_owner: 101 });
        assert_eq!(g3.frame, g2.frame);
        // Dirty the remaining clean frame too: now only dirty frames exist,
        // so the next grant must evict a dirty page (FIFO: owner 100).
        m.mark_dirty(g3.frame);
        let g4 = m.take(103);
        assert_eq!(g4.reclaim, Reclaim::Dirty { victim_owner: 100 });
    }

    #[test]
    fn mark_dirty_moves_counts() {
        let mut m = DramMgr::new(1);
        let g = m.take(7);
        assert_eq!(m.clean_count(), 1);
        m.mark_dirty(g.frame);
        assert_eq!(m.clean_count(), 0);
        assert_eq!(m.dirty_count(), 1);
        assert!(m.is_dirty(g.frame));
        // Idempotent.
        m.mark_dirty(g.frame);
        assert_eq!(m.dirty_count(), 1);
    }

    #[test]
    fn release_returns_to_free() {
        let mut m = DramMgr::new(1);
        let g = m.take(9);
        m.mark_dirty(g.frame);
        m.release(g.frame);
        assert_eq!(m.free_count(), 1);
        assert_eq!(m.dirty_count(), 0);
        assert_eq!(m.owner_of(g.frame), None);
        assert_eq!(m.utilization(), 0.0);
    }

    #[test]
    fn owner_tracking() {
        let mut m = DramMgr::new(4);
        let g = m.take(0xABC);
        assert_eq!(m.owner_of(g.frame), Some(0xABC));
    }

    #[test]
    fn stale_queue_entries_are_skipped() {
        let mut m = DramMgr::new(3);
        let a = m.take(1);
        let _b = m.take(2);
        let _c = m.take(3);
        // Dirty a (stale entry remains in the clean queue), then release
        // it; the stale clean and dirty entries must both be skipped.
        m.mark_dirty(a.frame);
        m.release(a.frame);
        let g = m.take(4); // free frame (the released one)
        assert_eq!(g.reclaim, Reclaim::Free);
        let g = m.take(5); // must evict a VALID clean frame (owner 2)
        assert_eq!(g.reclaim, Reclaim::Clean { victim_owner: 2 });
    }

    /// Churn regression for the lazy queues: a frame cycled through
    /// clean -> dirty -> clean (via take) many times leaves a trail of
    /// stale queue entries; every one must be discarded on pop, the
    /// counts must stay exact, and reclaim order (free -> clean FIFO ->
    /// dirty FIFO) must be computed only from *valid* entries.
    #[test]
    fn churned_frames_discard_stale_queue_entries() {
        let mut m = DramMgr::new(2);
        let a = m.take(1);
        let b = m.take(2);
        // Churn: repeatedly dirty both, then release + re-take so the
        // same physical frames re-enter the clean queue under new gens.
        for round in 0..50u64 {
            m.mark_dirty(a.frame);
            m.mark_dirty(b.frame);
            assert_eq!((m.clean_count(), m.dirty_count()), (0, 2),
                       "round {round}: counts must track churn exactly");
            m.release(a.frame);
            m.release(b.frame);
            assert_eq!(m.free_count(), 2);
            let g1 = m.take(100 + round);
            let g2 = m.take(200 + round);
            assert_eq!(g1.reclaim, Reclaim::Free);
            assert_eq!(g2.reclaim, Reclaim::Free);
            assert_eq!((m.clean_count(), m.dirty_count()), (2, 0));
        }
        // After heavy churn the queues hold dozens of stale entries.
        // The next reclaims must skip all of them and evict the two
        // *current* clean residents in FIFO order.
        let g = m.take(7777);
        assert_eq!(g.reclaim, Reclaim::Clean { victim_owner: 149 });
        let g = m.take(8888);
        assert_eq!(g.reclaim, Reclaim::Clean { victim_owner: 249 });
        // And with everything dirty, dirty-FIFO falls back correctly.
        m.mark_dirty(g.frame);
        let other = if g.frame == a.frame { b.frame } else { a.frame };
        m.mark_dirty(other);
        let g = m.take(9999);
        assert_eq!(g.reclaim, Reclaim::Dirty { victim_owner: 8888 });
        assert_eq!(m.free_count() + m.clean_count() + m.dirty_count(), 2);
    }

    /// Property: counts always partition the frame set — free + clean +
    /// dirty == total, and take() never double-grants a live frame.
    #[test]
    fn prop_lists_partition_frames() {
        forall(
            "dram-mgr-partition",
            0xD3A,
            30,
            |r: &mut Rng| {
                (0..100)
                    .map(|_| (r.below(4), r.below(64)))
                    .collect::<Vec<(u64, u64)>>()
            },
            |ops| {
                let mut m = DramMgr::new(16);
                let mut live: Vec<u64> = Vec::new();
                for &(op, arg) in ops {
                    match op {
                        0 => {
                            let g = m.take(arg);
                            live.retain(|&f| f != g.frame);
                            live.push(g.frame);
                        }
                        1 if !live.is_empty() => {
                            m.mark_dirty(live[(arg as usize) % live.len()]);
                        }
                        2 if !live.is_empty() => {
                            let f = live.remove((arg as usize) % live.len());
                            m.release(f);
                        }
                        _ => {}
                    }
                    let sum = m.free_count() + m.clean_count() + m.dirty_count();
                    if sum != 16 {
                        return Err(format!("partition broken: sum={sum}"));
                    }
                    let dup = {
                        let mut v = live.clone();
                        v.sort_unstable();
                        v.dedup();
                        v.len() != live.len()
                    };
                    if dup {
                        return Err("double-granted frame".into());
                    }
                }
                Ok(())
            },
        );
    }
}
