//! OS-level substrates: buddy allocator, page tables, virtual memory,
//! and the HSCC-style DRAM free/clean/dirty manager.

pub mod buddy;
pub mod dram_mgr;
pub mod page_table;
pub mod vm;

pub use buddy::Buddy;
pub use dram_mgr::{DramMgr, Grant, Reclaim};
pub use page_table::PageTable;
pub use vm::{AddressSpace, Region};
