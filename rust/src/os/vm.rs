//! Virtual address-space management: lazy first-touch allocation of 4 KB
//! pages or 2 MB superpages out of a buddy-managed physical region.
//!
//! The placement *decision* (DRAM vs NVM, interleaving) belongs to the
//! policy; this module provides the mechanism: region-scoped buddies and
//! the vpn -> ppn bookkeeping.

use crate::config::{PAGES_PER_SP, PAGE_SHIFT, PAGE_SIZE, SP_SHIFT};

use super::buddy::{Buddy, MAX_ORDER};
use super::page_table::PageTable;

/// A physical region (e.g. "the NVM", "the DRAM") with frame allocation.
#[derive(Clone, Debug)]
pub struct Region {
    /// Flat physical base address of the region.
    pub base: u64,
    buddy: Buddy,
}

impl Region {
    pub fn new(base: u64, bytes: u64) -> Region {
        assert_eq!(base % PAGE_SIZE, 0);
        Region { base, buddy: Buddy::new(bytes / PAGE_SIZE) }
    }

    /// Allocate one 4 KB frame; returns its flat physical address.
    pub fn alloc_4k(&mut self) -> Option<u64> {
        self.buddy.alloc(0).map(|pfn| self.base + pfn * PAGE_SIZE)
    }

    /// Allocate one aligned 2 MB block; returns its flat physical address.
    pub fn alloc_2m(&mut self) -> Option<u64> {
        self.buddy.alloc(MAX_ORDER).map(|pfn| self.base + pfn * PAGE_SIZE)
    }

    pub fn free_4k(&mut self, paddr: u64) {
        self.buddy.free((paddr - self.base) / PAGE_SIZE, 0);
    }

    pub fn free_2m(&mut self, paddr: u64) {
        self.buddy.free((paddr - self.base) / PAGE_SIZE, MAX_ORDER);
    }

    pub fn free_bytes(&self) -> u64 {
        self.buddy.free_frames() * PAGE_SIZE
    }
}

/// One process's address space, mapped at a single page granularity.
/// (Rainbow composes a 2 MB `AddressSpace` over NVM with a 4 KB shadow
/// table managed by its own policy code.)
#[derive(Clone, Debug)]
pub struct AddressSpace {
    pub pt_4k: PageTable,
    pub pt_2m: PageTable,
}

impl Default for AddressSpace {
    fn default() -> Self {
        Self::new()
    }
}

impl AddressSpace {
    pub fn new() -> AddressSpace {
        AddressSpace { pt_4k: PageTable::new(), pt_2m: PageTable::new() }
    }

    /// Resolve a 4 KB-mapped vaddr to a flat physical address.
    pub fn resolve_4k(&self, vaddr: u64) -> Option<u64> {
        self.pt_4k
            .translate(vaddr >> PAGE_SHIFT)
            .map(|ppn| (ppn << PAGE_SHIFT) | (vaddr & (PAGE_SIZE - 1)))
    }

    /// Resolve a 2 MB-mapped vaddr to a flat physical address.
    pub fn resolve_2m(&self, vaddr: u64) -> Option<u64> {
        self.pt_2m
            .translate(vaddr >> SP_SHIFT)
            .map(|sppn| (sppn << SP_SHIFT) | (vaddr & ((1 << SP_SHIFT) - 1)))
    }

    /// First-touch map of a 4 KB page into `region`; no-op if mapped.
    /// Returns the page's physical base address.
    pub fn ensure_4k(&mut self, vaddr: u64, region: &mut Region) -> Option<u64> {
        let vpn = vaddr >> PAGE_SHIFT;
        if let Some(ppn) = self.pt_4k.translate(vpn) {
            return Some(ppn << PAGE_SHIFT);
        }
        let paddr = region.alloc_4k()?;
        self.pt_4k.map(vpn, paddr >> PAGE_SHIFT);
        Some(paddr)
    }

    /// First-touch map of a 2 MB superpage into `region`.
    pub fn ensure_2m(&mut self, vaddr: u64, region: &mut Region) -> Option<u64> {
        let svpn = vaddr >> SP_SHIFT;
        if let Some(sppn) = self.pt_2m.translate(svpn) {
            return Some(sppn << SP_SHIFT);
        }
        let paddr = region.alloc_2m()?;
        self.pt_2m.map(svpn, paddr >> SP_SHIFT);
        Some(paddr)
    }

    pub fn mapped_bytes_4k(&self) -> u64 {
        self.pt_4k.len() as u64 * PAGE_SIZE
    }

    pub fn mapped_bytes_2m(&self) -> u64 {
        self.pt_2m.len() as u64 * PAGES_PER_SP * PAGE_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_then_stable() {
        let mut region = Region::new(0, 8 << 20);
        let mut a = AddressSpace::new();
        let p1 = a.ensure_4k(0x1234, &mut region).unwrap();
        let p2 = a.ensure_4k(0x1FFF, &mut region).unwrap(); // same page
        assert_eq!(p1, p2);
        let p3 = a.ensure_4k(0x2000, &mut region).unwrap(); // next page
        assert_ne!(p1, p3);
    }

    #[test]
    fn resolve_preserves_offset() {
        let mut region = Region::new(1 << 30, 8 << 20);
        let mut a = AddressSpace::new();
        a.ensure_4k(0x5678, &mut region).unwrap();
        let pa = a.resolve_4k(0x5678).unwrap();
        assert_eq!(pa & 0xFFF, 0x678);
        assert!(pa >= 1 << 30);
    }

    #[test]
    fn superpage_mapping_is_2m_aligned() {
        let mut region = Region::new(0, 32 << 20);
        let mut a = AddressSpace::new();
        let base = a.ensure_2m(0x40_0000 + 12345, &mut region).unwrap();
        assert_eq!(base % (2 << 20), 0);
        let pa = a.resolve_2m(0x40_0000 + 12345).unwrap();
        assert_eq!(pa, base + 12345);
        assert_eq!(a.mapped_bytes_2m(), 2 << 20);
    }

    #[test]
    fn exhaustion_is_none() {
        let mut region = Region::new(0, 2 << 20); // exactly one superpage
        let mut a = AddressSpace::new();
        assert!(a.ensure_2m(0, &mut region).is_some());
        assert!(a.ensure_2m(1 << SP_SHIFT << 1, &mut region).is_none());
    }

    #[test]
    fn region_free_and_realloc() {
        let mut region = Region::new(0, 4 << 20);
        let p = region.alloc_2m().unwrap();
        assert_eq!(region.free_bytes(), 2 << 20);
        region.free_2m(p);
        assert_eq!(region.free_bytes(), 4 << 20);
    }
}
