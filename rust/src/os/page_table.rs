//! Simulator-side page tables: the functional vpn -> ppn mapping each
//! policy maintains (the *timing* of hardware walks lives in `tlb::ptw`).
//!
//! Policies use one or both granularities: flat systems map 4 KB pages,
//! superpage systems map 2 MB pages, Rainbow maps superpages in NVM plus
//! a shadow 4 KB map for DRAM-cached hot pages.

use std::collections::HashMap;

/// One page-size mapping table.
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    map: HashMap<u64, u64>,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    pub fn translate(&self, vpn: u64) -> Option<u64> {
        self.map.get(&vpn).copied()
    }

    pub fn map(&mut self, vpn: u64, ppn: u64) {
        self.map.insert(vpn, ppn);
    }

    /// Change an existing mapping (migration); returns the old ppn.
    pub fn remap(&mut self, vpn: u64, new_ppn: u64) -> Option<u64> {
        self.map.insert(vpn, new_ppn)
    }

    pub fn unmap(&mut self, vpn: u64) -> Option<u64> {
        self.map.remove(&vpn)
    }

    pub fn is_mapped(&self, vpn: u64) -> bool {
        self.map.contains_key(&vpn)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&u64, &u64)> {
        self.map.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_translate_unmap() {
        let mut pt = PageTable::new();
        assert_eq!(pt.translate(1), None);
        pt.map(1, 100);
        assert_eq!(pt.translate(1), Some(100));
        assert!(pt.is_mapped(1));
        assert_eq!(pt.unmap(1), Some(100));
        assert!(pt.is_empty());
    }

    #[test]
    fn remap_returns_old() {
        let mut pt = PageTable::new();
        pt.map(5, 50);
        assert_eq!(pt.remap(5, 99), Some(50));
        assert_eq!(pt.translate(5), Some(99));
    }
}
