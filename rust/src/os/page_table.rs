//! Simulator-side page tables: the functional vpn -> ppn mapping each
//! policy maintains (the *timing* of hardware walks lives in `tlb::ptw`).
//!
//! Policies use one or both granularities: flat systems map 4 KB pages,
//! superpage systems map 2 MB pages, Rainbow maps superpages in NVM plus
//! a shadow 4 KB map for DRAM-cached hot pages.
//!
//! `translate` sits on the per-access hot path of every policy, so the
//! table is a two-level chunked array rather than a HashMap (same
//! flattening treatment as `rainbow::remap::RemapTable`): a directory
//! indexed by `vpn >> CHUNK_BITS` holding lazily-allocated 4096-entry
//! chunks of `u32` ppns, with `u32::MAX` as the not-mapped sentinel.
//! Workload vaddrs are confined to a few sparse gigabyte-scale arenas, so
//! the directory stays small and touched chunks are dense.

/// Entries per chunk (2^12); one chunk spans 16 MiB of 4 KB-page VA space.
const CHUNK_BITS: u32 = 12;
const CHUNK_LEN: usize = 1 << CHUNK_BITS;
const CHUNK_MASK: u64 = CHUNK_LEN as u64 - 1;

/// In-chunk sentinel for "no mapping".
const NO_PPN: u32 = u32::MAX;

/// One page-size mapping table.
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    dir: Vec<Option<Box<[u32]>>>,
    live: usize,
}

impl PageTable {
    pub fn new() -> PageTable {
        PageTable::default()
    }

    #[inline]
    fn split(vpn: u64) -> (usize, usize) {
        ((vpn >> CHUNK_BITS) as usize, (vpn & CHUNK_MASK) as usize)
    }

    #[inline]
    pub fn translate(&self, vpn: u64) -> Option<u64> {
        let (c, i) = Self::split(vpn);
        match self.dir.get(c) {
            Some(Some(chunk)) => {
                let ppn = chunk[i];
                if ppn == NO_PPN { None } else { Some(ppn as u64) }
            }
            _ => None,
        }
    }

    /// Mutable slot for `vpn`, allocating directory + chunk as needed.
    fn slot(&mut self, vpn: u64) -> &mut u32 {
        let (c, i) = Self::split(vpn);
        if c >= self.dir.len() {
            self.dir.resize(c + 1, None);
        }
        let chunk = self.dir[c]
            // rainbow-lint: allow(hot-alloc, amortized one-time chunk allocation)
            .get_or_insert_with(|| vec![NO_PPN; CHUNK_LEN].into_boxed_slice());
        &mut chunk[i]
    }

    pub fn map(&mut self, vpn: u64, ppn: u64) {
        assert!(ppn < NO_PPN as u64,
                "ppn {ppn:#x} out of the table's u32 domain");
        let slot = self.slot(vpn);
        if *slot == NO_PPN {
            self.live += 1;
        }
        *slot = ppn as u32;
    }

    /// Change an existing mapping (migration); returns the old ppn.
    pub fn remap(&mut self, vpn: u64, new_ppn: u64) -> Option<u64> {
        assert!(new_ppn < NO_PPN as u64,
                "ppn {new_ppn:#x} out of the table's u32 domain");
        let slot = self.slot(vpn);
        let old = *slot;
        *slot = new_ppn as u32;
        if old == NO_PPN {
            self.live += 1;
            None
        } else {
            Some(old as u64)
        }
    }

    pub fn unmap(&mut self, vpn: u64) -> Option<u64> {
        let (c, i) = Self::split(vpn);
        match self.dir.get_mut(c) {
            Some(Some(chunk)) => {
                let old = chunk[i];
                if old == NO_PPN {
                    None
                } else {
                    chunk[i] = NO_PPN;
                    self.live -= 1;
                    Some(old as u64)
                }
            }
            _ => None,
        }
    }

    pub fn is_mapped(&self, vpn: u64) -> bool {
        self.translate(vpn).is_some()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// All live mappings in ascending vpn order (off the hot path).
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.dir.iter().enumerate().flat_map(|(c, chunk)| {
            chunk.iter().flat_map(move |chunk| {
                chunk.iter().enumerate().filter_map(move |(i, &ppn)| {
                    if ppn == NO_PPN {
                        None
                    } else {
                        Some((((c as u64) << CHUNK_BITS) | i as u64,
                              ppn as u64))
                    }
                })
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_shrink, shrink_vec};
    use std::collections::HashMap;

    #[test]
    fn map_translate_unmap() {
        let mut pt = PageTable::new();
        assert_eq!(pt.translate(1), None);
        pt.map(1, 100);
        assert_eq!(pt.translate(1), Some(100));
        assert!(pt.is_mapped(1));
        assert_eq!(pt.unmap(1), Some(100));
        assert!(pt.is_empty());
    }

    #[test]
    fn remap_returns_old() {
        let mut pt = PageTable::new();
        pt.map(5, 50);
        assert_eq!(pt.remap(5, 99), Some(50));
        assert_eq!(pt.translate(5), Some(99));
    }

    #[test]
    fn chunk_boundaries_are_distinct_slots() {
        let mut pt = PageTable::new();
        // Neighbors across a chunk boundary and far-apart chunks.
        for &vpn in &[0u64, CHUNK_MASK, CHUNK_MASK + 1, 1 << 28, 1 << 36] {
            pt.map(vpn, vpn & 0xFFFF);
        }
        assert_eq!(pt.len(), 5);
        for &vpn in &[0u64, CHUNK_MASK, CHUNK_MASK + 1, 1 << 28, 1 << 36] {
            assert_eq!(pt.translate(vpn), Some(vpn & 0xFFFF));
        }
        assert_eq!(pt.translate(1), None);
    }

    #[test]
    fn iter_is_sorted_and_complete() {
        let mut pt = PageTable::new();
        for &vpn in &[77u64, 3, CHUNK_MASK + 9, 3 + (1 << 20)] {
            pt.map(vpn, vpn * 2);
        }
        let got: Vec<(u64, u64)> = pt.iter().collect();
        let mut want: Vec<(u64, u64)> =
            [77u64, 3, CHUNK_MASK + 9, 3 + (1 << 20)]
                .iter().map(|&v| (v, v * 2)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic(expected = "u32 domain")]
    fn oversized_ppn_panics() {
        let mut pt = PageTable::new();
        pt.map(1, u32::MAX as u64);
    }

    /// Property: the chunked table behaves exactly like a HashMap model
    /// under arbitrary map/remap/unmap interleavings.
    #[test]
    fn prop_matches_hashmap_model() {
        type Op = (u8, u64, u64); // (kind, vpn, ppn)
        let mut gen = |r: &mut crate::util::rng::Rng| {
            (0..r.below(120))
                .map(|_| {
                    // Cluster vpns so ops actually collide, with a few
                    // far-flung outliers to exercise directory growth.
                    let vpn = if r.chance(0.1) {
                        r.below(1 << 30)
                    } else {
                        r.below(3) * (CHUNK_LEN as u64) + r.below(48)
                    };
                    (r.below(3) as u8, vpn, r.below(1 << 20))
                })
                .collect::<Vec<Op>>()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut pt = PageTable::new();
            let mut model: HashMap<u64, u64> = HashMap::new();
            for &(kind, vpn, ppn) in ops {
                match kind {
                    0 => {
                        pt.map(vpn, ppn);
                        model.insert(vpn, ppn);
                    }
                    1 => {
                        let got = pt.remap(vpn, ppn);
                        let want = model.insert(vpn, ppn);
                        if got != want {
                            return Err(format!(
                                "remap({vpn}): {got:?} != {want:?}"));
                        }
                    }
                    _ => {
                        let got = pt.unmap(vpn);
                        let want = model.remove(&vpn);
                        if got != want {
                            return Err(format!(
                                "unmap({vpn}): {got:?} != {want:?}"));
                        }
                    }
                }
                if pt.len() != model.len() {
                    return Err(format!("len {} != model {}",
                                       pt.len(), model.len()));
                }
            }
            for (&vpn, &ppn) in &model {
                if pt.translate(vpn) != Some(ppn) {
                    return Err(format!("translate({vpn}) lost {ppn}"));
                }
                if !pt.is_mapped(vpn) {
                    return Err(format!("is_mapped({vpn}) false"));
                }
            }
            let mut live: Vec<(u64, u64)> = pt.iter().collect();
            let mut want: Vec<(u64, u64)> =
                model.iter().map(|(&v, &p)| (v, p)).collect();
            want.sort_unstable();
            if live != want {
                return Err("iter() disagrees with model".into());
            }
            live.dedup_by_key(|e| e.0);
            if live.len() != model.len() {
                return Err("iter() emitted duplicate vpns".into());
            }
            Ok(())
        };
        forall_shrink("page-table-model", 0x9A6E, 80, &mut gen,
                      shrink_vec, &mut prop);
    }
}
