//! Pure-Rust fallback of the AOT analytics pipeline — bit-exact with
//! `python/compile/kernels/ref.py` (f32 arithmetic, lax.top_k's stable
//! lowest-index tie-break). Used when artifacts are absent (`--no-accel`)
//! and as the oracle the PJRT integration test compares against.

/// Parameter layout — must match ref.py's `P_*` indices.
pub const P_TNR: usize = 0;
pub const P_TNW: usize = 1;
pub const P_TDR: usize = 2;
pub const P_TDW: usize = 3;
pub const P_TMIG: usize = 4;
pub const P_TWB: usize = 5;
pub const P_THRESH: usize = 6;
pub const P_WWEIGHT: usize = 7;

/// Stage 1: weighted scores + stable top-k indices.
pub fn stage1(sp_reads: &[i32], sp_writes: &[i32], params: &[f32; 8],
              top_n: usize) -> (Vec<f32>, Vec<i32>) {
    assert_eq!(sp_reads.len(), sp_writes.len());
    let w = params[P_WWEIGHT];
    let score: Vec<f32> = sp_reads
        .iter()
        .zip(sp_writes.iter())
        .map(|(&r, &wr)| r as f32 + w * wr as f32)
        .collect();
    // top_k_fast == top_k_stable (see `fast_equals_stable`) but O(n)
    // partition instead of a full sort — §Perf optimization #1.
    let idx = top_k_fast(&score, top_n.min(score.len()));
    (score, idx)
}

/// lax.top_k semantics: k highest values, ties broken by lowest index,
/// result ordered by descending value (then ascending index).
pub fn top_k_stable(score: &[f32], k: usize) -> Vec<i32> {
    let mut idx: Vec<i32> = (0..score.len() as i32).collect();
    // Full sort keeps the semantics obvious; the hot-path variant uses
    // select_nth_unstable — see `top_k_fast` + its equivalence test.
    idx.sort_by(|&a, &b| {
        let (sa, sb) = (score[a as usize], score[b as usize]);
        sb.partial_cmp(&sa).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Faster top-k used on the simulation hot path: O(n) partition + sort of
/// the k head only. Produces identical output to `top_k_stable`.
pub fn top_k_fast(score: &[f32], k: usize) -> Vec<i32> {
    let k = k.min(score.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<i32> = (0..score.len() as i32).collect();
    let cmp = |a: &i32, b: &i32| {
        let (sa, sb) = (score[*a as usize], score[*b as usize]);
        sb.partial_cmp(&sa).unwrap().then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx
}

/// Stage 2: Eq.-1 benefit + hot classification over flattened
/// (n_slots x 512) counter arrays.
pub fn stage2(pg_reads: &[i32], pg_writes: &[i32], params: &[f32; 8])
              -> (Vec<f32>, Vec<i32>) {
    assert_eq!(pg_reads.len(), pg_writes.len());
    let dr = params[P_TNR] - params[P_TDR];
    let dw = params[P_TNW] - params[P_TDW];
    let tmig = params[P_TMIG];
    let thresh = params[P_THRESH];
    let mut benefit = Vec::with_capacity(pg_reads.len());
    let mut hot = Vec::with_capacity(pg_reads.len());
    for (&r, &w) in pg_reads.iter().zip(pg_writes.iter()) {
        let b = dr * r as f32 + dw * w as f32 - tmig;
        benefit.push(b);
        hot.push(((b > thresh) && (r + w > 0)) as i32);
    }
    (benefit, hot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const PARAMS: [f32; 8] =
        [62.0, 547.0, 43.0, 91.0, 4096.0, 4096.0, 64.0, 3.0];

    #[test]
    fn stage1_write_weighting() {
        let (score, _) = stage1(&[1, 0], &[0, 1], &PARAMS, 2);
        assert_eq!(score, vec![1.0, 3.0]);
    }

    #[test]
    fn topk_ties_lowest_index() {
        let score = vec![1.0f32; 100];
        let idx = top_k_stable(&score, 5);
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn topk_descending_order() {
        let score = vec![3.0, 9.0, 1.0, 9.0, 5.0];
        let idx = top_k_stable(&score, 3);
        assert_eq!(idx, vec![1, 3, 4]); // 9(idx1), 9(idx3 tie), 5
    }

    #[test]
    fn fast_equals_stable() {
        let mut rng = Rng::new(77);
        for trial in 0..50 {
            let n = 1 + rng.below(2000) as usize;
            let score: Vec<f32> = (0..n)
                .map(|_| (rng.below(64) as f32) * 0.5) // many ties
                .collect();
            let k = 1 + rng.below(n as u64) as usize;
            assert_eq!(top_k_fast(&score, k), top_k_stable(&score, k),
                       "trial {trial} n={n} k={k}");
        }
    }

    #[test]
    fn stage2_matches_eq1() {
        let (b, h) = stage2(&[100, 0, 0], &[0, 100, 0], &PARAMS);
        // read page: 19*100 - 4096 = -2196 (cold)
        assert_eq!(b[0], (62.0 - 43.0) * 100.0 - 4096.0);
        assert_eq!(h[0], 0);
        // write page: 456*100 - 4096 = 41504 (hot)
        assert_eq!(b[1], (547.0 - 91.0) * 100.0 - 4096.0);
        assert_eq!(h[1], 1);
        // untouched: never hot even though -4096 < ... no: -4096 < 64.
        assert_eq!(h[2], 0);
    }

    #[test]
    fn stage2_untouched_guard_with_negative_threshold() {
        let mut p = PARAMS;
        p[P_THRESH] = -1e9;
        let (_, h) = stage2(&[0], &[0], &p);
        assert_eq!(h[0], 0, "untouched page must stay cold");
    }

    #[test]
    fn stage1_empty_and_small() {
        let (s, i) = stage1(&[], &[], &PARAMS, 10);
        assert!(s.is_empty() && i.is_empty());
        let (_, i) = stage1(&[5], &[5], &PARAMS, 10);
        assert_eq!(i, vec![0]);
    }
}
