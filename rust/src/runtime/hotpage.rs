//! Hot-page identification facade: the per-interval analytics pipeline
//! behind one interface, backed either by the AOT PJRT artifacts (the
//! shipping configuration) or the bit-exact native fallback (tests,
//! `--no-accel`, artifact-less builds).

use std::path::Path;

use crate::rainbow::counters::{count_value, overflowed, TwoStageCounters};
use crate::rainbow::migration::UtilityParams;

use super::native;
use super::pjrt::PjrtRuntime;

/// Which engine evaluates the pipeline.
pub enum Backend {
    Native,
    Pjrt(Box<PjrtRuntime>),
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }
}

/// Stage-2 verdict for one monitored superpage slot.
#[derive(Clone, Debug)]
pub struct SlotVerdict {
    /// The monitored NVM superpage.
    pub sp: u32,
    /// Hot 4 KB page indices with their (reads, writes) in the interval.
    /// Counts are overflow-masked 15-bit values (an overflowed counter
    /// contributes `COUNTER_MAX`, never the raw flagged word).
    pub hot_pages: Vec<(u16, u32, u32)>,
    /// True if any of the slot's counters hit the 15-bit ceiling: the
    /// counts above are floors, and the superpage is "definitely hot"
    /// (§III-B) — surfaced out-of-band instead of the in-band flag bit.
    pub overflowed: bool,
}

pub struct HotPageIdentifier {
    backend: Backend,
}

impl HotPageIdentifier {
    pub fn native() -> HotPageIdentifier {
        HotPageIdentifier { backend: Backend::Native }
    }

    /// Try PJRT from `dir`, falling back to native (with a warning) when
    /// artifacts are missing.
    pub fn auto(dir: &Path) -> HotPageIdentifier {
        match PjrtRuntime::load(dir) {
            Ok(rt) => HotPageIdentifier { backend: Backend::Pjrt(Box::new(rt)) },
            Err(e) => {
                eprintln!(
                    "rainbow: PJRT artifacts unavailable ({e:#}); \
                     using native identifier");
                HotPageIdentifier::native()
            }
        }
    }

    pub fn pjrt(dir: &Path) -> super::pjrt::Result<HotPageIdentifier> {
        Ok(HotPageIdentifier {
            backend: Backend::Pjrt(Box::new(PjrtRuntime::load(dir)?)),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Stage 1: select the top-N hot superpages from the interval's
    /// superpage counters.
    pub fn select_top(&self, counters: &TwoStageCounters,
                      params: &UtilityParams) -> Vec<u32> {
        let (r16, w16) = counters.sp_counts();
        let reads: Vec<i32> =
            r16.iter().map(|&x| count_value(x) as i32).collect();
        let writes: Vec<i32> =
            w16.iter().map(|&x| count_value(x) as i32).collect();
        let p = params.to_f32_vec();
        let top_n = counters.top_n();
        let idx: Vec<i32> = match &self.backend {
            Backend::Native => {
                native::stage1(&reads, &writes, &p, top_n).1
            }
            Backend::Pjrt(rt) => {
                // Artifact returns TOP_N indices over the padded array;
                // keep the first top_n that are in range and non-zero.
                match rt.stage1(&reads, &writes, &p) {
                    Ok((_, idx)) => idx,
                    Err(e) => {
                        eprintln!("rainbow: pjrt stage1 failed ({e:#}); \
                                   falling back to native");
                        native::stage1(&reads, &writes, &p, top_n).1
                    }
                }
            }
        };
        let n = reads.len() as i32;
        idx.into_iter()
            .filter(|&i| i < n)
            .map(|i| i as u32)
            // Skip completely-cold superpages (score 0).
            .filter(|&i| reads[i as usize] != 0 || writes[i as usize] != 0)
            .take(top_n)
            .collect()
    }

    /// Stage 2: classify the monitored slots' 4 KB pages, returning per-
    /// superpage hot lists (with counts for the Eq.-2 victim comparison).
    pub fn classify(&self, counters: &TwoStageCounters,
                    params: &UtilityParams) -> Vec<SlotVerdict> {
        let n_slots = counters.top_n();
        let mut reads = Vec::with_capacity(n_slots * 512);
        let mut writes = Vec::with_capacity(n_slots * 512);
        let mut owners = Vec::with_capacity(n_slots);
        let mut slot_ovf = Vec::with_capacity(n_slots);
        for slot in 0..n_slots {
            let Some(sp) = counters.slot_owner(slot) else { continue };
            let (r, w) = counters.slot_counts(slot);
            owners.push(sp);
            slot_ovf.push(r.iter().chain(w).any(|&x| overflowed(x)));
            reads.extend(r.iter().map(|&x| count_value(x) as i32));
            writes.extend(w.iter().map(|&x| count_value(x) as i32));
        }
        if owners.is_empty() {
            return Vec::new();
        }
        let p = params.to_f32_vec();
        let (_, hot) = match &self.backend {
            Backend::Native => native::stage2(&reads, &writes, &p),
            Backend::Pjrt(rt) => match rt.stage2(&reads, &writes, &p) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!("rainbow: pjrt stage2 failed ({e:#}); \
                               falling back to native");
                    native::stage2(&reads, &writes, &p)
                }
            },
        };
        owners
            .iter()
            .enumerate()
            .map(|(si, &sp)| {
                let base = si * 512;
                let hot_pages = (0..512usize)
                    .filter(|&pg| hot[base + pg] != 0)
                    .map(|pg| (pg as u16,
                               reads[base + pg] as u32,
                               writes[base + pg] as u32))
                    .collect();
                SlotVerdict { sp, hot_pages, overflowed: slot_ovf[si] }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn params() -> UtilityParams {
        UtilityParams::from_config(&Config::paper())
    }

    #[test]
    fn native_select_top_finds_hot_superpages() {
        let mut c = TwoStageCounters::new(256, 8);
        for _ in 0..500 {
            c.record(42, 0, true);
            c.record(17, 0, false);
        }
        c.record(3, 0, false);
        let id = HotPageIdentifier::native();
        let top = id.select_top(&c, &params());
        assert_eq!(top[0], 42, "write-weighted superpage first");
        assert_eq!(top[1], 17);
        assert!(top.contains(&3));
        // Cold superpages are not selected even to fill top-N.
        assert_eq!(top.len(), 3);
    }

    #[test]
    fn native_classify_flags_hot_pages_only() {
        let mut c = TwoStageCounters::new(64, 4);
        c.rotate(&[9]);
        // Page 5: heavily written (hot). Page 6: one read (cold).
        for _ in 0..200 {
            c.record(9, 5, true);
        }
        c.record(9, 6, false);
        let id = HotPageIdentifier::native();
        let verdicts = id.classify(&c, &params());
        assert_eq!(verdicts.len(), 1);
        assert_eq!(verdicts[0].sp, 9);
        let hot: Vec<u16> =
            verdicts[0].hot_pages.iter().map(|h| h.0).collect();
        assert_eq!(hot, vec![5]);
        let (_, r, w) = verdicts[0].hot_pages[0];
        assert_eq!((r, w), (0, 200));
    }

    /// Saturation-boundary regression: an overflowed counter (raw word
    /// `COUNTER_MAX | OVERFLOW_FLAG` = 0xFFFF) must contribute exactly
    /// `COUNTER_MAX` (32767) to ranking inputs — a bare `as u32` cast of
    /// the raw word would contribute 65535 — and the overflow condition
    /// must be visible as its own signal instead.
    #[test]
    fn overflowed_counter_contributes_masked_value() {
        use crate::rainbow::counters::COUNTER_MAX;
        let mut c = TwoStageCounters::new(64, 4);
        c.rotate(&[9]);
        for _ in 0..(COUNTER_MAX as u32 + 100) {
            c.record(9, 5, true); // drives page 5 past saturation
        }
        c.record(9, 6, false);
        let id = HotPageIdentifier::native();
        let verdicts = id.classify(&c, &params());
        assert_eq!(verdicts.len(), 1);
        let (_, r, w) = *verdicts[0]
            .hot_pages
            .iter()
            .find(|h| h.0 == 5)
            .expect("saturated page must still classify hot");
        assert_eq!(w, COUNTER_MAX as u32,
                   "overflowed counter must contribute the masked value");
        assert_eq!(r, 0);
        assert!(verdicts[0].overflowed,
                "overflow must surface as an explicit signal");
        assert!(c.sp_overflowed(9));
        // One counter tick below the ceiling: no overflow signal.
        let mut c2 = TwoStageCounters::new(64, 4);
        c2.rotate(&[9]);
        for _ in 0..(COUNTER_MAX as u32 - 1) {
            c2.record(9, 5, true);
        }
        let v2 = id.classify(&c2, &params());
        assert!(!v2[0].overflowed);
        assert!(!c2.sp_overflowed(9));
        let (_, _, w2) = v2[0].hot_pages[0];
        assert_eq!(w2, COUNTER_MAX as u32 - 1);
    }

    #[test]
    fn empty_monitoring_set_is_empty_verdicts() {
        let c = TwoStageCounters::new(16, 2);
        let id = HotPageIdentifier::native();
        assert!(id.classify(&c, &params()).is_empty());
        assert!(id.select_top(&c, &params()).is_empty());
    }
}
