//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client via the
//! `xla` crate. Python never runs here — the artifacts are self-contained.
//!
//! Artifact shapes are fixed at lowering time (ref.py): stage 1 takes
//! i32[N_SP] x2 + f32[8] and returns (f32[N_SP], i32[TOP_N]); stage 2
//! takes i32[TOP_N,512] x2 + f32[8] and returns (f32[...], i32[...]).
//! The simulator pads its (smaller, scaled) counter arrays to these
//! shapes.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Artifact shape constants — must match python/compile/kernels/ref.py.
pub const N_SP: usize = 16384;
pub const TOP_N: usize = 128;
pub const SP_PAGES: usize = 512;

/// A compiled pair of stage executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    stage1: xla::PjRtLoadedExecutable,
    stage2: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Load `hotpage_stage1.hlo.txt` / `hotpage_stage2.hlo.txt` from
    /// `artifacts_dir` and compile them on the CPU PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = artifacts_dir.join(name);
            if !path.exists() {
                bail!("artifact {} missing — run `make artifacts`",
                      path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        Ok(PjrtRuntime {
            stage1: load("hotpage_stage1.hlo.txt")?,
            stage2: load("hotpage_stage2.hlo.txt")?,
            client,
        })
    }

    /// Default artifacts location: `$RAINBOW_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RAINBOW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute stage 1. Inputs may be shorter than N_SP (padded with
    /// zeros). Returns (scores [n], top indices [TOP_N] into the padded
    /// array — callers filter indices >= n).
    pub fn stage1(&self, sp_reads: &[i32], sp_writes: &[i32],
                  params: &[f32; 8]) -> Result<(Vec<f32>, Vec<i32>)> {
        if sp_reads.len() > N_SP {
            bail!("n_sp {} exceeds artifact shape {N_SP}", sp_reads.len());
        }
        let r = pad_i32(sp_reads, N_SP);
        let w = pad_i32(sp_writes, N_SP);
        let lr = xla::Literal::vec1(&r);
        let lw = xla::Literal::vec1(&w);
        let lp = xla::Literal::vec1(&params[..]);
        let result = self.stage1.execute::<xla::Literal>(&[lr, lw, lp])?
            [0][0]
            .to_literal_sync()?;
        let (score, idx) = result.to_tuple2()?;
        Ok((score.to_vec::<f32>()?, idx.to_vec::<i32>()?))
    }

    /// Execute stage 2 over flattened (n_slots x 512) counters
    /// (n_slots <= TOP_N; rows padded with zeros).
    pub fn stage2(&self, pg_reads: &[i32], pg_writes: &[i32],
                  params: &[f32; 8]) -> Result<(Vec<f32>, Vec<i32>)> {
        let n = TOP_N * SP_PAGES;
        if pg_reads.len() > n {
            bail!("stage2 input {} exceeds artifact shape {n}",
                  pg_reads.len());
        }
        if pg_reads.len() % SP_PAGES != 0 {
            bail!("stage2 input must be a multiple of {SP_PAGES}");
        }
        let r = pad_i32(pg_reads, n);
        let w = pad_i32(pg_writes, n);
        let lr = xla::Literal::vec1(&r)
            .reshape(&[TOP_N as i64, SP_PAGES as i64])?;
        let lw = xla::Literal::vec1(&w)
            .reshape(&[TOP_N as i64, SP_PAGES as i64])?;
        let lp = xla::Literal::vec1(&params[..]);
        let result = self.stage2.execute::<xla::Literal>(&[lr, lw, lp])?
            [0][0]
            .to_literal_sync()?;
        let (benefit, hot) = result.to_tuple2()?;
        let mut b = benefit.to_vec::<f32>()?;
        let mut h = hot.to_vec::<i32>()?;
        b.truncate(pg_reads.len());
        h.truncate(pg_reads.len());
        Ok((b, h))
    }
}

fn pad_i32(xs: &[i32], n: usize) -> Vec<i32> {
    let mut v = Vec::with_capacity(n);
    v.extend_from_slice(xs);
    v.resize(n, 0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_extends_with_zeros() {
        assert_eq!(pad_i32(&[1, 2], 4), vec![1, 2, 0, 0]);
        assert_eq!(pad_i32(&[1, 2], 2), vec![1, 2]);
    }

    // Execution tests against the real artifacts live in
    // rust/tests/pjrt_integration.rs (they need `make artifacts`).
}
