//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` for execution on a PJRT client.
//!
//! Artifact shapes are fixed at lowering time (ref.py): stage 1 takes
//! i32[N_SP] x2 + f32[8] and returns (f32[N_SP], i32[TOP_N]); stage 2
//! takes i32[TOP_N,512] x2 + f32[8] and returns (f32[...], i32[...]).
//! The simulator pads its (smaller, scaled) counter arrays to these
//! shapes.
//!
//! The execution engine itself comes from the `xla` PJRT bindings, which
//! cannot be vendored in this offline environment (the same crates.io
//! constraint that substitutes `util::{rng, cli, proptest, bench}` for
//! rand/clap/proptest/criterion). The engine is therefore *gated*: this
//! module keeps the artifact contract — shapes, padding, validation, and
//! the error surface — compiled and tested, while [`PjrtRuntime::load`]
//! reports the backend as unavailable. Every caller already treats that
//! as "fall back to the bit-exact native pipeline" (`HotPageIdentifier::
//! auto`, the Rainbow policy) or "skip" (the PJRT integration tests, the
//! perf benches), so builds and tier-1 stay green with or without
//! artifacts present.

use std::fmt;
use std::path::{Path, PathBuf};

/// Artifact shape constants — must match python/compile/kernels/ref.py.
pub const N_SP: usize = 16384;
pub const TOP_N: usize = 128;
pub const SP_PAGES: usize = 512;

/// Error surface of the PJRT backend (anyhow is unavailable offline;
/// callers format errors with `{e:#}`, which Display satisfies).
#[derive(Clone, Debug)]
pub struct PjrtError(String);

impl fmt::Display for PjrtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PjrtError {}

pub type Result<T> = std::result::Result<T, PjrtError>;

fn err<T>(msg: String) -> Result<T> {
    Err(PjrtError(msg))
}

/// A compiled pair of stage executables. With the `xla` bindings gated
/// the struct is unconstructible — [`PjrtRuntime::load`] always reports
/// the engine unavailable — but its API (shape validation included)
/// stays the contract the accelerated path compiles against.
pub struct PjrtRuntime {
    _engine: (),
}

impl PjrtRuntime {
    /// Load `hotpage_stage1.hlo.txt` / `hotpage_stage2.hlo.txt` from
    /// `artifacts_dir` and compile them on the PJRT client.
    pub fn load(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        for name in ["hotpage_stage1.hlo.txt", "hotpage_stage2.hlo.txt"] {
            let path: PathBuf = artifacts_dir.join(name);
            if !path.exists() {
                return err(format!(
                    "artifact {} missing — run `make artifacts`",
                    path.display()));
            }
        }
        err(format!(
            "PJRT execution engine unavailable in this build (the `xla` \
             PJRT bindings cannot be vendored offline); artifacts present \
             under {} — using the bit-exact native pipeline instead",
            artifacts_dir.display()))
    }

    /// Default artifacts location: `$RAINBOW_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RAINBOW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        "gated".to_string()
    }

    /// Execute stage 1. Inputs may be shorter than N_SP (padded with
    /// zeros). Returns (scores [n], top indices [TOP_N] into the padded
    /// array — callers filter indices >= n).
    pub fn stage1(&self, sp_reads: &[i32], sp_writes: &[i32],
                  params: &[f32; 8]) -> Result<(Vec<f32>, Vec<i32>)> {
        if sp_reads.len() > N_SP {
            return err(format!(
                "n_sp {} exceeds artifact shape {N_SP}", sp_reads.len()));
        }
        let _padded = (pad_i32(sp_reads, N_SP), pad_i32(sp_writes, N_SP),
                       *params);
        err("PJRT execution engine gated (xla bindings unavailable)".into())
    }

    /// Execute stage 2 over flattened (n_slots x 512) counters
    /// (n_slots <= TOP_N; rows padded with zeros).
    pub fn stage2(&self, pg_reads: &[i32], pg_writes: &[i32],
                  params: &[f32; 8]) -> Result<(Vec<f32>, Vec<i32>)> {
        let n = TOP_N * SP_PAGES;
        if pg_reads.len() > n {
            return err(format!(
                "stage2 input {} exceeds artifact shape {n}",
                pg_reads.len()));
        }
        if pg_reads.len() % SP_PAGES != 0 {
            return err(format!(
                "stage2 input must be a multiple of {SP_PAGES}"));
        }
        let _padded = (pad_i32(pg_reads, n), pad_i32(pg_writes, n), *params);
        err("PJRT execution engine gated (xla bindings unavailable)".into())
    }
}

fn pad_i32(xs: &[i32], n: usize) -> Vec<i32> {
    let mut v = Vec::with_capacity(n);
    v.extend_from_slice(xs);
    v.resize(n, 0);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_extends_with_zeros() {
        assert_eq!(pad_i32(&[1, 2], 4), vec![1, 2, 0, 0]);
        assert_eq!(pad_i32(&[1, 2], 2), vec![1, 2]);
    }

    #[test]
    fn load_reports_missing_artifacts_first() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_no_artifacts_{}", std::process::id()));
        let e = PjrtRuntime::load(&dir).unwrap_err();
        assert!(e.to_string().contains("missing"), "{e}");
        // `{:#}` (what callers print) must also format.
        assert!(!format!("{e:#}").is_empty());
    }

    #[test]
    fn load_reports_gated_engine_when_artifacts_exist() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_fake_artifacts_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["hotpage_stage1.hlo.txt", "hotpage_stage2.hlo.txt"] {
            std::fs::write(dir.join(name), "HloModule stub").unwrap();
        }
        let e = PjrtRuntime::load(&dir).unwrap_err();
        assert!(e.to_string().contains("unavailable"), "{e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Execution tests against the real artifacts live in
    // rust/tests/pjrt_integration.rs (they skip while the engine is
    // gated, exactly as they skip when artifacts are absent).
}
