//! Artifact runtime: PJRT execution of the AOT-compiled analytics
//! pipeline plus the bit-exact native fallback.

pub mod hotpage;
pub mod native;
pub mod pjrt;

pub use hotpage::{Backend, HotPageIdentifier, SlotVerdict};
pub use pjrt::PjrtRuntime;
