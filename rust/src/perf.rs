//! Machine-readable hot-path throughput harness.
//!
//! One code path serves three callers — the `rainbow perf` CLI
//! subcommand, the `perf_hotpath` bench binary, and the tier-1 schema
//! tests — so the committed `BENCH_<n>.json` trajectory files, the CI
//! bench-smoke job, and local runs can never disagree on what is
//! measured or how it is serialized.
//!
//! The report schema is versioned (`rainbow-bench-v1`): top-level
//! `schema` / `config` (with a reproducibility fingerprint) /
//! `wall_clock_s` / `benches`, each bench carrying `name`, `iters`,
//! `ns_per_op`, and `ops_per_sec`. [`validate`] rejects any structural
//! drift, so a future PR that changes the shape must bump the schema
//! string and the committed reports together.

use std::time::{Duration, Instant};

use crate::config::Config;
use crate::policies::{self, Policy};
use crate::rainbow::counters::TwoStageCounters;
use crate::rainbow::migration::UtilityParams;
use crate::rainbow::RemapTable;
use crate::runtime::HotPageIdentifier;
use crate::telemetry::{EventKind, Telemetry};
use crate::tlb::CoreTlbs;
use crate::util::bench::{black_box, Bencher, Measurement};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workloads::{AppProfile, Synth};

/// Schema identifier stamped into (and required of) every report.
pub const SCHEMA: &str = "rainbow-bench-v1";

/// Everything that shapes a perf run — scale/seed pick the simulated
/// machine and workload stream, the rest budget the measurement. The
/// whole struct is serialized into the report (plus a one-line
/// fingerprint) so a reading is never detached from how it was taken.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PerfConfig {
    /// Capacity scale divisor vs the paper's Table IV machine.
    pub scale: u64,
    /// Workload RNG seed.
    pub seed: u64,
    /// Timed samples per benchmark.
    pub samples: usize,
    /// Warmup budget per benchmark (ms).
    pub warmup_ms: u64,
    /// Per-sample time budget iterations auto-scale toward (ms).
    pub target_ms: u64,
}

impl Default for PerfConfig {
    fn default() -> Self {
        PerfConfig {
            scale: 8,
            seed: 1,
            samples: 10,
            warmup_ms: 200,
            target_ms: 10,
        }
    }
}

impl PerfConfig {
    /// Defaults with the `RAINBOW_BENCH_SAMPLES` /
    /// `RAINBOW_BENCH_WARMUP_MS` / `RAINBOW_BENCH_TARGET_MS` env caps
    /// applied (the CI bench-smoke job shrinks a run to milliseconds
    /// with these; they are recorded in the fingerprint).
    pub fn from_env() -> Self {
        fn env_u64(key: &str) -> Option<u64> {
            std::env::var(key).ok().and_then(|v| v.parse().ok())
        }
        let mut c = PerfConfig::default();
        if let Some(n) = env_u64("RAINBOW_BENCH_SAMPLES") {
            c.samples = (n as usize).max(1);
        }
        if let Some(ms) = env_u64("RAINBOW_BENCH_WARMUP_MS") {
            c.warmup_ms = ms;
        }
        if let Some(ms) = env_u64("RAINBOW_BENCH_TARGET_MS") {
            c.target_ms = ms;
        }
        c
    }

    /// One-line self-describing reproducibility key.
    pub fn fingerprint(&self) -> String {
        format!(
            "rainbow-perf scale={} seed={} samples={} warmup_ms={} \
             target_ms={}",
            self.scale, self.seed, self.samples, self.warmup_ms,
            self.target_ms)
    }

    fn bencher(&self) -> Bencher {
        Bencher::new()
            .warmup(Duration::from_millis(self.warmup_ms))
            .samples(self.samples)
            .target_per_sample(Duration::from_millis(self.target_ms))
    }
}

/// One benchmark's published figures.
#[derive(Clone, Debug)]
pub struct BenchEntry {
    pub name: String,
    /// Total iterations timed (across all samples).
    pub iters: u64,
    /// Median per-operation cost.
    pub ns_per_op: f64,
    /// Reciprocal throughput (accesses/sec for the access benches).
    pub ops_per_sec: f64,
}

impl From<Measurement> for BenchEntry {
    fn from(m: Measurement) -> BenchEntry {
        BenchEntry {
            iters: m.total_iters(),
            ns_per_op: m.ns_per_op(),
            ops_per_sec: m.ops_per_sec(),
            name: m.name,
        }
    }
}

/// A complete perf run: per-stage figures plus suite wall-clock.
#[derive(Clone, Debug)]
pub struct PerfReport {
    pub config: PerfConfig,
    /// End-to-end harness wall-clock (setup + warmup + sampling).
    pub wall_clock_s: f64,
    pub benches: Vec<BenchEntry>,
}

impl PerfReport {
    /// Serialize to the `rainbow-bench-v1` document ([`validate`]
    /// accepts exactly this shape).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        Json::Obj(vec![
            ("schema".into(), Json::Str(SCHEMA.into())),
            ("config".into(), Json::Obj(vec![
                ("scale".into(), Json::Num(c.scale as f64)),
                ("seed".into(), Json::Num(c.seed as f64)),
                ("samples".into(), Json::Num(c.samples as f64)),
                ("warmup_ms".into(), Json::Num(c.warmup_ms as f64)),
                ("target_ms".into(), Json::Num(c.target_ms as f64)),
                ("fingerprint".into(), Json::Str(c.fingerprint())),
            ])),
            ("wall_clock_s".into(), Json::Num(self.wall_clock_s)),
            ("benches".into(), Json::Arr(
                self.benches.iter().map(|b| Json::Obj(vec![
                    ("name".into(), Json::Str(b.name.clone())),
                    ("iters".into(), Json::Num(b.iters as f64)),
                    ("ns_per_op".into(), Json::Num(b.ns_per_op)),
                    ("ops_per_sec".into(), Json::Num(b.ops_per_sec)),
                ])).collect())),
        ])
    }
}

/// The hot-path stages every report must cover (beyond the per-policy
/// `policy.<name>.access` entries): workload generation, remap-table
/// lookup, split-TLB lookup, the two interval-analytics stages, and
/// the telemetry sink's record path with the sink disabled (the
/// default every simulation runs with — the DESIGN.md §14 <2% budget)
/// and enabled (one ring write).
pub const REQUIRED_STAGES: [&str; 7] = [
    "synth.next_mem",
    "remap.lookup",
    "tlb.lookup",
    "analytics.select_top",
    "analytics.classify",
    "telemetry.record_off",
    "telemetry.record_on",
];

/// Run the full hot-path suite and collect the report.
pub fn run_suite(cfg: &PerfConfig) -> PerfReport {
    let t0 = Instant::now();
    let b = cfg.bencher();
    let mut benches: Vec<BenchEntry> = Vec::new();

    // Stage: workload generation (the simulator's input side).
    let prof = AppProfile::by_name("mcf").unwrap().scaled(cfg.scale);
    let mut synth = Synth::new(prof, 0, cfg.seed);
    benches.push(b.run("synth.next_mem", || {
        black_box(synth.next_mem());
    }).into());

    // Stage: end-to-end `Policy::access` per policy (the L3-miss hot
    // path: translation, counters, tier access, interval machinery).
    let config = Config::scaled(cfg.scale);
    for name in policies::all_names() {
        let mut pol = policies::from_name(name, &config, false).unwrap();
        let prof = AppProfile::by_name("DICT").unwrap().scaled(cfg.scale);
        let mut s = Synth::new(prof, 0, cfg.seed.wrapping_add(1));
        let mut now = 0u64;
        benches.push(b.run(&format!("policy.{name}.access"), || {
            let (vaddr, is_write) = s.next_mem();
            now += pol.access(0, vaddr, is_write, now) + 1;
            black_box(now);
        }).into());
    }

    // Stage: flat remap-table lookup (behind every superpage-TLB hit
    // with a set bitmap bit; 1 Mi pages, 1/16 migrated).
    let n_pages = 1usize << 20;
    let n_frames = 1usize << 17;
    let mut remap = RemapTable::with_capacity(n_pages, n_frames);
    for f in 0..(n_frames as u64 / 2) {
        remap.insert(f * 8, f);
    }
    let mut rr = Rng::new(cfg.seed.wrapping_add(2));
    benches.push(b.run("remap.lookup", || {
        black_box(remap.lookup(rr.below(n_pages as u64)));
    }).into());

    // Stage: the parallel split-TLB lookup over a hot 2 MB region
    // (mixed 4K/SP hits and misses).
    let mut tlbs = CoreTlbs::new(&config);
    for vpn in 0..64u64 {
        tlbs.insert_4k(vpn, vpn + 1000);
    }
    tlbs.insert_2m(0, 1);
    let mut tr = Rng::new(cfg.seed.wrapping_add(3));
    benches.push(b.run("tlb.lookup", || {
        black_box(tlbs.lookup(tr.below(1 << 21)).cycles());
    }).into());

    // Stage: interval analytics at artifact shapes — stage-1 top-N
    // selection over every superpage, stage-2 classification of the
    // monitored slots' 4 KB counters.
    let id = HotPageIdentifier::native();
    let mut counters = TwoStageCounters::new(2048, 50);
    counters.rotate(&(0..50).collect::<Vec<u32>>());
    let mut cr = Rng::new(cfg.seed.wrapping_add(4));
    for _ in 0..100_000 {
        counters.record(cr.below(2048) as u32, cr.below(512) as u16,
                        cr.chance(0.3));
    }
    let up = UtilityParams::from_config(&config);
    benches.push(b.run("analytics.select_top", || {
        black_box(id.select_top(&counters, &up));
    }).into());
    benches.push(b.run("analytics.classify", || {
        black_box(id.classify(&counters, &up));
    }).into());

    // Stage: the telemetry sink's record path. Disabled is the state
    // every ordinary simulation runs in — this stage is the measured
    // half of the "<2% when off" budget; enabled costs one ring write
    // (pre-allocated by `enable`, wraparound included).
    let mut tel_off = Telemetry::default();
    let mut toff = 0u64;
    benches.push(b.run("telemetry.record_off", || {
        toff += 1;
        tel_off.event(toff, EventKind::Shootdown, toff, 1);
        black_box(tel_off.events_held());
    }).into());
    let mut tel_on = Telemetry::default();
    tel_on.enable(1 << 12, 1 << 8);
    let mut ton = 0u64;
    benches.push(b.run("telemetry.record_on", || {
        ton += 1;
        tel_on.event(ton, EventKind::Shootdown, ton, 1);
        black_box(tel_on.events_held());
    }).into());

    PerfReport {
        config: cfg.clone(),
        wall_clock_s: t0.elapsed().as_secs_f64(),
        benches,
    }
}

fn field<'a>(obj: &'a Json, key: &str, what: &str)
             -> Result<&'a Json, String> {
    obj.get(key).ok_or_else(|| format!("{what}: missing field {key:?}"))
}

fn num_field(obj: &Json, key: &str, what: &str) -> Result<f64, String> {
    field(obj, key, what)?
        .as_f64()
        .ok_or_else(|| format!("{what}: field {key:?} must be a number"))
}

/// Validate a parsed document against the `rainbow-bench-v1` schema.
/// Structural drift (wrong schema string, missing/ill-typed fields,
/// empty or duplicate benches, ns/op and ops/sec disagreeing) is an
/// error naming the offending field.
pub fn validate(doc: &Json) -> Result<(), String> {
    if doc.as_obj().is_none() {
        return Err("report: document must be a JSON object".into());
    }
    let schema = field(doc, "schema", "report")?
        .as_str()
        .ok_or("report: field \"schema\" must be a string")?;
    if schema != SCHEMA {
        return Err(format!(
            "report: schema {schema:?} is not the supported {SCHEMA:?}"));
    }

    let config = field(doc, "config", "report")?;
    if config.as_obj().is_none() {
        return Err("report: field \"config\" must be an object".into());
    }
    for key in ["scale", "seed", "samples", "warmup_ms", "target_ms"] {
        field(config, key, "config")?
            .as_u64()
            .ok_or_else(|| format!(
                "config: field {key:?} must be a non-negative integer"))?;
    }
    let fp = field(config, "fingerprint", "config")?
        .as_str()
        .ok_or("config: field \"fingerprint\" must be a string")?;
    if fp.is_empty() {
        return Err("config: fingerprint must be non-empty".into());
    }

    let wall = num_field(doc, "wall_clock_s", "report")?;
    if !(wall >= 0.0 && wall.is_finite()) {
        return Err("report: wall_clock_s must be a finite non-negative \
                    number".into());
    }

    let benches = field(doc, "benches", "report")?
        .as_arr()
        .ok_or("report: field \"benches\" must be an array")?;
    if benches.is_empty() {
        return Err("report: benches must be non-empty".into());
    }
    let mut names: Vec<&str> = Vec::with_capacity(benches.len());
    for (i, b) in benches.iter().enumerate() {
        let what = format!("benches[{i}]");
        if b.as_obj().is_none() {
            return Err(format!("{what}: must be an object"));
        }
        let name = field(b, "name", &what)?
            .as_str()
            .ok_or_else(|| format!("{what}: field \"name\" must be a \
                                    string"))?;
        if name.is_empty() {
            return Err(format!("{what}: name must be non-empty"));
        }
        if names.contains(&name) {
            return Err(format!("{what}: duplicate bench name {name:?}"));
        }
        names.push(name);
        let iters = field(b, "iters", &what)?
            .as_u64()
            .ok_or_else(|| format!(
                "{what}: field \"iters\" must be a non-negative integer"))?;
        if iters == 0 {
            return Err(format!("{what}: iters must be >= 1"));
        }
        let ns = num_field(b, "ns_per_op", &what)?;
        let ops = num_field(b, "ops_per_sec", &what)?;
        if !(ns > 0.0 && ns.is_finite()) || !(ops > 0.0 && ops.is_finite()) {
            return Err(format!(
                "{what}: ns_per_op/ops_per_sec must be positive finite"));
        }
        // The two are one measurement in reciprocal views; a report
        // where they disagree was edited by hand or emitted by a
        // drifted writer.
        let implied = 1e9 / ns;
        if (implied - ops).abs() > 0.05 * implied {
            return Err(format!(
                "{what}: ops_per_sec {ops} disagrees with 1e9/ns_per_op \
                 = {implied}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny() -> PerfConfig {
        PerfConfig {
            scale: 64,
            seed: 7,
            samples: 1,
            warmup_ms: 1,
            target_ms: 1,
        }
    }

    #[test]
    fn suite_covers_stages_and_roundtrips_valid_json() {
        let report = run_suite(&tiny());
        let names: Vec<&str> =
            report.benches.iter().map(|b| b.name.as_str()).collect();
        for stage in REQUIRED_STAGES {
            assert!(names.contains(&stage), "missing stage {stage}");
        }
        for pol in policies::all_names() {
            let n = format!("policy.{pol}.access");
            assert!(names.iter().any(|&x| x == n), "missing {n}");
        }
        assert!(report.wall_clock_s > 0.0);
        // Serialize -> parse -> validate: the committed-report path.
        let text = report.to_json().pretty();
        let doc = json::parse(&text).expect("emitted JSON must parse");
        validate(&doc).expect("emitted JSON must validate");
    }

    fn valid_doc() -> Json {
        let report = PerfReport {
            config: PerfConfig::default(),
            wall_clock_s: 1.5,
            benches: vec![
                BenchEntry {
                    name: "synth.next_mem".into(),
                    iters: 1000,
                    ns_per_op: 40.0,
                    ops_per_sec: 25_000_000.0,
                },
                BenchEntry {
                    name: "remap.lookup".into(),
                    iters: 2000,
                    ns_per_op: 8.0,
                    ops_per_sec: 125_000_000.0,
                },
            ],
        };
        report.to_json()
    }

    fn set(doc: &mut Json, key: &str, v: Json) {
        let Json::Obj(fields) = doc else { panic!("not an object") };
        match fields.iter_mut().find(|(k, _)| k == key) {
            Some((_, slot)) => *slot = v,
            None => fields.push((key.to_string(), v)),
        }
    }

    #[test]
    fn validator_accepts_the_emitted_shape() {
        validate(&valid_doc()).unwrap();
    }

    #[test]
    fn validator_rejects_schema_drift() {
        let mut d = valid_doc();
        set(&mut d, "schema", Json::Str("rainbow-bench-v0".into()));
        let e = validate(&d).unwrap_err();
        assert!(e.contains("schema"), "got: {e}");

        let mut d = valid_doc();
        set(&mut d, "benches", Json::Arr(vec![]));
        assert!(validate(&d).unwrap_err().contains("non-empty"));

        let mut d = valid_doc();
        set(&mut d, "wall_clock_s", Json::Str("fast".into()));
        assert!(validate(&d).unwrap_err().contains("wall_clock_s"));

        // A bench losing a field is drift, not a tolerated extension.
        let mut d = valid_doc();
        if let Some(Json::Arr(benches)) = match &mut d {
            Json::Obj(f) => f.iter_mut()
                .find(|(k, _)| k == "benches")
                .map(|(_, v)| v),
            _ => None,
        } {
            if let Json::Obj(fields) = &mut benches[0] {
                fields.retain(|(k, _)| k != "iters");
            }
        }
        let e = validate(&d).unwrap_err();
        assert!(e.contains("iters"), "got: {e}");
    }

    #[test]
    fn validator_rejects_inconsistent_reciprocals() {
        let mut d = valid_doc();
        if let Json::Obj(f) = &mut d {
            let benches = f.iter_mut()
                .find(|(k, _)| k == "benches")
                .map(|(_, v)| v)
                .unwrap();
            if let Json::Arr(items) = benches {
                set(&mut items[0], "ops_per_sec", Json::Num(1.0));
            }
        }
        let e = validate(&d).unwrap_err();
        assert!(e.contains("disagrees"), "got: {e}");
    }

    #[test]
    fn validator_rejects_duplicate_names() {
        let mut d = valid_doc();
        if let Json::Obj(f) = &mut d {
            let benches = f.iter_mut()
                .find(|(k, _)| k == "benches")
                .map(|(_, v)| v)
                .unwrap();
            if let Json::Arr(items) = benches {
                set(&mut items[1], "name",
                    Json::Str("synth.next_mem".into()));
            }
        }
        let e = validate(&d).unwrap_err();
        assert!(e.contains("duplicate"), "got: {e}");
    }

    #[test]
    fn fingerprint_is_stable_and_self_describing() {
        let c = PerfConfig::default();
        assert_eq!(
            c.fingerprint(),
            "rainbow-perf scale=8 seed=1 samples=10 warmup_ms=200 \
             target_ms=10");
    }
}
