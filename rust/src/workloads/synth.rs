//! Synthetic access-stream generator driven by an [`AppProfile`].
//!
//! The generative model (DESIGN.md §1): the application's footprint is a
//! range of virtual superpages; at any time a subset is *active* (the
//! working set). Each active superpage owns a set of hot 4 KB pages whose
//! count is drawn from the app's Table II histogram. Accesses split
//! `hot_access_share` : rest between a Zipf draw over the hot set and a
//! uniform draw over the touched set; line selection within a page follows
//! the spatial-locality knob. At interval boundaries the active set drifts.

use crate::config::{PAGES_PER_SP, PAGE_SIZE, SP_SIZE};
use crate::util::rng::{Rng, Zipf};

use super::profile::AppProfile;

/// One generated operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Non-memory instructions (batched).
    Think(u32),
    /// A memory access.
    Mem { vaddr: u64, is_write: bool },
}

/// Per-superpage generator state.
#[derive(Clone, Debug)]
struct ActiveSp {
    /// Virtual superpage index within the app's footprint.
    sp: u64,
    /// Hot 4 KB page indices within the superpage (0..512).
    hot_pages: Vec<u16>,
    /// Touched-but-cold page indices.
    cold_pages: Vec<u16>,
}

/// The stream generator.
#[derive(Clone, Debug)]
pub struct Synth {
    pub profile: AppProfile,
    /// Virtual base address of this app's region (mixes offset each app).
    pub base: u64,
    rng: Rng,
    active: Vec<ActiveSp>,
    /// Flattened (active index, page) list of hot pages + zipf over it.
    hot_flat: Vec<(u32, u16)>,
    zipf: Option<Zipf>,
    n_sps: u64,
    /// Sequential-access cursor (line index) for spatial locality.
    cursor_page: u64,
    cursor_line: u64,
    /// Think-batch accumulator.
    think_per_mem: f64,
    think_credit: f64,
    /// A Think batch was just emitted; the next op must be the Mem.
    mem_due: bool,
}

impl Synth {
    pub fn new(profile: AppProfile, base: u64, seed: u64) -> Synth {
        let mut rng = Rng::new(seed ^ 0x5717C0DE);
        let n_sps = profile.footprint.div_ceil(SP_SIZE).max(1);
        let think_per_mem = (1.0 / profile.memop_per_inst - 1.0).max(0.0);
        let mut s = Synth {
            profile,
            base,
            rng: rng.fork(1),
            active: Vec::new(),
            hot_flat: Vec::new(),
            zipf: None,
            n_sps,
            cursor_page: 0,
            cursor_line: 0,
            think_per_mem,
            think_credit: 0.0,
            mem_due: false,
        };
        s.rebuild_active(&mut rng, 1.0);
        s
    }

    /// Number of active superpages targeted by the working set.
    fn target_active(&self) -> usize {
        // Average touched pages per superpage: hot count (Table II mean)
        // times a touched/hot expansion factor; working_set / that.
        let mean_hot = self.mean_hot_per_sp();
        let touched_per_sp = (mean_hot * 1.5).min(PAGES_PER_SP as f64);
        let ws_pages = (self.profile.working_set / PAGE_SIZE).max(1) as f64;
        ((ws_pages / touched_per_sp).ceil() as usize)
            .clamp(1, self.n_sps as usize)
    }

    fn mean_hot_per_sp(&self) -> f64 {
        // Expected value of the Table II histogram (bucket midpoints).
        let mids = [16.5, 48.5, 96.5, 192.5, 320.5, 448.5];
        self.profile
            .hot_sp_hist
            .iter()
            .zip(mids.iter())
            .map(|(f, m)| f * m)
            .sum()
    }

    /// (Re)build the active set; `frac` = fraction of slots replaced.
    fn rebuild_active(&mut self, rng: &mut Rng, frac: f64) {
        let target = self.target_active();
        let n_replace = ((target as f64 * frac).ceil() as usize).min(target);
        // Shrink or grow to target.
        self.active.truncate(target.saturating_sub(n_replace));
        while self.active.len() < target {
            let sp = rng.below(self.n_sps);
            let hot_n = self
                .profile
                .sample_hot_count(rng)
                .min(PAGES_PER_SP) as usize;
            let touched_n =
                ((hot_n as f64 * 1.5) as usize).clamp(hot_n, PAGES_PER_SP as usize);
            let pages = rng.sample_indices(PAGES_PER_SP as usize, touched_n);
            let hot_pages: Vec<u16> =
                pages[..hot_n].iter().map(|&p| p as u16).collect();
            let cold_pages: Vec<u16> =
                pages[hot_n..].iter().map(|&p| p as u16).collect();
            self.active.push(ActiveSp { sp, hot_pages, cold_pages });
        }
        // Rebuild the flat hot list + zipf.
        self.hot_flat.clear();
        for (i, a) in self.active.iter().enumerate() {
            for &p in &a.hot_pages {
                self.hot_flat.push((i as u32, p));
            }
        }
        // Shuffle so zipf rank 0 isn't always superpage 0.
        rng.shuffle(&mut self.hot_flat);
        self.zipf = if self.hot_flat.is_empty() {
            None
        } else {
            Some(Zipf::new(self.hot_flat.len() as u64,
                           self.profile.zipf_alpha.max(0.05)))
        };
    }

    /// Advance the phase (call at sampling-interval boundaries).
    pub fn advance_phase(&mut self) {
        let drift = self.profile.phase_drift;
        let mut rng = self.rng.fork(0x9A5E_5A17);
        self.rebuild_active(&mut rng, drift);
    }

    /// Generate the next operation: a Think batch (the non-memory
    /// instructions preceding an access) alternating with the Mem op it
    /// precedes, at the profile's memop ratio.
    pub fn next_op(&mut self) -> Op {
        if !self.mem_due {
            // Accrue the think budget for exactly one upcoming memory op.
            self.think_credit += self.think_per_mem;
            let n = self.think_credit as u32;
            self.think_credit -= n as f64;
            if n > 0 {
                self.mem_due = true;
                return Op::Think(n);
            }
        }
        self.mem_due = false;
        Op::Mem {
            vaddr: self.gen_vaddr(),
            is_write: !self.rng.chance(self.profile.read_ratio),
        }
    }

    /// Generate only a memory access (used by analyzers).
    pub fn next_mem(&mut self) -> (u64, bool) {
        let vaddr = self.gen_vaddr();
        let is_write = !self.rng.chance(self.profile.read_ratio);
        (vaddr, is_write)
    }

    fn gen_vaddr(&mut self) -> u64 {
        // Spatial locality: continue the sequential cursor.
        if self.cursor_line > 0 && self.rng.chance(self.profile.spatial) {
            self.cursor_line = (self.cursor_line + 1) % (PAGE_SIZE / 64);
            return self.base
                + self.cursor_page * PAGE_SIZE
                + self.cursor_line * 64;
        }
        let (sp, page) = if !self.hot_flat.is_empty()
            && self.rng.chance(self.profile.hot_access_share)
        {
            let rank = self.zipf.as_ref().unwrap().sample(&mut self.rng);
            let (ai, p) = self.hot_flat[rank as usize];
            (self.active[ai as usize].sp, p as u64)
        } else {
            // Uniform over the touched working set.
            let ai = self.rng.below(self.active.len() as u64) as usize;
            let a = &self.active[ai];
            let total = a.hot_pages.len() + a.cold_pages.len();
            let k = self.rng.below(total as u64) as usize;
            let p = if k < a.hot_pages.len() {
                a.hot_pages[k]
            } else {
                a.cold_pages[k - a.hot_pages.len()]
            };
            (a.sp, p as u64)
        };
        let page_global = sp * PAGES_PER_SP + page;
        self.cursor_page = page_global;
        self.cursor_line = self.rng.below(PAGE_SIZE / 64);
        self.base + page_global * PAGE_SIZE + self.cursor_line * 64
    }

    /// Footprint in virtual superpages.
    pub fn n_superpages(&self) -> u64 {
        self.n_sps
    }

    pub fn active_superpages(&self) -> usize {
        self.active.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn synth(name: &str) -> Synth {
        let p = AppProfile::by_name(name).unwrap().scaled(8);
        Synth::new(p, 0, 42)
    }

    #[test]
    fn addresses_stay_in_footprint() {
        let mut s = synth("mcf");
        let fp = s.profile.footprint.div_ceil(SP_SIZE) * SP_SIZE;
        for _ in 0..50_000 {
            let (v, _) = s.next_mem();
            assert!(v < fp, "vaddr {v:#x} outside footprint {fp:#x}");
        }
    }

    #[test]
    fn base_offsets_all_addresses() {
        let p = AppProfile::by_name("DICT").unwrap().scaled(8);
        let mut s = Synth::new(p, 1 << 40, 7);
        for _ in 0..1000 {
            let (v, _) = s.next_mem();
            assert!(v >= 1 << 40);
        }
    }

    #[test]
    fn read_ratio_approximated() {
        let mut s = synth("streamcluster"); // 85% reads
        let n = 20_000;
        let reads = (0..n).filter(|_| !s.next_mem().1).count();
        let ratio = reads as f64 / n as f64;
        assert!((ratio - 0.85).abs() < 0.03, "ratio={ratio}");
    }

    #[test]
    fn hot_pages_dominate_accesses() {
        // CHOP-style check: the top pages by access count should carry
        // ~hot_access_share of all accesses.
        let mut s = synth("soplex");
        let mut counts: HashMap<u64, u64> = HashMap::new();
        let n = 200_000u64;
        for _ in 0..n {
            let (v, _) = s.next_mem();
            *counts.entry(v / PAGE_SIZE).or_default() += 1;
        }
        let mut by_count: Vec<u64> = counts.values().copied().collect();
        by_count.sort_unstable_by(|a, b| b.cmp(a));
        let hot_n = (counts.len() as f64 * 0.5) as usize; // generous cut
        let hot_sum: u64 = by_count[..hot_n].iter().sum();
        assert!(hot_sum as f64 / n as f64 > 0.65,
                "hot pages carry {:.2}", hot_sum as f64 / n as f64);
    }

    #[test]
    fn working_set_size_in_range() {
        let mut s = synth("soplex"); // ws 70.9MB/8 ≈ 8.9MB ≈ 2269 pages
        let mut touched = std::collections::HashSet::new();
        for _ in 0..300_000 {
            let (v, _) = s.next_mem();
            touched.insert(v / PAGE_SIZE);
        }
        let ws_pages = (s.profile.working_set / PAGE_SIZE) as f64;
        let got = touched.len() as f64;
        assert!(got > ws_pages * 0.2 && got < ws_pages * 3.0,
                "touched {got} vs target {ws_pages}");
    }

    #[test]
    fn think_ops_interleave() {
        let mut s = synth("bodytrack"); // 0.30 memops/inst -> thinks exist
        let mut thinks = 0u64;
        let mut mems = 0u64;
        for _ in 0..10_000 {
            match s.next_op() {
                Op::Think(n) => thinks += n as u64,
                Op::Mem { .. } => mems += 1,
            }
        }
        let ratio = mems as f64 / (mems + thinks) as f64;
        assert!((ratio - 0.30).abs() < 0.05, "memop ratio {ratio}");
    }

    #[test]
    fn phase_drift_changes_active_set() {
        let mut s = synth("BFS");
        let before: Vec<u64> = s.active.iter().map(|a| a.sp).collect();
        s.advance_phase();
        let after: Vec<u64> = s.active.iter().map(|a| a.sp).collect();
        assert_ne!(before, after, "drift must replace some superpages");
        // But not everything (drift = 0.20).
        let kept = before.iter().filter(|sp| after.contains(sp)).count();
        assert!(kept > 0, "some superpages must persist");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = AppProfile::by_name("mcf").unwrap().scaled(8);
        let mut a = Synth::new(p.clone(), 0, 9);
        let mut b = Synth::new(p, 0, 9);
        for _ in 0..1000 {
            assert_eq!(a.next_mem(), b.next_mem());
        }
    }

    #[test]
    fn gups_is_low_locality() {
        let mut g = synth("GUPS");
        let mut s = synth("streamcluster");
        let uniq = |x: &mut Synth| {
            let mut set = std::collections::HashSet::new();
            for _ in 0..20_000 {
                set.insert(x.next_mem().0 / PAGE_SIZE);
            }
            set.len()
        };
        assert!(uniq(&mut g) > 2 * uniq(&mut s),
                "GUPS must touch far more distinct pages");
    }
}
