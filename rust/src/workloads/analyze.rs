//! Workload analytics: regenerates the paper's motivation data —
//! Fig. 1 (CDF of touched 4 KB pages per superpage), Table I (hot-page
//! access statistics), Table II (hot-page distribution within superpages)
//! — from the synthetic streams, at any scale.

use std::collections::HashMap;

use crate::config::{PAGES_PER_SP, PAGE_SIZE};
use crate::util::stats::Histogram;

use super::profile::{AppProfile, HOT_HIST_BOUNDS};
use super::synth::Synth;

/// Access statistics gathered over one sampling interval's worth of
/// memory operations.
#[derive(Clone, Debug)]
pub struct IntervalStats {
    /// page number -> access count.
    pub page_counts: HashMap<u64, u64>,
    pub total_accesses: u64,
}

impl IntervalStats {
    /// Drive `synth` for `n_accesses` memory ops and tally page counts.
    pub fn collect(synth: &mut Synth, n_accesses: u64) -> IntervalStats {
        let mut page_counts: HashMap<u64, u64> = HashMap::new();
        for _ in 0..n_accesses {
            let (vaddr, _) = synth.next_mem();
            *page_counts.entry(vaddr / PAGE_SIZE).or_default() += 1;
        }
        IntervalStats { page_counts, total_accesses: n_accesses }
    }

    /// Touched 4 KB pages per superpage (Fig. 1's underlying samples).
    pub fn touched_per_sp(&self) -> Vec<u64> {
        let mut per_sp: HashMap<u64, u64> = HashMap::new();
        for &page in self.page_counts.keys() {
            *per_sp.entry(page / PAGES_PER_SP).or_default() += 1;
        }
        per_sp.into_values().collect()
    }

    /// CHOP-style hot-page set: the smallest top-ranked set of pages that
    /// carries `share` (0.70) of all accesses. Returns (hot page set,
    /// minimum access count among them).
    pub fn hot_pages(&self, share: f64) -> (Vec<u64>, u64) {
        let mut pairs: Vec<(u64, u64)> =
            self.page_counts.iter().map(|(&p, &c)| (p, c)).collect();
        pairs.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let target = (self.total_accesses as f64 * share) as u64;
        let mut acc = 0u64;
        let mut hot = Vec::new();
        let mut min_count = u64::MAX;
        for (p, c) in pairs {
            if acc >= target {
                break;
            }
            acc += c;
            min_count = min_count.min(c);
            hot.push(p);
        }
        if hot.is_empty() {
            min_count = 0;
        }
        (hot, min_count)
    }

    /// Working set in bytes (touched pages x 4 KB).
    pub fn working_set_bytes(&self) -> u64 {
        self.page_counts.len() as u64 * PAGE_SIZE
    }

    /// Table II row: fraction of superpages whose hot-page count lands in
    /// each bucket.
    pub fn hot_dist_per_sp(&self, share: f64) -> [f64; 6] {
        let (hot, _) = self.hot_pages(share);
        let mut per_sp: HashMap<u64, u64> = HashMap::new();
        for p in hot {
            *per_sp.entry(p / PAGES_PER_SP).or_default() += 1;
        }
        let mut h = Histogram::with_bounds(&HOT_HIST_BOUNDS);
        for (_, c) in per_sp {
            h.add(c);
        }
        let f = h.fractions();
        [f[0], f[1], f[2], f[3], f[4], f[5]]
    }
}

/// One row of Table I, as measured from the generator.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub app: String,
    pub hot_min_access: u64,
    pub working_set_mb: f64,
    pub hot_percent: f64,
    pub footprint_mb: f64,
}

/// Measure a Table I row for `profile` at `scale`, over `n_accesses`.
pub fn table1_row(profile: &AppProfile, scale: u64, seed: u64,
                  n_accesses: u64) -> Table1Row {
    let p = profile.scaled(scale);
    let mut s = Synth::new(p.clone(), 0, seed);
    let st = IntervalStats::collect(&mut s, n_accesses);
    let (hot, min_access) = st.hot_pages(p.hot_access_share);
    let ws = st.working_set_bytes();
    Table1Row {
        app: p.name.to_string(),
        hot_min_access: min_access,
        working_set_mb: ws as f64 / (1 << 20) as f64,
        hot_percent: hot.len() as f64 * PAGE_SIZE as f64 / ws as f64 * 100.0,
        footprint_mb: p.footprint as f64 / (1 << 20) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::cdf_at;

    fn stats(name: &str, n: u64) -> (AppProfile, IntervalStats) {
        let p = AppProfile::by_name(name).unwrap().scaled(8);
        let mut s = Synth::new(p.clone(), 0, 17);
        let st = IntervalStats::collect(&mut s, n);
        (p, st)
    }

    #[test]
    fn fig1_cdf_shape_most_sps_sparsely_touched() {
        // Paper Observation 1: ~80% of superpages have only a few touched
        // small pages per interval (for most apps).
        let (_, st) = stats("mcf", 200_000);
        let touched = st.touched_per_sp();
        let cdf = cdf_at(&touched, &[128, 512]);
        assert!(cdf[0] > 0.5,
                "most superpages should touch <=128 pages, cdf={cdf:?}");
    }

    #[test]
    fn hot_pages_carry_the_share() {
        let (p, st) = stats("soplex", 200_000);
        let (hot, min_access) = st.hot_pages(p.hot_access_share);
        assert!(!hot.is_empty());
        assert!(min_access >= 1);
        let hot_set: std::collections::HashSet<u64> =
            hot.iter().copied().collect();
        let carried: u64 = st
            .page_counts
            .iter()
            .filter(|(pg, _)| hot_set.contains(pg))
            .map(|(_, c)| c)
            .sum();
        let frac = carried as f64 / st.total_accesses as f64;
        assert!(frac >= 0.69, "hot pages carry {frac}");
    }

    #[test]
    fn hot_dist_matches_profile_histogram_roughly() {
        // Graph500's Table II row is extreme (61% + 38% in the two lowest
        // buckets) — the measured distribution should reproduce the shape.
        let (p, st) = stats("Graph500", 400_000);
        let dist = st.hot_dist_per_sp(p.hot_access_share);
        assert!(dist[0] + dist[1] > 0.85,
                "low buckets should dominate: {dist:?}");
        assert!(dist[4] + dist[5] < 0.05);
    }

    #[test]
    fn table1_row_sane() {
        let p = AppProfile::by_name("DICT").unwrap();
        let r = table1_row(&p, 8, 3, 150_000);
        assert_eq!(r.app, "DICT");
        assert!(r.hot_percent > 1.0 && r.hot_percent < 100.0);
        assert!(r.working_set_mb > 0.1);
        assert!(r.footprint_mb > 1.0);
    }
}
