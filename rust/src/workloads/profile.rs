//! Per-application workload profiles, parameterized from the paper's own
//! published measurements (Table I footprints / working sets / hot-page
//! fractions and Table II hot-page-per-superpage histograms).
//!
//! The real SPEC/PARSEC/PBBS binaries cannot run here (no Pin, no
//! licenses); the paper's mechanisms respond to the *access distribution*,
//! which these profiles reproduce — see DESIGN.md §1.

/// Table II bucket upper bounds: hot 4 KB pages per superpage.
pub const HOT_HIST_BOUNDS: [u64; 6] = [32, 64, 128, 256, 384, 512];

/// A synthetic application profile.
#[derive(Clone, Debug)]
pub struct AppProfile {
    pub name: &'static str,
    /// Total memory footprint in bytes (paper scale).
    pub footprint: u64,
    /// Working set per 1e8-cycle interval in bytes (Table I).
    pub working_set: u64,
    /// Hot pages as a fraction of the working set (Table I "hot page %").
    pub hot_fraction: f64,
    /// Table II distribution: fraction of superpages whose hot-page count
    /// falls in each bucket (1-32, 33-64, 65-128, 129-256, 257-384,
    /// 385-512).
    pub hot_sp_hist: [f64; 6],
    /// Fraction of memory operations that are reads.
    pub read_ratio: f64,
    /// Memory operations per instruction.
    pub memop_per_inst: f64,
    /// Zipf skew of accesses over the hot-page set.
    pub zipf_alpha: f64,
    /// Fraction of accesses going to hot pages (CHOP-style: 0.70).
    pub hot_access_share: f64,
    /// P(sequential next line within the page) — spatial locality.
    pub spatial: f64,
    /// Fraction of active superpages replaced at each interval (phase
    /// behaviour / working-set drift).
    pub phase_drift: f64,
}

const MB: u64 = 1 << 20;
const GB: u64 = 1 << 30;

impl AppProfile {
    /// All 14 single-application workloads of Table I/Table V.
    pub fn all() -> Vec<AppProfile> {
        vec![
            AppProfile {
                name: "cactusADM",
                footprint: 776 * MB,
                working_set: (74.6 * MB as f64) as u64,
                hot_fraction: 0.0471,
                hot_sp_hist: [0.2801, 0.341, 0.2932, 0.0065, 0.0745, 0.0047],
                read_ratio: 0.64,
                memop_per_inst: 0.32,
                zipf_alpha: 0.8,
                hot_access_share: 0.70,
                spatial: 0.80,
                phase_drift: 0.05,
            },
            AppProfile {
                name: "mcf",
                footprint: 1698 * MB,
                working_set: 1089 * MB,
                hot_fraction: 0.0236,
                hot_sp_hist: [0.5756, 0.1648, 0.1084, 0.0995, 0.0478, 0.0039],
                read_ratio: 0.75,
                memop_per_inst: 0.38,
                zipf_alpha: 0.9,
                hot_access_share: 0.70,
                spatial: 0.30,
                phase_drift: 0.10,
            },
            AppProfile {
                name: "soplex",
                footprint: 1888 * MB,
                working_set: (70.9 * MB as f64) as u64,
                hot_fraction: 0.1963,
                hot_sp_hist: [0.4569, 0.1088, 0.2276, 0.0928, 0.0677, 0.0462],
                read_ratio: 0.72,
                memop_per_inst: 0.35,
                zipf_alpha: 0.9,
                hot_access_share: 0.70,
                spatial: 0.55,
                phase_drift: 0.08,
            },
            AppProfile {
                name: "canneal",
                footprint: 972 * MB,
                working_set: (891.6 * MB as f64) as u64,
                hot_fraction: 0.0852,
                hot_sp_hist: [0.6218, 0.1586, 0.089, 0.1157, 0.0091, 0.0058],
                read_ratio: 0.70,
                memop_per_inst: 0.36,
                zipf_alpha: 0.6,
                hot_access_share: 0.70,
                spatial: 0.20,
                phase_drift: 0.15,
            },
            AppProfile {
                name: "bodytrack",
                footprint: 620 * MB,
                working_set: (16.2 * MB as f64) as u64,
                hot_fraction: 0.01,
                hot_sp_hist: [0.8319, 0.0601, 0.0766, 0.0218, 0.0063, 0.0033],
                read_ratio: 0.68,
                memop_per_inst: 0.30,
                zipf_alpha: 1.1,
                hot_access_share: 0.75,
                spatial: 0.70,
                phase_drift: 0.05,
            },
            AppProfile {
                name: "streamcluster",
                footprint: 150 * MB,
                working_set: (105.5 * MB as f64) as u64,
                hot_fraction: 0.276,
                hot_sp_hist: [0.2377, 0.3055, 0.1438, 0.1371, 0.175, 0.0009],
                read_ratio: 0.85,
                memop_per_inst: 0.33,
                zipf_alpha: 0.7,
                hot_access_share: 0.70,
                spatial: 0.85,
                phase_drift: 0.03,
            },
            AppProfile {
                name: "DICT",
                footprint: 384 * MB,
                working_set: (20.3 * MB as f64) as u64,
                hot_fraction: 0.372,
                hot_sp_hist: [0.2386, 0.1453, 0.2827, 0.2214, 0.1106, 0.0014],
                read_ratio: 0.78,
                memop_per_inst: 0.34,
                zipf_alpha: 1.0,
                hot_access_share: 0.72,
                spatial: 0.40,
                phase_drift: 0.06,
            },
            AppProfile {
                name: "BFS",
                footprint: 3718 * MB,
                working_set: (404.1 * MB as f64) as u64,
                hot_fraction: 0.2051,
                hot_sp_hist: [0.0394, 0.1819, 0.5742, 0.0635, 0.056, 0.085],
                read_ratio: 0.80,
                memop_per_inst: 0.40,
                zipf_alpha: 0.75,
                hot_access_share: 0.70,
                spatial: 0.35,
                phase_drift: 0.20,
            },
            AppProfile {
                name: "setCover",
                footprint: 2520 * MB,
                working_set: (49.8 * MB as f64) as u64,
                hot_fraction: 0.3753,
                hot_sp_hist: [0.1626, 0.2428, 0.2758, 0.1736, 0.075, 0.0702],
                read_ratio: 0.74,
                memop_per_inst: 0.37,
                zipf_alpha: 0.85,
                hot_access_share: 0.70,
                spatial: 0.45,
                phase_drift: 0.08,
            },
            AppProfile {
                name: "MST",
                footprint: 6660 * MB,
                working_set: (121.2 * MB as f64) as u64,
                hot_fraction: 0.3242,
                hot_sp_hist: [0.1344, 0.2128, 0.2177, 0.258, 0.1631, 0.014],
                read_ratio: 0.76,
                memop_per_inst: 0.38,
                zipf_alpha: 0.8,
                hot_access_share: 0.70,
                spatial: 0.40,
                phase_drift: 0.12,
            },
            AppProfile {
                name: "Graph500",
                footprint: (27.4 * GB as f64) as u64,
                working_set: (7.2 * MB as f64) as u64,
                hot_fraction: 0.0635,
                hot_sp_hist: [0.6148, 0.3846, 0.0006, 0.0, 0.0, 0.0],
                read_ratio: 0.82,
                memop_per_inst: 0.42,
                zipf_alpha: 1.05,
                hot_access_share: 0.70,
                spatial: 0.25,
                phase_drift: 0.30,
            },
            AppProfile {
                name: "Linpack",
                footprint: (23.9 * GB as f64) as u64,
                working_set: 40 * MB,
                hot_fraction: 0.2119,
                hot_sp_hist: [0.2221, 0.1471, 0.2918, 0.163, 0.0964, 0.0796],
                read_ratio: 0.66,
                memop_per_inst: 0.30,
                zipf_alpha: 0.7,
                hot_access_share: 0.70,
                spatial: 0.90,
                phase_drift: 0.25,
            },
            AppProfile {
                name: "NPB-CG",
                footprint: (22.9 * GB as f64) as u64,
                working_set: (40.9 * MB as f64) as u64,
                hot_fraction: 0.247,
                hot_sp_hist: [0.0005, 0.9629, 0.0266, 0.01, 0.0, 0.0],
                read_ratio: 0.79,
                memop_per_inst: 0.39,
                zipf_alpha: 0.75,
                hot_access_share: 0.70,
                spatial: 0.50,
                phase_drift: 0.10,
            },
            AppProfile {
                name: "GUPS",
                footprint: (8.06 * GB as f64) as u64,
                working_set: (7.6 * GB as f64) as u64,
                hot_fraction: 0.058,
                hot_sp_hist: [0.955, 0.045, 0.0, 0.0, 0.0, 0.0],
                read_ratio: 0.50, // read-modify-write updates
                memop_per_inst: 0.45,
                zipf_alpha: 0.5, // near-uniform random
                hot_access_share: 0.40,
                spatial: 0.05,
                phase_drift: 0.40,
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<AppProfile> {
        Self::all().into_iter().find(|p| p.name.eq_ignore_ascii_case(name))
    }

    /// Scale footprint + working set down by `factor` (capacities in the
    /// scaled config shrink by the same factor, preserving pressure).
    pub fn scaled(&self, factor: u64) -> AppProfile {
        let mut p = self.clone();
        p.footprint = (p.footprint / factor).max(8 << 20);
        p.working_set = (p.working_set / factor).max(1 << 20);
        p
    }

    /// Sample a hot-page count for one superpage from the Table II
    /// histogram (uniform within the chosen bucket).
    pub fn sample_hot_count(&self, rng: &mut crate::util::rng::Rng) -> u64 {
        let x = rng.f64();
        let mut acc = 0.0;
        for (i, &frac) in self.hot_sp_hist.iter().enumerate() {
            acc += frac;
            if x < acc {
                let lo = if i == 0 { 1 } else { HOT_HIST_BOUNDS[i - 1] + 1 };
                let hi = HOT_HIST_BOUNDS[i];
                return rng.range(lo, hi + 1);
            }
        }
        1
    }
}

/// Multi-programmed mixes: Table V's four-app mixes (each app on two
/// of the eight cores) plus larger 8-app mixes (one app per core on
/// the 8-core machine) that stress regimes Table V never reaches —
/// every core competing for DRAM with hot working sets, pure streaming
/// with almost nothing worth migrating, maximum app diversity, and
/// capacity pressure from the largest-footprint apps all at once.
pub fn mixes() -> Vec<(&'static str, Vec<&'static str>)> {
    vec![
        ("mix1", vec!["cactusADM", "soplex", "setCover", "MST"]),
        ("mix2", vec!["setCover", "BFS", "DICT", "mcf"]),
        ("mix3", vec!["canneal", "DICT", "MST", "soplex"]),
        // All-hot-heavy: the eight highest hot-fraction profiles —
        // every core's working set is a migration candidate, so the
        // top-N monitor and the DRAM tier are maximally contended.
        ("mixhot", vec!["setCover", "DICT", "MST", "streamcluster",
                        "NPB-CG", "Linpack", "BFS", "soplex"]),
        // All-streaming: high-spatial-locality, low-drift apps (two
        // copies each, own address spaces) — row-buffer-friendly
        // traffic where migration should barely trigger.
        ("mixstream", vec!["streamcluster", "Linpack", "cactusADM",
                           "bodytrack", "streamcluster", "Linpack",
                           "cactusADM", "bodytrack"]),
        // 8-app mixed: one core each across eight distinct profiles
        // spanning the full locality/footprint spectrum.
        ("mixwide", vec!["cactusADM", "mcf", "soplex", "canneal",
                         "DICT", "BFS", "Graph500", "GUPS"]),
        // Capacity-stress: the eight largest footprints simultaneously
        // — DRAM-tier pressure and NVM residency at their worst.
        ("mixcap", vec!["Graph500", "Linpack", "NPB-CG", "GUPS",
                        "MST", "BFS", "setCover", "mcf"]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fourteen_apps() {
        let all = AppProfile::all();
        assert_eq!(all.len(), 14);
        let names: Vec<&str> = all.iter().map(|p| p.name).collect();
        assert!(names.contains(&"GUPS") && names.contains(&"mcf"));
    }

    #[test]
    fn histograms_normalized() {
        for p in AppProfile::all() {
            let s: f64 = p.hot_sp_hist.iter().sum();
            assert!((s - 1.0).abs() < 0.02, "{}: hist sums to {s}", p.name);
        }
    }

    #[test]
    fn table1_spotchecks() {
        let mcf = AppProfile::by_name("mcf").unwrap();
        assert_eq!(mcf.footprint, 1698 << 20);
        assert_eq!(mcf.working_set, 1089 << 20);
        let gups = AppProfile::by_name("gups").unwrap(); // case-insensitive
        assert!(gups.footprint > 8 * (1 << 30));
    }

    #[test]
    fn hot_count_respects_histogram() {
        let g = AppProfile::by_name("Graph500").unwrap();
        let mut rng = Rng::new(1);
        let mut low = 0;
        let n = 10_000;
        for _ in 0..n {
            let c = g.sample_hot_count(&mut rng);
            assert!((1..=512).contains(&c));
            if c <= 32 {
                low += 1;
            }
        }
        // Graph500: 61.48% of superpages have 1-32 hot pages.
        let frac = low as f64 / n as f64;
        assert!((frac - 0.6148).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn scaling_floors() {
        let sc = AppProfile::by_name("streamcluster").unwrap().scaled(8);
        assert_eq!(sc.footprint, (150 << 20) / 8);
        let tiny = AppProfile::by_name("bodytrack").unwrap().scaled(1 << 30);
        assert!(tiny.footprint >= 8 << 20);
    }

    #[test]
    fn mixes_reference_real_apps() {
        for (name, apps) in mixes() {
            // Table V mixes pair 4 apps across 8 cores; the larger
            // mixes give each of the 8 cores its own app slot.
            assert!(apps.len() == 4 || apps.len() == 8,
                    "{name}: {} apps", apps.len());
            for a in apps {
                assert!(AppProfile::by_name(a).is_some(), "unknown app {a}");
            }
        }
    }

    #[test]
    fn eight_app_mixes_registered() {
        let m = mixes();
        assert_eq!(m.len(), 7, "3 Table-V mixes + 4 eight-app mixes");
        for name in ["mixhot", "mixstream", "mixwide", "mixcap"] {
            let (_, apps) = m
                .iter()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("mix {name} missing"));
            assert_eq!(apps.len(), 8, "{name} must fill all 8 cores");
        }
        // mixwide really is 8 distinct apps; mixcap picks the giants.
        let wide = &m.iter().find(|(n, _)| *n == "mixwide").unwrap().1;
        let uniq: std::collections::HashSet<&&str> = wide.iter().collect();
        assert_eq!(uniq.len(), 8);
        let cap = &m.iter().find(|(n, _)| *n == "mixcap").unwrap().1;
        for a in cap.iter() {
            assert!(AppProfile::by_name(a).unwrap().footprint > GB,
                    "{a} is not capacity-stressing");
        }
    }
}
