//! Workload substrate: application profiles from the paper's published
//! statistics, the synthetic stream generator, trace record/replay,
//! multi-programmed mixes, and the Fig.-1/Table-I/Table-II analyzers.

pub mod analyze;
pub mod mix;
pub mod profile;
pub mod synth;
pub mod trace;

pub use analyze::{table1_row, IntervalStats, Table1Row};
pub use mix::Workload;
pub use profile::{mixes, AppProfile, HOT_HIST_BOUNDS};
pub use synth::{Op, Synth};
pub use trace::{Trace, TraceRec};
