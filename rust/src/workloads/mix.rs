//! Multi-core workload assembly: single-app (one stream per core, shared
//! footprint — the threads of the application) and multi-programmed mixes
//! (Table V: 4 apps x 2 cores on the 8-core machine, disjoint address
//! spaces offset in the high virtual bits).

use crate::util::rng::Rng;

use super::profile::{mixes, AppProfile};
use super::synth::{Op, Synth};

/// Virtual-address stride between apps in a mix (1 TB apart).
pub const APP_STRIDE: u64 = 1 << 40;

/// A ready-to-run multi-core workload.
pub struct Workload {
    pub name: String,
    /// One stream per core.
    pub streams: Vec<Synth>,
}

impl Workload {
    /// Single application across all `cores` (thread-per-core, shared
    /// virtual footprint, distinct per-thread access patterns).
    pub fn single(profile: &AppProfile, cores: usize, scale: u64,
                  seed: u64) -> Workload {
        let p = profile.scaled(scale);
        let mut root = Rng::new(seed);
        let streams = (0..cores)
            .map(|c| Synth::new(p.clone(), 0, root.fork(c as u64).next_u64()))
            .collect();
        Workload { name: p.name.to_string(), streams }
    }

    /// Multi-programmed mix: apps round-robin over cores, each app in its
    /// own address-space slot.
    pub fn mix_of(name: &str, apps: &[&str], cores: usize, scale: u64,
                  seed: u64) -> Workload {
        assert!(!apps.is_empty());
        let mut root = Rng::new(seed);
        let profiles: Vec<AppProfile> = apps
            .iter()
            .map(|a| {
                AppProfile::by_name(a)
                    .unwrap_or_else(|| panic!("unknown app {a}"))
                    .scaled(scale)
            })
            .collect();
        let streams = (0..cores)
            .map(|c| {
                let ai = c % profiles.len();
                Synth::new(
                    profiles[ai].clone(),
                    ai as u64 * APP_STRIDE,
                    root.fork(c as u64).next_u64(),
                )
            })
            .collect();
        Workload { name: name.to_string(), streams }
    }

    /// Look up a workload by name: an application or a mix (Table V).
    pub fn by_name(name: &str, cores: usize, scale: u64, seed: u64)
                   -> Option<Workload> {
        if let Some(p) = AppProfile::by_name(name) {
            return Some(Workload::single(&p, cores, scale, seed));
        }
        mixes()
            .into_iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(n, apps)| Workload::mix_of(n, &apps, cores, scale, seed))
    }

    /// All workload names of the evaluation (14 apps + the Table-V and
    /// 8-app mixes).
    pub fn all_names() -> Vec<String> {
        let mut v: Vec<String> =
            AppProfile::all().iter().map(|p| p.name.to_string()).collect();
        v.extend(mixes().iter().map(|(n, _)| n.to_string()));
        v
    }

    pub fn cores(&self) -> usize {
        self.streams.len()
    }

    pub fn next_op(&mut self, core: usize) -> Op {
        self.streams[core].next_op()
    }

    /// Advance every stream's phase (interval boundary).
    pub fn advance_phase(&mut self) {
        for s in &mut self.streams {
            s.advance_phase();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_uses_shared_footprint() {
        let p = AppProfile::by_name("DICT").unwrap();
        let mut w = Workload::single(&p, 4, 8, 1);
        assert_eq!(w.cores(), 4);
        let fp = w.streams[0].profile.footprint.div_ceil(2 << 20) * (2 << 20);
        for c in 0..4 {
            for _ in 0..200 {
                if let Op::Mem { vaddr, .. } = w.next_op(c) {
                    assert!(vaddr < fp);
                }
            }
        }
    }

    #[test]
    fn mix_separates_address_spaces() {
        let mut w =
            Workload::mix_of("mix1", &["cactusADM", "soplex"], 4, 8, 2);
        // Cores 0,2 run app 0 (base 0); cores 1,3 run app 1 (base 1TB).
        let mut saw_base0 = false;
        let mut saw_base1 = false;
        for c in 0..4 {
            for _ in 0..100 {
                if let Op::Mem { vaddr, .. } = w.next_op(c) {
                    if vaddr < APP_STRIDE {
                        saw_base0 = true;
                    } else {
                        saw_base1 = true;
                        assert!(vaddr < 2 * APP_STRIDE);
                    }
                }
            }
        }
        assert!(saw_base0 && saw_base1);
    }

    #[test]
    fn by_name_finds_apps_and_mixes() {
        assert!(Workload::by_name("mcf", 2, 8, 1).is_some());
        assert!(Workload::by_name("mix2", 8, 8, 1).is_some());
        assert!(Workload::by_name("not-an-app", 2, 8, 1).is_none());
    }

    #[test]
    fn twentyone_workloads() {
        // 14 apps + 3 Table-V mixes + 4 eight-app mixes.
        assert_eq!(Workload::all_names().len(), 21);
    }

    #[test]
    fn eight_app_mixes_assemble_one_app_per_core() {
        for name in ["mixhot", "mixstream", "mixwide", "mixcap"] {
            let mut w = Workload::by_name(name, 8, 64, 5)
                .unwrap_or_else(|| panic!("mix {name} must resolve"));
            assert_eq!(w.cores(), 8);
            // Eight app slots: every core's stream lives in its own
            // 1 TB address-space slot, and all eight slots are used.
            let mut slots = std::collections::HashSet::new();
            for c in 0..8 {
                for _ in 0..50 {
                    if let Op::Mem { vaddr, .. } = w.next_op(c) {
                        slots.insert(vaddr / APP_STRIDE);
                    }
                }
            }
            assert_eq!(slots.len(), 8,
                       "{name}: every core must get its own app slot");
        }
    }

    #[test]
    fn streams_are_decorrelated() {
        let p = AppProfile::by_name("GUPS").unwrap();
        let mut w = Workload::single(&p, 2, 8, 3);
        let a: Vec<u64> = (0..50)
            .filter_map(|_| match w.next_op(0) {
                Op::Mem { vaddr, .. } => Some(vaddr),
                _ => None,
            })
            .collect();
        let b: Vec<u64> = (0..50)
            .filter_map(|_| match w.next_op(1) {
                Op::Mem { vaddr, .. } => Some(vaddr),
                _ => None,
            })
            .collect();
        assert_ne!(a, b);
    }
}
