//! Compact binary trace format: record a generated stream once, replay it
//! identically across policies (and across runs — the e2e driver uses this
//! to guarantee every system sees byte-identical input).
//!
//! Record layout (two little-endian u64 words per op): `[meta, vaddr]`.
//!
//!   meta bit 63      = is_write
//!   meta bits 62..32 = think instructions preceding this access (31 bits)
//!   meta bits 31..0  = reserved, must be zero
//!
//! Header: magic, version, record count. Version history:
//!   v1: `think_before` was clamped to 32 bits at record time but packed
//!       into bits 63..32 — a think count ≥ 2^31 overwrote the `is_write`
//!       flag, silently turning reads into writes. v1 files are rejected.
//!   v2: 31-bit think clamp applied at record time, save refuses
//!       out-of-range values, load rejects nonzero reserved bits.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::synth::Op;

const MAGIC: u64 = 0x5241_494E_424F_5754; // "RAINBOWT"
const VERSION: u64 = 2;

/// Largest representable think count (31 bits, see the meta layout).
pub const THINK_MAX: u32 = 0x7FFF_FFFF;

/// One replayable record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRec {
    pub think_before: u32,
    pub vaddr: u64,
    pub is_write: bool,
}

/// In-memory trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub recs: Vec<TraceRec>,
}

fn corrupt(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

impl Trace {
    /// Capture `n_mem` memory operations from an op stream. Accumulated
    /// think time is clamped to the 31 bits the format can carry.
    pub fn record<F: FnMut() -> Op>(mut next: F, n_mem: usize) -> Trace {
        let mut recs = Vec::with_capacity(n_mem);
        let mut think: u64 = 0;
        while recs.len() < n_mem {
            match next() {
                Op::Think(n) => think += n as u64,
                Op::Mem { vaddr, is_write } => {
                    recs.push(TraceRec {
                        think_before: think.min(THINK_MAX as u64) as u32,
                        vaddr,
                        is_write,
                    });
                    think = 0;
                }
            }
        }
        Trace { recs }
    }

    pub fn len(&self) -> usize {
        self.recs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recs.is_empty()
    }

    /// Total instructions represented (memory ops + think).
    pub fn instructions(&self) -> u64 {
        self.recs
            .iter()
            .map(|r| 1 + r.think_before as u64)
            .sum()
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.recs.len() as u64).to_le_bytes())?;
        for (i, r) in self.recs.iter().enumerate() {
            if r.think_before > THINK_MAX {
                return Err(corrupt(format!(
                    "record {i}: think_before {:#x} exceeds the 31-bit \
                     trace field (max {THINK_MAX:#x})",
                    r.think_before)));
            }
            let meta = ((r.is_write as u64) << 63)
                | ((r.think_before as u64) << 32);
            w.write_all(&meta.to_le_bytes())?;
            w.write_all(&r.vaddr.to_le_bytes())?;
        }
        w.flush()
    }

    pub fn load(path: &Path) -> std::io::Result<Trace> {
        let mut r = BufReader::new(File::open(path)?);
        let mut buf = [0u8; 8];
        let mut read_u64 = |r: &mut BufReader<File>| -> std::io::Result<u64> {
            r.read_exact(&mut buf)?;
            Ok(u64::from_le_bytes(buf))
        };
        let magic = read_u64(&mut r)?;
        if magic != MAGIC {
            return Err(corrupt("bad trace magic"));
        }
        let version = read_u64(&mut r)?;
        if version != VERSION {
            return Err(corrupt(format!(
                "unsupported trace version {version} (want {VERSION}; v1 \
                 files corrupt the write flag and must be re-recorded)")));
        }
        let n = read_u64(&mut r)? as usize;
        let mut recs = Vec::with_capacity(n);
        for i in 0..n {
            let meta = read_u64(&mut r)?;
            let vaddr = read_u64(&mut r)?;
            if meta & 0xFFFF_FFFF != 0 {
                return Err(corrupt(format!(
                    "record {i}: nonzero reserved meta bits {:#x}",
                    meta & 0xFFFF_FFFF)));
            }
            recs.push(TraceRec {
                think_before: ((meta >> 32) & THINK_MAX as u64) as u32,
                vaddr,
                is_write: meta >> 63 == 1,
            });
        }
        Ok(Trace { recs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_shrink, shrink_vec};
    use crate::workloads::profile::AppProfile;
    use crate::workloads::synth::Synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("rainbow_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn record_from_synth() {
        let p = AppProfile::by_name("DICT").unwrap().scaled(64);
        let mut s = Synth::new(p, 0, 3);
        let t = Trace::record(|| s.next_op(), 1000);
        assert_eq!(t.len(), 1000);
        assert!(t.instructions() >= 1000);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = AppProfile::by_name("mcf").unwrap().scaled(64);
        let mut s = Synth::new(p, 0, 5);
        let t = Trace::record(|| s.next_op(), 500);
        let path = tmp("t.trace");
        t.save(&path).unwrap();
        let u = Trace::load(&path).unwrap();
        assert_eq!(t.recs, u.recs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.trace");
        std::fs::write(&path, b"not a trace file, definitely").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_bit_and_think_preserved() {
        let t = Trace {
            recs: vec![
                TraceRec { think_before: 7, vaddr: 0xABCDE000, is_write: true },
                TraceRec { think_before: 0, vaddr: 0x1000, is_write: false },
            ],
        };
        let path = tmp("w.trace");
        t.save(&path).unwrap();
        let u = Trace::load(&path).unwrap();
        assert_eq!(u.recs[0].is_write, true);
        assert_eq!(u.recs[0].think_before, 7);
        assert_eq!(u.recs[1].is_write, false);
        std::fs::remove_file(&path).ok();
    }

    /// The v1 corruption regression: a *read* with maximal think time must
    /// round-trip as a read. Under the old layout (think in bits 63..32)
    /// `think_before = THINK_MAX` followed by the 32-bit record clamp let a
    /// think count ≥ 2^31 flip bit 63 and come back as a write.
    #[test]
    fn max_think_read_stays_a_read() {
        let t = Trace {
            recs: vec![
                TraceRec { think_before: THINK_MAX, vaddr: 0x2000,
                           is_write: false },
                TraceRec { think_before: THINK_MAX, vaddr: 0x3000,
                           is_write: true },
            ],
        };
        let path = tmp("maxthink.trace");
        t.save(&path).unwrap();
        let u = Trace::load(&path).unwrap();
        assert_eq!(u.recs, t.recs);
        assert!(!u.recs[0].is_write, "read must not round-trip as a write");
        assert_eq!(u.recs[0].think_before, THINK_MAX);
        std::fs::remove_file(&path).ok();
    }

    /// Record-time clamp: accumulated think ≥ 2^31 is clamped into the
    /// 31-bit field instead of being stored out of range.
    #[test]
    fn record_clamps_think_to_31_bits() {
        let mut ops = vec![
            Op::Mem { vaddr: 0x9000, is_write: false },
            Op::Think(u32::MAX),     // 2^32 - 1 ...
            Op::Think(u32::MAX),     // ... accumulated well past 2^31
            Op::Mem { vaddr: 0x8000, is_write: false },
        ];
        // `record` consumes via pop(), i.e. back-to-front of this vec.
        let t = Trace::record(|| ops.pop().unwrap(), 2);
        assert_eq!(t.recs[0].think_before, 0);
        assert_eq!(t.recs[0].vaddr, 0x8000);
        assert_eq!(t.recs[1].think_before, THINK_MAX);
        assert!(!t.recs[1].is_write);
    }

    /// Out-of-range records are rejected loudly at save time rather than
    /// silently truncated or smeared into the flag bit.
    #[test]
    fn save_rejects_out_of_range_think() {
        let t = Trace {
            recs: vec![TraceRec { think_before: THINK_MAX + 1, vaddr: 0,
                                  is_write: false }],
        };
        let path = tmp("oor.trace");
        let err = t.save(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        std::fs::remove_file(&path).ok();
    }

    /// v1 files (and any unknown version) are rejected: the v1 meta layout
    /// is ambiguous, so pretending to read it would resurrect the bug.
    #[test]
    fn old_version_rejected() {
        let path = tmp("v1.trace");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes()); // VERSION 1
        bytes.extend_from_slice(&1u64.to_le_bytes()); // one record
        // v1 encoding of a read with think ≥ 2^31: bit 63 set by accident.
        let meta = (0x8000_0000u64) << 32;
        bytes.extend_from_slice(&meta.to_le_bytes());
        bytes.extend_from_slice(&0x1000u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Trace::load(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("version"), "err: {err}");
        std::fs::remove_file(&path).ok();
    }

    /// Nonzero reserved low bits mean the record was not produced by a
    /// conforming writer; reject instead of decoding garbage.
    #[test]
    fn nonzero_reserved_bits_rejected() {
        let path = tmp("reserved.trace");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&0xDEAD_BEEFu64.to_le_bytes()); // reserved!
        bytes.extend_from_slice(&0x1000u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Trace::load(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("reserved"), "err: {err}");
        std::fs::remove_file(&path).ok();
    }

    /// A file that ends mid-record (or mid-header) must error, not yield a
    /// short trace.
    #[test]
    fn truncated_file_rejected() {
        let p = AppProfile::by_name("mcf").unwrap().scaled(64);
        let mut s = Synth::new(p, 0, 9);
        let t = Trace::record(|| s.next_op(), 64);
        let path = tmp("trunc.trace");
        t.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Chop at several interesting boundaries: inside the header,
        // between records, and mid-record.
        for cut in [4usize, 20, 24 + 16 * 10 + 3, full.len() - 8,
                    full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(Trace::load(&path).is_err(),
                    "truncation at {cut} bytes must be rejected");
        }
        std::fs::remove_file(&path).ok();
    }

    /// Property: any in-range trace round-trips bit-exactly through
    /// save/load, independent of flag/think/vaddr combinations.
    #[test]
    fn prop_roundtrip_matches() {
        let path = tmp("prop.trace");
        let mut gen = |r: &mut crate::util::rng::Rng| {
            (0..r.below(40))
                .map(|_| TraceRec {
                    // Bias towards the 31-bit boundary where v1 corrupted.
                    think_before: match r.below(4) {
                        0 => THINK_MAX,
                        1 => THINK_MAX - r.below(16) as u32,
                        _ => r.below(1 << 31) as u32,
                    },
                    vaddr: r.below(1 << 48),
                    is_write: r.chance(0.5),
                })
                .collect::<Vec<TraceRec>>()
        };
        let mut prop = |recs: &Vec<TraceRec>| -> Result<(), String> {
            let t = Trace { recs: recs.clone() };
            t.save(&path).map_err(|e| format!("save: {e}"))?;
            let u = Trace::load(&path).map_err(|e| format!("load: {e}"))?;
            if u.recs != t.recs {
                return Err("round-trip mismatch".into());
            }
            Ok(())
        };
        forall_shrink("trace-roundtrip", 0x7ACE5, 60, &mut gen, shrink_vec,
                      &mut prop);
        std::fs::remove_file(&path).ok();
    }
}
