//! Compact binary trace format: record a generated stream once, replay it
//! identically across policies (and across runs — the e2e driver uses this
//! to guarantee every system sees byte-identical input).
//!
//! Record layout (little-endian u64 per op):
//!   bit 63      = is_write
//!   bits 62..32 = think instructions preceding this access (31 bits)
//!   bits 31..0  = vaddr / 64 truncated? -- no: vaddr stored separately.
//! We use a simple two-word record: [meta, vaddr]. Header: magic, version,
//! record count.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::synth::Op;

const MAGIC: u64 = 0x5241_494E_424F_5754; // "RAINBOWT"
const VERSION: u64 = 1;

/// One replayable record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceRec {
    pub think_before: u32,
    pub vaddr: u64,
    pub is_write: bool,
}

/// In-memory trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub recs: Vec<TraceRec>,
}

impl Trace {
    /// Capture `n_mem` memory operations from an op stream.
    pub fn record<F: FnMut() -> Op>(mut next: F, n_mem: usize) -> Trace {
        let mut recs = Vec::with_capacity(n_mem);
        let mut think: u64 = 0;
        while recs.len() < n_mem {
            match next() {
                Op::Think(n) => think += n as u64,
                Op::Mem { vaddr, is_write } => {
                    recs.push(TraceRec {
                        think_before: think.min(u32::MAX as u64) as u32,
                        vaddr,
                        is_write,
                    });
                    think = 0;
                }
            }
        }
        Trace { recs }
    }

    pub fn len(&self) -> usize {
        self.recs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.recs.is_empty()
    }

    /// Total instructions represented (memory ops + think).
    pub fn instructions(&self) -> u64 {
        self.recs
            .iter()
            .map(|r| 1 + r.think_before as u64)
            .sum()
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.recs.len() as u64).to_le_bytes())?;
        for r in &self.recs {
            let meta = ((r.is_write as u64) << 63) | ((r.think_before as u64) << 32);
            w.write_all(&meta.to_le_bytes())?;
            w.write_all(&r.vaddr.to_le_bytes())?;
        }
        w.flush()
    }

    pub fn load(path: &Path) -> std::io::Result<Trace> {
        let mut r = BufReader::new(File::open(path)?);
        let mut buf = [0u8; 8];
        let mut read_u64 = |r: &mut BufReader<File>| -> std::io::Result<u64> {
            r.read_exact(&mut buf)?;
            Ok(u64::from_le_bytes(buf))
        };
        let magic = read_u64(&mut r)?;
        if magic != MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData, "bad trace magic"));
        }
        let version = read_u64(&mut r)?;
        if version != VERSION {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("unsupported trace version {version}")));
        }
        let n = read_u64(&mut r)? as usize;
        let mut recs = Vec::with_capacity(n);
        for _ in 0..n {
            let meta = read_u64(&mut r)?;
            let vaddr = read_u64(&mut r)?;
            recs.push(TraceRec {
                think_before: ((meta >> 32) & 0x7FFF_FFFF) as u32,
                vaddr,
                is_write: meta >> 63 == 1,
            });
        }
        Ok(Trace { recs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::profile::AppProfile;
    use crate::workloads::synth::Synth;

    #[test]
    fn record_from_synth() {
        let p = AppProfile::by_name("DICT").unwrap().scaled(64);
        let mut s = Synth::new(p, 0, 3);
        let t = Trace::record(|| s.next_op(), 1000);
        assert_eq!(t.len(), 1000);
        assert!(t.instructions() >= 1000);
    }

    #[test]
    fn save_load_roundtrip() {
        let p = AppProfile::by_name("mcf").unwrap().scaled(64);
        let mut s = Synth::new(p, 0, 5);
        let t = Trace::record(|| s.next_op(), 500);
        let dir = std::env::temp_dir().join("rainbow_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        t.save(&path).unwrap();
        let u = Trace::load(&path).unwrap();
        assert_eq!(t.recs, u.recs);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("rainbow_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.trace");
        std::fs::write(&path, b"not a trace file, definitely").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_bit_and_think_preserved() {
        let t = Trace {
            recs: vec![
                TraceRec { think_before: 7, vaddr: 0xABCDE000, is_write: true },
                TraceRec { think_before: 0, vaddr: 0x1000, is_write: false },
            ],
        };
        let dir = std::env::temp_dir().join("rainbow_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.trace");
        t.save(&path).unwrap();
        let u = Trace::load(&path).unwrap();
        assert_eq!(u.recs[0].is_write, true);
        assert_eq!(u.recs[0].think_before, 7);
        assert_eq!(u.recs[1].is_write, false);
        std::fs::remove_file(&path).ok();
    }
}
