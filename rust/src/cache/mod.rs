//! zsim-equivalent on-chip cache hierarchy.

pub mod cache;
pub mod hierarchy;

pub use cache::{Cache, CacheOutcome, CacheStats, Writeback};
pub use hierarchy::{CacheHierarchy, HierOutcome, WbBuf};
