//! Generic set-associative cache (tags only — the simulator tracks
//! presence/dirtiness, data values live in the functional model).
//!
//! Write-back + write-allocate, true-LRU replacement, with the
//! invalidation/flush hooks page migration needs (clflush semantics:
//! dirty lines are reported back so they can be written to memory).

/// One cache way.
#[derive(Clone, Copy, Debug, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU stamp (higher = more recent).
    lru: u64,
}

/// Eviction notice: a dirty victim line that must be written back.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Writeback {
    pub addr: u64,
}

/// Result of a cache access.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheOutcome {
    pub hit: bool,
    /// Dirty victim displaced by the fill (miss path only).
    pub writeback: Option<Writeback>,
}

#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub invalidations: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.accesses();
        if t == 0 { 0.0 } else { self.hits as f64 / t as f64 }
    }
}

/// Set-associative cache over 64 B lines.
#[derive(Clone, Debug)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    lines: Vec<Line>,
    tick: u64,
    pub latency: u64,
    pub stats: CacheStats,
}

const LINE_SHIFT: u32 = 6;

impl Cache {
    /// `size` bytes, `assoc` ways, `latency` cycles.
    pub fn new(size: u64, assoc: usize, latency: u64) -> Cache {
        let n_lines = (size >> LINE_SHIFT) as usize;
        assert!(assoc > 0 && n_lines >= assoc,
                "cache too small: {size}B/{assoc}-way");
        let sets = n_lines / assoc;
        assert!(sets.is_power_of_two(), "sets must be 2^k (got {sets})");
        Cache {
            sets,
            assoc,
            lines: vec![Line::default(); n_lines],
            tick: 0,
            latency,
            stats: CacheStats::default(),
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> usize {
        ((addr >> LINE_SHIFT) as usize) & (self.sets - 1)
    }

    #[inline]
    fn tag_of(&self, addr: u64) -> u64 {
        (addr >> LINE_SHIFT) / self.sets as u64
    }

    fn addr_of(&self, set: usize, tag: u64) -> u64 {
        ((tag * self.sets as u64 + set as u64) as u64) << LINE_SHIFT
    }

    /// Access (lookup + fill on miss). Returns hit/miss + optional dirty
    /// victim writeback address.
    pub fn access(&mut self, addr: u64, is_write: bool) -> CacheOutcome {
        self.tick += 1;
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.assoc;
        // Lookup.
        for i in base..base + self.assoc {
            let l = &mut self.lines[i];
            if l.valid && l.tag == tag {
                l.lru = self.tick;
                l.dirty |= is_write;
                self.stats.hits += 1;
                return CacheOutcome { hit: true, writeback: None };
            }
        }
        // Miss: pick victim (invalid first, else LRU).
        self.stats.misses += 1;
        let mut victim = base;
        let mut best = u64::MAX;
        for i in base..base + self.assoc {
            let l = &self.lines[i];
            if !l.valid {
                victim = i;
                best = 0;
                break;
            }
            if l.lru < best {
                best = l.lru;
                victim = i;
            }
        }
        let v = self.lines[victim];
        let writeback = if v.valid && v.dirty {
            self.stats.writebacks += 1;
            Some(Writeback { addr: self.addr_of(set, v.tag) })
        } else {
            None
        };
        self.lines[victim] = Line { tag, valid: true, dirty: is_write,
                                    lru: self.tick };
        CacheOutcome { hit: false, writeback }
    }

    /// Probe without filling or touching LRU.
    pub fn contains(&self, addr: u64) -> bool {
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        self.lines[set * self.assoc..(set + 1) * self.assoc]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    /// Invalidate one line; returns Some(Writeback) if it was dirty
    /// (clflush semantics).
    pub fn flush_line(&mut self, addr: u64) -> Option<Writeback> {
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.assoc;
        for i in base..base + self.assoc {
            let l = &mut self.lines[i];
            if l.valid && l.tag == tag {
                l.valid = false;
                self.stats.invalidations += 1;
                if l.dirty {
                    l.dirty = false;
                    return Some(Writeback { addr });
                }
                return None;
            }
        }
        None
    }

    /// Flush every line in `[start, start+len)`; returns dirty writebacks.
    pub fn flush_range(&mut self, start: u64, len: u64) -> Vec<Writeback> {
        // rainbow-lint: allow(hot-alloc, per-migration-event flush, not per-access)
        let mut out = Vec::new();
        let mut a = start & !((1 << LINE_SHIFT) - 1);
        while a < start + len {
            if let Some(wb) = self.flush_line(a) {
                out.push(wb);
            }
            a += 1 << LINE_SHIFT;
        }
        out
    }

    /// Number of resident valid lines (test/debug helper).
    pub fn occupancy(&self) -> usize {
        self.lines.iter().filter(|l| l.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B = 512B
        Cache::new(512, 2, 3)
    }

    #[test]
    fn miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access(0x1000, false).hit);
        assert!(c.access(0x1000, false).hit);
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = tiny();
        // Three distinct tags mapping to set 0 in a 2-way set.
        let a = 0u64;
        let b = 4 * 64; // sets=4: +4 lines advances the tag, same set
        let d = 8 * 64;
        c.access(a, false);
        c.access(b, false);
        c.access(a, false); // refresh a; b is now LRU
        c.access(d, false); // evicts b
        assert!(c.contains(a));
        assert!(!c.contains(b));
        assert!(c.contains(d));
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = tiny();
        let a = 0u64;
        let b = 4 * 64;
        let d = 8 * 64;
        c.access(a, true); // dirty
        c.access(b, false);
        c.access(d, false); // evicts a (LRU), which is dirty
        let out = c.access(12 * 64, false); // evicts b (clean): no wb
        assert_eq!(out.writeback, None);
        // Recreate precisely: fresh cache
        let mut c = tiny();
        c.access(a, true);
        c.access(b, false);
        let out = c.access(d, false);
        assert_eq!(out.writeback, Some(Writeback { addr: a }));
    }

    #[test]
    fn write_hit_marks_dirty() {
        let mut c = tiny();
        c.access(0, false);
        c.access(0, true); // hit, now dirty
        let wb = c.flush_line(0);
        assert_eq!(wb, Some(Writeback { addr: 0 }));
    }

    #[test]
    fn flush_clean_line_no_writeback() {
        let mut c = tiny();
        c.access(0, false);
        assert_eq!(c.flush_line(0), None);
        assert!(!c.contains(0));
        assert_eq!(c.stats.invalidations, 1);
    }

    #[test]
    fn flush_range_collects_dirty_lines() {
        let mut c = Cache::new(64 << 10, 4, 3);
        for i in 0..8u64 {
            c.access(0x2000 + i * 64, i % 2 == 0); // even lines dirty
        }
        let wbs = c.flush_range(0x2000, 512);
        assert_eq!(wbs.len(), 4);
        assert_eq!(c.occupancy(), 0);
    }

    #[test]
    fn tag_set_roundtrip() {
        let c = tiny();
        for addr in [0u64, 64, 4096, 1 << 20, (1 << 30) + 192] {
            let set = c.set_of(addr);
            let tag = c.tag_of(addr);
            assert_eq!(c.addr_of(set, tag), addr & !63);
        }
    }

    #[test]
    fn paper_l3_geometry_valid() {
        // shared 8MB 16-way from Table IV must construct.
        let c = Cache::new(8 << 20, 16, 34);
        assert_eq!(c.occupancy(), 0);
    }
}
