//! Three-level cache hierarchy: private L1D/L2 per core, shared L3.
//!
//! The hierarchy is non-inclusive and tag-only. An access walks
//! L1 -> L2 -> L3; the returned outcome tells the caller (the policy)
//! whether main memory must be consulted and which dirty victims must be
//! written back to their home device. clflush for page migration flushes a
//! physical range out of every level of every core (broadcast through the
//! coherence domain, as §III-F describes).

use crate::config::Config;

use super::cache::{Cache, Writeback};

/// Fixed-capacity dirty-victim buffer. One access displaces at most three
/// dirty lines (an L1-spill escaping L3, an L2-spill escaping L3, and the
/// demand fill's own L3 victim), so the outcome carries them inline — the
/// old `Vec` put a heap allocation on every dirty-traffic access.
#[derive(Clone, Copy, Debug)]
pub struct WbBuf {
    buf: [Writeback; 4],
    len: u8,
}

impl Default for WbBuf {
    fn default() -> WbBuf {
        WbBuf { buf: [Writeback { addr: 0 }; 4], len: 0 }
    }
}

impl WbBuf {
    #[inline]
    pub fn push(&mut self, wb: Writeback) {
        assert!((self.len as usize) < self.buf.len(),
                "more dirty victims than one access can displace");
        self.buf[self.len as usize] = wb;
        self.len += 1;
    }

    #[inline]
    pub fn as_slice(&self) -> &[Writeback] {
        &self.buf[..self.len as usize]
    }

    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Result of a hierarchy access.
#[derive(Clone, Debug, Default)]
pub struct HierOutcome {
    /// Cycles spent in the cache path (lookup latencies of levels touched).
    pub cycles: u64,
    /// True if the request must go to main memory (LLC miss).
    pub llc_miss: bool,
    /// Dirty victim lines displaced at any level; the caller writes them
    /// to their home memory device.
    pub writebacks: WbBuf,
}

#[derive(Clone, Debug)]
pub struct CacheHierarchy {
    l1: Vec<Cache>,
    l2: Vec<Cache>,
    l3: Cache,
}

impl CacheHierarchy {
    pub fn new(cfg: &Config) -> CacheHierarchy {
        CacheHierarchy {
            l1: (0..cfg.cores)
                .map(|_| Cache::new(cfg.l1_cache.size, cfg.l1_cache.assoc,
                                    cfg.l1_cache.latency))
                .collect(),
            l2: (0..cfg.cores)
                .map(|_| Cache::new(cfg.l2_cache.size, cfg.l2_cache.assoc,
                                    cfg.l2_cache.latency))
                .collect(),
            l3: Cache::new(cfg.l3_cache.size, cfg.l3_cache.assoc,
                           cfg.l3_cache.latency),
        }
    }

    /// Access physical address `paddr` from `core`.
    pub fn access(&mut self, core: usize, paddr: u64, is_write: bool)
                  -> HierOutcome {
        let mut out = HierOutcome::default();
        // L1
        out.cycles += self.l1[core].latency;
        let r1 = self.l1[core].access(paddr, is_write);
        if let Some(wb) = r1.writeback {
            // Dirty L1 victim spills into L2.
            if let Some(wb2) = self.spill(core, wb) {
                out.writebacks.push(wb2);
            }
        }
        if r1.hit {
            return out;
        }
        // L2
        out.cycles += self.l2[core].latency;
        let r2 = self.l2[core].access(paddr, false);
        if let Some(wb) = r2.writeback {
            if let Some(wb3) = self.spill_l3(wb) {
                out.writebacks.push(wb3);
            }
        }
        if r2.hit {
            return out;
        }
        // L3 (shared)
        out.cycles += self.l3.latency;
        let r3 = self.l3.access(paddr, false);
        if let Some(wb) = r3.writeback {
            out.writebacks.push(wb);
        }
        out.llc_miss = !r3.hit;
        out
    }

    /// Dirty L1 victim lands in L2 (write-back); may displace L2 victim
    /// into L3, which may displace to memory.
    fn spill(&mut self, core: usize, wb: Writeback) -> Option<Writeback> {
        let r = self.l2[core].access(wb.addr, true);
        r.writeback.and_then(|w| self.spill_l3(w))
    }

    fn spill_l3(&mut self, wb: Writeback) -> Option<Writeback> {
        let r = self.l3.access(wb.addr, true);
        r.writeback
    }

    /// clflush a physical range from all levels of all cores; returns the
    /// dirty lines that must reach memory, plus the number of resident
    /// lines invalidated (each costs `t_clflush_line`).
    pub fn clflush_range(&mut self, start: u64, len: u64)
                         -> (Vec<Writeback>, u64) {
        // rainbow-lint: allow(hot-alloc, per-migration-event flush, not per-access)
        let mut wbs = Vec::new();
        let mut lines = 0u64;
        for c in self.l1.iter_mut().chain(self.l2.iter_mut()) {
            let before = c.stats.invalidations;
            wbs.extend(c.flush_range(start, len));
            lines += c.stats.invalidations - before;
        }
        let before = self.l3.stats.invalidations;
        wbs.extend(self.l3.flush_range(start, len));
        lines += self.l3.stats.invalidations - before;
        (wbs, lines)
    }

    /// Aggregated stats across levels: (l1 hit rate, l2 hit rate, llc
    /// misses).
    pub fn summary(&self) -> (f64, f64, u64) {
        let l1h: u64 = self.l1.iter().map(|c| c.stats.hits).sum();
        let l1t: u64 = self.l1.iter().map(|c| c.stats.accesses()).sum();
        let l2h: u64 = self.l2.iter().map(|c| c.stats.hits).sum();
        let l2t: u64 = self.l2.iter().map(|c| c.stats.accesses()).sum();
        let rate = |h: u64, t: u64| if t == 0 { 0.0 } else { h as f64 / t as f64 };
        (rate(l1h, l1t), rate(l2h, l2t), self.l3.stats.misses)
    }

    pub fn llc_misses(&self) -> u64 {
        self.l3.stats.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hier() -> CacheHierarchy {
        let mut cfg = Config::paper();
        cfg.cores = 2;
        CacheHierarchy::new(&cfg)
    }

    #[test]
    fn first_access_misses_everywhere_second_hits_l1() {
        let mut h = hier();
        let a = h.access(0, 0x10000, false);
        assert!(a.llc_miss);
        assert_eq!(a.cycles, 3 + 10 + 34);
        let b = h.access(0, 0x10000, false);
        assert!(!b.llc_miss);
        assert_eq!(b.cycles, 3);
    }

    #[test]
    fn sharing_through_l3() {
        let mut h = hier();
        h.access(0, 0x20000, false); // core 0 brings line into L3
        let b = h.access(1, 0x20000, false); // core 1 misses L1/L2, hits L3
        assert!(!b.llc_miss);
        assert_eq!(b.cycles, 3 + 10 + 34);
    }

    #[test]
    fn clflush_returns_dirty_lines_and_count() {
        let mut h = hier();
        for i in 0..4u64 {
            h.access(0, 0x4000 + i * 64, true);
        }
        let (wbs, lines) = h.clflush_range(0x4000, 4096);
        assert_eq!(wbs.len(), 4, "all 4 dirty lines written back");
        assert!(lines >= 4);
        // After the flush the lines are gone from every level.
        let again = h.access(0, 0x4000, false);
        assert!(again.llc_miss);
    }

    #[test]
    fn dirty_writeback_eventually_reaches_caller() {
        // Thrash a single L1/L2/L3 set with dirty lines until a dirty
        // victim escapes the LLC.
        // Working set must exceed the 8 MB LLC (131072 lines) before dirty
        // victims can escape to memory.
        let mut h = hier();
        let mut got_wb = false;
        for i in 0..400_000u64 {
            let out = h.access(0, i * 64, true);
            if !out.writebacks.is_empty() {
                got_wb = true;
                break;
            }
        }
        assert!(got_wb, "dirty victims must eventually reach memory");
    }

    #[test]
    fn wbbuf_holds_inline_victims() {
        let mut b = WbBuf::default();
        assert!(b.is_empty());
        for i in 0..3u64 {
            b.push(Writeback { addr: i * 64 });
        }
        assert_eq!(b.len(), 3);
        let addrs: Vec<u64> = b.as_slice().iter().map(|w| w.addr).collect();
        assert_eq!(addrs, vec![0, 64, 128]);
    }

    #[test]
    fn heavy_dirty_traffic_never_overflows_wbbuf() {
        // The inline buffer's bound (3 victims per access) must hold under
        // sustained dirty thrashing; push() asserts on overflow.
        let mut cfg = Config::scaled(8);
        cfg.cores = 1;
        let mut h = CacheHierarchy::new(&cfg);
        for i in 0..200_000u64 {
            let out = h.access(0, (i % 100_000) * 64, true);
            assert!(out.writebacks.len() <= 3);
        }
    }

    #[test]
    fn llc_miss_counter_advances() {
        let mut h = hier();
        let before = h.llc_misses();
        h.access(0, 0x999000, false);
        assert_eq!(h.llc_misses(), before + 1);
    }
}
