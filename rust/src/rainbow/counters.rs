//! Two-stage memory access counting (paper §III-B, Fig. 3/4).
//!
//! Stage 1: a 2-byte counter per NVM superpage, updated by the memory
//! controller on every (LLC-filtered) NVM reference. Stage 2: for the
//! top-N superpages selected at the previous interval boundary, per-4 KB
//! counters (15-bit value + 1-bit overflow, Fig. 4) in a small table of
//! `4B PSN + 512 x 2B` entries.
//!
//! Reads and writes are tracked separately so the write weighting
//! (§III-B: "NVM write operations have a higher weighting") and the
//! Eq.-1 utility model both get their inputs.

use crate::config::PAGES_PER_SP;

/// 15-bit saturating counter with overflow flag (Fig. 4).
pub const COUNTER_MAX: u16 = 0x7FFF;

#[derive(Clone, Debug)]
pub struct TwoStageCounters {
    /// Stage-1 superpage counters (reads / writes), one pair per NVM
    /// superpage.
    sp_reads: Vec<u16>,
    sp_writes: Vec<u16>,
    /// Stage-2 table: monitored superpage -> slot.
    slots: std::collections::HashMap<u32, u32>,
    /// Slot payloads: top_n x 512 small-page read/write counters.
    pg_reads: Vec<u16>,
    pg_writes: Vec<u16>,
    top_n: usize,
    /// Which superpage each slot monitors (u32::MAX = empty).
    slot_owner: Vec<u32>,
}

impl TwoStageCounters {
    pub fn new(n_superpages: usize, top_n: usize) -> TwoStageCounters {
        TwoStageCounters {
            sp_reads: vec![0; n_superpages],
            sp_writes: vec![0; n_superpages],
            slots: std::collections::HashMap::with_capacity(top_n),
            pg_reads: vec![0; top_n * PAGES_PER_SP as usize],
            pg_writes: vec![0; top_n * PAGES_PER_SP as usize],
            top_n,
            slot_owner: vec![u32::MAX; top_n],
        }
    }

    pub fn n_superpages(&self) -> usize {
        self.sp_reads.len()
    }

    pub fn top_n(&self) -> usize {
        self.top_n
    }

    /// Record one NVM reference (memory-controller hook). `sp` is the NVM
    /// superpage index, `page` the 4 KB index within it.
    #[inline]
    pub fn record(&mut self, sp: u32, page: u16, is_write: bool) {
        let spi = sp as usize;
        if is_write {
            self.sp_writes[spi] = sat(self.sp_writes[spi]);
        } else {
            self.sp_reads[spi] = sat(self.sp_reads[spi]);
        }
        // Stage 2: only for monitored superpages.
        if let Some(&slot) = self.slots.get(&sp) {
            let idx = slot as usize * PAGES_PER_SP as usize + page as usize;
            if is_write {
                self.pg_writes[idx] = sat(self.pg_writes[idx]);
            } else {
                self.pg_reads[idx] = sat(self.pg_reads[idx]);
            }
        }
    }

    /// Stage-1 snapshot for the hot-page identifier (flat arrays).
    pub fn sp_counts(&self) -> (&[u16], &[u16]) {
        (&self.sp_reads, &self.sp_writes)
    }

    /// Stage-2 counters of the monitored superpage in `slot`.
    pub fn slot_counts(&self, slot: usize) -> (&[u16], &[u16]) {
        let a = slot * PAGES_PER_SP as usize;
        let b = a + PAGES_PER_SP as usize;
        (&self.pg_reads[a..b], &self.pg_writes[a..b])
    }

    /// Superpage monitored by `slot` (None if empty).
    pub fn slot_owner(&self, slot: usize) -> Option<u32> {
        let o = self.slot_owner[slot];
        (o != u32::MAX).then_some(o)
    }

    pub fn monitored(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.slots.iter().map(|(&sp, &slot)| (sp, slot))
    }

    /// Interval boundary: adopt the new top-N monitored set and clear all
    /// counters (history-based policy — the new set is monitored at fine
    /// grain during the *next* interval). Duplicate superpage numbers in
    /// `new_top` occupy a single slot: each slot must own a distinct PSN
    /// or `record` would split one superpage's traffic across slots.
    pub fn rotate(&mut self, new_top: &[u32]) {
        self.sp_reads.fill(0);
        self.sp_writes.fill(0);
        self.pg_reads.fill(0);
        self.pg_writes.fill(0);
        self.slots.clear();
        self.slot_owner.fill(u32::MAX);
        let mut slot = 0usize;
        for &sp in new_top {
            if slot >= self.top_n {
                break;
            }
            if self.slots.contains_key(&sp) {
                continue;
            }
            self.slots.insert(sp, slot as u32);
            self.slot_owner[slot] = sp;
            slot += 1;
        }
    }

    /// SRAM footprint of the whole structure in bytes (Table VI model):
    /// 2 B/superpage stage-1 counters + per-slot (4 B PSN + 512 x 2 B).
    pub fn sram_bytes(&self) -> u64 {
        // Reads and writes share the 2-byte budget in hardware (weighted
        // single counter); we model split counters but report the paper's
        // hardware budget.
        self.sp_reads.len() as u64 * 2
            + self.top_n as u64 * (4 + PAGES_PER_SP * 2)
    }
}

#[inline]
fn sat(x: u16) -> u16 {
    // Saturate at 15 bits; the MSB is the overflow flag which stays set.
    if x >= COUNTER_MAX {
        COUNTER_MAX | 0x8000
    } else {
        x + 1
    }
}

/// Strip the overflow flag for arithmetic use.
#[inline]
pub fn count_value(x: u16) -> u16 {
    x & COUNTER_MAX
}

/// Overflow flag (the superpage is "definitely hot", §III-B).
#[inline]
pub fn overflowed(x: u16) -> bool {
    x & 0x8000 != 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage1_counts_all_stage2_only_monitored() {
        let mut c = TwoStageCounters::new(64, 4);
        c.record(7, 3, false);
        c.record(9, 5, true);
        let (r, w) = c.sp_counts();
        assert_eq!(r[7], 1);
        assert_eq!(w[9], 1);
        // Nothing monitored yet: stage-2 empty.
        assert_eq!(c.slot_counts(0).0.iter().sum::<u16>(), 0);

        c.rotate(&[7, 9]);
        c.record(7, 3, false);
        c.record(9, 5, true);
        assert_eq!(c.slot_counts(0).0[3], 1); // slot 0 = sp 7, page 3 read
        assert_eq!(c.slot_counts(1).1[5], 1); // slot 1 = sp 9, page 5 write
        assert_eq!(c.slot_owner(0), Some(7));
        assert_eq!(c.slot_owner(2), None);
    }

    #[test]
    fn rotate_clears_history() {
        let mut c = TwoStageCounters::new(16, 2);
        c.rotate(&[1]);
        for _ in 0..100 {
            c.record(1, 0, false);
        }
        assert_eq!(c.slot_counts(0).0[0], 100);
        c.rotate(&[1]);
        assert_eq!(c.sp_counts().0[1], 0);
        assert_eq!(c.slot_counts(0).0[0], 0);
    }

    #[test]
    fn saturation_sets_overflow_and_holds() {
        let mut c = TwoStageCounters::new(4, 1);
        for _ in 0..40_000 {
            c.record(0, 0, false);
        }
        let x = c.sp_counts().0[0];
        assert!(overflowed(x), "overflow flag must be set");
        assert_eq!(count_value(x), COUNTER_MAX);
    }

    #[test]
    fn table6_storage_model() {
        // 1 TB PCM = 512 Ki superpages, N = 100:
        // 1 MB stage-1 + 100 * 1028 B stage-2 ≈ 1.098 MB.
        let c = TwoStageCounters::new(512 * 1024, 100);
        let bytes = c.sram_bytes();
        assert_eq!(bytes, 512 * 1024 * 2 + 100 * 1028);
        assert!((bytes as f64 / (1 << 20) as f64) < 1.2);
    }

    #[test]
    fn rotate_truncates_to_top_n() {
        let mut c = TwoStageCounters::new(16, 2);
        c.rotate(&[3, 5, 7, 9]); // only 2 slots exist
        assert_eq!(c.monitored().count(), 2);
    }

    #[test]
    fn rotate_dedupes_duplicate_superpages() {
        let mut c = TwoStageCounters::new(16, 2);
        // A duplicated PSN must not burn a second slot (or leave a slot
        // whose owner is shadowed in the sp->slot map).
        c.rotate(&[5, 5, 7]);
        assert_eq!(c.slot_owner(0), Some(5));
        assert_eq!(c.slot_owner(1), Some(7));
        assert_eq!(c.monitored().count(), 2);
        c.record(5, 3, false);
        assert_eq!(c.slot_counts(0).0[3], 1, "traffic lands in sp 5's slot");
        assert_eq!(c.slot_counts(1).0[3], 0);
    }

    #[test]
    fn rotate_empty_clears_ownership() {
        let mut c = TwoStageCounters::new(8, 2);
        c.rotate(&[1, 2]);
        c.record(1, 0, false);
        c.rotate(&[]);
        assert_eq!(c.monitored().count(), 0);
        assert_eq!(c.slot_owner(0), None);
        assert_eq!(c.slot_owner(1), None);
        // Records to a previously-monitored superpage now stay stage-1.
        c.record(1, 0, false);
        assert_eq!(c.sp_counts().0[1], 1);
        assert_eq!(c.slot_counts(0).0[0], 0);
    }

    #[test]
    fn stage2_counters_saturate_like_stage1() {
        let mut c = TwoStageCounters::new(4, 1);
        c.rotate(&[2]);
        for _ in 0..40_000 {
            c.record(2, 511, true); // last page of the superpage
        }
        let w = c.slot_counts(0).1[511];
        assert!(overflowed(w), "stage-2 overflow flag must be set");
        assert_eq!(count_value(w), COUNTER_MAX);
        // Stage-1 saturated in lockstep.
        let sw = c.sp_counts().1[2];
        assert!(overflowed(sw));
        assert_eq!(count_value(sw), COUNTER_MAX);
    }

    #[test]
    fn record_boundary_indices() {
        // Last superpage and both extreme page indices must hit their own
        // slots (off-by-one in the slot*512+page math would alias).
        let mut c = TwoStageCounters::new(8, 2);
        c.rotate(&[7, 0]);
        c.record(7, 0, false);
        c.record(7, 511, false);
        c.record(0, 511, true);
        assert_eq!(c.slot_counts(0).0[0], 1);
        assert_eq!(c.slot_counts(0).0[511], 1);
        assert_eq!(c.slot_counts(1).1[511], 1);
        assert_eq!(c.slot_counts(1).0[511], 0);
        assert_eq!(c.sp_counts().0[7], 2);
    }

    #[test]
    fn zero_top_n_monitors_nothing() {
        let mut c = TwoStageCounters::new(8, 0);
        c.rotate(&[1, 2, 3]);
        assert_eq!(c.monitored().count(), 0);
        c.record(1, 0, false); // must not index an empty stage-2 table
        assert_eq!(c.sp_counts().0[1], 1);
    }
}
