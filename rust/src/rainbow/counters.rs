//! Two-stage memory access counting (paper §III-B, Fig. 3/4).
//!
//! Stage 1: a 2-byte counter per NVM superpage, updated by the memory
//! controller on every (LLC-filtered) NVM reference. Stage 2: for the
//! top-N superpages selected at the previous interval boundary, per-4 KB
//! counters (15-bit value + 1-bit overflow, Fig. 4) in a small table of
//! `4B PSN + 512 x 2B` entries.
//!
//! Reads and writes are tracked separately so the write weighting
//! (§III-B: "NVM write operations have a higher weighting") and the
//! Eq.-1 utility model both get their inputs.
//!
//! The stage-2 sp -> slot association is consulted on *every* counted NVM
//! reference, so it is a direct-mapped `Vec<u32>` indexed by superpage
//! (sentinel `u32::MAX` = unmonitored) rather than a HashMap — the same
//! flattening as `remap::RemapTable`. A property test below pins it to a
//! HashMap model.

use crate::config::PAGES_PER_SP;

/// 15-bit saturating counter with overflow flag (Fig. 4).
pub const COUNTER_MAX: u16 = 0x7FFF;

/// In-band overflow flag bit (Fig. 4's 16th bit). Raw counter words carry
/// it; arithmetic consumers must go through [`count_value`].
pub const OVERFLOW_FLAG: u16 = 0x8000;

/// Sentinel in the direct-mapped sp -> slot array.
const NO_SLOT: u32 = u32::MAX;

#[derive(Clone, Debug)]
pub struct TwoStageCounters {
    /// Stage-1 superpage counters (reads / writes), one pair per NVM
    /// superpage.
    sp_reads: Vec<u16>,
    sp_writes: Vec<u16>,
    /// Stage-2 association: superpage index -> slot (direct-mapped,
    /// NO_SLOT = unmonitored). Hot-path lookup on every NVM reference.
    sp_slot: Vec<u32>,
    /// Slot payloads: top_n x 512 small-page read/write counters.
    pg_reads: Vec<u16>,
    pg_writes: Vec<u16>,
    top_n: usize,
    /// Which superpage each slot monitors (u32::MAX = empty).
    slot_owner: Vec<u32>,
}

impl TwoStageCounters {
    pub fn new(n_superpages: usize, top_n: usize) -> TwoStageCounters {
        TwoStageCounters {
            sp_reads: vec![0; n_superpages],
            sp_writes: vec![0; n_superpages],
            sp_slot: vec![NO_SLOT; n_superpages],
            pg_reads: vec![0; top_n * PAGES_PER_SP as usize],
            pg_writes: vec![0; top_n * PAGES_PER_SP as usize],
            top_n,
            slot_owner: vec![u32::MAX; top_n],
        }
    }

    pub fn n_superpages(&self) -> usize {
        self.sp_reads.len()
    }

    pub fn top_n(&self) -> usize {
        self.top_n
    }

    /// Record one NVM reference (memory-controller hook). `sp` is the NVM
    /// superpage index, `page` the 4 KB index within it.
    #[inline]
    pub fn record(&mut self, sp: u32, page: u16, is_write: bool) {
        let spi = sp as usize;
        if is_write {
            self.sp_writes[spi] = sat(self.sp_writes[spi]);
        } else {
            self.sp_reads[spi] = sat(self.sp_reads[spi]);
        }
        // Stage 2: only for monitored superpages (one indexed load).
        let slot = self.sp_slot[spi];
        if slot != NO_SLOT {
            let idx = slot as usize * PAGES_PER_SP as usize + page as usize;
            if is_write {
                self.pg_writes[idx] = sat(self.pg_writes[idx]);
            } else {
                self.pg_reads[idx] = sat(self.pg_reads[idx]);
            }
        }
    }

    /// Stage-1 snapshot for the hot-page identifier (flat arrays).
    pub fn sp_counts(&self) -> (&[u16], &[u16]) {
        (&self.sp_reads, &self.sp_writes)
    }

    /// Stage-2 counters of the monitored superpage in `slot`.
    pub fn slot_counts(&self, slot: usize) -> (&[u16], &[u16]) {
        let a = slot * PAGES_PER_SP as usize;
        let b = a + PAGES_PER_SP as usize;
        (&self.pg_reads[a..b], &self.pg_writes[a..b])
    }

    /// Superpage monitored by `slot` (None if empty).
    pub fn slot_owner(&self, slot: usize) -> Option<u32> {
        let o = self.slot_owner[slot];
        (o != u32::MAX).then_some(o)
    }

    /// Monitored (superpage, slot) pairs in slot order (deterministic).
    pub fn monitored(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.slot_owner.iter().enumerate().filter_map(|(slot, &sp)| {
            (sp != u32::MAX).then_some((sp, slot as u32))
        })
    }

    /// True if any stage-1 or monitored stage-2 counter of `sp` has hit
    /// its 15-bit ceiling this interval ("definitely hot", §III-B).
    pub fn sp_overflowed(&self, sp: u32) -> bool {
        let spi = sp as usize;
        if overflowed(self.sp_reads[spi]) || overflowed(self.sp_writes[spi]) {
            return true;
        }
        let slot = self.sp_slot[spi];
        if slot != NO_SLOT {
            let (r, w) = self.slot_counts(slot as usize);
            return r.iter().chain(w).any(|&x| overflowed(x));
        }
        false
    }

    /// Number of superpages whose stage-1 counters overflowed this
    /// interval — an explicit signal instead of the in-band flag bit.
    pub fn overflow_count(&self) -> usize {
        self.sp_reads
            .iter()
            .zip(&self.sp_writes)
            .filter(|&(&r, &w)| overflowed(r) || overflowed(w))
            .count()
    }

    /// Interval boundary: adopt the new top-N monitored set and clear all
    /// counters (history-based policy — the new set is monitored at fine
    /// grain during the *next* interval). Duplicate superpage numbers in
    /// `new_top` occupy a single slot: each slot must own a distinct PSN
    /// or `record` would split one superpage's traffic across slots.
    pub fn rotate(&mut self, new_top: &[u32]) {
        self.sp_reads.fill(0);
        self.sp_writes.fill(0);
        self.pg_reads.fill(0);
        self.pg_writes.fill(0);
        // Clear only the O(top_n) populated sp_slot entries.
        for &sp in &self.slot_owner {
            if sp != u32::MAX {
                self.sp_slot[sp as usize] = NO_SLOT;
            }
        }
        self.slot_owner.fill(u32::MAX);
        let mut slot = 0usize;
        for &sp in new_top {
            if slot >= self.top_n {
                break;
            }
            assert!((sp as usize) < self.sp_slot.len(),
                    "rotate: superpage {sp} out of range");
            if self.sp_slot[sp as usize] != NO_SLOT {
                continue;
            }
            self.sp_slot[sp as usize] = slot as u32;
            self.slot_owner[slot] = sp;
            slot += 1;
        }
    }

    /// SRAM footprint of the whole structure in bytes (Table VI model):
    /// 2 B/superpage stage-1 counters + per-slot (4 B PSN + 512 x 2 B).
    pub fn sram_bytes(&self) -> u64 {
        // Reads and writes share the 2-byte budget in hardware (weighted
        // single counter); we model split counters but report the paper's
        // hardware budget.
        self.sp_reads.len() as u64 * 2
            + self.top_n as u64 * (4 + PAGES_PER_SP * 2)
    }
}

#[inline]
fn sat(x: u16) -> u16 {
    // Saturate at 15 bits; the MSB is the overflow flag which stays set.
    if x >= COUNTER_MAX {
        COUNTER_MAX | OVERFLOW_FLAG
    } else {
        x + 1
    }
}

/// Strip the overflow flag for arithmetic use.
#[inline]
pub fn count_value(x: u16) -> u16 {
    x & COUNTER_MAX
}

/// Overflow flag (the superpage is "definitely hot", §III-B).
#[inline]
pub fn overflowed(x: u16) -> bool {
    x & OVERFLOW_FLAG != 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_shrink, shrink_vec};
    use std::collections::HashMap;

    #[test]
    fn stage1_counts_all_stage2_only_monitored() {
        let mut c = TwoStageCounters::new(64, 4);
        c.record(7, 3, false);
        c.record(9, 5, true);
        let (r, w) = c.sp_counts();
        assert_eq!(r[7], 1);
        assert_eq!(w[9], 1);
        // Nothing monitored yet: stage-2 empty.
        assert_eq!(c.slot_counts(0).0.iter().sum::<u16>(), 0);

        c.rotate(&[7, 9]);
        c.record(7, 3, false);
        c.record(9, 5, true);
        assert_eq!(c.slot_counts(0).0[3], 1); // slot 0 = sp 7, page 3 read
        assert_eq!(c.slot_counts(1).1[5], 1); // slot 1 = sp 9, page 5 write
        assert_eq!(c.slot_owner(0), Some(7));
        assert_eq!(c.slot_owner(2), None);
    }

    #[test]
    fn rotate_clears_history() {
        let mut c = TwoStageCounters::new(16, 2);
        c.rotate(&[1]);
        for _ in 0..100 {
            c.record(1, 0, false);
        }
        assert_eq!(c.slot_counts(0).0[0], 100);
        c.rotate(&[1]);
        assert_eq!(c.sp_counts().0[1], 0);
        assert_eq!(c.slot_counts(0).0[0], 0);
    }

    #[test]
    fn saturation_sets_overflow_and_holds() {
        let mut c = TwoStageCounters::new(4, 1);
        for _ in 0..40_000 {
            c.record(0, 0, false);
        }
        let x = c.sp_counts().0[0];
        assert!(overflowed(x), "overflow flag must be set");
        assert_eq!(count_value(x), COUNTER_MAX);
    }

    #[test]
    fn overflow_surfaced_as_signal() {
        let mut c = TwoStageCounters::new(8, 2);
        c.rotate(&[3]);
        assert_eq!(c.overflow_count(), 0);
        assert!(!c.sp_overflowed(3));
        for _ in 0..(COUNTER_MAX as u32 + 5) {
            c.record(3, 1, true);
        }
        assert!(c.sp_overflowed(3), "stage-1/2 overflow must be visible");
        assert!(!c.sp_overflowed(4));
        assert_eq!(c.overflow_count(), 1);
        c.rotate(&[3]);
        assert_eq!(c.overflow_count(), 0, "rotate clears overflow state");
    }

    #[test]
    fn table6_storage_model() {
        // 1 TB PCM = 512 Ki superpages, N = 100:
        // 1 MB stage-1 + 100 * 1028 B stage-2 ≈ 1.098 MB.
        let c = TwoStageCounters::new(512 * 1024, 100);
        let bytes = c.sram_bytes();
        assert_eq!(bytes, 512 * 1024 * 2 + 100 * 1028);
        assert!((bytes as f64 / (1 << 20) as f64) < 1.2);
    }

    #[test]
    fn rotate_truncates_to_top_n() {
        let mut c = TwoStageCounters::new(16, 2);
        c.rotate(&[3, 5, 7, 9]); // only 2 slots exist
        assert_eq!(c.monitored().count(), 2);
    }

    #[test]
    fn rotate_dedupes_duplicate_superpages() {
        let mut c = TwoStageCounters::new(16, 2);
        // A duplicated PSN must not burn a second slot (or leave a slot
        // whose owner is shadowed in the sp->slot map).
        c.rotate(&[5, 5, 7]);
        assert_eq!(c.slot_owner(0), Some(5));
        assert_eq!(c.slot_owner(1), Some(7));
        assert_eq!(c.monitored().count(), 2);
        c.record(5, 3, false);
        assert_eq!(c.slot_counts(0).0[3], 1, "traffic lands in sp 5's slot");
        assert_eq!(c.slot_counts(1).0[3], 0);
    }

    #[test]
    fn rotate_empty_clears_ownership() {
        let mut c = TwoStageCounters::new(8, 2);
        c.rotate(&[1, 2]);
        c.record(1, 0, false);
        c.rotate(&[]);
        assert_eq!(c.monitored().count(), 0);
        assert_eq!(c.slot_owner(0), None);
        assert_eq!(c.slot_owner(1), None);
        // Records to a previously-monitored superpage now stay stage-1.
        c.record(1, 0, false);
        assert_eq!(c.sp_counts().0[1], 1);
        assert_eq!(c.slot_counts(0).0[0], 0);
    }

    #[test]
    fn stage2_counters_saturate_like_stage1() {
        let mut c = TwoStageCounters::new(4, 1);
        c.rotate(&[2]);
        for _ in 0..40_000 {
            c.record(2, 511, true); // last page of the superpage
        }
        let w = c.slot_counts(0).1[511];
        assert!(overflowed(w), "stage-2 overflow flag must be set");
        assert_eq!(count_value(w), COUNTER_MAX);
        // Stage-1 saturated in lockstep.
        let sw = c.sp_counts().1[2];
        assert!(overflowed(sw));
        assert_eq!(count_value(sw), COUNTER_MAX);
    }

    #[test]
    fn record_boundary_indices() {
        // Last superpage and both extreme page indices must hit their own
        // slots (off-by-one in the slot*512+page math would alias).
        let mut c = TwoStageCounters::new(8, 2);
        c.rotate(&[7, 0]);
        c.record(7, 0, false);
        c.record(7, 511, false);
        c.record(0, 511, true);
        assert_eq!(c.slot_counts(0).0[0], 1);
        assert_eq!(c.slot_counts(0).0[511], 1);
        assert_eq!(c.slot_counts(1).1[511], 1);
        assert_eq!(c.slot_counts(1).0[511], 0);
        assert_eq!(c.sp_counts().0[7], 2);
    }

    #[test]
    fn zero_top_n_monitors_nothing() {
        let mut c = TwoStageCounters::new(8, 0);
        c.rotate(&[1, 2, 3]);
        assert_eq!(c.monitored().count(), 0);
        c.record(1, 0, false); // must not index an empty stage-2 table
        assert_eq!(c.sp_counts().0[1], 1);
    }

    /// Property: the direct-mapped sp -> slot array agrees with a HashMap
    /// model across arbitrary rotate/record sequences — same monitored
    /// set, same slot assignment, same per-slot counts.
    #[test]
    fn prop_slot_assoc_matches_hashmap_model() {
        const N_SP: u64 = 24;
        const TOP_N: usize = 4;
        // Op: rotate with a fresh top list (kind 0) or record (kind 1+).
        type Op = (u8, Vec<u32>, u32, u16, bool);
        let mut gen = |r: &mut crate::util::rng::Rng| {
            (0..r.below(60))
                .map(|_| {
                    let kind = r.below(5) as u8;
                    let top: Vec<u32> = (0..r.below(8))
                        .map(|_| r.below(N_SP) as u32)
                        .collect();
                    (kind, top, r.below(N_SP) as u32,
                     r.below(PAGES_PER_SP) as u16, r.chance(0.4))
                })
                .collect::<Vec<Op>>()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut c = TwoStageCounters::new(N_SP as usize, TOP_N);
            let mut model: HashMap<u32, u32> = HashMap::new();
            let mut model_pg: HashMap<(u32, u16), u32> = HashMap::new();
            for (kind, top, sp, page, is_write) in ops {
                if *kind == 0 {
                    c.rotate(top);
                    model.clear();
                    model_pg.clear();
                    let mut slot = 0u32;
                    for &s in top {
                        if slot as usize >= TOP_N {
                            break;
                        }
                        if model.contains_key(&s) {
                            continue;
                        }
                        model.insert(s, slot);
                        slot += 1;
                    }
                } else {
                    c.record(*sp, *page, *is_write);
                    if model.contains_key(sp) {
                        *model_pg.entry((*sp, *page)).or_insert(0) += 1;
                    }
                }
                // Monitored sets must agree exactly.
                let got: HashMap<u32, u32> = c.monitored().collect();
                if got != model {
                    return Err(format!("monitored {got:?} != {model:?}"));
                }
            }
            for (&(sp, page), &n) in &model_pg {
                let slot = model[&sp] as usize;
                let (r, w) = c.slot_counts(slot);
                let total = count_value(r[page as usize]) as u32
                    + count_value(w[page as usize]) as u32;
                if total != n.min(COUNTER_MAX as u32) {
                    return Err(format!(
                        "sp {sp} page {page}: count {total} != {n}"));
                }
            }
            Ok(())
        };
        forall_shrink("counters-slot-model", 0xC0417, 60, &mut gen,
                      shrink_vec, &mut prop);
    }
}
