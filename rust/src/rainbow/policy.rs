//! The Rainbow policy (§III): NVM managed in 2 MB superpages, DRAM as a
//! 4 KB hot-page cache, split TLBs consulted in parallel, the migration
//! bitmap + bitmap cache, NVM→DRAM address remapping, and two-stage
//! access counting feeding the utility migration model.
//!
//! Key properties implemented exactly as the paper argues:
//! * NVM→DRAM migration never splinters a superpage and never invalidates
//!   a superpage TLB entry (no shootdown on the migrate-in path).
//! * The 4 KB TLB entry for a migrated page is built lazily on first
//!   access through the superpage path (bitmap hit → 8-byte pointer read).
//! * DRAM→NVM eviction shoots down the 4 KB entry only; clean evictions
//!   write back just the 8-byte pointer area.
//! * Counting is memory-controller level (LLC-filtered), superpage-
//!   granular in stage 1 and 4 KB-granular for the monitored top-N.

use std::path::PathBuf;

use crate::config::{Config, PAGES_PER_SP, PAGE_SHIFT, PAGE_SIZE, SP_SHIFT,
                    SP_SIZE};
use crate::os::{AddressSpace, DramMgr, Reclaim, Region};
use crate::policies::flat_static::TABLE_RESERVE;
use crate::policies::Policy;
use crate::runtime::HotPageIdentifier;
use crate::sim::machine::{Machine, TableHome};
use crate::telemetry::EventKind;
use crate::tlb::{shootdown_4k, ShootdownStats};

use super::bitmap::{BitmapCache, MigrationBitmap};
use super::counters::TwoStageCounters;
use super::migration::{ThresholdCtl, UtilityParams};
use super::remap::RemapTable;

/// Sentinel in [`Rainbow::sp_rev`]: superpage never allocated.
const NO_SVPN: u64 = u64::MAX;

pub struct Rainbow {
    m: Machine,
    /// Virtual 2 MB mapping into NVM.
    aspace: AddressSpace,
    nvm: Region,
    /// DRAM 4 KB frame manager (free/clean/dirty lists).
    dram: DramMgr,
    /// NVM superpage index -> virtual superpage number (for shootdowns).
    /// Flat array indexed by superpage, [`NO_SVPN`] = not yet touched —
    /// the eviction path reads it, so no HashMap here.
    sp_rev: Vec<u64>,
    counters: TwoStageCounters,
    bitmap: MigrationBitmap,
    bitmap_cache: BitmapCache,
    remap: RemapTable,
    identifier: HotPageIdentifier,
    params: UtilityParams,
    threshold: ThresholdCtl,
    sd_stats: ShootdownStats,
    nvm_base: u64,
}

impl Rainbow {
    /// `accel`: use the PJRT AOT artifacts for hot-page identification
    /// (falls back to the bit-exact native pipeline if unavailable).
    pub fn new(cfg: &Config, accel: bool) -> Rainbow {
        let m = Machine::new(cfg, TableHome::Dram, TableHome::Nvm);
        let nvm_base = m.mem.nvm_base();
        let n_sp = ((cfg.nvm.size - TABLE_RESERVE) / SP_SIZE) as usize;
        let n_frames = ((cfg.dram.size - TABLE_RESERVE) / PAGE_SIZE) as usize;
        let params = UtilityParams::from_config(cfg);
        let identifier = if accel {
            HotPageIdentifier::auto(&PathBuf::from(
                crate::runtime::PjrtRuntime::default_dir()))
        } else {
            HotPageIdentifier::native()
        };
        Rainbow {
            nvm: Region::new(nvm_base, cfg.nvm.size - TABLE_RESERVE),
            dram: DramMgr::new((cfg.dram.size - TABLE_RESERVE) / PAGE_SIZE),
            aspace: AddressSpace::new(),
            sp_rev: vec![NO_SVPN; n_sp],
            counters: TwoStageCounters::new(n_sp, cfg.top_n),
            bitmap: MigrationBitmap::new(n_sp),
            bitmap_cache: BitmapCache::new(cfg.bitmap_cache_entries,
                                           cfg.bitmap_cache_assoc,
                                           cfg.bitmap_cache_latency),
            // Pre-sized flat arrays: the lookup sits on every
            // superpage-TLB hit with a set bitmap bit (hot path).
            remap: RemapTable::with_capacity(n_sp * PAGES_PER_SP as usize,
                                             n_frames),
            identifier,
            threshold: ThresholdCtl::new(params.threshold),
            params,
            m,
            sd_stats: ShootdownStats::default(),
            nvm_base,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.identifier.backend_name()
    }

    /// NVM superpage index of a flat NVM physical address.
    #[inline]
    fn sp_index(&self, nvm_paddr: u64) -> u32 {
        ((nvm_paddr - self.nvm_base) >> SP_SHIFT) as u32
    }

    /// First-touch superpage allocation in NVM.
    fn ensure_sp(&mut self, vaddr: u64) -> u64 {
        if let Some(pa) = self.aspace.resolve_2m(vaddr) {
            return pa & !(SP_SIZE - 1);
        }
        let base = self
            .aspace
            .ensure_2m(vaddr, &mut self.nvm)
            .expect("rainbow: NVM exhausted");
        self.sp_rev[self.sp_index(base) as usize] = vaddr >> SP_SHIFT;
        base
    }

    /// Bitmap consultation for an NVM-translated access (§III-D/E).
    /// Returns (migrated?, cycles).
    fn check_bitmap(&mut self, sp: u32, page: u16, now: u64) -> (bool, u64) {
        let mut cycles = self.bitmap_cache.latency;
        if !self.bitmap_cache.touch(sp) {
            // Miss: fetch the 64 B bitmap line from main memory (it lives
            // in the NVM's reserved table area) — one flat NVM reference.
            let addr = self.m.sp_walker.cfg.table_base
                + (sp as u64 * 64) % (self.m.sp_walker.cfg.table_len - 64);
            let r = self.m.mem.table_ref(addr, 64);
            cycles += r.latency;
            self.m.metrics.bitmap_misses += 1;
        } else {
            self.m.metrics.bitmap_hits += 1;
        }
        self.m.metrics.xlat.bitmap_cycles += cycles;
        (self.bitmap.get(sp, page), cycles)
    }

    /// Follow the in-page remap pointer (8-byte NVM read) and install the
    /// 4 KB TLB entry (§III-E case 3, path ②).
    fn remap_read(&mut self, core: usize, vaddr: u64, nvm_page_addr: u64,
                  _now: u64) -> (u64, u64) {
        // One NVM reference at t_nr (§III-E's analytic cost).
        let r = self.m.mem.table_ref(nvm_page_addr, 8);
        self.m.metrics.xlat.remap_cycles += r.latency;
        self.m.metrics.remap_reads += 1;
        self.m.metrics.tlb_miss_cycles += r.latency;
        let nvm_page = (nvm_page_addr - self.nvm_base) >> PAGE_SHIFT;
        let frame = self.remap.lookup(nvm_page)
            .expect("bitmap set but no remap entry");
        let dram_pa = frame << PAGE_SHIFT;
        self.m.tlbs[core].insert_4k(vaddr >> PAGE_SHIFT,
                                    dram_pa >> PAGE_SHIFT);
        (dram_pa | (vaddr & (PAGE_SIZE - 1)), r.latency)
    }

    /// Evict the DRAM frame (returns cycles). Clean pages write back only
    /// the 8-byte pointer area; dirty pages copy the full 4 KB.
    fn evict_frame(&mut self, frame: u64, dirty: bool, now: u64) -> u64 {
        let nvm_page = self.remap.owner_of_frame(frame)
            .expect("evicting frame with no remap owner");
        let nvm_addr = self.nvm_base + (nvm_page << PAGE_SHIFT);
        let sp = self.sp_index(nvm_addr);
        let page_in_sp = (nvm_page % PAGES_PER_SP) as u16;
        let dram_pa = frame << PAGE_SHIFT;
        let mut cycles = 0;

        let (wbs, lines) = self.m.caches.clflush_range(dram_pa, PAGE_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        if dirty {
            // Background DMA + the Eq.-2 constant T_writeback.
            self.m.mem.migrate(now, dram_pa, nvm_addr, PAGE_SIZE,
                               &mut self.m.tel);
            cycles += self.m.cfg.t_writeback_4k;
            self.m.metrics.writeback_bytes += PAGE_SIZE;
        } else {
            // Restore the 8 bytes the remap pointer overwrote.
            let r = self.m.mem.access(now, nvm_addr, true, 8);
            cycles += r.latency;
            self.m.metrics.writeback_bytes += 8;
        }
        self.m.metrics.writebacks += 1;
        self.bitmap.set(sp, page_in_sp, false);
        self.remap.remove(nvm_page);
        // Shoot down the 4 KB translation (the only shootdown Rainbow
        // ever performs, §III-F).
        let svpn = self.sp_rev[sp as usize];
        if svpn != NO_SVPN {
            let vpn = svpn * PAGES_PER_SP + page_in_sp as u64;
            let sd = shootdown_4k(&self.m.cfg, &mut self.m.tlbs, vpn,
                                  &mut self.sd_stats, &mut self.m.tel, now);
            cycles += sd;
            self.m.metrics.rt.shootdown_cycles += sd;
            self.m.metrics.shootdowns += 1;
        }
        self.dram.release(frame);
        cycles
    }

    /// Migrate one hot NVM page into DRAM (§III-C/E). No superpage
    /// shootdown; the remap pointer + bitmap make it transparent.
    fn migrate_in(&mut self, sp: u32, page_in_sp: u16, now: u64) -> u64 {
        let nvm_page = sp as u64 * PAGES_PER_SP + page_in_sp as u64;
        debug_assert!(!self.bitmap.get(sp, page_in_sp));
        let nvm_addr = self.nvm_base + (nvm_page << PAGE_SHIFT);
        let mut cycles = 0;

        let grant = self.dram.take(nvm_page);
        match grant.reclaim {
            Reclaim::Free => {}
            Reclaim::Clean { .. } => {
                cycles += self.evict_frame_of(grant.frame, false, now);
            }
            Reclaim::Dirty { .. } => {
                cycles += self.evict_frame_of(grant.frame, true, now);
            }
        }
        let dram_pa = grant.frame << PAGE_SHIFT;
        // Flush any cached lines of the NVM copy (§III-F).
        let (wbs, lines) = self.m.caches.clflush_range(nvm_addr, PAGE_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        self.m.mem.migrate(now + cycles, nvm_addr, dram_pa, PAGE_SIZE,
                           &mut self.m.tel);
        // Background DMA; CPU pays the Eq.-1 constant T_mig.
        cycles += self.m.cfg.t_mig_4k;
        // Store the destination pointer in the page's original residence
        // (8-byte NVM write), set the migration bit.
        let w = self.m.mem.access(now + cycles, nvm_addr, true, 8);
        cycles += w.latency;
        self.bitmap.set(sp, page_in_sp, true);
        self.remap.insert(nvm_page, grant.frame);
        self.m.metrics.migrations += 1;
        self.m.metrics.migrated_bytes += PAGE_SIZE;
        self.m.tel.mig_hist.record(cycles);
        cycles
    }

    fn evict_frame_of(&mut self, frame: u64, dirty: bool, now: u64) -> u64 {
        // DramMgr::take already removed residency; the remap table still
        // knows the owner.
        self.evict_frame(frame, dirty, now)
    }

    /// Fraction of DRAM frames in use (exposed for ablations/benches).
    pub fn dram_utilization(&self) -> f64 {
        self.dram.utilization()
    }

    pub fn migrated_pages(&self) -> usize {
        self.remap.len()
    }
}

impl Policy for Rainbow {
    fn name(&self) -> &'static str {
        "Rainbow"
    }

    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64 {
        let look = self.m.tlbs[core].lookup(vaddr);
        let mut cycles = look.cycles();
        self.m.metrics.xlat.tlb_cycles += cycles;

        let paddr;
        let mut nvm_resident = false; // final address is in NVM
        match (look.small.ppn, look.sp.ppn) {
            // Cases 1-2: 4 KB TLB hit — the page is cached in DRAM.
            (Some(ppn), _) => {
                paddr = (ppn << PAGE_SHIFT) | (vaddr & (PAGE_SIZE - 1));
            }
            // Case 3: superpage hit only.
            (None, Some(sppn)) => {
                let sp_base = sppn << SP_SHIFT;
                let nvm_addr = sp_base | (vaddr & (SP_SIZE - 1));
                let sp = self.sp_index(sp_base);
                let page_in_sp =
                    ((vaddr >> PAGE_SHIFT) % PAGES_PER_SP) as u16;
                let (migrated, bc) = self.check_bitmap(sp, page_in_sp,
                                                       now + cycles);
                cycles += bc;
                if migrated {
                    let (pa, rc) = self.remap_read(
                        core, vaddr, nvm_addr & !(PAGE_SIZE - 1),
                        now + cycles);
                    cycles += rc;
                    paddr = pa;
                } else {
                    paddr = nvm_addr;
                    nvm_resident = true;
                }
            }
            // Case 4: both miss — superpage table walk (3 refs, NVM).
            (None, None) => {
                let walk = self.m.sp_walker.walk_2m(&mut self.m.mem,
                                                    vaddr >> SP_SHIFT,
                                                    now + cycles);
                cycles += walk;
                self.m.metrics.xlat.sptw_cycles += walk;
                self.m.metrics.tlb_miss_cycles += walk;
                self.m.tel.ptw_hist.record(walk);
                let sp_base = self.ensure_sp(vaddr);
                self.m.tlbs[core].insert_2m(vaddr >> SP_SHIFT,
                                            sp_base >> SP_SHIFT);
                let nvm_addr = sp_base | (vaddr & (SP_SIZE - 1));
                let sp = self.sp_index(sp_base);
                let page_in_sp =
                    ((vaddr >> PAGE_SHIFT) % PAGES_PER_SP) as u16;
                let (migrated, bc) = self.check_bitmap(sp, page_in_sp,
                                                       now + cycles);
                cycles += bc;
                if migrated {
                    let (pa, rc) = self.remap_read(
                        core, vaddr, nvm_addr & !(PAGE_SIZE - 1),
                        now + cycles);
                    cycles += rc;
                    paddr = pa;
                } else {
                    paddr = nvm_addr;
                    nvm_resident = true;
                }
            }
        }

        if is_write && paddr < self.m.mem.dram_size() {
            self.dram.mark_dirty(paddr >> PAGE_SHIFT);
        }
        let (dcycles, llc_miss) = self.m.data_path(core, paddr, is_write,
                                                   now + cycles);
        // Memory-controller counting: LLC-filtered NVM references only.
        if llc_miss && nvm_resident {
            let sp = self.sp_index(paddr & !(SP_SIZE - 1));
            let page_in_sp = ((paddr >> PAGE_SHIFT) % PAGES_PER_SP) as u16;
            self.counters.record(sp, page_in_sp, is_write);
        }
        cycles + dcycles
    }

    fn on_interval(&mut self, now: u64) -> u64 {
        // Software/accelerator cost of identification (DESIGN.md §5).
        let identify = self.counters.n_superpages() as u64 * 2
            + self.counters.top_n() as u64 * 64;
        self.m.metrics.rt.identify_cycles += identify;
        let mut cycles = identify;

        // Stage 2: classify the pages monitored during this interval.
        self.params.threshold = self.threshold.threshold();
        let verdicts = self.identifier.classify(&self.counters, &self.params);
        let migrated_before = self.m.metrics.migrated_bytes;
        let wb_before = self.m.metrics.writeback_bytes;
        let under_pressure_thresh = 2.0 * self.params.threshold;
        // Rate-limited, staggered DMA (see policies::migration_budget_pages).
        let budget = crate::policies::migration_budget_pages(&self.m.cfg);
        let spacing = self.m.cfg.interval_cycles / (budget + 1);
        let mut issued = 0u64;
        'outer: for v in verdicts {
            for (page, r, w) in v.hot_pages {
                if issued >= budget {
                    break 'outer;
                }
                if self.bitmap.get(v.sp, page) {
                    continue; // already cached in DRAM
                }
                if self.dram.free_count() == 0 {
                    // Eq. 2 regime: demand a clearly-hotter page.
                    let b = self.params.benefit(r as u64, w as u64);
                    if b < under_pressure_thresh {
                        continue;
                    }
                }
                cycles += self.migrate_in(v.sp, page, now + issued * spacing);
                issued += 1;
            }
        }
        self.m.metrics.rt.migration_cycles +=
            cycles.saturating_sub(identify);

        // Stage 1: choose next interval's monitored top-N, reset counters.
        let top = self.identifier.select_top(&self.counters, &self.params);
        self.m.tel.event(now + cycles, EventKind::CounterRotate,
                         top.len() as u64, 0);
        self.counters.rotate(&top);
        self.threshold.update(
            self.m.metrics.migrated_bytes - migrated_before,
            self.m.metrics.writeback_bytes - wb_before,
        );
        cycles
    }

    fn machine(&self) -> &Machine {
        &self.m
    }

    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.m
    }

    fn dram_utilization(&self) -> f64 {
        self.dram.utilization()
    }

    fn finalize(&mut self, elapsed: u64) {
        self.m.finalize(elapsed);
        // Rainbow's 4 KB-side misses never cause a walk (the superpage
        // TLB covers them); MPKI counts true walks only (§IV-B).
        self.m.metrics.tlb_miss_4k = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Rainbow {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        cfg.top_n = 16;
        // Tiny caches so unit-test traffic actually reaches the memory
        // controller (Rainbow's counting is LLC-filtered by design).
        cfg.l1_cache.size = 8 << 10;
        cfg.l2_cache.size = 16 << 10;
        cfg.l3_cache.size = 32 << 10;
        Rainbow::new(&cfg, false)
    }

    /// Drive enough hot LLC-missing writes that pages of the superpage at
    /// `vaddr` migrate: interval 1 selects the superpage (stage 1),
    /// interval 2 monitors it at 4 KB grain and migrates (stage 2).
    fn heat_and_migrate(p: &mut Rainbow, vaddr: u64) -> u64 {
        let sp_base = vaddr & !(SP_SIZE - 1);
        let mut now = 0;
        for round in 0..3 {
            // 64 pages x 8 lines = 512 lines/sweep > the 32 KB LLC, so
            // sweeps keep missing; 20 sweeps = 160 writes per page.
            for sweep in 0..20u64 {
                for pg in 0..64u64 {
                    let line = (sweep % 8) * 512;
                    now += p.access(0, sp_base + pg * PAGE_SIZE + line,
                                    true, now);
                }
            }
            now += p.on_interval(now);
            if p.m.metrics.migrations > 0 {
                break;
            }
            assert!(round < 2, "page should migrate within two intervals");
        }
        now
    }

    #[test]
    fn first_touch_maps_superpage_in_nvm() {
        let mut p = policy();
        p.access(0, 0x123_4567, false, 0);
        let pa = p.aspace.resolve_2m(0x123_4567).unwrap();
        assert!(pa >= p.m.mem.dram_size());
        // Table VI bookkeeping: reverse map populated.
        assert_eq!(p.sp_rev.iter().filter(|&&s| s != NO_SVPN).count(), 1);
    }

    #[test]
    fn superpage_tlb_survives_migration() {
        let mut p = policy();
        let v = 0x40_0000u64;
        heat_and_migrate(&mut p, v);
        assert!(p.m.metrics.migrations > 0, "hot page must migrate");
        // The key claim: migration performed ZERO shootdowns.
        assert_eq!(p.m.metrics.shootdowns, 0,
                   "NVM->DRAM migration must not shoot down TLBs");
        // And the superpage entry still translates (no SPTW needed).
        let walks = p.m.sp_walker.stats.walks_2m;
        p.access(0, v + 8192, false, 1 << 30);
        assert_eq!(p.m.sp_walker.stats.walks_2m, walks,
                   "superpage TLB entry must still be live");
    }

    #[test]
    fn migrated_page_redirects_to_dram_via_remap() {
        let mut p = policy();
        let v = 0x40_0000u64;
        let now = heat_and_migrate(&mut p, v);
        assert!(p.migrated_pages() > 0);
        // Flush 4 KB TLBs so the next access goes through case 3 + remap.
        for t in &mut p.m.tlbs {
            t.l1_4k.flush_all();
            t.l2_4k.flush_all();
        }
        let remaps_before = p.m.metrics.remap_reads;
        p.access(0, v, false, now);
        assert_eq!(p.m.metrics.remap_reads, remaps_before + 1,
                   "first access after TLB loss uses the remap pointer");
        // Second access: 4 KB TLB hit, no more remap reads.
        p.access(0, v, false, now + 10_000);
        assert_eq!(p.m.metrics.remap_reads, remaps_before + 1);
    }

    #[test]
    fn bitmap_and_remap_stay_consistent() {
        let mut p = policy();
        heat_and_migrate(&mut p, 0x20_0000);
        // Every set bitmap bit must have a remap entry and vice versa.
        let mut bits = 0;
        for sp in 0..p.bitmap.n_superpages() as u32 {
            bits += p.bitmap.popcount(sp) as usize;
        }
        assert_eq!(bits, p.remap.len());
        assert!(bits > 0);
    }

    #[test]
    fn cold_interval_migrates_nothing() {
        let mut p = policy();
        let mut now = 0;
        for i in 0..64u64 {
            now += p.access(0, i * PAGE_SIZE, false, now);
        }
        now += p.on_interval(now);
        p.on_interval(now);
        assert_eq!(p.m.metrics.migrations, 0);
    }

    #[test]
    fn bitmap_checked_on_nvm_path_only() {
        let mut p = policy();
        let v = 0x60_0000u64;
        p.access(0, v, false, 0); // case 4: walk + bitmap
        let checks1 = p.m.metrics.bitmap_hits + p.m.metrics.bitmap_misses;
        assert!(checks1 >= 1);
        p.access(0, v, false, 50_000); // case 3 (4K miss, SP hit): bitmap
        let checks2 = p.m.metrics.bitmap_hits + p.m.metrics.bitmap_misses;
        assert_eq!(checks2, checks1 + 1);
    }

    #[test]
    fn finalize_zeroes_4k_miss_mpki() {
        let mut p = policy();
        p.access(0, 0x1000, false, 0);
        p.finalize(100_000);
        assert_eq!(p.m.metrics.tlb_miss_4k, 0);
        assert!(p.m.metrics.tlb_miss_2m > 0);
    }
}
