//! Utility-based migration model (§III-C): Eq. 1 benefit, Eq. 2 swap
//! accounting, and the dynamic threshold controller that raises the bar
//! when bidirectional traffic (thrashing) grows.

use crate::config::Config;

/// Latency parameters of the utility model (cycles), mirrored into the
/// f32[8] parameter vector the AOT kernels consume.
#[derive(Clone, Copy, Debug)]
pub struct UtilityParams {
    pub t_nr: f64,
    pub t_nw: f64,
    pub t_dr: f64,
    pub t_dw: f64,
    pub t_mig: f64,
    pub t_writeback: f64,
    pub threshold: f64,
    pub write_weight: f64,
}

impl UtilityParams {
    pub fn from_config(cfg: &Config) -> UtilityParams {
        UtilityParams {
            t_nr: cfg.nvm.read_cycles as f64,
            t_nw: cfg.nvm.write_cycles as f64,
            t_dr: cfg.dram.read_cycles as f64,
            t_dw: cfg.dram.write_cycles as f64,
            t_mig: cfg.t_mig_4k as f64,
            t_writeback: cfg.t_writeback_4k as f64,
            threshold: cfg.migration_threshold,
            write_weight: cfg.write_weight,
        }
    }

    /// The f32[8] vector in the artifact's parameter layout (ref.py).
    pub fn to_f32_vec(&self) -> [f32; 8] {
        [
            self.t_nr as f32,
            self.t_nw as f32,
            self.t_dr as f32,
            self.t_dw as f32,
            self.t_mig as f32,
            self.t_writeback as f32,
            self.threshold as f32,
            self.write_weight as f32,
        ]
    }

    /// Eq. 1: benefit of migrating a page expected to see (c_r, c_w).
    pub fn benefit(&self, c_r: u64, c_w: u64) -> f64 {
        (self.t_nr - self.t_dr) * c_r as f64
            + (self.t_nw - self.t_dw) * c_w as f64
            - self.t_mig
    }

    /// Eq. 2: net benefit when a victim page (c_r1, c_w1) must be swapped
    /// out for the incoming page (c_r2, c_w2).
    pub fn swap_benefit(&self, c_r2: u64, c_w2: u64, c_r1: u64, c_w1: u64)
                        -> f64 {
        (self.t_nr - self.t_dr) * (c_r2 as f64 - c_r1 as f64)
            + (self.t_nw - self.t_dw) * (c_w2 as f64 - c_w1 as f64)
            - self.t_mig
            - self.t_writeback
    }
}

/// Dynamic threshold controller (§III-C): "we monitor the data traffic of
/// bidirectional page migrations, and dynamically increase the threshold
/// ... to select hotter small pages".
#[derive(Clone, Debug)]
pub struct ThresholdCtl {
    base: f64,
    current: f64,
    /// Raise factor when thrashing is detected; decay toward base.
    raise: f64,
    decay: f64,
    /// Writeback:migration byte ratio above which we call it thrashing.
    thrash_ratio: f64,
}

impl ThresholdCtl {
    pub fn new(base: f64) -> ThresholdCtl {
        ThresholdCtl {
            base,
            current: base,
            raise: 2.0,
            decay: 0.5,
            thrash_ratio: 0.5,
        }
    }

    pub fn threshold(&self) -> f64 {
        self.current
    }

    /// Feed one interval's traffic; returns the updated threshold.
    pub fn update(&mut self, migrated_bytes: u64, writeback_bytes: u64) -> f64 {
        let ratio = if migrated_bytes == 0 {
            0.0
        } else {
            writeback_bytes as f64 / migrated_bytes as f64
        };
        if ratio > self.thrash_ratio {
            self.current = (self.current * self.raise).min(self.base * 64.0);
        } else {
            // Geometric decay back toward the base threshold.
            self.current = self.base + (self.current - self.base) * self.decay;
        }
        self.current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> UtilityParams {
        UtilityParams::from_config(&Config::paper())
    }

    #[test]
    fn eq1_write_heavy_pages_benefit_more() {
        let p = params();
        // (t_nw - t_dw) = 547-91 = 456 >> (t_nr - t_dr) = 19.
        assert!(p.benefit(0, 100) > p.benefit(100, 0));
    }

    #[test]
    fn eq1_cold_page_negative() {
        let p = params();
        assert!(p.benefit(0, 0) < 0.0);
        assert!(p.benefit(1, 0) < 0.0, "one read cannot repay T_mig");
    }

    #[test]
    fn eq2_swap_requires_hotter_incoming() {
        let p = params();
        // Equal hotness: pure loss (pay T_mig + T_writeback).
        let even = p.swap_benefit(50, 50, 50, 50);
        assert!(even < 0.0);
        // Much hotter incoming: worth it.
        let hot = p.swap_benefit(500, 500, 5, 5);
        assert!(hot > 0.0);
        // Eq. 2 <= Eq. 1 always (swap adds writeback cost).
        assert!(p.swap_benefit(100, 100, 0, 0) < p.benefit(100, 100));
    }

    #[test]
    fn params_vector_matches_python_layout() {
        let v = params().to_f32_vec();
        assert_eq!(v[0], 62.0); // t_nr
        assert_eq!(v[1], 547.0); // t_nw
        assert_eq!(v[2], 43.0); // t_dr
        assert_eq!(v[3], 91.0); // t_dw
        assert_eq!(v[7], 3.0); // write_weight
    }

    #[test]
    fn threshold_rises_on_thrash_decays_after() {
        let mut t = ThresholdCtl::new(64.0);
        assert_eq!(t.threshold(), 64.0);
        // Heavy writeback traffic -> raise.
        t.update(1000, 900);
        assert!(t.threshold() > 64.0);
        let peak = t.threshold();
        // Calm intervals -> decay toward base.
        for _ in 0..10 {
            t.update(1000, 0);
        }
        assert!(t.threshold() < peak);
        assert!((t.threshold() - 64.0).abs() < 8.0);
    }

    #[test]
    fn threshold_bounded() {
        let mut t = ThresholdCtl::new(64.0);
        for _ in 0..100 {
            t.update(1, 1_000_000);
        }
        assert!(t.threshold() <= 64.0 * 64.0);
    }
}
