//! NVM-to-DRAM address remapping (§III-E, Fig. 6).
//!
//! When a hot 4 KB page migrates to DRAM, its destination address is
//! written into the first 8 bytes of the page's *original* NVM residence.
//! Addressing a migrated page through the superpage TLB therefore costs
//! one extra NVM read (the pointer), after which the 4 KB TLB entry is
//! installed and subsequent accesses go straight to DRAM. Superpage TLB
//! entries are never invalidated by NVM→DRAM migration — the paper's key
//! transparency property.
//!
//! The functional side (which DRAM frame holds which NVM page) is a map;
//! the timing side (the 8-byte NVM read / 8-byte pointer write) is charged
//! against the memory devices by the policy.

use std::collections::HashMap;

/// Remap table: NVM 4 KB page number -> DRAM frame number.
#[derive(Clone, Debug, Default)]
pub struct RemapTable {
    fwd: HashMap<u64, u64>,
    /// Reverse map for eviction: DRAM frame -> NVM page.
    rev: HashMap<u64, u64>,
}

impl RemapTable {
    pub fn new() -> RemapTable {
        RemapTable::default()
    }

    /// Install a remap (page migrated). Panics on double-migrate — the
    /// bitmap must prevent that.
    pub fn insert(&mut self, nvm_page: u64, dram_frame: u64) {
        let old = self.fwd.insert(nvm_page, dram_frame);
        assert!(old.is_none(), "page {nvm_page:#x} already migrated");
        let old = self.rev.insert(dram_frame, nvm_page);
        assert!(old.is_none(), "frame {dram_frame:#x} already in use");
    }

    /// Follow the pointer stored in the NVM page (the 8-byte read).
    pub fn lookup(&self, nvm_page: u64) -> Option<u64> {
        self.fwd.get(&nvm_page).copied()
    }

    /// Which NVM page a DRAM frame caches (eviction path).
    pub fn owner_of_frame(&self, dram_frame: u64) -> Option<u64> {
        self.rev.get(&dram_frame).copied()
    }

    /// Remove on eviction/writeback; returns the DRAM frame it occupied.
    pub fn remove(&mut self, nvm_page: u64) -> Option<u64> {
        let frame = self.fwd.remove(&nvm_page)?;
        let back = self.rev.remove(&frame);
        debug_assert_eq!(back, Some(nvm_page));
        Some(frame)
    }

    pub fn len(&self) -> usize {
        self.fwd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fwd.is_empty()
    }
}

/// Analytic DRAM-page addressing cost model (§III-E):
/// traditional 4-level PTW costs `4*t_dr`; Rainbow costs
/// `R_hit*t_nr + (1-R_hit)*4*t_nr`. Used by the `ana_remap_cost` bench to
/// reproduce the paper's crossover claim (Rainbow wins iff R_hit > ~67%).
pub fn rainbow_addressing_cost(r_hit: f64, t_nr: f64) -> f64 {
    r_hit * t_nr + (1.0 - r_hit) * 4.0 * t_nr
}

pub fn ptw_addressing_cost(t_dr: f64) -> f64 {
    4.0 * t_dr
}

/// The R_hit above which Rainbow's addressing is cheaper than the walk.
pub fn crossover_r_hit(t_nr: f64, t_dr: f64) -> f64 {
    // r*t_nr + (1-r)*4 t_nr = 4 t_dr  =>  r = (4 t_nr - 4 t_dr) / (3 t_nr)
    (4.0 * t_nr - 4.0 * t_dr) / (3.0 * t_nr)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_lookup_remove() {
        let mut r = RemapTable::new();
        r.insert(100, 5);
        assert_eq!(r.lookup(100), Some(5));
        assert_eq!(r.owner_of_frame(5), Some(100));
        assert_eq!(r.remove(100), Some(5));
        assert_eq!(r.lookup(100), None);
        assert_eq!(r.owner_of_frame(5), None);
        assert!(r.is_empty());
    }

    #[test]
    #[should_panic(expected = "already migrated")]
    fn double_migration_panics() {
        let mut r = RemapTable::new();
        r.insert(1, 2);
        r.insert(1, 3);
    }

    #[test]
    #[should_panic(expected = "already in use")]
    fn frame_reuse_panics() {
        let mut r = RemapTable::new();
        r.insert(1, 2);
        r.insert(9, 2);
    }

    #[test]
    fn paper_crossover_at_67_percent() {
        // t_nr ≈ 2 * t_dr (paper): crossover = (8-4)/6 = 66.7%.
        let x = crossover_r_hit(2.0, 1.0);
        assert!((x - 0.6667).abs() < 0.01, "crossover {x}");
        // At R_hit = 95% the paper claims 42.5% reduction.
        let rainbow = rainbow_addressing_cost(0.95, 2.0);
        let walk = ptw_addressing_cost(1.0);
        let reduction = 1.0 - rainbow / walk;
        assert!((reduction - 0.425).abs() < 0.01, "reduction {reduction}");
    }

    #[test]
    fn cost_decreases_with_hit_rate() {
        let c50 = rainbow_addressing_cost(0.50, 62.0);
        let c99 = rainbow_addressing_cost(0.99, 62.0);
        assert!(c99 < c50);
    }
}
