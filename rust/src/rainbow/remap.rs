//! NVM-to-DRAM address remapping (§III-E, Fig. 6).
//!
//! When a hot 4 KB page migrates to DRAM, its destination address is
//! written into the first 8 bytes of the page's *original* NVM residence.
//! Addressing a migrated page through the superpage TLB therefore costs
//! one extra NVM read (the pointer), after which the 4 KB TLB entry is
//! installed and subsequent accesses go straight to DRAM. Superpage TLB
//! entries are never invalidated by NVM→DRAM migration — the paper's key
//! transparency property.
//!
//! The functional side (which DRAM frame holds which NVM page) is a map;
//! the timing side (the 8-byte NVM read / 8-byte pointer write) is charged
//! against the memory devices by the policy.
//!
//! Because the table is consulted on every superpage-TLB hit whose bitmap
//! bit is set, it sits on the simulator's per-access hot path. It is
//! therefore stored as two flat sentinel-encoded arrays — forward indexed
//! by NVM page number, reverse indexed by DRAM frame number — instead of
//! hash maps: a lookup is one bounds check plus one load, which is what
//! makes wide parallel sweeps (`report::sweep`) affordable. Policies
//! pre-size the arrays via [`RemapTable::with_capacity`]; `new()` starts
//! empty and grows on demand (unit tests, ad-hoc use).

/// Sentinel marking an unmapped slot in the flat arrays. Page and frame
/// numbers are far below this at every supported scale (paper scale:
/// 8 Mi NVM pages, 1 Mi DRAM frames).
const NO_MAPPING: u32 = u32::MAX;

/// Remap table: NVM 4 KB page number -> DRAM frame number.
#[derive(Clone, Debug, Default)]
pub struct RemapTable {
    /// NVM page -> DRAM frame (`NO_MAPPING` = not migrated).
    fwd: Vec<u32>,
    /// Reverse map for eviction: DRAM frame -> NVM page.
    rev: Vec<u32>,
    /// Live mappings (kept explicitly; the arrays are sparse).
    live: usize,
}

impl RemapTable {
    pub fn new() -> RemapTable {
        RemapTable::default()
    }

    /// Pre-sized table covering `n_nvm_pages` forward slots and
    /// `n_dram_frames` reverse slots (no growth on the hot path).
    pub fn with_capacity(n_nvm_pages: usize, n_dram_frames: usize)
                         -> RemapTable {
        RemapTable {
            fwd: vec![NO_MAPPING; n_nvm_pages],
            rev: vec![NO_MAPPING; n_dram_frames],
            live: 0,
        }
    }

    #[inline]
    fn slot(v: &[u32], idx: u64) -> u32 {
        v.get(idx as usize).copied().unwrap_or(NO_MAPPING)
    }

    #[inline]
    fn grow_to(v: &mut Vec<u32>, idx: usize) {
        if idx >= v.len() {
            v.resize(idx + 1, NO_MAPPING);
        }
    }

    /// Install a remap (page migrated). Panics on double-migrate — the
    /// bitmap must prevent that.
    pub fn insert(&mut self, nvm_page: u64, dram_frame: u64) {
        // Hard asserts: beyond the u32 sentinel domain (>= 16 TB of 4 KB
        // pages) the flat encoding would silently alias; insert is off
        // the per-access hot path, so the checks cost nothing.
        assert!(nvm_page < NO_MAPPING as u64,
                "page {nvm_page:#x} outside the flat remap domain");
        assert!(dram_frame < NO_MAPPING as u64,
                "frame {dram_frame:#x} outside the flat remap domain");
        // Check both invariants before writing either side, so a panic
        // leaves the table untouched (fwd/rev stay consistent).
        assert!(Self::slot(&self.fwd, nvm_page) == NO_MAPPING,
                "page {nvm_page:#x} already migrated");
        assert!(Self::slot(&self.rev, dram_frame) == NO_MAPPING,
                "frame {dram_frame:#x} already in use");
        Self::grow_to(&mut self.fwd, nvm_page as usize);
        Self::grow_to(&mut self.rev, dram_frame as usize);
        self.fwd[nvm_page as usize] = dram_frame as u32;
        self.rev[dram_frame as usize] = nvm_page as u32;
        self.live += 1;
    }

    /// Follow the pointer stored in the NVM page (the 8-byte read).
    #[inline]
    pub fn lookup(&self, nvm_page: u64) -> Option<u64> {
        match Self::slot(&self.fwd, nvm_page) {
            NO_MAPPING => None,
            f => Some(f as u64),
        }
    }

    /// Which NVM page a DRAM frame caches (eviction path).
    #[inline]
    pub fn owner_of_frame(&self, dram_frame: u64) -> Option<u64> {
        match Self::slot(&self.rev, dram_frame) {
            NO_MAPPING => None,
            p => Some(p as u64),
        }
    }

    /// Remove on eviction/writeback; returns the DRAM frame it occupied.
    pub fn remove(&mut self, nvm_page: u64) -> Option<u64> {
        let frame = match Self::slot(&self.fwd, nvm_page) {
            NO_MAPPING => return None,
            f => f as usize,
        };
        self.fwd[nvm_page as usize] = NO_MAPPING;
        debug_assert_eq!(self.rev[frame], nvm_page as u32);
        self.rev[frame] = NO_MAPPING;
        self.live -= 1;
        Some(frame as u64)
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

/// Analytic DRAM-page addressing cost model (§III-E):
/// traditional 4-level PTW costs `4*t_dr`; Rainbow costs
/// `R_hit*t_nr + (1-R_hit)*4*t_nr`. Used by the `ana_remap_cost` bench to
/// reproduce the paper's crossover claim (Rainbow wins iff R_hit > ~67%).
pub fn rainbow_addressing_cost(r_hit: f64, t_nr: f64) -> f64 {
    r_hit * t_nr + (1.0 - r_hit) * 4.0 * t_nr
}

pub fn ptw_addressing_cost(t_dr: f64) -> f64 {
    4.0 * t_dr
}

/// The R_hit above which Rainbow's addressing is cheaper than the walk.
pub fn crossover_r_hit(t_nr: f64, t_dr: f64) -> f64 {
    // r*t_nr + (1-r)*4 t_nr = 4 t_dr  =>  r = (4 t_nr - 4 t_dr) / (3 t_nr)
    (4.0 * t_nr - 4.0 * t_dr) / (3.0 * t_nr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_shrink, shrink_vec};
    use std::collections::HashMap;

    #[test]
    fn insert_lookup_remove() {
        let mut r = RemapTable::new();
        r.insert(100, 5);
        assert_eq!(r.lookup(100), Some(5));
        assert_eq!(r.owner_of_frame(5), Some(100));
        assert_eq!(r.remove(100), Some(5));
        assert_eq!(r.lookup(100), None);
        assert_eq!(r.owner_of_frame(5), None);
        assert!(r.is_empty());
    }

    #[test]
    fn presized_table_behaves_like_grown() {
        let mut r = RemapTable::with_capacity(256, 64);
        assert_eq!(r.lookup(255), None);
        r.insert(255, 63);
        assert_eq!(r.lookup(255), Some(63));
        assert_eq!(r.owner_of_frame(63), Some(255));
        // Out-of-capacity probes are misses, not panics.
        assert_eq!(r.lookup(10_000), None);
        assert_eq!(r.owner_of_frame(10_000), None);
        // Inserting past the pre-size grows transparently.
        r.insert(10_000, 10_001);
        assert_eq!(r.lookup(10_000), Some(10_001));
        assert_eq!(r.len(), 2);
    }

    #[test]
    #[should_panic(expected = "already migrated")]
    fn double_migration_panics() {
        let mut r = RemapTable::new();
        r.insert(1, 2);
        r.insert(1, 3);
    }

    #[test]
    #[should_panic(expected = "already in use")]
    fn frame_reuse_panics() {
        let mut r = RemapTable::new();
        r.insert(1, 2);
        r.insert(9, 2);
    }

    #[test]
    fn failed_insert_leaves_table_consistent() {
        // The no-double-migrate panic must fire before any mutation, so
        // fwd/rev never diverge even if a caller catches the unwind.
        let mut r = RemapTable::new();
        r.insert(7, 3);
        for (p, f) in [(7u64, 9u64), (8, 3)] {
            let res = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| r.insert(p, f)));
            assert!(res.is_err(), "insert({p},{f}) must panic");
        }
        assert_eq!(r.len(), 1);
        assert_eq!(r.lookup(7), Some(3));
        assert_eq!(r.owner_of_frame(3), Some(7));
        assert_eq!(r.lookup(8), None);
        assert_eq!(r.owner_of_frame(9), None);
    }

    /// One random op: 0 = insert, 1 = remove, 2 = probe.
    type Op = (u8, u64, u64);

    fn apply_checked(t: &mut RemapTable, model: &mut HashMap<u64, u64>,
                     &(op, page, frame): &Op) -> Result<(), String> {
        match op {
            0 => {
                let page_mapped = model.contains_key(&page);
                let frame_used = model.values().any(|&f| f == frame);
                if page_mapped || frame_used {
                    // No-double-migrate invariant: the insert must refuse
                    // (panic) and leave the table untouched.
                    let res = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| t.insert(page, frame)));
                    if res.is_ok() {
                        return Err(format!(
                            "insert({page},{frame}) accepted a \
                             double-migrate (mapped={page_mapped}, \
                             frame_used={frame_used})"));
                    }
                } else {
                    t.insert(page, frame);
                    model.insert(page, frame);
                }
            }
            1 => {
                let got = t.remove(page);
                let want = model.remove(&page);
                if got != want {
                    return Err(format!(
                        "remove({page}) = {got:?}, model says {want:?}"));
                }
            }
            _ => {
                if t.lookup(page) != model.get(&page).copied() {
                    return Err(format!("lookup({page}) diverged"));
                }
                let owner =
                    model.iter().find(|(_, &f)| f == frame).map(|(&p, _)| p);
                if t.owner_of_frame(frame) != owner {
                    return Err(format!("owner_of_frame({frame}) diverged"));
                }
            }
        }
        if t.len() != model.len() {
            return Err(format!("len {} != model {}", t.len(), model.len()));
        }
        Ok(())
    }

    /// Full fwd/rev agreement against the model after a whole op sequence.
    fn check_consistent(t: &RemapTable, model: &HashMap<u64, u64>)
                        -> Result<(), String> {
        for (&p, &f) in model {
            if t.lookup(p) != Some(f) {
                return Err(format!("fwd lost {p} -> {f}"));
            }
            if t.owner_of_frame(f) != Some(p) {
                return Err(format!("rev lost {f} -> {p}"));
            }
        }
        Ok(())
    }

    #[test]
    fn prop_matches_hashmap_model() {
        let mut gen = |r: &mut crate::util::rng::Rng| -> Vec<Op> {
            let n = r.below(120);
            (0..n)
                .map(|_| (r.below(3) as u8, r.below(48), r.below(24)))
                .collect()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut t = RemapTable::new();
            let mut model = HashMap::new();
            for op in ops {
                apply_checked(&mut t, &mut model, op)?;
            }
            check_consistent(&t, &model)
        };
        forall_shrink("remap-model", 0x2E3A9, 80, &mut gen, shrink_vec,
                      &mut prop);
    }

    #[test]
    fn prop_presized_matches_hashmap_model() {
        // Same property on a pre-sized table (the policy configuration).
        let mut gen = |r: &mut crate::util::rng::Rng| -> Vec<Op> {
            let n = r.below(120);
            (0..n)
                .map(|_| (r.below(3) as u8, r.below(48), r.below(24)))
                .collect()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut t = RemapTable::with_capacity(48, 24);
            let mut model = HashMap::new();
            for op in ops {
                apply_checked(&mut t, &mut model, op)?;
            }
            check_consistent(&t, &model)
        };
        forall_shrink("remap-model-presized", 0x51AB, 80, &mut gen,
                      shrink_vec, &mut prop);
    }

    #[test]
    fn paper_crossover_at_67_percent() {
        // t_nr ≈ 2 * t_dr (paper): crossover = (8-4)/6 = 66.7%.
        let x = crossover_r_hit(2.0, 1.0);
        assert!((x - 0.6667).abs() < 0.01, "crossover {x}");
        // At R_hit = 95% the paper claims 42.5% reduction.
        let rainbow = rainbow_addressing_cost(0.95, 2.0);
        let walk = ptw_addressing_cost(1.0);
        let reduction = 1.0 - rainbow / walk;
        assert!((reduction - 0.425).abs() < 0.01, "reduction {reduction}");
    }

    #[test]
    fn cost_decreases_with_hit_rate() {
        let c50 = rainbow_addressing_cost(0.50, 62.0);
        let c99 = rainbow_addressing_cost(0.99, 62.0);
        assert!(c99 < c50);
    }
}
