//! The paper's contribution: two-stage access counting, migration bitmap
//! + bitmap cache, NVM→DRAM address remapping, utility-based migration,
//! and the full Rainbow policy tying them to the split-TLB machine.

pub mod bitmap;
pub mod counters;
pub mod migration;
pub mod policy;
pub mod remap;

pub use bitmap::{BitmapCache, MigrationBitmap};
pub use counters::TwoStageCounters;
pub use migration::{ThresholdCtl, UtilityParams};
pub use policy::Rainbow;
pub use remap::RemapTable;
