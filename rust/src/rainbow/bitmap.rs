//! Migration bitmap + the memory-controller bitmap cache (§III-D, Fig. 5).
//!
//! One bit per 4 KB page per NVM superpage (512 bits = 64 B per
//! superpage). The full bitmap lives in main memory; an 8-way
//! set-associative cache of 4000 entries (4 B PSN tag + 512-bit bitmap
//! each, 272 KB SRAM) sits in the memory controller. A hit costs 9 cycles
//! (CACTI, Table IV); a miss additionally reads the 64 B bitmap line from
//! NVM.

use crate::config::PAGES_PER_SP;

/// Backing store: the full migration bitmap in "main memory".
#[derive(Clone, Debug)]
pub struct MigrationBitmap {
    /// 8 x u64 per superpage = 512 bits.
    words: Vec<u64>,
    n_sp: usize,
}

impl MigrationBitmap {
    pub fn new(n_superpages: usize) -> MigrationBitmap {
        MigrationBitmap { words: vec![0; n_superpages * 8], n_sp: n_superpages }
    }

    #[inline]
    fn locate(&self, sp: u32, page: u16) -> (usize, u64) {
        debug_assert!((page as u64) < PAGES_PER_SP);
        let w = sp as usize * 8 + (page as usize >> 6);
        (w, 1u64 << (page & 63))
    }

    pub fn get(&self, sp: u32, page: u16) -> bool {
        let (w, m) = self.locate(sp, page);
        self.words[w] & m != 0
    }

    pub fn set(&mut self, sp: u32, page: u16, v: bool) {
        let (w, m) = self.locate(sp, page);
        if v {
            self.words[w] |= m;
        } else {
            self.words[w] &= !m;
        }
    }

    /// Number of migrated pages in a superpage.
    pub fn popcount(&self, sp: u32) -> u32 {
        let base = sp as usize * 8;
        self.words[base..base + 8].iter().map(|w| w.count_ones()).sum()
    }

    pub fn n_superpages(&self) -> usize {
        self.n_sp
    }

    /// Total backing-store bytes (1 bit per 4 KB page).
    pub fn backing_bytes(&self) -> u64 {
        self.words.len() as u64 * 8
    }
}

/// One cache entry: PSN tag + the superpage's 512-bit bitmap.
#[derive(Clone, Copy, Debug, Default)]
struct Entry {
    psn: u32,
    valid: bool,
    lru: u64,
}

#[derive(Clone, Debug, Default)]
pub struct BitmapCacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl BitmapCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 { 0.0 } else { self.hits as f64 / t as f64 }
    }
}

/// The 8-way set-associative bitmap cache (tags only; bit values are read
/// through to the backing store, which is exact — write-through design).
#[derive(Clone, Debug)]
pub struct BitmapCache {
    sets: usize,
    assoc: usize,
    entries: Vec<Entry>,
    tick: u64,
    pub latency: u64,
    pub stats: BitmapCacheStats,
}

impl BitmapCache {
    /// `entries` total (Fig. 5: 4000), `assoc`-way (8), `latency` (9).
    pub fn new(entries: usize, assoc: usize, latency: u64) -> BitmapCache {
        assert!(assoc > 0 && entries % assoc == 0);
        let sets = entries / assoc;
        // Fig. 5's 4000-entry cache has 500 sets — not a power of two; we
        // index by modulo to honour the paper's sizing.
        BitmapCache {
            sets,
            assoc,
            entries: vec![Entry::default(); entries],
            tick: 0,
            latency,
            stats: BitmapCacheStats::default(),
        }
    }

    /// Look up the bitmap entry for `sp`. Returns true on hit; on miss the
    /// entry is installed (caller charges the backing-store read).
    pub fn touch(&mut self, sp: u32) -> bool {
        self.tick += 1;
        let set = (sp as usize) % self.sets;
        let base = set * self.assoc;
        for i in base..base + self.assoc {
            let e = &mut self.entries[i];
            if e.valid && e.psn == sp {
                e.lru = self.tick;
                self.stats.hits += 1;
                return true;
            }
        }
        self.stats.misses += 1;
        // Install (LRU victim).
        let mut victim = base;
        let mut best = u64::MAX;
        for i in base..base + self.assoc {
            let e = &self.entries[i];
            if !e.valid {
                victim = i;
                break;
            }
            if e.lru < best {
                best = e.lru;
                victim = i;
            }
        }
        self.entries[victim] = Entry { psn: sp, valid: true, lru: self.tick };
        false
    }

    /// SRAM budget: 4 B tag + 64 B bitmap per entry (Fig. 5: 272 KB for
    /// 4000 entries).
    pub fn sram_bytes(&self) -> u64 {
        self.entries.len() as u64 * (4 + 64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn bitmap_get_set_roundtrip() {
        let mut b = MigrationBitmap::new(16);
        assert!(!b.get(3, 100));
        b.set(3, 100, true);
        assert!(b.get(3, 100));
        assert_eq!(b.popcount(3), 1);
        b.set(3, 100, false);
        assert!(!b.get(3, 100));
        assert_eq!(b.popcount(3), 0);
    }

    #[test]
    fn bitmap_bit_isolation() {
        let mut b = MigrationBitmap::new(4);
        b.set(1, 0, true);
        b.set(1, 511, true);
        assert!(b.get(1, 0) && b.get(1, 511));
        assert!(!b.get(1, 1) && !b.get(1, 510));
        assert!(!b.get(0, 0) && !b.get(2, 0));
        assert_eq!(b.popcount(1), 2);
    }

    #[test]
    fn paper_storage_budgets() {
        // 1 TB PCM: 512Ki superpages -> 32 MB backing bitmap.
        let b = MigrationBitmap::new(512 * 1024);
        assert_eq!(b.backing_bytes(), 32 << 20);
        // 4000-entry cache -> 272 KB SRAM.
        let c = BitmapCache::new(4000, 8, 9);
        assert_eq!(c.sram_bytes(), 4000 * 68);
        assert_eq!(c.sram_bytes(), 272_000); // "272 KB" in the paper (decimal)
    }

    #[test]
    fn cache_hit_after_install() {
        let mut c = BitmapCache::new(64, 8, 9);
        assert!(!c.touch(5));
        assert!(c.touch(5));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn cache_lru_within_set() {
        let mut c = BitmapCache::new(16, 2, 9); // 8 sets, 2-way
        // psn 0, 8, 16 all map to set 0.
        c.touch(0);
        c.touch(8);
        c.touch(0); // refresh
        c.touch(16); // evicts 8
        assert!(c.touch(0), "0 must still be resident");
        assert!(!c.touch(8), "8 must have been evicted");
    }

    #[test]
    fn high_locality_gives_high_hit_rate() {
        let mut c = BitmapCache::new(4000, 8, 9);
        let mut rng = Rng::new(3);
        for _ in 0..100_000 {
            c.touch(rng.below(1000) as u32); // working set << capacity
        }
        assert!(c.stats.hit_rate() > 0.98, "rate={}", c.stats.hit_rate());
    }

    /// Property: the cache is only a performance hint — correctness state
    /// (the bits) lives in the backing store and survives any eviction
    /// pattern.
    #[test]
    fn prop_backing_store_exact_under_random_ops() {
        forall(
            "bitmap-exactness",
            0xB17,
            25,
            |r: &mut Rng| {
                (0..200)
                    .map(|_| (r.below(32) as u32, r.below(512) as u16,
                              r.chance(0.5)))
                    .collect::<Vec<(u32, u16, bool)>>()
            },
            |ops| {
                let mut b = MigrationBitmap::new(32);
                let mut c = BitmapCache::new(16, 2, 9);
                let mut model =
                    std::collections::HashSet::<(u32, u16)>::new();
                for &(sp, pg, v) in ops {
                    c.touch(sp);
                    b.set(sp, pg, v);
                    if v {
                        model.insert((sp, pg));
                    } else {
                        model.remove(&(sp, pg));
                    }
                }
                for sp in 0..32u32 {
                    for pg in (0..512u16).step_by(7) {
                        if b.get(sp, pg) != model.contains(&(sp, pg)) {
                            return Err(format!("mismatch at {sp}/{pg}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
