//! Memory request/response types shared across the memory subsystem.

/// Which memory technology a physical address resolves to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemKind {
    Dram,
    Nvm,
}

impl MemKind {
    pub fn name(self) -> &'static str {
        match self {
            MemKind::Dram => "DRAM",
            MemKind::Nvm => "NVM",
        }
    }
}

/// One memory access as seen by a device controller.
#[derive(Clone, Copy, Debug)]
pub struct MemReq {
    /// Device-local physical address (0-based within the device).
    pub addr: u64,
    pub is_write: bool,
    /// Payload size in bytes (64 for a line fill, 8 for a remap read, ...).
    pub bytes: u64,
    /// Bulk transfers (migration copies) yield to demand requests in the
    /// FR-FCFS scheduler and are charged as background traffic.
    pub is_bulk: bool,
}

impl MemReq {
    pub fn line_read(addr: u64) -> Self {
        MemReq { addr, is_write: false, bytes: 64, is_bulk: false }
    }

    pub fn line_write(addr: u64) -> Self {
        MemReq { addr, is_write: true, bytes: 64, is_bulk: false }
    }

    pub fn bulk(addr: u64, is_write: bool, bytes: u64) -> Self {
        MemReq { addr, is_write, bytes, is_bulk: true }
    }
}

/// Timing + energy outcome of a device access.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemResult {
    /// Total latency in CPU cycles (including queueing).
    pub latency: u64,
    /// Dynamic energy in picojoules.
    pub energy_pj: f64,
    pub row_hit: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let r = MemReq::line_read(0x1000);
        assert!(!r.is_write && r.bytes == 64 && !r.is_bulk);
        let w = MemReq::line_write(0x40);
        assert!(w.is_write);
        let b = MemReq::bulk(0, true, 4096);
        assert!(b.is_bulk && b.bytes == 4096);
    }
}
