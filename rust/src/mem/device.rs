//! Memory device timing + energy model (one per controller slot).
//!
//! A `Device` is technology-agnostic: all timing, energy, geometry, and
//! the [`MemTech`] identity come from its `MemConfig` bundle — either
//! `Config::paper()`'s Table IV pair or a named catalog entry from
//! `config::profiles` (selected via the `dram.profile`/`nvm.profile`
//! knobs), so nothing here assumes "the fast slot is DDR3" or "the slow
//! slot is PCM".
//!
//! Approximation contract (DESIGN.md §5): a blocking demand request
//! arriving at CPU-cycle `now` waits for its bank and channel to free,
//! pays row-buffer activate/precharge penalties on a row miss, the array
//! access latency from Table IV, and the bus transfer. Bulk (migration)
//! requests occupy the same banks/channels, so migration traffic contends
//! with demand traffic exactly as the paper's Fig. 11 discussion assumes.

use crate::config::{MemConfig, MemTech};

use super::bank::{decode, total_banks, BankState};
use super::req::{MemReq, MemResult};

/// Memory-controller clock ratio: Table IV timing fields are in memory
/// cycles (800 MHz bus vs the 3.2 GHz core = 4 CPU cycles each).
pub const MEM_CLK_RATIO: u64 = 4;

/// Bus transfer cycles for 64 bytes at ~10.7 GB/s (Table IV) at 3.2 GHz:
/// 64 B / 10.7 GB/s ≈ 6 ns ≈ 19 CPU cycles per line per channel.
pub const LINE_XFER_CYCLES: u64 = 19;

/// Aggregate device statistics (per run).
#[derive(Clone, Debug, Default)]
pub struct DevStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub demand_bytes: u64,
    pub bulk_bytes: u64,
    pub energy_pj: f64,
    /// Total cycles requests waited on busy banks/channels (contention).
    pub wait_cycles: u64,
}

impl DevStats {
    pub fn accesses(&self) -> u64 {
        self.reads + self.writes
    }

    pub fn row_hit_rate(&self) -> f64 {
        let t = self.row_hits + self.row_misses;
        if t == 0 { 0.0 } else { self.row_hits as f64 / t as f64 }
    }
}

/// One memory device (all channels/ranks/banks of a technology).
#[derive(Clone, Debug)]
pub struct Device {
    pub cfg: MemConfig,
    banks: Vec<BankState>,
    /// Per-channel bus free time (CPU cycles).
    channel_free: Vec<u64>,
    pub stats: DevStats,
}

impl Device {
    pub fn new(cfg: MemConfig) -> Device {
        Device {
            banks: vec![BankState::default(); total_banks(&cfg)],
            channel_free: vec![0; cfg.channels],
            cfg,
            stats: DevStats::default(),
        }
    }

    /// The memory technology behind this device (profile identity).
    pub fn tech(&self) -> MemTech {
        self.cfg.tech
    }

    /// Service a request arriving at CPU-cycle `now`; returns latency from
    /// `now` until data is available, plus energy.
    pub fn access(&mut self, now: u64, req: &MemReq) -> MemResult {
        let coord = decode(&self.cfg, req.addr);
        let bi = coord.bank_index(&self.cfg);
        let bank = &mut self.banks[bi];

        // Wait for bank and channel.
        let start = now
            .max(bank.busy_until)
            .max(self.channel_free[coord.channel]);
        let waited = start - now;

        // Row-buffer outcome.
        let row_hit = bank.open_row == Some(coord.row);
        let array_cycles = if req.is_write {
            self.cfg.write_cycles
        } else {
            self.cfg.read_cycles
        };
        let rb_penalty = if row_hit {
            0
        } else {
            (self.cfg.t_rp + self.cfg.t_rcd) * MEM_CLK_RATIO
        };
        let lines = req.bytes.div_ceil(64);
        let xfer = LINE_XFER_CYCLES * lines;
        let service = rb_penalty + array_cycles + xfer;
        let done = start + service;

        bank.open_row = Some(coord.row);
        bank.busy_until = done;
        self.channel_free[coord.channel] = start + xfer.max(1);

        // Energy: pJ/bit by row-buffer outcome.
        let pj_bit = match (req.is_write, row_hit) {
            (false, true) => self.cfg.e_read_hit_pj_bit,
            (true, true) => self.cfg.e_write_hit_pj_bit,
            (false, false) => self.cfg.e_read_miss_pj_bit,
            (true, false) => self.cfg.e_write_miss_pj_bit,
        };
        let energy = pj_bit * (req.bytes * 8) as f64;

        // Stats.
        if req.is_write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        if row_hit {
            self.stats.row_hits += 1;
        } else {
            self.stats.row_misses += 1;
        }
        if req.is_bulk {
            self.stats.bulk_bytes += req.bytes;
        } else {
            self.stats.demand_bytes += req.bytes;
        }
        self.stats.energy_pj += energy;
        self.stats.wait_cycles += waited;

        MemResult { latency: done - now, energy_pj: energy, row_hit }
    }

    /// A flat-latency metadata read (page-table entries, remap pointers):
    /// charged at the device's array read latency plus a small transfer,
    /// without row-buffer state effects — PTE reads enjoy MMU-cache and
    /// row locality that the hashed walk addresses would misrepresent.
    /// This matches the paper's analytic model (§III-E: 4·t_dr vs 3·t_nr).
    pub fn flat_read(&mut self, bytes: u64) -> MemResult {
        let latency = self.cfg.read_cycles + 8;
        let energy = self.cfg.e_read_hit_pj_bit * (bytes * 8) as f64;
        self.stats.reads += 1;
        self.stats.row_hits += 1;
        self.stats.demand_bytes += bytes;
        self.stats.energy_pj += energy;
        MemResult { latency, energy_pj: energy, row_hit: true }
    }

    /// Background (standby + refresh) energy over `cycles` at `ghz`, in
    /// pJ. Scales with device capacity (refresh power is per-cell).
    pub fn background_energy_pj(&self, cycles: u64, ghz: f64) -> f64 {
        let seconds = cycles as f64 / (ghz * 1e9);
        let gb = self.cfg.size as f64 / (1u64 << 30) as f64;
        self.cfg.background_w_per_gb * gb * seconds * 1e12
    }

    /// Earliest cycle at which a new request to `addr` could start.
    pub fn free_at(&self, addr: u64) -> u64 {
        let coord = decode(&self.cfg, addr);
        self.banks[coord.bank_index(&self.cfg)]
            .busy_until
            .max(self.channel_free[coord.channel])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn dram() -> Device {
        Device::new(Config::paper().dram)
    }

    fn nvm() -> Device {
        Device::new(Config::paper().nvm)
    }

    #[test]
    fn first_access_is_row_miss_second_hits() {
        let mut d = dram();
        let a = d.access(0, &MemReq::line_read(0));
        assert!(!a.row_hit);
        // Same row, next column, after the bank frees.
        let b = d.access(a.latency, &MemReq::line_read(64));
        assert!(b.row_hit);
        assert!(b.latency < a.latency, "row hit must be faster");
    }

    #[test]
    fn nvm_write_much_slower_than_read() {
        // Compare on row-buffer hits so the array latency asymmetry
        // (19.5 ns read vs 171 ns write) is visible without the shared
        // activate/precharge penalty.
        let mut d = nvm();
        let a = d.access(0, &MemReq::line_read(0));
        let r = d.access(a.latency, &MemReq::line_read(64 * 4)); // same row
        assert!(r.row_hit);
        let w = d.access(a.latency + r.latency,
                         &MemReq::line_write(64 * 8));
        assert!(w.row_hit);
        assert!(w.latency > 3 * r.latency, "w={} r={}", w.latency, r.latency);
    }

    #[test]
    fn nvm_write_energy_dominates() {
        let mut d = nvm();
        d.access(0, &MemReq::line_read(0));
        let e_read = d.stats.energy_pj;
        let mut d2 = nvm();
        d2.access(0, &MemReq::line_write(0));
        let e_write = d2.stats.energy_pj;
        assert!(e_write > 10.0 * e_read);
    }

    #[test]
    fn bank_contention_delays_back_to_back() {
        let mut d = dram();
        let a = d.access(0, &MemReq::line_read(0));
        // Immediately issue to the same bank+row at time 0: must queue.
        let before = d.stats.wait_cycles;
        let _b = d.access(0, &MemReq::line_read(64));
        assert!(d.stats.wait_cycles > before);
        let _ = a;
    }

    #[test]
    fn different_channels_no_contention() {
        let mut d = nvm(); // 4 channels
        let a = d.access(0, &MemReq::line_read(0));
        let w0 = d.stats.wait_cycles;
        // Next line strides to the next channel + different bank.
        let _ = d.access(0, &MemReq::line_read(64));
        assert_eq!(d.stats.wait_cycles, w0, "no waiting across channels");
        let _ = a;
    }

    #[test]
    fn bulk_traffic_accounted_separately() {
        let mut d = dram();
        d.access(0, &MemReq::bulk(0, true, 4096));
        assert_eq!(d.stats.bulk_bytes, 4096);
        assert_eq!(d.stats.demand_bytes, 0);
    }

    #[test]
    fn background_energy_scales_with_time() {
        let d = dram();
        let e1 = d.background_energy_pj(1_000_000, 3.2);
        let e2 = d.background_energy_pj(2_000_000, 3.2);
        assert!(e2 > 1.9 * e1);
        // NVM has no background draw.
        assert_eq!(nvm().background_energy_pj(1_000_000, 3.2), 0.0);
    }

    #[test]
    fn tech_identity_comes_from_the_bundle() {
        use crate::config::profiles;
        assert_eq!(dram().tech(), MemTech::Dram);
        assert_eq!(nvm().tech(), MemTech::Pcm);
        let d = Device::new(profiles::by_name("optane-dcpmm").unwrap().mem());
        assert_eq!(d.tech(), MemTech::Optane);
        assert!(d.tech().is_nonvolatile());
    }

    #[test]
    fn row_hit_rate_counts() {
        let mut d = dram();
        let mut t = 0;
        for i in 0..10 {
            let r = d.access(t, &MemReq::line_read(i * 64));
            t += r.latency;
        }
        assert_eq!(d.stats.accesses(), 10);
        assert!(d.stats.row_hit_rate() > 0.5);
    }
}
