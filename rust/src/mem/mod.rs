//! NVMain-equivalent memory subsystem: device timing (row buffers, banks,
//! channels), FR-FCFS bulk scheduling, energy accounting, and the hybrid
//! DRAM+NVM controller facade.

pub mod bank;
pub mod controller;
pub mod device;
pub mod req;
pub mod sched;

pub use controller::HybridMemory;
pub use device::Device;
pub use req::{MemKind, MemReq, MemResult};
