//! Bank/row-buffer state and physical address decode.
//!
//! The decode follows NVMain's default order: the line-aligned address is
//! split into (channel, rank, bank, row, column) with channel bits lowest
//! so consecutive lines stripe across channels (maximizing bandwidth for
//! streaming, as the paper's 4-channel PCM configuration intends).

use crate::config::MemConfig;

/// Per-bank state: which row is latched in the row buffer and until when
/// the bank is busy (Lamport-clock style timing, no event queue).
#[derive(Clone, Copy, Debug, Default)]
pub struct BankState {
    pub open_row: Option<u64>,
    pub busy_until: u64,
}

/// Decoded coordinates of a physical address within a device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Coord {
    pub channel: usize,
    pub rank: usize,
    pub bank: usize,
    pub row: u64,
}

impl Coord {
    /// Flat index of the bank across the whole device.
    pub fn bank_index(&self, cfg: &MemConfig) -> usize {
        (self.channel * cfg.ranks_per_channel + self.rank) * cfg.banks_per_rank
            + self.bank
    }
}

/// Decode a device-local physical address.
pub fn decode(cfg: &MemConfig, addr: u64) -> Coord {
    let line = addr / 64;
    let mut x = line;
    let channel = (x % cfg.channels as u64) as usize;
    x /= cfg.channels as u64;
    // Columns within a row buffer: row_size bytes = row_size/64 lines.
    let cols = cfg.row_size / 64;
    x /= cols;
    let bank = (x % cfg.banks_per_rank as u64) as usize;
    x /= cfg.banks_per_rank as u64;
    let rank = (x % cfg.ranks_per_channel as u64) as usize;
    x /= cfg.ranks_per_channel as u64;
    let row = x % cfg.rows_per_bank;
    Coord { channel, rank, bank, row }
}

/// Total number of banks in a device.
pub fn total_banks(cfg: &MemConfig) -> usize {
    cfg.channels * cfg.ranks_per_channel * cfg.banks_per_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn decode_within_bounds() {
        let cfg = Config::paper().nvm;
        for addr in [0u64, 64, 4096, 1 << 20, (32u64 << 30) - 64] {
            let c = decode(&cfg, addr);
            assert!(c.channel < cfg.channels);
            assert!(c.rank < cfg.ranks_per_channel);
            assert!(c.bank < cfg.banks_per_rank);
            assert!(c.row < cfg.rows_per_bank);
            assert!(c.bank_index(&cfg) < total_banks(&cfg));
        }
    }

    #[test]
    fn consecutive_lines_stripe_channels() {
        let cfg = Config::paper().nvm; // 4 channels
        let c0 = decode(&cfg, 0);
        let c1 = decode(&cfg, 64);
        let c2 = decode(&cfg, 128);
        assert_ne!(c0.channel, c1.channel);
        assert_ne!(c1.channel, c2.channel);
    }

    #[test]
    fn same_row_for_adjacent_columns() {
        let cfg = Config::paper().dram; // 1 channel, 64-col rows
        let a = decode(&cfg, 0);
        let b = decode(&cfg, 64); // next line, same row (different col)
        assert_eq!(a.row, b.row);
        assert_eq!(a.bank, b.bank);
    }

    #[test]
    fn paper_bank_counts() {
        let p = Config::paper();
        assert_eq!(total_banks(&p.dram), 32); // 1 ch x 4 ranks x 8 banks
        assert_eq!(total_banks(&p.nvm), 256); // 4 ch x 8 ranks x 8 banks
    }
}
