//! FR-FCFS bulk-transfer scheduler.
//!
//! Demand requests are blocking (the core waits), so they never queue up
//! behind each other; what does queue is *migration* traffic — whole 4 KB
//! pages (or 2 MB superpages for HSCC-2MB-mig) copied between devices.
//! This scheduler issues those line transfers First-Ready (row-buffer hits
//! first within the ready window), First-Come-First-Served otherwise, and
//! returns the completion time so migration cost lands on the clock the
//! paper's `T_mig` models.

use super::device::Device;
use super::req::MemReq;

/// Outcome of a bulk page copy.
#[derive(Clone, Copy, Debug, Default)]
pub struct CopyResult {
    /// Cycle at which the last line landed.
    pub done_at: u64,
    pub energy_pj: f64,
    pub bytes: u64,
}

/// Copy `bytes` from `src_addr` in `src` to `dst_addr` in `dst`,
/// starting at `now`. Lines are issued FR-FCFS per device: we sort the
/// line offsets so that lines sharing a row go back-to-back (first-ready),
/// which is what a real FR-FCFS front end converges to for a streaming
/// copy.
pub fn copy_page(
    src: &mut Device,
    dst: &mut Device,
    src_addr: u64,
    dst_addr: u64,
    bytes: u64,
    now: u64,
) -> CopyResult {
    let lines = bytes.div_ceil(64);
    let mut energy = 0.0;
    let mut t_read = now;
    let mut done = now;
    for i in 0..lines {
        let off = i * 64;
        // Read from source (pipelined: next read can start as soon as the
        // source bank frees, not when the write lands).
        let r = src.access(t_read, &MemReq::bulk(src_addr + off, false, 64));
        let read_done = t_read + r.latency;
        energy += r.energy_pj;
        // Write to destination once the line is available.
        let w = dst.access(read_done, &MemReq::bulk(dst_addr + off, true, 64));
        energy += w.energy_pj;
        done = read_done + w.latency;
        // The next source read can issue as soon as the source is free.
        t_read = src.free_at(src_addr + off + 64).max(now);
    }
    CopyResult { done_at: done, energy_pj: energy, bytes }
}

/// Write back `bytes` from DRAM to NVM (dirty-page eviction path).
pub fn writeback_page(
    dram: &mut Device,
    nvm: &mut Device,
    dram_addr: u64,
    nvm_addr: u64,
    bytes: u64,
    now: u64,
) -> CopyResult {
    copy_page(dram, nvm, dram_addr, nvm_addr, bytes, now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn pair() -> (Device, Device) {
        let c = Config::paper();
        (Device::new(c.nvm), Device::new(c.dram))
    }

    #[test]
    fn copy_4k_page_costs_roughly_t_mig() {
        let (mut nvm, mut dram) = pair();
        let r = copy_page(&mut nvm, &mut dram, 0, 0, 4096, 0);
        let cycles = r.done_at;
        // Paper's T_mig for 4 KB is ~4096 cycles; our device-level model
        // should land in the same order of magnitude (0.5x..4x).
        assert!(cycles > 1000 && cycles < 20_000, "cycles={cycles}");
        assert_eq!(r.bytes, 4096);
        assert!(r.energy_pj > 0.0);
    }

    #[test]
    fn superpage_copy_is_hundreds_of_times_costlier() {
        let (mut nvm, mut dram) = pair();
        let small = copy_page(&mut nvm, &mut dram, 0, 0, 4096, 0).done_at;
        let (mut nvm2, mut dram2) = pair();
        let big = copy_page(&mut nvm2, &mut dram2, 0, 0, 2 << 20, 0).done_at;
        let ratio = big as f64 / small as f64;
        assert!(ratio > 50.0, "2MB/4KB cost ratio {ratio} too small");
    }

    #[test]
    fn writeback_hits_nvm_write_energy() {
        let c = Config::paper();
        let mut dram = Device::new(c.dram);
        let mut nvm = Device::new(c.nvm);
        let r = writeback_page(&mut dram, &mut nvm, 0, 0, 4096, 0);
        // PCM write at 1684.8 pJ/bit on misses dominates: >> 4096*8*10 pJ.
        assert!(r.energy_pj > 4096.0 * 8.0 * 10.0, "e={}", r.energy_pj);
    }

    #[test]
    fn copy_interleaves_reads_and_writes() {
        let lines = 64u64; // one 4 KB page
        let (mut nvm, mut dram) = pair();
        let r = copy_page(&mut nvm, &mut dram, 0, 0, lines * 64, 0);
        // Every line is one source read + one destination write.
        assert_eq!(nvm.stats.reads, lines);
        assert_eq!(nvm.stats.writes, 0);
        assert_eq!(dram.stats.writes, lines);
        assert_eq!(dram.stats.reads, 0);
        // The interleave pipelines: line i+1's read overlaps line i's
        // write, so the copy beats a fully serialized read→write→read…
        // chain, while each write still waits for its own read.
        let (mut nvm2, mut dram2) = pair();
        let mut serial = 0;
        for i in 0..lines {
            let rr = nvm2.access(serial, &MemReq::bulk(i * 64, false, 64));
            serial += rr.latency;
            let ww = dram2.access(serial, &MemReq::bulk(i * 64, true, 64));
            serial += ww.latency;
        }
        assert!(r.done_at < serial,
                "pipelined copy {} must beat serialized {}",
                r.done_at, serial);
        let first_read = {
            let (mut n3, _) = pair();
            n3.access(0, &MemReq::bulk(0, false, 64)).latency
        };
        assert!(r.done_at > first_read,
                "the first write cannot land before its read completes");
    }

    #[test]
    fn copy_energy_attributed_to_both_devices() {
        let (mut nvm, mut dram) = pair();
        let r = copy_page(&mut nvm, &mut dram, 0, 0, 4096, 0);
        assert!(nvm.stats.energy_pj > 0.0, "source reads draw energy");
        assert!(dram.stats.energy_pj > 0.0, "destination writes draw energy");
        let total = nvm.stats.energy_pj + dram.stats.energy_pj;
        assert!((total - r.energy_pj).abs() <= 1e-6 * total,
                "copy energy {} must equal the two devices' rollup {total}",
                r.energy_pj);
        // ...and the traffic is accounted as bulk on both sides.
        assert_eq!(nvm.stats.bulk_bytes, 4096);
        assert_eq!(dram.stats.bulk_bytes, 4096);
    }

    #[test]
    fn copy_contends_with_in_flight_demand_traffic() {
        // The Fig. 11 assumption stated in device.rs: bulk migration
        // occupies the same banks/channels as demand traffic, in both
        // directions.
        // (a) A demand read in flight on the source bank delays the copy.
        let (mut nvm, mut dram) = pair();
        let free = copy_page(&mut nvm, &mut dram, 0, 0, 4096, 0).done_at;
        let (mut nvm2, mut dram2) = pair();
        nvm2.access(0, &MemReq::line_read(0)); // occupies bank 0 at t=0
        let w0 = nvm2.stats.wait_cycles;
        let busy = copy_page(&mut nvm2, &mut dram2, 0, 0, 4096, 0).done_at;
        assert!(nvm2.stats.wait_cycles > w0,
                "the copy's reads must queue behind the demand read");
        assert!(busy > free, "contended copy {busy} vs uncontended {free}");
        // (b) A demand read issued during the copy queues behind it.
        let (mut nvm3, mut dram3) = pair();
        copy_page(&mut nvm3, &mut dram3, 0, 0, 4096, 0);
        let w1 = nvm3.stats.wait_cycles;
        nvm3.access(0, &MemReq::line_read(0));
        assert!(nvm3.stats.wait_cycles > w1,
                "demand traffic must queue behind bulk migration");
    }

    #[test]
    fn copy_monotone_in_time() {
        let (mut nvm, mut dram) = pair();
        let a = copy_page(&mut nvm, &mut dram, 0, 0, 4096, 1000);
        assert!(a.done_at > 1000);
        let b = copy_page(&mut nvm, &mut dram, 8192, 4096, 4096, a.done_at);
        assert!(b.done_at > a.done_at);
    }
}
