//! Hybrid memory controller: routes physical addresses to the fast
//! (DRAM-slot) or slow (NVM-slot) device and owns the energy rollup.
//! The slots are positional — which *technology* sits in each comes
//! from the config/profile bundle ([`HybridMemory::tier_techs`]).
//!
//! Physical address map (all policies):
//!   [0, dram.size)                  -> DRAM
//!   [dram.size, dram.size+nvm.size) -> NVM (device-local = paddr - base)

use crate::config::Config;
use crate::telemetry::{EventKind, Telemetry};

use super::device::Device;
use super::req::{MemKind, MemReq, MemResult};
use super::sched::{copy_page, CopyResult};

/// The hybrid memory system: one DRAM + one NVM device behind one
/// controller facade.
#[derive(Clone, Debug)]
pub struct HybridMemory {
    pub dram: Device,
    pub nvm: Device,
    dram_size: u64,
    cpu_ghz: f64,
}

impl HybridMemory {
    pub fn new(cfg: &Config) -> HybridMemory {
        HybridMemory {
            dram: Device::new(cfg.dram),
            nvm: Device::new(cfg.nvm),
            dram_size: cfg.dram.size,
            cpu_ghz: cfg.cpu_ghz,
        }
    }

    pub fn dram_size(&self) -> u64 {
        self.dram_size
    }

    /// Technology identity of the (fast, slow) tiers.
    pub fn tier_techs(&self) -> (crate::config::MemTech,
                                 crate::config::MemTech) {
        (self.dram.tech(), self.nvm.tech())
    }

    /// NVM addresses start here in the flat physical map.
    pub fn nvm_base(&self) -> u64 {
        self.dram_size
    }

    pub fn kind_of(&self, paddr: u64) -> MemKind {
        if paddr < self.dram_size {
            MemKind::Dram
        } else {
            MemKind::Nvm
        }
    }

    /// Access a flat physical address at `now`.
    pub fn access(&mut self, now: u64, paddr: u64, is_write: bool,
                  bytes: u64) -> MemResult {
        let req = MemReq { addr: self.local(paddr), is_write, bytes,
                           is_bulk: false };
        match self.kind_of(paddr) {
            MemKind::Dram => self.dram.access(now, &req),
            MemKind::Nvm => self.nvm.access(now, &req),
        }
    }

    /// Flat-latency metadata read (page-table walks, remap pointers) at a
    /// physical address — see `Device::flat_read`.
    pub fn table_ref(&mut self, paddr: u64, bytes: u64) -> MemResult {
        match self.kind_of(paddr) {
            MemKind::Dram => self.dram.flat_read(bytes),
            MemKind::Nvm => self.nvm.flat_read(bytes),
        }
    }

    /// Bulk page copy between flat physical addresses (migration).
    /// Stamps `migration_start`/`migration_done` telemetry events
    /// (frame numbers + completion latency) when the sink is enabled.
    pub fn migrate(&mut self, now: u64, src: u64, dst: u64, bytes: u64,
                   tel: &mut Telemetry) -> CopyResult {
        tel.event(now, EventKind::MigrationStart, src >> 12, dst >> 12);
        let r = self.migrate_inner(now, src, dst, bytes);
        tel.event(r.done_at, EventKind::MigrationDone, dst >> 12,
                  r.done_at - now);
        r
    }

    fn migrate_inner(&mut self, now: u64, src: u64, dst: u64, bytes: u64)
                     -> CopyResult {
        let (src_kind, dst_kind) = (self.kind_of(src), self.kind_of(dst));
        let (src_local, dst_local) = (self.local(src), self.local(dst));
        match (src_kind, dst_kind) {
            (MemKind::Nvm, MemKind::Dram) => copy_page(
                &mut self.nvm, &mut self.dram, src_local, dst_local, bytes, now),
            (MemKind::Dram, MemKind::Nvm) => copy_page(
                &mut self.dram, &mut self.nvm, src_local, dst_local, bytes, now),
            (MemKind::Dram, MemKind::Dram) => {
                // Same-device copy: model as read+write through one device.
                // Split borrow via a temporary clone-free two-phase access.
                let lines = bytes.div_ceil(64);
                let mut t = now;
                let mut energy = 0.0;
                for i in 0..lines {
                    let r = self.dram.access(
                        t, &MemReq::bulk(src_local + i * 64, false, 64));
                    let w = self.dram.access(
                        t + r.latency,
                        &MemReq::bulk(dst_local + i * 64, true, 64));
                    energy += r.energy_pj + w.energy_pj;
                    t += r.latency + w.latency;
                }
                CopyResult { done_at: t, energy_pj: energy, bytes }
            }
            (MemKind::Nvm, MemKind::Nvm) => {
                // Same-device copy through the NVM (rare: compaction paths).
                let lines = bytes.div_ceil(64);
                let mut t = now;
                let mut energy = 0.0;
                for i in 0..lines {
                    let r = self.nvm.access(
                        t, &MemReq::bulk(src_local + i * 64, false, 64));
                    let w = self.nvm.access(
                        t + r.latency,
                        &MemReq::bulk(dst_local + i * 64, true, 64));
                    energy += r.energy_pj + w.energy_pj;
                    t += r.latency + w.latency;
                }
                CopyResult { done_at: t, energy_pj: energy, bytes }
            }
        }
    }

    fn local(&self, paddr: u64) -> u64 {
        if paddr < self.dram_size {
            paddr
        } else {
            paddr - self.dram_size
        }
    }

    /// Total energy (dynamic + background over `elapsed_cycles`), in pJ.
    pub fn total_energy_pj(&self, elapsed_cycles: u64) -> f64 {
        self.dram.stats.energy_pj
            + self.nvm.stats.energy_pj
            + self.dram.background_energy_pj(elapsed_cycles, self.cpu_ghz)
            + self.nvm.background_energy_pj(elapsed_cycles, self.cpu_ghz)
    }

    /// Total migration (bulk) bytes moved in either direction.
    pub fn migration_bytes(&self) -> u64 {
        self.dram.stats.bulk_bytes + self.nvm.stats.bulk_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mem() -> HybridMemory {
        HybridMemory::new(&Config::paper())
    }

    #[test]
    fn tier_techs_follow_the_profile_bundles() {
        use crate::config::{profiles, MemTech};
        let mut cfg = Config::paper();
        assert_eq!(mem().tier_techs(), (MemTech::Dram, MemTech::Pcm));
        cfg.nvm = profiles::by_name("cxl-remote").unwrap().mem();
        let m = HybridMemory::new(&cfg);
        assert_eq!(m.tier_techs(), (MemTech::Dram, MemTech::CxlDram));
    }

    #[test]
    fn address_map_routes_correctly() {
        let m = mem();
        assert_eq!(m.kind_of(0), MemKind::Dram);
        assert_eq!(m.kind_of((4 << 30) - 1), MemKind::Dram);
        assert_eq!(m.kind_of(4 << 30), MemKind::Nvm);
        assert_eq!(m.nvm_base(), 4 << 30);
    }

    #[test]
    fn dram_faster_than_nvm() {
        let mut m = mem();
        let d = m.access(0, 0, false, 64);
        let n = m.access(0, m.nvm_base(), false, 64);
        assert!(d.latency < n.latency);
    }

    #[test]
    fn migration_counted_as_bulk() {
        let mut m = mem();
        let nvm_page = m.nvm_base() + 4096;
        let r = m.migrate(0, nvm_page, 0, 4096, &mut Telemetry::default());
        assert_eq!(r.bytes, 4096);
        assert_eq!(m.nvm.stats.bulk_bytes, 4096);
        assert_eq!(m.dram.stats.bulk_bytes, 4096);
        assert_eq!(m.migration_bytes(), 8192);
    }

    #[test]
    fn migration_emits_cycle_stamped_events() {
        let mut m = mem();
        let mut tel = Telemetry::default();
        tel.enable(8, 8);
        let nvm_page = m.nvm_base() + 4096;
        let r = m.migrate(100, nvm_page, 0, 4096, &mut tel);
        let ev: Vec<_> = tel.events().collect();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].kind, EventKind::MigrationStart);
        assert_eq!(ev[0].cycle, 100);
        assert_eq!((ev[0].a, ev[0].b), (nvm_page >> 12, 0));
        assert_eq!(ev[1].kind, EventKind::MigrationDone);
        assert_eq!(ev[1].cycle, r.done_at);
        assert_eq!(ev[1].b, r.done_at - 100);
    }

    #[test]
    fn energy_rollup_includes_background() {
        let mut m = mem();
        m.access(0, 0, true, 64);
        let e_short = m.total_energy_pj(1_000);
        let e_long = m.total_energy_pj(1_000_000_000);
        assert!(e_long > e_short, "background term must grow with time");
    }
}
