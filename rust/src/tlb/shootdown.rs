//! TLB shootdown cost model (§III-F, citing Black et al.).
//!
//! When a page's mapping changes (HSCC migrations, Rainbow DRAM->NVM
//! evictions), the initiating core interrupts every other core, each
//! invalidates its local entry, and the initiator waits for all acks.
//! Cost = fixed IPI/sync latency plus a small per-responding-core term;
//! the paper models this with "reasonable latencies", we use
//! `t_shootdown` from the config as the 8-core full-broadcast cost.

use crate::config::Config;
use crate::telemetry::{EventKind, Telemetry};

use super::split::CoreTlbs;

#[derive(Clone, Debug, Default)]
pub struct ShootdownStats {
    pub shootdowns: u64,
    pub cycles: u64,
    pub entries_invalidated: u64,
}

/// Broadcast invalidation of a 4 KB translation across all cores.
/// Returns the cycles charged to the initiating core. Stamps a
/// `shootdown` telemetry event (vpn + holder count) when enabled.
pub fn shootdown_4k(
    cfg: &Config,
    tlbs: &mut [CoreTlbs],
    vpn: u64,
    stats: &mut ShootdownStats,
    tel: &mut Telemetry,
    now: u64,
) -> u64 {
    let mut present = 0u64;
    for t in tlbs.iter_mut() {
        if t.invalidate_4k(vpn) {
            present += 1;
        }
    }
    tel.event(now, EventKind::Shootdown, vpn, present);
    charge(cfg, present, stats)
}

/// Broadcast invalidation of a 2 MB translation across all cores.
pub fn shootdown_2m(
    cfg: &Config,
    tlbs: &mut [CoreTlbs],
    vpn: u64,
    stats: &mut ShootdownStats,
    tel: &mut Telemetry,
    now: u64,
) -> u64 {
    let mut present = 0u64;
    for t in tlbs.iter_mut() {
        if t.invalidate_2m(vpn) {
            present += 1;
        }
    }
    tel.event(now, EventKind::Shootdown, vpn, present);
    charge(cfg, present, stats)
}

fn charge(cfg: &Config, present: u64, stats: &mut ShootdownStats) -> u64 {
    // Base IPI broadcast + wait; scaled mildly by how many cores actually
    // held the entry (they must ack after invalidating).
    let cycles = cfg.t_shootdown + present * (cfg.t_shootdown / 16);
    stats.shootdowns += 1;
    stats.cycles += cycles;
    stats.entries_invalidated += present;
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shootdown_removes_entry_everywhere() {
        let cfg = Config::paper();
        let mut tlbs: Vec<CoreTlbs> =
            (0..4).map(|_| CoreTlbs::new(&cfg)).collect();
        for t in &mut tlbs {
            t.insert_4k(77, 700);
        }
        let mut st = ShootdownStats::default();
        let mut tel = Telemetry::default();
        tel.enable(8, 8);
        let c = shootdown_4k(&cfg, &mut tlbs, 77, &mut st, &mut tel, 42);
        assert!(c >= cfg.t_shootdown);
        assert_eq!(st.entries_invalidated, 4);
        let ev: Vec<_> = tel.events().collect();
        assert_eq!(ev.len(), 1);
        assert_eq!((ev[0].cycle, ev[0].a, ev[0].b), (42, 77, 4));
        for t in &mut tlbs {
            assert_eq!(t.lookup(77 << 12).small.ppn, None);
        }
    }

    #[test]
    fn absent_entry_still_pays_broadcast() {
        let cfg = Config::paper();
        let mut tlbs: Vec<CoreTlbs> =
            (0..2).map(|_| CoreTlbs::new(&cfg)).collect();
        let mut st = ShootdownStats::default();
        let c = shootdown_2m(&cfg, &mut tlbs, 123, &mut st,
                             &mut Telemetry::default(), 0);
        assert_eq!(c, cfg.t_shootdown);
        assert_eq!(st.entries_invalidated, 0);
        assert_eq!(st.shootdowns, 1);
    }

    #[test]
    fn more_holders_cost_more() {
        let cfg = Config::paper();
        let mut st = ShootdownStats::default();
        let few = charge(&cfg, 1, &mut st);
        let many = charge(&cfg, 8, &mut st);
        assert!(many > few);
    }
}
