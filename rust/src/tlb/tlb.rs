//! Set-associative TLB, generic over page size.
//!
//! Keys are virtual page numbers (already shifted by the page-size shift);
//! payload is the physical page number. True-LRU within a set, like the
//! split data TLBs of Table IV (4-way L1, 8-way L2).

#[derive(Clone, Copy, Debug, Default)]
struct Entry {
    vpn: u64,
    ppn: u64,
    valid: bool,
    lru: u64,
}

#[derive(Clone, Debug, Default)]
pub struct TlbStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub shootdowns: u64,
}

impl TlbStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let t = self.accesses();
        if t == 0 { 0.0 } else { self.hits as f64 / t as f64 }
    }
}

#[derive(Clone, Debug)]
pub struct Tlb {
    sets: usize,
    assoc: usize,
    entries: Vec<Entry>,
    tick: u64,
    pub latency: u64,
    pub stats: TlbStats,
}

impl Tlb {
    pub fn new(n_entries: usize, assoc: usize, latency: u64) -> Tlb {
        assert!(assoc > 0 && n_entries % assoc == 0,
                "entries {n_entries} not divisible by assoc {assoc}");
        let sets = n_entries / assoc;
        assert!(sets.is_power_of_two(), "TLB sets must be 2^k (got {sets})");
        Tlb {
            sets,
            assoc,
            entries: vec![Entry::default(); n_entries],
            tick: 0,
            latency,
            stats: TlbStats::default(),
        }
    }

    #[inline]
    fn set_of(&self, vpn: u64) -> usize {
        (vpn as usize) & (self.sets - 1)
    }

    /// Translate `vpn`; returns `Some(ppn)` on hit (LRU refreshed).
    pub fn lookup(&mut self, vpn: u64) -> Option<u64> {
        self.tick += 1;
        let base = self.set_of(vpn) * self.assoc;
        for i in base..base + self.assoc {
            let e = &mut self.entries[i];
            if e.valid && e.vpn == vpn {
                e.lru = self.tick;
                self.stats.hits += 1;
                return Some(e.ppn);
            }
        }
        self.stats.misses += 1;
        None
    }

    /// Probe without statistics/LRU effects.
    pub fn contains(&self, vpn: u64) -> bool {
        let base = self.set_of(vpn) * self.assoc;
        self.entries[base..base + self.assoc]
            .iter()
            .any(|e| e.valid && e.vpn == vpn)
    }

    /// Install (or update) a translation; returns the displaced valid
    /// entry's (vpn, ppn) if an eviction happened.
    pub fn insert(&mut self, vpn: u64, ppn: u64) -> Option<(u64, u64)> {
        self.tick += 1;
        let base = self.set_of(vpn) * self.assoc;
        // Update in place if present.
        for i in base..base + self.assoc {
            let e = &mut self.entries[i];
            if e.valid && e.vpn == vpn {
                e.ppn = ppn;
                e.lru = self.tick;
                return None;
            }
        }
        let mut victim = base;
        let mut best = u64::MAX;
        for i in base..base + self.assoc {
            let e = &self.entries[i];
            if !e.valid {
                victim = i;
                best = 0;
                break;
            }
            if e.lru < best {
                best = e.lru;
                victim = i;
            }
        }
        let old = self.entries[victim];
        let evicted = if old.valid {
            self.stats.evictions += 1;
            Some((old.vpn, old.ppn))
        } else {
            None
        };
        self.entries[victim] = Entry { vpn, ppn, valid: true, lru: self.tick };
        evicted
    }

    /// Invalidate a translation (shootdown); true if it was present.
    pub fn invalidate(&mut self, vpn: u64) -> bool {
        let base = self.set_of(vpn) * self.assoc;
        for i in base..base + self.assoc {
            let e = &mut self.entries[i];
            if e.valid && e.vpn == vpn {
                e.valid = false;
                self.stats.shootdowns += 1;
                return true;
            }
        }
        false
    }

    pub fn flush_all(&mut self) {
        for e in &mut self.entries {
            e.valid = false;
        }
    }

    pub fn occupancy(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tlb() -> Tlb {
        Tlb::new(32, 4, 1)
    }

    #[test]
    fn miss_insert_hit() {
        let mut t = tlb();
        assert_eq!(t.lookup(0x42), None);
        t.insert(0x42, 0x99);
        assert_eq!(t.lookup(0x42), Some(0x99));
        assert_eq!(t.stats.hits, 1);
        assert_eq!(t.stats.misses, 1);
    }

    #[test]
    fn insert_updates_existing() {
        let mut t = tlb();
        t.insert(5, 10);
        assert_eq!(t.insert(5, 20), None);
        assert_eq!(t.lookup(5), Some(20));
    }

    #[test]
    fn lru_eviction_in_set() {
        let mut t = Tlb::new(8, 2, 1); // 4 sets x 2 ways
        // vpns 0, 4, 8 all map to set 0.
        t.insert(0, 100);
        t.insert(4, 104);
        t.lookup(0); // refresh 0
        let ev = t.insert(8, 108);
        assert_eq!(ev, Some((4, 104)));
        assert!(t.contains(0) && t.contains(8) && !t.contains(4));
    }

    #[test]
    fn invalidate_is_shootdown() {
        let mut t = tlb();
        t.insert(7, 70);
        assert!(t.invalidate(7));
        assert!(!t.invalidate(7));
        assert_eq!(t.lookup(7), None);
        assert_eq!(t.stats.shootdowns, 1);
    }

    /// Shootdown-then-refill edge: the invalidated way must absorb the
    /// next fill in its set instead of evicting a still-valid LRU entry.
    #[test]
    fn shootdown_slot_reused_before_lru_eviction() {
        let mut t = Tlb::new(8, 2, 1); // 4 sets x 2 ways
        // vpns 0, 4, 8 all map to set 0.
        t.insert(0, 100);
        t.insert(4, 104);
        t.lookup(4); // 4 MRU, 0 LRU
        assert!(t.invalidate(4)); // shootdown mid-set
        let ev = t.insert(8, 108);
        assert_eq!(ev, None, "invalid way must absorb the refill");
        assert_eq!(t.stats.evictions, 0);
        assert!(t.contains(0) && t.contains(8) && !t.contains(4));
    }

    /// A refill after a shootdown gets a *fresh* LRU stamp (it is the MRU
    /// of its set), and serves the new translation, never the stale one —
    /// the exact lifecycle of a migrated page's 4 KB entry.
    #[test]
    fn refill_after_shootdown_is_mru_with_new_ppn() {
        let mut t = Tlb::new(8, 2, 1);
        t.insert(0, 100);
        t.insert(4, 104);
        t.lookup(0); // 0 MRU, 4 LRU
        assert!(t.invalidate(0));
        t.insert(0, 200); // refill post-migration with the new frame
        // The refilled entry must be MRU: a conflicting insert evicts 4.
        let ev = t.insert(8, 108);
        assert_eq!(ev, Some((4, 104)),
                   "refilled entry must not be the eviction victim");
        assert_eq!(t.lookup(0), Some(200), "refill serves the new ppn");
    }

    #[test]
    fn flush_all_empties() {
        let mut t = tlb();
        for i in 0..32 {
            t.insert(i, i);
        }
        assert!(t.occupancy() > 0);
        t.flush_all();
        assert_eq!(t.occupancy(), 0);
    }

    #[test]
    fn paper_geometries_construct() {
        Tlb::new(32, 4, 1); // L1: 32-entry 4-way
        Tlb::new(512, 8, 8); // L2: 512-entry 8-way
    }
}
