//! Hardware page-table walker cost model.
//!
//! x86-64 semantics per §III-E: a 4 KB translation walks 4 levels
//! (4 memory references), a 2 MB superpage translation walks 3. Each
//! reference is a real 8-byte read issued to the memory device holding the
//! page tables, so walk cost responds to device latency exactly as the
//! paper's analytic model (4·t_dr vs 3·t_nr + remap) assumes. MMU caches
//! are deliberately not modeled — the paper's analysis charges full walks.

use crate::mem::HybridMemory;

/// Where a process's page tables live in physical memory.
#[derive(Clone, Copy, Debug)]
pub struct WalkerConfig {
    /// Base flat physical address of the page-table pool.
    pub table_base: u64,
    /// Pool size in bytes (walk targets are hashed into this window).
    pub table_len: u64,
}

#[derive(Clone, Debug, Default)]
pub struct WalkStats {
    pub walks_4k: u64,
    pub walks_2m: u64,
    pub cycles_4k: u64,
    pub cycles_2m: u64,
}

/// The walker: stateless except for statistics.
#[derive(Clone, Debug)]
pub struct Walker {
    pub cfg: WalkerConfig,
    pub stats: WalkStats,
    levels_4k: u64,
    levels_2m: u64,
}

impl Walker {
    pub fn new(cfg: WalkerConfig, levels_4k: u64, levels_2m: u64) -> Walker {
        Walker { cfg, stats: WalkStats::default(), levels_4k, levels_2m }
    }

    /// Deterministic pseudo-address for level `l` of the walk of `vpn`.
    fn table_addr(&self, vpn: u64, l: u64) -> u64 {
        // Fibonacci hashing keeps walks spread across table banks/rows.
        let h = (vpn.wrapping_mul(0x9E3779B97F4A7C15)).rotate_left((7 * l) as u32)
            ^ l.wrapping_mul(0xD1B54A32D192ED03);
        self.cfg.table_base + (h % (self.cfg.table_len / 8)) * 8
    }

    /// Walk for a 4 KB translation; returns cycles spent. Each level is a
    /// flat-latency table reference (paper §III-E: cost = 4·t_dr).
    pub fn walk_4k(&mut self, mem: &mut HybridMemory, vpn: u64,
                   _now: u64) -> u64 {
        let mut cycles = 0;
        for l in 0..self.levels_4k {
            cycles += mem.table_ref(self.table_addr(vpn, l), 8).latency;
        }
        self.stats.walks_4k += 1;
        self.stats.cycles_4k += cycles;
        cycles
    }

    /// Walk for a 2 MB translation (one fewer level: 3·t_nr for Rainbow's
    /// NVM-resident superpage tables).
    pub fn walk_2m(&mut self, mem: &mut HybridMemory, vpn: u64,
                   _now: u64) -> u64 {
        let mut cycles = 0;
        for l in 0..self.levels_2m {
            cycles +=
                mem.table_ref(self.table_addr(vpn ^ 0x5555, l), 8).latency;
        }
        self.stats.walks_2m += 1;
        self.stats.cycles_2m += cycles;
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn setup(in_nvm: bool) -> (Walker, HybridMemory) {
        let cfg = Config::paper();
        let mem = HybridMemory::new(&cfg);
        let base = if in_nvm { mem.nvm_base() } else { 0 };
        let w = Walker::new(
            WalkerConfig { table_base: base, table_len: 16 << 20 },
            cfg.ptw_levels_4k,
            cfg.ptw_levels_2m,
        );
        (w, mem)
    }

    #[test]
    fn walk_4k_is_four_references() {
        let (mut w, mut mem) = setup(false);
        let before = mem.dram.stats.reads;
        w.walk_4k(&mut mem, 42, 0);
        assert_eq!(mem.dram.stats.reads - before, 4);
        assert_eq!(w.stats.walks_4k, 1);
        assert!(w.stats.cycles_4k >= 4 * 43);
    }

    #[test]
    fn walk_2m_is_three_references() {
        let (mut w, mut mem) = setup(false);
        let before = mem.dram.stats.reads;
        w.walk_2m(&mut mem, 42, 0);
        assert_eq!(mem.dram.stats.reads - before, 3);
    }

    #[test]
    fn nvm_tables_cost_more() {
        let (mut wd, mut md) = setup(false);
        let (mut wn, mut mn) = setup(true);
        let cd = wd.walk_2m(&mut md, 7, 0);
        let cn = wn.walk_2m(&mut mn, 7, 0);
        assert!(cn > cd, "NVM walk {cn} <= DRAM walk {cd}");
    }

    #[test]
    fn walks_are_deterministic() {
        let (mut w1, mut m1) = setup(false);
        let (mut w2, mut m2) = setup(false);
        assert_eq!(w1.walk_4k(&mut m1, 9, 0), w2.walk_4k(&mut m2, 9, 0));
    }
}
