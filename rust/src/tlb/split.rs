//! Per-core split TLBs: a two-level hierarchy for each page size
//! (4 KB and 2 MB), consulted in parallel as §II-A / §III-E describe.
//!
//! The lookup result distinguishes the four cases of Fig. 6:
//! (1) 4K hit + SP hit, (2) 4K hit + SP miss, (3) 4K miss + SP hit,
//! (4) both miss — the policy decides what each case costs.

use crate::config::{Config, PAGE_SHIFT, SP_SHIFT};

use super::tlb::Tlb;

/// Which level produced a hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HitLevel {
    L1,
    L2,
    Miss,
}

/// Outcome of one page-size lookup through L1 then L2.
#[derive(Clone, Copy, Debug)]
pub struct SizedLookup {
    pub level: HitLevel,
    pub ppn: Option<u64>,
    /// Cycles charged for this lookup path.
    pub cycles: u64,
}

/// The two split lookups performed in parallel (Fig. 6): total latency is
/// the max of the two paths, not the sum.
#[derive(Clone, Copy, Debug)]
pub struct SplitLookup {
    pub small: SizedLookup,
    pub sp: SizedLookup,
}

impl SplitLookup {
    pub fn cycles(&self) -> u64 {
        self.small.cycles.max(self.sp.cycles)
    }
}

/// One core's split TLBs.
#[derive(Clone, Debug)]
pub struct CoreTlbs {
    pub l1_4k: Tlb,
    pub l1_2m: Tlb,
    pub l2_4k: Tlb,
    pub l2_2m: Tlb,
}

impl CoreTlbs {
    pub fn new(cfg: &Config) -> CoreTlbs {
        CoreTlbs {
            l1_4k: Tlb::new(cfg.l1_tlb_4k.entries, cfg.l1_tlb_4k.assoc,
                            cfg.l1_tlb_4k.latency),
            l1_2m: Tlb::new(cfg.l1_tlb_2m.entries, cfg.l1_tlb_2m.assoc,
                            cfg.l1_tlb_2m.latency),
            l2_4k: Tlb::new(cfg.l2_tlb_4k.entries, cfg.l2_tlb_4k.assoc,
                            cfg.l2_tlb_4k.latency),
            l2_2m: Tlb::new(cfg.l2_tlb_2m.entries, cfg.l2_tlb_2m.assoc,
                            cfg.l2_tlb_2m.latency),
        }
    }

    fn lookup_sized(l1: &mut Tlb, l2: &mut Tlb, vpn: u64) -> SizedLookup {
        let mut cycles = l1.latency;
        if let Some(ppn) = l1.lookup(vpn) {
            return SizedLookup { level: HitLevel::L1, ppn: Some(ppn), cycles };
        }
        cycles += l2.latency;
        if let Some(ppn) = l2.lookup(vpn) {
            // Promote into L1 (victim falls back into L2).
            if let Some((evpn, eppn)) = l1.insert(vpn, ppn) {
                l2.insert(evpn, eppn);
            }
            return SizedLookup { level: HitLevel::L2, ppn: Some(ppn), cycles };
        }
        SizedLookup { level: HitLevel::Miss, ppn: None, cycles }
    }

    /// 4 KB-only lookup (flat systems leave the superpage TLBs idle,
    /// §II-A).
    pub fn lookup_4k(&mut self, vaddr: u64) -> SizedLookup {
        Self::lookup_sized(&mut self.l1_4k, &mut self.l2_4k,
                           vaddr >> PAGE_SHIFT)
    }

    /// 2 MB-only lookup (superpage-only systems).
    pub fn lookup_2m(&mut self, vaddr: u64) -> SizedLookup {
        Self::lookup_sized(&mut self.l1_2m, &mut self.l2_2m,
                           vaddr >> SP_SHIFT)
    }

    /// Parallel split lookup of a virtual address.
    pub fn lookup(&mut self, vaddr: u64) -> SplitLookup {
        let small =
            Self::lookup_sized(&mut self.l1_4k, &mut self.l2_4k,
                               vaddr >> PAGE_SHIFT);
        let sp = Self::lookup_sized(&mut self.l1_2m, &mut self.l2_2m,
                                    vaddr >> SP_SHIFT);
        SplitLookup { small, sp }
    }

    /// Install a 4 KB translation (fill both levels, L1 victim demotes).
    pub fn insert_4k(&mut self, vpn: u64, ppn: u64) {
        if let Some((evpn, eppn)) = self.l1_4k.insert(vpn, ppn) {
            self.l2_4k.insert(evpn, eppn);
        }
    }

    /// Install a 2 MB translation.
    pub fn insert_2m(&mut self, vpn: u64, ppn: u64) {
        if let Some((evpn, eppn)) = self.l1_2m.insert(vpn, ppn) {
            self.l2_2m.insert(evpn, eppn);
        }
    }

    /// Invalidate a 4 KB translation in both levels; true if present.
    pub fn invalidate_4k(&mut self, vpn: u64) -> bool {
        let a = self.l1_4k.invalidate(vpn);
        let b = self.l2_4k.invalidate(vpn);
        a || b
    }

    /// Invalidate a 2 MB translation in both levels; true if present.
    pub fn invalidate_2m(&mut self, vpn: u64) -> bool {
        let a = self.l1_2m.invalidate(vpn);
        let b = self.l2_2m.invalidate(vpn);
        a || b
    }

    /// Total 4 KB-side misses (L2-level, i.e. true misses needing a walk).
    pub fn misses_4k(&self) -> u64 {
        self.l2_4k.stats.misses
    }

    pub fn misses_2m(&self) -> u64 {
        self.l2_2m.stats.misses
    }

    /// Superpage TLB hit rate over both levels (paper §III-E's R_hit).
    pub fn sp_hit_rate(&self) -> f64 {
        let l1 = &self.l1_2m.stats;
        // Hits at either level count; accesses are L1 accesses.
        let acc = l1.accesses();
        if acc == 0 {
            return 0.0;
        }
        (l1.hits + self.l2_2m.stats.hits) as f64 / acc as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tlbs() -> CoreTlbs {
        CoreTlbs::new(&Config::paper())
    }

    #[test]
    fn parallel_lookup_takes_max_latency() {
        let mut t = tlbs();
        let r = t.lookup(0x12345678);
        // Both sides miss: each path is L1(1) + L2(8) = 9 cycles, in
        // parallel -> 9 total.
        assert_eq!(r.small.level, HitLevel::Miss);
        assert_eq!(r.sp.level, HitLevel::Miss);
        assert_eq!(r.cycles(), 9);
    }

    #[test]
    fn case3_sp_hit_small_miss() {
        let mut t = tlbs();
        let vaddr = 0x4000_0000u64;
        t.insert_2m(vaddr >> SP_SHIFT, 7);
        let r = t.lookup(vaddr);
        assert_eq!(r.small.level, HitLevel::Miss);
        assert_eq!(r.sp.level, HitLevel::L1);
        assert_eq!(r.sp.ppn, Some(7));
        // Small path pays 9, SP path pays 1: parallel max is 9.
        assert_eq!(r.cycles(), 9);
    }

    #[test]
    fn case1_both_hit_uses_small_path() {
        let mut t = tlbs();
        let vaddr = 0x4000_0000u64;
        t.insert_4k(vaddr >> PAGE_SHIFT, 100);
        t.insert_2m(vaddr >> SP_SHIFT, 7);
        let r = t.lookup(vaddr);
        assert_eq!(r.small.ppn, Some(100));
        assert_eq!(r.sp.ppn, Some(7));
        assert_eq!(r.cycles(), 1);
    }

    #[test]
    fn l2_hit_promotes_to_l1() {
        let mut t = tlbs();
        let vpn = 0x999u64;
        t.l2_4k.insert(vpn, 5);
        let r = t.lookup(vpn << PAGE_SHIFT);
        assert_eq!(r.small.level, HitLevel::L2);
        // Second lookup should now hit L1.
        let r2 = t.lookup(vpn << PAGE_SHIFT);
        assert_eq!(r2.small.level, HitLevel::L1);
    }

    #[test]
    fn shootdown_clears_both_levels() {
        let mut t = tlbs();
        t.insert_4k(3, 30);
        assert!(t.invalidate_4k(3));
        let r = t.lookup(3 << PAGE_SHIFT);
        assert_eq!(r.small.level, HitLevel::Miss);
    }

    /// Migration lifecycle across the two levels: shootdown, then refill
    /// with the page's new frame — the stale ppn must be unreachable.
    #[test]
    fn shootdown_refill_serves_new_translation() {
        let mut t = tlbs();
        let vpn = 0x42u64;
        t.insert_4k(vpn, 10);
        t.lookup(vpn << PAGE_SHIFT);
        assert!(t.invalidate_4k(vpn));
        t.insert_4k(vpn, 99);
        let r = t.lookup(vpn << PAGE_SHIFT);
        assert_eq!(r.small.level, HitLevel::L1);
        assert_eq!(r.small.ppn, Some(99), "stale ppn must not survive");
    }

    /// A shootdown must reach an entry that only lives in L2 (e.g. after
    /// demotion), and a later refill restores the normal hit path.
    #[test]
    fn shootdown_reaches_demoted_l2_entry() {
        let mut t = tlbs();
        let vpn = 0x7u64;
        t.l2_4k.insert(vpn, 70); // resident only in L2
        assert!(t.invalidate_4k(vpn));
        let r = t.lookup(vpn << PAGE_SHIFT);
        assert_eq!(r.small.level, HitLevel::Miss);
        t.insert_4k(vpn, 71);
        assert_eq!(t.lookup(vpn << PAGE_SHIFT).small.ppn, Some(71));
    }

    #[test]
    fn sp_hit_rate_tracks() {
        let mut t = tlbs();
        t.insert_2m(0, 0);
        for _ in 0..99 {
            t.lookup(0);
        }
        t.lookup(1u64 << SP_SHIFT); // one miss
        assert!(t.sp_hit_rate() > 0.97);
    }
}
