//! Split-TLB hierarchy, page-table walker, and shootdown cost model.

pub mod ptw;
pub mod shootdown;
pub mod split;
#[allow(clippy::module_inception)]
pub mod tlb;

pub use ptw::{WalkStats, Walker, WalkerConfig};
pub use shootdown::{shootdown_2m, shootdown_4k, ShootdownStats};
pub use split::{CoreTlbs, HitLevel, SizedLookup, SplitLookup};
pub use tlb::{Tlb, TlbStats};
