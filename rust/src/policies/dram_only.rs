//! DRAM-only upper bound (§IV-A): a machine whose whole memory is DRAM
//! (sized like the hybrid's NVM), 2 MB superpages everywhere, no
//! migration. "Not a completely fair comparison, since DRAM-only uses
//! more DRAM" — it is the performance ceiling of Figs. 7/8/10.

use crate::config::{Config, SP_SHIFT};
use crate::os::{AddressSpace, Region};
use crate::sim::machine::{Machine, TableHome};
use crate::tlb::HitLevel;

use super::flat_static::TABLE_RESERVE;
use super::Policy;

pub struct DramOnly {
    m: Machine,
    aspace: AddressSpace,
    dram: Region,
}

impl DramOnly {
    pub fn new(cfg: &Config) -> DramOnly {
        // Upgrade DRAM to the NVM's capacity; the NVM device sits unused.
        let mut big = cfg.clone();
        big.dram.size = cfg.nvm.size;
        big.dram.rows_per_bank = cfg.nvm.rows_per_bank;
        let m = Machine::new(&big, TableHome::Dram, TableHome::Dram);
        DramOnly {
            dram: Region::new(0, big.dram.size - TABLE_RESERVE),
            aspace: AddressSpace::new(),
            m,
        }
    }

    fn ensure_mapped(&mut self, vaddr: u64) -> u64 {
        if let Some(pa) = self.aspace.resolve_2m(vaddr) {
            return pa;
        }
        self.aspace
            .ensure_2m(vaddr, &mut self.dram)
            .expect("dram-only: memory exhausted");
        self.aspace.resolve_2m(vaddr).unwrap()
    }
}

impl Policy for DramOnly {
    fn name(&self) -> &'static str {
        "DRAM-only(2MB)"
    }

    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64 {
        let look = self.m.tlbs[core].lookup_2m(vaddr);
        let mut cycles = look.cycles;
        self.m.metrics.xlat.tlb_cycles += look.cycles;
        let paddr = match look.level {
            HitLevel::Miss => {
                let walk = self.m.walker.walk_2m(&mut self.m.mem,
                                                 vaddr >> SP_SHIFT,
                                                 now + cycles);
                cycles += walk;
                self.m.metrics.xlat.sptw_cycles += walk;
                self.m.metrics.tlb_miss_cycles += walk;
                self.m.tel.ptw_hist.record(walk);
                let pa = self.ensure_mapped(vaddr);
                self.m.tlbs[core].insert_2m(vaddr >> SP_SHIFT, pa >> SP_SHIFT);
                pa
            }
            _ => {
                let sppn = look.ppn.unwrap();
                (sppn << SP_SHIFT) | (vaddr & ((1 << SP_SHIFT) - 1))
            }
        };
        let (dcycles, _) = self.m.data_path(core, paddr, is_write,
                                            now + cycles);
        cycles + dcycles
    }

    fn on_interval(&mut self, _now: u64) -> u64 {
        0
    }

    fn machine(&self) -> &Machine {
        &self.m
    }

    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> DramOnly {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        DramOnly::new(&cfg)
    }

    #[test]
    fn everything_lands_in_dram() {
        let mut p = policy();
        for i in 0..50u64 {
            p.access(0, i * (3 << 20), false, i * 10_000);
        }
        assert_eq!(p.m.mem.nvm.stats.accesses(), 0, "NVM must stay idle");
        assert!(p.m.mem.dram.stats.accesses() > 0);
    }

    #[test]
    fn superpage_tlb_covers_2mb() {
        let mut p = policy();
        let c1 = p.access(0, 0, false, 0);
        // Anywhere within the same 2 MB: TLB hit (no walk).
        let walks_before = p.m.walker.stats.walks_2m;
        let c2 = p.access(0, 1 << 20, false, c1);
        assert_eq!(p.m.walker.stats.walks_2m, walks_before);
        assert!(c2 <= c1);
    }

    #[test]
    fn dram_capacity_is_nvm_sized() {
        let p = policy();
        let cfg = Config::scaled(8);
        assert_eq!(p.m.mem.dram_size(), cfg.nvm.size);
    }
}
