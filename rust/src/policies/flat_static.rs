//! Flat-static baseline (§IV-A): DRAM and NVM in one flat 4 KB-paged
//! address space; data spread statically DRAM:NVM = 1:8 by page hash; no
//! migration. The comparison baseline every figure normalizes to.

use crate::config::{Config, PAGE_SHIFT};
use crate::os::{AddressSpace, Region};
use crate::sim::machine::{Machine, TableHome};
use crate::tlb::HitLevel;

use super::Policy;

/// Reserved for page tables at the top of each device.
pub const TABLE_RESERVE: u64 = 16 << 20;

pub struct FlatStatic {
    m: Machine,
    aspace: AddressSpace,
    dram: Region,
    nvm: Region,
    /// DRAM share: 1 of every `ratio+1` pages (paper: 1:8).
    ratio: u64,
}

impl FlatStatic {
    pub fn new(cfg: &Config) -> FlatStatic {
        let m = Machine::new(cfg, TableHome::Dram, TableHome::Dram);
        let nvm_base = m.mem.nvm_base();
        FlatStatic {
            dram: Region::new(0, cfg.dram.size - TABLE_RESERVE),
            nvm: Region::new(nvm_base, cfg.nvm.size - TABLE_RESERVE),
            aspace: AddressSpace::new(),
            ratio: cfg.nvm.size / cfg.dram.size,
            m,
        }
    }

    /// Static interleave: page -> DRAM iff hash(vpn) % (ratio+1) == 0.
    fn wants_dram(&self, vpn: u64) -> bool {
        vpn.wrapping_mul(0x9E3779B97F4A7C15) % (self.ratio + 1) == 0
    }

    fn ensure_mapped(&mut self, vaddr: u64) -> u64 {
        let vpn = vaddr >> PAGE_SHIFT;
        if let Some(pa) = self.aspace.resolve_4k(vaddr) {
            return pa;
        }
        let page = if self.wants_dram(vpn) {
            self.aspace
                .ensure_4k(vaddr, &mut self.dram)
                .or_else(|| self.aspace.ensure_4k(vaddr, &mut self.nvm))
        } else {
            self.aspace
                .ensure_4k(vaddr, &mut self.nvm)
                .or_else(|| self.aspace.ensure_4k(vaddr, &mut self.dram))
        };
        page.expect("flat-static: physical memory exhausted");
        self.aspace.resolve_4k(vaddr).unwrap()
    }
}

impl Policy for FlatStatic {
    fn name(&self) -> &'static str {
        "Flat-static"
    }

    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64 {
        let look = self.m.tlbs[core].lookup_4k(vaddr);
        let mut cycles = look.cycles;
        self.m.metrics.xlat.tlb_cycles += look.cycles;
        let paddr = match look.level {
            HitLevel::Miss => {
                // Hardware 4-level walk (tables in DRAM), then install.
                let walk =
                    self.m.walker.walk_4k(&mut self.m.mem,
                                          vaddr >> PAGE_SHIFT, now + cycles);
                cycles += walk;
                self.m.metrics.xlat.ptw_cycles += walk;
                self.m.metrics.tlb_miss_cycles += walk;
                self.m.tel.ptw_hist.record(walk);
                let pa = self.ensure_mapped(vaddr);
                self.m.tlbs[core]
                    .insert_4k(vaddr >> PAGE_SHIFT, pa >> PAGE_SHIFT);
                pa
            }
            _ => {
                let ppn = look.ppn.unwrap();
                (ppn << PAGE_SHIFT) | (vaddr & 0xFFF)
            }
        };
        let (dcycles, _) = self.m.data_path(core, paddr, is_write,
                                            now + cycles);
        cycles + dcycles
    }

    fn on_interval(&mut self, _now: u64) -> u64 {
        0 // no migration machinery
    }

    fn machine(&self) -> &Machine {
        &self.m
    }

    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::Policy;

    fn policy() -> FlatStatic {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        FlatStatic::new(&cfg)
    }

    #[test]
    fn placement_ratio_roughly_one_in_nine() {
        let p = policy();
        let dram = (0..100_000u64).filter(|&v| p.wants_dram(v)).count();
        let frac = dram as f64 / 100_000.0;
        assert!((frac - 1.0 / 9.0).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn access_returns_nonzero_and_maps() {
        let mut p = policy();
        let c1 = p.access(0, 0x1234_5678, false, 0);
        assert!(c1 > 0);
        // Second access: TLB hit, cheaper.
        let c2 = p.access(0, 0x1234_5678, false, c1);
        assert!(c2 < c1);
        assert_eq!(p.m.metrics.xlat.ptw_cycles > 0, true);
    }

    #[test]
    fn placement_is_stable() {
        let mut p = policy();
        p.access(0, 0x8000, false, 0);
        let pa1 = p.aspace.resolve_4k(0x8000).unwrap();
        p.access(1, 0x8000, true, 100);
        let pa2 = p.aspace.resolve_4k(0x8000).unwrap();
        assert_eq!(pa1, pa2, "no migration in flat-static");
    }

    #[test]
    fn interval_is_free() {
        let mut p = policy();
        assert_eq!(p.on_interval(0), 0);
    }
}
