//! HSCC-2MB-mig (§IV-A): HSCC modified for superpages — 2 MB TLBs and
//! page tables, with migration at whole-superpage granularity. Retains
//! wide TLB coverage but pays 512x the migration traffic, which is the
//! penalty Figs. 10/11 quantify (it can even underperform HSCC-4KB).

use crate::config::{Config, SP_SHIFT, SP_SIZE};
use crate::os::{AddressSpace, DramMgr, PageTable, Reclaim, Region};
use crate::rainbow::migration::{ThresholdCtl, UtilityParams};
use crate::sim::machine::{Machine, TableHome};
use crate::tlb::{shootdown_2m, HitLevel, ShootdownStats};

use super::accounting::{FrameOwners, IntervalCounters};
use super::flat_static::TABLE_RESERVE;
use super::Policy;

pub struct Hscc2M {
    m: Machine,
    aspace: AddressSpace,
    nvm: Region,
    /// DRAM managed in 2 MB frames.
    dram: DramMgr,
    /// Superpage counters (svpn -> reads/writes), TLB-level.
    counters: IntervalCounters,
    frame_owner: FrameOwners,
    /// svpn -> original NVM superpage number.
    nvm_home: PageTable,
    params: UtilityParams,
    threshold: ThresholdCtl,
    sd_stats: ShootdownStats,
}

impl Hscc2M {
    pub fn new(cfg: &Config) -> Hscc2M {
        let m = Machine::new(cfg, TableHome::Dram, TableHome::Dram);
        let nvm_base = m.mem.nvm_base();
        let n_frames = (cfg.dram.size - TABLE_RESERVE) / SP_SIZE;
        let mut params = UtilityParams::from_config(cfg);
        // Migration unit is a superpage.
        params.t_mig = cfg.t_mig_2m as f64;
        params.t_writeback = cfg.t_mig_2m as f64;
        Hscc2M {
            nvm: Region::new(nvm_base, cfg.nvm.size - TABLE_RESERVE),
            dram: DramMgr::new(n_frames),
            aspace: AddressSpace::new(),
            counters: IntervalCounters::new(),
            frame_owner: FrameOwners::new(n_frames as usize),
            nvm_home: PageTable::new(),
            threshold: ThresholdCtl::new(params.threshold * 8.0),
            params,
            m,
            sd_stats: ShootdownStats::default(),
        }
    }

    fn ensure_mapped(&mut self, vaddr: u64) -> u64 {
        if let Some(pa) = self.aspace.resolve_2m(vaddr) {
            return pa;
        }
        let pa = self
            .aspace
            .ensure_2m(vaddr, &mut self.nvm)
            .expect("hscc2m: NVM exhausted");
        self.nvm_home.map(vaddr >> SP_SHIFT, pa >> SP_SHIFT);
        self.aspace.resolve_2m(vaddr).unwrap()
    }

    fn evict(&mut self, frame: u64, dirty: bool, now: u64) -> u64 {
        let svpn = self.frame_owner.take(frame)
            .expect("evicting unowned 2MB frame");
        let home = self.nvm_home.translate(svpn)
            .expect("evicted superpage has no NVM home") << SP_SHIFT;
        let dram_pa = frame * SP_SIZE;
        let mut cycles = 0;
        let (wbs, lines) = self.m.caches.clflush_range(dram_pa, SP_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        if dirty {
            // Background DMA + the constant CPU charge (512 x 4 KB unit).
            self.m.mem.migrate(now, dram_pa, home, SP_SIZE,
                               &mut self.m.tel);
            cycles += self.m.cfg.t_mig_2m;
            self.m.metrics.writebacks += 1;
            self.m.metrics.writeback_bytes += SP_SIZE;
        }
        self.aspace.pt_2m.remap(svpn, home >> SP_SHIFT);
        let sd = shootdown_2m(&self.m.cfg, &mut self.m.tlbs, svpn,
                              &mut self.sd_stats, &mut self.m.tel, now);
        cycles += sd;
        self.m.metrics.rt.shootdown_cycles += sd;
        self.m.metrics.shootdowns += 1;
        cycles
    }

    fn migrate_in(&mut self, svpn: u64, now: u64) -> u64 {
        let src = self.nvm_home.translate(svpn)
            .expect("migrating superpage with no NVM home") << SP_SHIFT;
        let mut cycles = 0;
        let grant = self.dram.take(svpn);
        match grant.reclaim {
            Reclaim::Free => {}
            Reclaim::Clean { victim_owner } => {
                cycles += self.evict_check(victim_owner, grant.frame, false,
                                           now);
            }
            Reclaim::Dirty { victim_owner } => {
                cycles += self.evict_check(victim_owner, grant.frame, true,
                                           now);
            }
        }
        let dst = grant.frame * SP_SIZE;
        let (wbs, lines) = self.m.caches.clflush_range(src, SP_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        self.m.mem.migrate(now + cycles, src, dst, SP_SIZE,
                           &mut self.m.tel);
        // Background DMA; CPU pays the superpage T_mig (512x the 4 KB
        // constant) — the cost Figs. 10/11 attribute to HSCC-2MB.
        cycles += self.m.cfg.t_mig_2m;
        self.m.metrics.migrations += 1;
        self.m.metrics.migrated_bytes += SP_SIZE;
        self.aspace.pt_2m.remap(svpn, dst >> SP_SHIFT);
        let sd = shootdown_2m(&self.m.cfg, &mut self.m.tlbs, svpn,
                              &mut self.sd_stats, &mut self.m.tel,
                              now + cycles);
        cycles += sd;
        self.m.metrics.rt.shootdown_cycles += sd;
        self.m.metrics.shootdowns += 1;
        self.frame_owner.set(grant.frame, svpn);
        self.m.tel.mig_hist.record(cycles);
        cycles
    }

    fn evict_check(&mut self, svpn: u64, frame: u64, dirty: bool,
                   now: u64) -> u64 {
        debug_assert_eq!(self.frame_owner.get(frame), Some(svpn));
        self.evict(frame, dirty, now)
    }
}

impl Policy for Hscc2M {
    fn name(&self) -> &'static str {
        "HSCC-2MB-mig"
    }

    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64 {
        let look = self.m.tlbs[core].lookup_2m(vaddr);
        let mut cycles = look.cycles;
        self.m.metrics.xlat.tlb_cycles += look.cycles;
        let paddr = match look.level {
            HitLevel::Miss => {
                let walk = self.m.walker.walk_2m(&mut self.m.mem,
                                                 vaddr >> SP_SHIFT,
                                                 now + cycles);
                cycles += walk;
                self.m.metrics.xlat.sptw_cycles += walk;
                self.m.metrics.tlb_miss_cycles += walk;
                self.m.tel.ptw_hist.record(walk);
                let pa = self.ensure_mapped(vaddr);
                self.m.tlbs[core].insert_2m(vaddr >> SP_SHIFT, pa >> SP_SHIFT);
                pa
            }
            _ => (look.ppn.unwrap() << SP_SHIFT)
                | (vaddr & ((1 << SP_SHIFT) - 1)),
        };
        self.counters.record(vaddr >> SP_SHIFT, is_write);
        if is_write && paddr < self.m.mem.dram_size() {
            self.dram.mark_dirty(paddr / SP_SIZE);
        }
        let (dcycles, _) = self.m.data_path(core, paddr, is_write,
                                            now + cycles);
        cycles + dcycles
    }

    fn on_interval(&mut self, now: u64) -> u64 {
        let thresh = self.threshold.threshold();
        let mut cand: Vec<(u64, f64)> = self
            .counters
            .iter()
            .filter(|&(svpn, _, _)| {
                self.aspace
                    .pt_2m
                    .translate(svpn)
                    .map(|p| p << SP_SHIFT >= self.m.mem.dram_size())
                    .unwrap_or(false)
            })
            .map(|(svpn, r, w)| {
                (svpn, self.params.benefit(r as u64, w as u64))
            })
            .filter(|&(_, b)| b > thresh)
            .collect();
        cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let identify = (self.counters.len() as u64) * 2;
        self.m.metrics.rt.identify_cycles += identify;

        let migrated_before = self.m.metrics.migrated_bytes;
        let wb_before = self.m.metrics.writeback_bytes;
        let mut cycles = identify;
        // Same DMA budget as the 4 KB policies, in superpage units.
        let budget =
            (super::migration_budget_pages(&self.m.cfg) / 512).max(2);
        let spacing = self.m.cfg.interval_cycles / (budget + 1);
        for (i, (svpn, benefit)) in cand.into_iter().enumerate() {
            if i as u64 >= budget {
                break;
            }
            if self.dram.free_count() == 0 && benefit < 2.0 * thresh {
                continue;
            }
            cycles += self.migrate_in(svpn, now + i as u64 * spacing);
        }
        self.m.metrics.rt.migration_cycles +=
            cycles.saturating_sub(identify);
        self.threshold.update(
            self.m.metrics.migrated_bytes - migrated_before,
            self.m.metrics.writeback_bytes - wb_before,
        );
        self.counters.clear();
        cycles
    }

    fn machine(&self) -> &Machine {
        &self.m
    }

    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.m
    }

    fn dram_utilization(&self) -> f64 {
        self.dram.utilization()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Hscc2M {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        Hscc2M::new(&cfg)
    }

    #[test]
    fn migrates_whole_superpage() {
        let mut p = policy();
        let mut now = 0;
        for _ in 0..6000 {
            now += p.access(0, 0x40_0000, true, now);
        }
        now += p.on_interval(now);
        assert_eq!(p.m.metrics.migrations, 1);
        assert_eq!(p.m.metrics.migrated_bytes, SP_SIZE,
                   "2 MB moved for one hot page's worth of use");
        let pa = p.aspace.resolve_2m(0x40_0000).unwrap();
        assert!(pa < p.m.mem.dram_size());
    }

    #[test]
    fn migration_cost_is_hundreds_of_times_4k() {
        let mut p = policy();
        let mut now = 0;
        for _ in 0..6000 {
            now += p.access(0, 0, true, now);
        }
        let os = p.on_interval(now);
        // One 2 MB copy ≈ 512 line round-trips; must dwarf a 4 KB cost.
        assert!(os > 100_000, "2MB migration cost {os} too cheap");
    }

    #[test]
    fn superpage_migration_needs_much_higher_benefit() {
        let mut p = policy();
        let mut now = 0;
        // 100 writes: hot enough for a 4 KB page, nowhere near enough to
        // repay a 2 MB move (T_mig = 512 * 4096).
        for _ in 0..100 {
            now += p.access(0, 0, true, now);
        }
        p.on_interval(now);
        assert_eq!(p.m.metrics.migrations, 0);
    }

    #[test]
    fn shootdowns_use_2m_entries() {
        let mut p = policy();
        let mut now = 0;
        for _ in 0..6000 {
            now += p.access(0, 0x20_0000, true, now);
        }
        p.on_interval(now);
        assert!(p.sd_stats.shootdowns >= 1);
        // The 2 MB entry must be gone: next access walks again.
        let walks = p.m.walker.stats.walks_2m;
        p.access(0, 0x20_0000, false, now + 1_000_000);
        assert_eq!(p.m.walker.stats.walks_2m, walks + 1);
    }
}
