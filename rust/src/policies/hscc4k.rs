//! HSCC-4KB-mig (§IV-A): the state-of-the-art comparator. Flat 4 KB
//! paging, data resident in NVM, utility-based hot-page migration into a
//! DRAM cache managed with free/clean/dirty lists. Counting is TLB-level
//! (per access, *not* filtered by on-chip caches — the reason Fig. 11
//! shows HSCC migrating more than Rainbow). Every migration remaps the
//! page table, so it costs a TLB shootdown + clflush.

use crate::config::{Config, PAGE_SHIFT, PAGE_SIZE};
use crate::os::{AddressSpace, DramMgr, PageTable, Reclaim, Region};
use crate::rainbow::migration::{ThresholdCtl, UtilityParams};
use crate::sim::machine::{Machine, TableHome};
use crate::tlb::{shootdown_4k, HitLevel, ShootdownStats};

use super::accounting::{FrameOwners, IntervalCounters};
use super::flat_static::TABLE_RESERVE;
use super::Policy;

pub struct Hscc4K {
    m: Machine,
    aspace: AddressSpace,
    nvm: Region,
    dram: DramMgr,
    /// TLB-level access counters: vpn -> (reads, writes) this interval.
    counters: IntervalCounters,
    /// DRAM frame -> vpn, for eviction bookkeeping.
    frame_owner: FrameOwners,
    /// vpn -> original NVM page number (migration is a cache: eviction
    /// returns the page home).
    nvm_home: PageTable,
    params: UtilityParams,
    threshold: ThresholdCtl,
    sd_stats: ShootdownStats,
}

impl Hscc4K {
    pub fn new(cfg: &Config) -> Hscc4K {
        let m = Machine::new(cfg, TableHome::Dram, TableHome::Dram);
        let nvm_base = m.mem.nvm_base();
        let n_frames = (cfg.dram.size - TABLE_RESERVE) / PAGE_SIZE;
        let params = UtilityParams::from_config(cfg);
        Hscc4K {
            nvm: Region::new(nvm_base, cfg.nvm.size - TABLE_RESERVE),
            dram: DramMgr::new(n_frames),
            aspace: AddressSpace::new(),
            counters: IntervalCounters::new(),
            frame_owner: FrameOwners::new(n_frames as usize),
            nvm_home: PageTable::new(),
            threshold: ThresholdCtl::new(params.threshold),
            params,
            m,
            sd_stats: ShootdownStats::default(),
        }
    }

    fn ensure_mapped(&mut self, vaddr: u64) -> u64 {
        if let Some(pa) = self.aspace.resolve_4k(vaddr) {
            return pa;
        }
        let pa = self
            .aspace
            .ensure_4k(vaddr, &mut self.nvm)
            .expect("hscc4k: NVM exhausted");
        self.nvm_home.map(vaddr >> PAGE_SHIFT, pa >> PAGE_SHIFT);
        self.aspace.resolve_4k(vaddr).unwrap()
    }

    /// Evict the page in `frame` back to its NVM home. Returns cycles.
    fn evict(&mut self, frame: u64, dirty: bool, now: u64) -> u64 {
        let vpn = self.frame_owner.take(frame)
            .expect("evicting unowned frame");
        let home = self.nvm_home.translate(vpn)
            .expect("evicted page has no NVM home") << PAGE_SHIFT;
        let dram_pa = frame * PAGE_SIZE;
        let mut cycles = 0;
        // Flush the page's lines out of the coherence domain.
        let (wbs, lines) = self.m.caches.clflush_range(dram_pa, PAGE_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        if dirty {
            // The copy occupies the devices (background DMA); the CPU is
            // charged the paper's constant T_writeback (Eq. 2).
            self.m.mem.migrate(now, dram_pa, home, PAGE_SIZE,
                               &mut self.m.tel);
            cycles += self.m.cfg.t_writeback_4k;
            self.m.metrics.writebacks += 1;
            self.m.metrics.writeback_bytes += PAGE_SIZE;
        }
        // Remap back to NVM + shoot down the stale DRAM translation.
        self.aspace.pt_4k.remap(vpn, home >> PAGE_SHIFT);
        let sd = shootdown_4k(&self.m.cfg, &mut self.m.tlbs, vpn,
                              &mut self.sd_stats, &mut self.m.tel, now);
        cycles += sd;
        self.m.metrics.rt.shootdown_cycles += sd;
        self.m.metrics.shootdowns += 1;
        cycles
    }

    /// Migrate `vpn` into DRAM; returns cycles spent.
    fn migrate_in(&mut self, vpn: u64, now: u64) -> u64 {
        let src = self.nvm_home.translate(vpn)
            .expect("migrating page with no NVM home") << PAGE_SHIFT;
        let mut cycles = 0;
        let grant = self.dram.take(vpn);
        match grant.reclaim {
            Reclaim::Free => {}
            Reclaim::Clean { victim_owner } => {
                cycles += self.evict_owner(victim_owner, grant.frame, false,
                                           now);
            }
            Reclaim::Dirty { victim_owner } => {
                cycles += self.evict_owner(victim_owner, grant.frame, true,
                                           now);
            }
        }
        let dst = grant.frame * PAGE_SIZE;
        // Source lines may be cached: flush before the copy (§III-F).
        let (wbs, lines) = self.m.caches.clflush_range(src, PAGE_SIZE);
        cycles += lines * self.m.cfg.t_clflush_line;
        self.m.metrics.rt.clflush_cycles += lines * self.m.cfg.t_clflush_line;
        for wb in wbs {
            self.m.mem.access(now, wb.addr, true, 64);
        }
        self.m.mem.migrate(now + cycles, src, dst, PAGE_SIZE,
                           &mut self.m.tel);
        // Background DMA; the CPU pays the paper's T_mig constant (Eq. 1).
        cycles += self.m.cfg.t_mig_4k;
        self.m.metrics.migrations += 1;
        self.m.metrics.migrated_bytes += PAGE_SIZE;
        // Remap + shootdown (HSCC changes the address the TLBs hold).
        self.aspace.pt_4k.remap(vpn, dst >> PAGE_SHIFT);
        let sd = shootdown_4k(&self.m.cfg, &mut self.m.tlbs, vpn,
                              &mut self.sd_stats, &mut self.m.tel,
                              now + cycles);
        cycles += sd;
        self.m.metrics.rt.shootdown_cycles += sd;
        self.m.metrics.shootdowns += 1;
        self.frame_owner.set(grant.frame, vpn);
        self.m.tel.mig_hist.record(cycles);
        cycles
    }

    fn evict_owner(&mut self, vpn: u64, frame: u64, dirty: bool,
                   now: u64) -> u64 {
        debug_assert_eq!(self.frame_owner.get(frame), Some(vpn));
        self.evict(frame, dirty, now)
    }
}

impl Policy for Hscc4K {
    fn name(&self) -> &'static str {
        "HSCC-4KB-mig"
    }

    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64 {
        let look = self.m.tlbs[core].lookup_4k(vaddr);
        let mut cycles = look.cycles;
        self.m.metrics.xlat.tlb_cycles += look.cycles;
        let paddr = match look.level {
            HitLevel::Miss => {
                let walk = self.m.walker.walk_4k(&mut self.m.mem,
                                                 vaddr >> PAGE_SHIFT,
                                                 now + cycles);
                cycles += walk;
                self.m.metrics.xlat.ptw_cycles += walk;
                self.m.metrics.tlb_miss_cycles += walk;
                self.m.tel.ptw_hist.record(walk);
                let pa = self.ensure_mapped(vaddr);
                self.m.tlbs[core]
                    .insert_4k(vaddr >> PAGE_SHIFT, pa >> PAGE_SHIFT);
                pa
            }
            _ => (look.ppn.unwrap() << PAGE_SHIFT) | (vaddr & 0xFFF),
        };
        // TLB-level (unfiltered) access counting — HSCC's design.
        self.counters.record(vaddr >> PAGE_SHIFT, is_write);
        // Dirty tracking for cached pages.
        if is_write && paddr < self.m.mem.dram_size() {
            self.dram.mark_dirty(paddr >> PAGE_SHIFT);
        }
        let (dcycles, _) = self.m.data_path(core, paddr, is_write,
                                            now + cycles);
        cycles + dcycles
    }

    fn on_interval(&mut self, now: u64) -> u64 {
        let thresh = self.threshold.threshold();
        // Rank candidate pages by Eq.-1 benefit.
        let mut cand: Vec<(u64, f64, u32, u32)> = self
            .counters
            .iter()
            .filter(|&(vpn, _, _)| {
                // Only NVM-resident pages are migration candidates.
                self.aspace
                    .pt_4k
                    .translate(vpn)
                    .map(|ppn| ppn << PAGE_SHIFT >= self.m.mem.dram_size())
                    .unwrap_or(false)
            })
            .map(|(vpn, r, w)| {
                (vpn, self.params.benefit(r as u64, w as u64), r, w)
            })
            .filter(|&(_, b, _, _)| b > thresh)
            .collect();
        cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        // Software cost of the scan+sort.
        let identify = (self.counters.len() as u64) * 2;
        self.m.metrics.rt.identify_cycles += identify;

        let migrated_before = self.m.metrics.migrated_bytes;
        let wb_before = self.m.metrics.writeback_bytes;
        let mut cycles = identify;
        // Migration DMA is rate-limited (paper §IV-D: migrations consume
        // <= ~1.35% of bandwidth) and staggered across the next interval
        // so demand traffic doesn't queue behind a copy burst.
        let budget = super::migration_budget_pages(&self.m.cfg);
        let spacing = self.m.cfg.interval_cycles / (budget + 1);
        for (i, (vpn, benefit, r, w)) in cand.into_iter().enumerate() {
            if i as u64 >= budget {
                break;
            }
            // Eq. 2 check under DRAM pressure: compare against the
            // would-be victim's counters.
            if self.dram.free_count() == 0 {
                let swap_ok = self.params.swap_benefit(
                    r as u64, w as u64, 0, 0) > thresh;
                if !swap_ok || benefit < 2.0 * thresh {
                    continue;
                }
            }
            cycles += self.migrate_in(vpn, now + i as u64 * spacing);
        }
        self.m.metrics.rt.migration_cycles +=
            cycles.saturating_sub(identify);
        self.threshold.update(
            self.m.metrics.migrated_bytes - migrated_before,
            self.m.metrics.writeback_bytes - wb_before,
        );
        self.counters.clear();
        cycles
    }

    fn machine(&self) -> &Machine {
        &self.m
    }

    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.m
    }

    fn dram_utilization(&self) -> f64 {
        self.dram.utilization()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Hscc4K {
        let mut cfg = Config::scaled(8);
        cfg.cores = 2;
        Hscc4K::new(&cfg)
    }

    #[test]
    fn first_touch_lands_in_nvm() {
        let mut p = policy();
        p.access(0, 0x4000, false, 0);
        let pa = p.aspace.resolve_4k(0x4000).unwrap();
        assert!(pa >= p.m.mem.dram_size(), "initial placement is NVM");
    }

    #[test]
    fn hot_page_migrates_to_dram_on_interval() {
        let mut p = policy();
        let v = 0x40_0000u64;
        let mut now = 0;
        for _ in 0..500 {
            now += p.access(0, v, true, now);
        }
        let os = p.on_interval(now);
        assert!(os > 0, "migration must cost cycles");
        let pa = p.aspace.resolve_4k(v).unwrap();
        assert!(pa < p.m.mem.dram_size(), "hot page must now be in DRAM");
        assert_eq!(p.m.metrics.migrations, 1);
        assert!(p.m.metrics.shootdowns >= 1);
    }

    #[test]
    fn cold_pages_stay_in_nvm() {
        let mut p = policy();
        let mut now = 0;
        for i in 0..50u64 {
            now += p.access(0, i * 4096, false, now); // one touch each
        }
        p.on_interval(now);
        assert_eq!(p.m.metrics.migrations, 0,
                   "single-touch pages cannot repay T_mig");
    }

    #[test]
    fn counter_clears_each_interval() {
        let mut p = policy();
        let mut now = 0;
        for _ in 0..300 {
            now += p.access(0, 0x9000, true, now);
        }
        p.on_interval(now);
        assert!(p.counters.is_empty());
        // A single later access must not look hot.
        p.access(0, 0x9000, false, now);
        p.on_interval(now + 10_000);
        assert_eq!(p.m.metrics.migrations, 1, "no re-migration");
    }

    #[test]
    fn migrated_page_served_from_dram() {
        let mut p = policy();
        let v = 0x80_0000u64;
        let mut now = 0;
        for _ in 0..500 {
            now += p.access(0, v, true, now);
        }
        now += p.on_interval(now);
        let nvm_before = p.m.mem.nvm.stats.accesses();
        for _ in 0..100 {
            now += p.access(0, v, false, now);
        }
        // Post-migration demand traffic should not touch NVM.
        assert_eq!(p.m.mem.nvm.stats.accesses(), nvm_before);
    }
}
