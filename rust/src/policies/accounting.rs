//! Flat per-interval accounting structures shared by the HSCC policies.
//!
//! HSCC counts at TLB level, i.e. its counter update sits on *every*
//! access — the per-access HashMap `entry()` was one of the hottest
//! operations in the whole simulator. These are the same flattening
//! moves as `rainbow::remap::RemapTable`, property-tested against
//! HashMap models below:
//!
//! * [`IntervalCounters`]: vpn -> (reads, writes) as a chunked two-level
//!   array plus a touched-vpn list, so the hot-path update is two indexed
//!   stores and the interval scan/clear is O(pages touched) in a
//!   deterministic first-touch order (the HashMap iterated in random
//!   order, which made equal-benefit migration ties nondeterministic).
//! * [`FrameOwners`]: DRAM frame -> owning vpn as a dense array with a
//!   `u64::MAX` sentinel (frames are small dense indices by construction).

const CHUNK_BITS: u32 = 12;
const CHUNK_LEN: usize = 1 << CHUNK_BITS;
const CHUNK_MASK: u64 = CHUNK_LEN as u64 - 1;

/// Per-interval (reads, writes) counters keyed by virtual page number.
#[derive(Clone, Debug, Default)]
pub struct IntervalCounters {
    dir: Vec<Option<Box<[(u32, u32)]>>>,
    /// Distinct vpns counted this interval, in first-touch order.
    touched: Vec<u64>,
}

impl IntervalCounters {
    pub fn new() -> IntervalCounters {
        IntervalCounters::default()
    }

    /// Count one access (hot path: two indexed loads + a store).
    #[inline]
    pub fn record(&mut self, vpn: u64, is_write: bool) {
        let (c, i) = ((vpn >> CHUNK_BITS) as usize,
                      (vpn & CHUNK_MASK) as usize);
        if c >= self.dir.len() {
            self.dir.resize(c + 1, None);
        }
        let chunk = self.dir[c].get_or_insert_with(|| {
            // rainbow-lint: allow(hot-alloc, amortized one-time chunk allocation)
            vec![(0u32, 0u32); CHUNK_LEN].into_boxed_slice()
        });
        let e = &mut chunk[i];
        if e.0 == 0 && e.1 == 0 {
            self.touched.push(vpn);
        }
        if is_write {
            e.1 += 1;
        } else {
            e.0 += 1;
        }
    }

    /// Counters of one vpn ((0, 0) if untouched).
    pub fn get(&self, vpn: u64) -> (u32, u32) {
        let (c, i) = ((vpn >> CHUNK_BITS) as usize,
                      (vpn & CHUNK_MASK) as usize);
        match self.dir.get(c) {
            Some(Some(chunk)) => chunk[i],
            _ => (0, 0),
        }
    }

    /// Distinct pages touched this interval.
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// All touched pages as (vpn, reads, writes), in first-touch order
    /// (deterministic, unlike the HashMap this replaces).
    pub fn iter(&self) -> impl Iterator<Item = (u64, u32, u32)> + '_ {
        self.touched.iter().map(move |&vpn| {
            let (r, w) = self.get(vpn);
            (vpn, r, w)
        })
    }

    /// Reset for the next interval: O(pages touched), keeps chunks
    /// allocated for reuse.
    pub fn clear(&mut self) {
        for i in 0..self.touched.len() {
            let vpn = self.touched[i];
            let (c, j) = ((vpn >> CHUNK_BITS) as usize,
                          (vpn & CHUNK_MASK) as usize);
            if let Some(Some(chunk)) = self.dir.get_mut(c) {
                chunk[j] = (0, 0);
            }
        }
        self.touched.clear();
    }
}

/// Sentinel: frame owns nothing.
const NO_OWNER: u64 = u64::MAX;

/// DRAM frame -> owning vpn, dense (frames come from `DramMgr` and are
/// `< n_frames` by construction).
#[derive(Clone, Debug)]
pub struct FrameOwners {
    owners: Vec<u64>,
}

impl FrameOwners {
    pub fn new(n_frames: usize) -> FrameOwners {
        FrameOwners { owners: vec![NO_OWNER; n_frames] }
    }

    pub fn set(&mut self, frame: u64, vpn: u64) {
        assert_ne!(vpn, NO_OWNER, "vpn collides with the empty sentinel");
        self.owners[frame as usize] = vpn;
    }

    pub fn get(&self, frame: u64) -> Option<u64> {
        let o = self.owners[frame as usize];
        (o != NO_OWNER).then_some(o)
    }

    /// Remove and return the owner (None if the frame was empty).
    pub fn take(&mut self, frame: u64) -> Option<u64> {
        let o = std::mem::replace(&mut self.owners[frame as usize], NO_OWNER);
        (o != NO_OWNER).then_some(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall_shrink, shrink_vec};
    use std::collections::HashMap;

    #[test]
    fn record_get_clear() {
        let mut c = IntervalCounters::new();
        assert!(c.is_empty());
        c.record(7, false);
        c.record(7, true);
        c.record(7, true);
        assert_eq!(c.get(7), (1, 2));
        assert_eq!(c.get(8), (0, 0));
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(7), (0, 0));
    }

    #[test]
    fn iter_is_first_touch_order() {
        let mut c = IntervalCounters::new();
        for &vpn in &[9u64, 2, CHUNK_MASK + 3, 2, 9] {
            c.record(vpn, false);
        }
        let order: Vec<u64> = c.iter().map(|(v, _, _)| v).collect();
        assert_eq!(order, vec![9, 2, CHUNK_MASK + 3]);
        assert_eq!(c.iter().find(|&(v, _, _)| v == 9).unwrap(), (9, 2, 0));
    }

    #[test]
    fn clear_reuses_across_intervals() {
        let mut c = IntervalCounters::new();
        c.record(1, true);
        c.clear();
        c.record(1, false);
        assert_eq!(c.get(1), (1, 0), "old interval's counts must not leak");
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn frame_owners_set_take() {
        let mut f = FrameOwners::new(8);
        assert_eq!(f.get(3), None);
        f.set(3, 0x42);
        assert_eq!(f.get(3), Some(0x42));
        assert_eq!(f.take(3), Some(0x42));
        assert_eq!(f.take(3), None);
    }

    /// Property: IntervalCounters behaves exactly like a
    /// HashMap<vpn, (r, w)> model across record/clear interleavings.
    #[test]
    fn prop_counters_match_hashmap_model() {
        type Op = (u8, u64, bool); // 0 = clear, else record
        let mut gen = |r: &mut crate::util::rng::Rng| {
            (0..r.below(100))
                .map(|_| {
                    let vpn = if r.chance(0.15) {
                        r.below(1 << 30)
                    } else {
                        r.below(2) * CHUNK_LEN as u64 + r.below(24)
                    };
                    (r.below(8) as u8, vpn, r.chance(0.4))
                })
                .collect::<Vec<Op>>()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut c = IntervalCounters::new();
            let mut model: HashMap<u64, (u32, u32)> = HashMap::new();
            for &(kind, vpn, is_write) in ops {
                if kind == 0 {
                    c.clear();
                    model.clear();
                } else {
                    c.record(vpn, is_write);
                    let e = model.entry(vpn).or_insert((0, 0));
                    if is_write {
                        e.1 += 1;
                    } else {
                        e.0 += 1;
                    }
                }
                if c.len() != model.len() {
                    return Err(format!("len {} != model {}",
                                       c.len(), model.len()));
                }
            }
            for (&vpn, &rw) in &model {
                if c.get(vpn) != rw {
                    return Err(format!("get({vpn}) {:?} != {rw:?}",
                                       c.get(vpn)));
                }
            }
            let mut got: Vec<(u64, u32, u32)> = c.iter().collect();
            got.sort_unstable();
            let mut want: Vec<(u64, u32, u32)> =
                model.iter().map(|(&v, &(r, w))| (v, r, w)).collect();
            want.sort_unstable();
            if got != want {
                return Err("iter() disagrees with model".into());
            }
            Ok(())
        };
        forall_shrink("interval-counters-model", 0x1C7E5, 80, &mut gen,
                      shrink_vec, &mut prop);
    }

    /// Property: FrameOwners behaves like a HashMap<frame, vpn> model.
    #[test]
    fn prop_frame_owners_match_hashmap_model() {
        const N: u64 = 16;
        type Op = (u8, u64, u64);
        let mut gen = |r: &mut crate::util::rng::Rng| {
            (0..r.below(80))
                .map(|_| (r.below(3) as u8, r.below(N), r.below(1 << 36)))
                .collect::<Vec<Op>>()
        };
        let mut prop = |ops: &Vec<Op>| -> Result<(), String> {
            let mut f = FrameOwners::new(N as usize);
            let mut model: HashMap<u64, u64> = HashMap::new();
            for &(kind, frame, vpn) in ops {
                match kind {
                    0 => {
                        f.set(frame, vpn);
                        model.insert(frame, vpn);
                    }
                    1 => {
                        let (got, want) =
                            (f.take(frame), model.remove(&frame));
                        if got != want {
                            return Err(format!(
                                "take({frame}): {got:?} != {want:?}"));
                        }
                    }
                    _ => {
                        let (got, want) =
                            (f.get(frame), model.get(&frame).copied());
                        if got != want {
                            return Err(format!(
                                "get({frame}): {got:?} != {want:?}"));
                        }
                    }
                }
            }
            Ok(())
        };
        forall_shrink("frame-owners-model", 0xF04E5, 80, &mut gen,
                      shrink_vec, &mut prop);
    }
}
