//! The policy boundary: each evaluated system (Flat-static, HSCC-4KB-mig,
//! HSCC-2MB-mig, DRAM-only, Rainbow) implements [`Policy`]; the engine is
//! policy-agnostic.

use crate::sim::machine::Machine;

pub mod accounting;
pub mod dram_only;
pub mod flat_static;
pub mod hscc2m;
pub mod hscc4k;

pub use dram_only::DramOnly;
pub use flat_static::FlatStatic;
pub use hscc2m::Hscc2M;
pub use hscc4k::Hscc4K;

/// One evaluated memory-management system.
pub trait Policy {
    fn name(&self) -> &'static str;

    /// Perform one memory access: translate `vaddr`, traverse the cache
    /// hierarchy / memory, do all bookkeeping. Returns cycles consumed.
    fn access(&mut self, core: usize, vaddr: u64, is_write: bool,
              now: u64) -> u64;

    /// Sampling-interval boundary: identification + migration. Returns
    /// OS/mechanism cycles that stall execution (stop-the-world model).
    fn on_interval(&mut self, now: u64) -> u64;

    fn machine(&self) -> &Machine;

    fn machine_mut(&mut self) -> &mut Machine;

    /// Fraction of the fast tier's frames in use, for the per-epoch
    /// telemetry series. Policies without a managed DRAM pool (flat
    /// placement, DRAM-only) report 0.
    fn dram_utilization(&self) -> f64 {
        0.0
    }

    /// End-of-run rollup; policies may override to adjust counters whose
    /// meaning is policy-specific (e.g. Rainbow's 4 KB-side misses).
    fn finalize(&mut self, elapsed: u64) {
        self.machine_mut().finalize(elapsed);
    }
}

/// Canonical short name for any accepted policy alias (None = unknown).
/// Single source of truth for [`from_name`] and [`is_valid_name`].
fn canonical_name(name: &str) -> Option<&'static str> {
    Some(match name.to_ascii_lowercase().as_str() {
        "flat" | "flat-static" => "flat",
        "hscc4k" | "hscc-4kb-mig" => "hscc4k",
        "hscc2m" | "hscc-2mb-mig" => "hscc2m",
        "dram" | "dram-only" => "dram",
        "rainbow" => "rainbow",
        _ => return None,
    })
}

/// Construct a policy by name ("flat", "hscc4k", "hscc2m", "dram",
/// "rainbow"), with `accel` choosing the Rainbow identification backend.
pub fn from_name(name: &str, cfg: &crate::config::Config, accel: bool)
               -> Option<Box<dyn Policy>> {
    let p: Box<dyn Policy> = match canonical_name(name)? {
        "flat" => Box::new(FlatStatic::new(cfg)),
        "hscc4k" => Box::new(Hscc4K::new(cfg)),
        "hscc2m" => Box::new(Hscc2M::new(cfg)),
        "dram" => Box::new(DramOnly::new(cfg)),
        "rainbow" => Box::new(crate::rainbow::policy::Rainbow::new(cfg, accel)),
        _ => unreachable!("canonical_name returned a non-canonical name"),
    };
    Some(p)
}

/// Whether `name` resolves to a policy — the same aliases [`from_name`]
/// accepts — without constructing the policy's machine (used for CLI
/// validation before a sweep fans out to worker threads).
pub fn is_valid_name(name: &str) -> bool {
    canonical_name(name).is_some()
}

/// Canonical evaluation order of Figs. 7-12.
pub fn all_names() -> [&'static str; 5] {
    ["flat", "hscc4k", "hscc2m", "rainbow", "dram"]
}

/// Per-interval migration budget in 4 KB pages: a bandwidth cap (~10% of
/// the NVM channels' line bandwidth over one interval) that also bounds
/// the stop-the-world OS work. Paper §IV-D observes migrations consume
/// at most ~1.35% of bandwidth in steady state; the cap only binds during
/// warm-up bursts.
pub fn migration_budget_pages(cfg: &crate::config::Config) -> u64 {
    let lines_per_interval = cfg.interval_cycles * cfg.nvm.channels as u64
        / crate::mem::device::LINE_XFER_CYCLES;
    (lines_per_interval / 10 / 64).max(64)
}
