//! `rainbow` — the leader binary: run single simulations, regenerate any
//! paper table/figure, or run the whole evaluation suite.

use std::path::{Path, PathBuf};
use std::time::Instant;

use rainbow::analysis;
use rainbow::config::{knobs, profiles, Config};
use rainbow::report::figures::{self, FigureCtx};
use rainbow::report::netstore::{CacheServer, NetStore};
use rainbow::report::queue;
use rainbow::report::shard;
use rainbow::report::spec_cli;
use rainbow::report::sweep::{self, SweepConfig};
use rainbow::report::{self, serde_kv, RunSpec, Store};
use rainbow::util::cli::{help_text, Args, OptSpec};
use rainbow::util::tables::Table;

const OPTS: &[OptSpec] = &[
    OptSpec { name: "app", help: "workload name (app or mix1..3)",
              default: Some("mcf"), is_flag: false },
    OptSpec { name: "policy",
              help: "flat | hscc4k | hscc2m | rainbow | dram",
              default: Some("rainbow"), is_flag: false },
    OptSpec { name: "instructions", help: "instructions to simulate",
              default: Some("4000000"), is_flag: false },
    OptSpec { name: "scale", help: "capacity scale divisor vs Table IV",
              default: Some("8"), is_flag: false },
    OptSpec { name: "interval", help: "sampling interval (cycles)",
              default: None, is_flag: false },
    OptSpec { name: "top-n", help: "top-N monitored hot superpages",
              default: None, is_flag: false },
    OptSpec { name: "seed", help: "workload RNG seed",
              default: Some("0xEA7BEEF as decimal 246202095"),
              is_flag: false },
    OptSpec { name: "set",
              help: "config-knob override knob=value (repeatable; \
                     `rainbow list` shows knobs)",
              default: None, is_flag: false },
    OptSpec { name: "spec", help: "load a RunSpec from a spec (.kv) file",
              default: None, is_flag: false },
    OptSpec { name: "save-spec",
              help: "write the resolved RunSpec to a spec (.kv) file",
              default: None, is_flag: false },
    OptSpec { name: "cache-dir",
              help: "results-cache directory (default: RAINBOW_CACHE or \
                     target/rainbow_results)",
              default: None, is_flag: false },
    OptSpec { name: "store",
              help: "results store: a cache directory, tcp://host:port \
                     for a `rainbow cache-server`, or \
                     tcp://a,tcp://b,... for a replicated server set \
                     (consistent-hash placement, write-through, \
                     read-repair; overrides --cache-dir)",
              default: None, is_flag: false },
    OptSpec { name: "listen",
              help: "cache-server: bind address (port 0 = ephemeral; \
                     see --port-file)",
              default: Some("127.0.0.1:7700"), is_flag: false },
    OptSpec { name: "port-file",
              help: "cache-server: write the bound host:port to this \
                     file once listening (for scripts using port 0)",
              default: None, is_flag: false },
    OptSpec { name: "stop",
              help: "cache-server: ask the server at tcp://host:port \
                     to shut down cleanly, then exit",
              default: None, is_flag: false },
    OptSpec { name: "mem",
              help: "cache-server: serve an ephemeral in-memory store \
                     instead of a directory",
              default: None, is_flag: true },
    OptSpec { name: "log",
              help: "cache-server: append-only durability log for \
                     --mem (fsynced per PUT, replayed on startup, \
                     torn tails truncated loudly, snapshot+compacted \
                     on clean shutdown)",
              default: None, is_flag: false },
    OptSpec { name: "fig",
              help: "figure/table id: \
                     1,7,8,9,10,11,12,13,14,15,16,t1,t2,t6,remap",
              default: None, is_flag: false },
    OptSpec { name: "csv", help: "also write CSV next to target/figures/",
              default: None, is_flag: true },
    OptSpec { name: "all",
              help: "use every registered workload (suite/figures)",
              default: None, is_flag: true },
    OptSpec { name: "accel",
              help: "use PJRT AOT artifacts for Rainbow identification",
              default: None, is_flag: true },
    OptSpec { name: "no-accel",
              help: "force the native identification backend (e.g. to \
                     negate a spec file's accel=1)",
              default: None, is_flag: true },
    OptSpec { name: "paper-scale",
              help: "full Table IV capacities (scale=1, slow)",
              default: None, is_flag: true },
    OptSpec { name: "no-cache", help: "ignore the results cache",
              default: None, is_flag: true },
    OptSpec { name: "apps",
              help: "sweep/backends: comma-separated workloads (or 'all')",
              default: None, is_flag: false },
    OptSpec { name: "policies",
              help: "sweep/backends: comma-separated policies",
              default: None, is_flag: false },
    OptSpec { name: "profiles",
              help: "backends: comma-separated NVM device profiles, or \
                     'all' (default: the slow-tier catalog)",
              default: None, is_flag: false },
    OptSpec { name: "workers",
              help: "sweep: worker threads; with --shards, max \
                     concurrent shard processes (0 = one per core)",
              default: Some("0"), is_flag: false },
    OptSpec { name: "check",
              help: "sweep: verify results against a serial replay",
              default: None, is_flag: true },
    OptSpec { name: "shards",
              help: "sweep/suite: split the matrix across N child \
                     shard-worker processes (0 = in-process sweep)",
              default: Some("0"), is_flag: false },
    OptSpec { name: "shard-cmd",
              help: "sweep: DEPRECATED worker wrapper — the whole value \
                     is one program path (the old whitespace splitting \
                     was dropped); --specs/--store are appended. Prefer \
                     --queue with `rainbow queue-worker` on each host",
              default: None, is_flag: false },
    OptSpec { name: "queue",
              help: "sweep: dynamic work-stealing dispatch through the \
                     cache server at --store tcp://host:port (workers \
                     lease one spec at a time; stragglers and dead \
                     workers are re-leased on deadline)",
              default: None, is_flag: true },
    OptSpec { name: "worker-id",
              help: "queue-worker: stable worker identity (default: \
                     w<pid>); also seeds the deterministic \
                     connect-retry jitter",
              default: None, is_flag: false },
    OptSpec { name: "lease-ms",
              help: "cache-server: job-queue lease deadline in ms — a \
                     spec leased longer than this is re-leased to the \
                     next idle worker",
              default: Some("60000"), is_flag: false },
    OptSpec { name: "shard-dir",
              help: "sweep: directory for shard spec lists + manifest \
                     (default: <cache-dir>/shards, or \
                     target/rainbow_shards with a tcp:// store)",
              default: None, is_flag: false },
    OptSpec { name: "specs",
              help: "shard-worker: spec-list (.kv) file to execute",
              default: None, is_flag: false },
    OptSpec { name: "list-rules",
              help: "lint: print the rule catalog and exit",
              default: None, is_flag: true },
    OptSpec { name: "fix-allow",
              help: "lint: stamp a TODO allow marker above every \
                     suppressible finding (then edit each into an \
                     honest reason, or fix the code)",
              default: None, is_flag: true },
    OptSpec { name: "stale-allows",
              help: "lint: also report allow markers that suppress \
                     nothing",
              default: None, is_flag: true },
    OptSpec { name: "update-schemas",
              help: "lint: re-stamp rust/schemas.lock (refuses layout \
                     drift without a version-constant bump)",
              default: None, is_flag: true },
    OptSpec { name: "src",
              help: "lint: source root to lint (default: rust/src of \
                     this checkout)",
              default: None, is_flag: false },
    OptSpec { name: "out",
              help: "perf: write the JSON report to FILE (e.g. \
                     BENCH_6.json); default prints it to stdout",
              default: None, is_flag: false },
    OptSpec { name: "validate",
              help: "perf: validate an existing report FILE against \
                     the rainbow-bench-v1 schema and exit",
              default: None, is_flag: false },
    OptSpec { name: "trace-out",
              help: "run: write the telemetry trace (JSON-lines: meta, \
                     per-epoch series, cycle-stamped events, summary) \
                     to FILE; the traced run bypasses the results \
                     cache but produces identical metrics",
              default: None, is_flag: false },
    OptSpec { name: "csv-series",
              help: "sweep: also write every cell's per-epoch \
                     time-series to a CSV FILE (one row per epoch per \
                     cell, from deterministic traced re-runs)",
              default: None, is_flag: false },
];

const COMMANDS: &[(&str, &str)] = &[
    ("run", "simulate one (workload, policy) pair and print metrics"),
    ("sweep", "run a workload x policy matrix on parallel workers \
               (--shards N spreads it across child processes)"),
    ("shard-worker", "execute one shard's spec-list file against a \
                      shared results store (spawned by sweep --shards)"),
    ("queue-worker", "lease specs one at a time from a cache server's \
                      job queue, simulate, push results (spawned by \
                      sweep --queue; run standalone on any host)"),
    ("cache-server", "serve a results store + work-stealing job queue \
                      to sweep/shard workers over TCP (--listen; \
                      clients use --store tcp://host:port)"),
    ("backends", "policy x NVM-backend matrix across device profiles"),
    ("figure", "regenerate one paper table/figure (--fig N)"),
    ("suite", "regenerate every paper table/figure (fig 16 backend \
               matrix runs separately: `backends` / --fig 16)"),
    ("analyze", "workload analytics (Fig 1 / Tables I-II) for --app"),
    ("storage", "Table VI storage-overhead model"),
    ("perf", "measure hot-path throughput and emit a machine-readable \
              rainbow-bench-v1 JSON report (--out FILE; --validate \
              FILE checks an existing report)"),
    ("stats", "print one fleet-stats row per cache-server endpoint of \
               --store (STATS opcode: per-opcode request counts, \
               lease-latency quantiles, WAL durability and \
               replica-degradation counters)"),
    ("trace-summary", "strictly validate a `run --trace-out` trace \
                       file and print its identity, event counts, and \
                       per-epoch time-series"),
    ("lint", "static-analysis pass enforcing the hot-path, determinism, \
              wire-format, and panic-hygiene invariants (--list-rules; \
              --fix-allow; --stale-allows; --update-schemas; exits \
              non-zero on findings)"),
    ("list", "list workloads and policies"),
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw, OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.command.is_none() {
        print!("{}", help_text("rainbow",
            "hybrid-memory superpage + lightweight-migration simulator \
             (paper reproduction)", COMMANDS, OPTS));
        return;
    }
    let cmd = args.command.clone().unwrap();
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Resolve the spec from `--spec`/options/`--set` (see
/// `report::spec_cli`), honoring `--save-spec` as a side effect.
fn spec_from_args(args: &Args) -> Result<RunSpec, String> {
    let s = spec_cli::spec_from_args(args)?;
    if let Some(path) = args.get("save-spec") {
        std::fs::write(path, serde_kv::spec_to_kv(&s))
            .map_err(|e| format!("--save-spec {path}: {e}"))?;
        println!("spec written to {path}");
    }
    Ok(s)
}

fn cache_dir_from_args(args: &Args) -> PathBuf {
    args.get("cache-dir")
        .map(PathBuf::from)
        .unwrap_or_else(report::default_cache_dir)
}

/// Resolve the results store: `--store DIR|tcp://host:port` wins, else
/// a directory store at `--cache-dir` (or its default). A networked
/// store is pinged here — before any simulation or fan-out — so an
/// unreachable cache server is one clear CLI error, not a mid-sweep
/// worker panic.
fn store_from_args(args: &Args) -> Result<Store, String> {
    let store = match args.get("store") {
        Some(arg) => Store::parse(arg).map_err(|e| format!("--store: {e}"))?,
        None => Store::fs(cache_dir_from_args(args)),
    };
    if store.is_remote() {
        store.ping().map_err(|e| format!("--store: {e}"))?;
    }
    Ok(store)
}

fn ctx_from_args(args: &Args) -> Result<FigureCtx, String> {
    let workloads: Vec<String> = if args.flag("all") {
        report::all_workloads()
    } else {
        report::default_workloads().iter().map(|s| s.to_string()).collect()
    };
    let mut ctx = FigureCtx::new(workloads, spec_from_args(args)?);
    ctx.sweep.disk_cache = !args.flag("no-cache");
    ctx.sweep.store = Some(store_from_args(args)?);
    Ok(ctx)
}

fn csv_path(args: &Args, name: &str) -> Option<String> {
    args.flag("csv").then(|| format!("target/figures/{name}.csv"))
}

fn dispatch(cmd: &str, args: &Args) -> Result<(), String> {
    match cmd {
        "run" => cmd_run(args),
        "sweep" => cmd_sweep(args),
        "shard-worker" => cmd_shard_worker(args),
        "queue-worker" => cmd_queue_worker(args),
        "cache-server" => cmd_cache_server(args),
        "backends" => cmd_backends(args),
        "figure" => cmd_figure(args),
        "suite" => cmd_suite(args),
        "analyze" => cmd_analyze(args),
        "storage" => {
            figures::tab06_storage().emit(csv_path(args, "tab06").as_deref());
            Ok(())
        }
        "perf" => cmd_perf(args),
        "stats" => cmd_stats(args),
        "trace-summary" => cmd_trace_summary(args),
        "lint" => cmd_lint(args),
        "list" => {
            println!("workloads: {}", report::all_workloads().join(", "));
            println!("policies : {}", report::policy_names().join(", "));
            println!("knobs (for --set key=value / spec files):");
            for k in knobs::all() {
                println!("  {:<32} {:<4} {}", k.key, k.kind.name(), k.help);
            }
            println!("device profiles (for --set dram.profile= / \
                      nvm.profile= and `backends --profiles`):");
            for p in profiles::all() {
                println!("  {:<16} {:<8} {}", p.name, p.tech.name(),
                         p.summary);
            }
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try --help")),
    }
}

/// `perf`: run the hot-path throughput suite (`rainbow::perf`) and
/// emit the versioned `rainbow-bench-v1` JSON report — the command
/// behind the committed `BENCH_<n>.json` trajectory files (see
/// EXPERIMENTS.md §Perf). `--validate FILE` instead checks an existing
/// report against the schema, the drift guard CI's bench-smoke job
/// runs. The `RAINBOW_BENCH_SAMPLES` / `RAINBOW_BENCH_WARMUP_MS` /
/// `RAINBOW_BENCH_TARGET_MS` env caps shrink a run for smoke tests.
fn cmd_perf(args: &Args) -> Result<(), String> {
    use rainbow::perf;
    use rainbow::util::json;
    if let Some(path) = args.get("validate") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("--validate {path}: {e}"))?;
        let doc = json::parse(&text)
            .map_err(|e| format!("--validate {path}: {e}"))?;
        perf::validate(&doc)
            .map_err(|e| format!("--validate {path}: {e}"))?;
        println!("{path}: valid {} report", perf::SCHEMA);
        return Ok(());
    }
    let cfg = perf::PerfConfig::from_env();
    let report = perf::run_suite(&cfg);
    let text = report.to_json().pretty();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)
                .map_err(|e| format!("--out {path}: {e}"))?;
            println!("perf: {} report with {} benches written to {path} \
                      (suite wall-clock {:.1}s)",
                     perf::SCHEMA, report.benches.len(),
                     report.wall_clock_s);
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    // rainbow-lint: allow(nondet-clock, operator-facing wall-clock display only)
    let t0 = Instant::now();
    let m = if let Some(path) = args.get("trace-out") {
        // Traced runs bypass the cache in both directions: stored
        // metrics carry no rings, and the sink never feeds back into
        // timing, so the metrics printed below still equal a cached
        // run's bit-for-bit (pinned in sweep_determinism.rs).
        let (m, tel) = report::run_traced(&spec);
        let text = rainbow::telemetry::trace::render_trace(
            &report::trace_meta(&spec), &m, &tel);
        std::fs::write(path, &text)
            .map_err(|e| format!("--trace-out {path}: {e}"))?;
        println!("trace: {} event(s) held ({} dropped), {} epoch(s) \
                  written to {path}",
                 tel.events_held(), tel.events_dropped(), tel.epochs());
        m
    } else if args.flag("no-cache") {
        report::run_uncached(&spec)
    } else {
        report::run_stored(&store_from_args(args)?, &spec)?
    };
    let dt = t0.elapsed();
    let mut t = Table::new(
        &format!("{} on {} (scale 1/{}, {} instructions, {:.1}s)",
                 spec.policy, spec.workload, spec.scale,
                 spec.instructions, dt.as_secs_f64()),
        &["metric", "value"]);
    let fp = spec.footprint_bytes();
    t.row(&["IPC".into(), format!("{:.4}", m.ipc())]);
    t.row(&["cycles".into(), m.cycles.to_string()]);
    t.row(&["MPKI".into(), format!("{:.3}", m.mpki())]);
    t.row(&["TLB-miss cycle %".into(),
            format!("{:.2}%", 100.0 * m.tlb_miss_cycle_frac())]);
    t.row(&["SP TLB hit rate".into(),
            format!("{:.2}%", 100.0 * m.sp_hit_rate)]);
    t.row(&["migrations".into(), m.migrations.to_string()]);
    t.row(&["migration traffic/footprint".into(),
            format!("{:.3}", m.migration_traffic_ratio(fp))]);
    t.row(&["shootdowns".into(), m.shootdowns.to_string()]);
    t.row(&["bitmap hit rate".into(),
            format!("{:.2}%", 100.0 * m.bitmap_hit_rate())]);
    t.row(&["runtime overhead %".into(),
            format!("{:.2}%", 100.0 * m.runtime_overhead_frac())]);
    t.row(&["rt mig/sd/clf/id Mcyc".into(),
            format!("{:.1}/{:.1}/{:.1}/{:.1}",
                    m.rt.migration_cycles as f64 / 1e6,
                    m.rt.shootdown_cycles as f64 / 1e6,
                    m.rt.clflush_cycles as f64 / 1e6,
                    m.rt.identify_cycles as f64 / 1e6)]);
    t.row(&["LLC misses".into(), m.llc_misses.to_string()]);
    t.row(&["mem stall Mcyc".into(),
            format!("{:.1}", m.mem_stall_cycles as f64 / 1e6)]);
    t.row(&["xlat tlb/bm/ptw/sptw/remap Mcyc".into(),
            format!("{:.1}/{:.1}/{:.1}/{:.1}/{:.1}",
                    m.xlat.tlb_cycles as f64 / 1e6,
                    m.xlat.bitmap_cycles as f64 / 1e6,
                    m.xlat.ptw_cycles as f64 / 1e6,
                    m.xlat.sptw_cycles as f64 / 1e6,
                    m.xlat.remap_cycles as f64 / 1e6)]);
    t.row(&["energy (mJ)".into(), format!("{:.3}", m.energy_mj())]);
    t.row(&["DRAM/NVM reads".into(),
            format!("{}/{}", m.dram_reads, m.nvm_reads)]);
    t.row(&["DRAM/NVM writes".into(),
            format!("{}/{}", m.dram_writes, m.nvm_writes)]);
    t.emit(None);
    Ok(())
}

/// Build the shard-orchestrator config from the CLI surface
/// (`--shards`, `--workers`, `--store`/`--cache-dir`, `--shard-dir`,
/// `--shard-cmd`). Shard spec-list files default next to a directory
/// store; with a networked store there is no shared directory to
/// derive from, so they land in `target/rainbow_shards` unless
/// `--shard-dir` says otherwise.
fn shard_config_from_args(args: &Args, shards: usize)
                          -> Result<shard::ShardConfig, String> {
    let store = store_from_args(args)?;
    let work_dir = match args.get("shard-dir") {
        Some(dir) => PathBuf::from(dir),
        None => match store.fs_dir() {
            Some(dir) => dir.join("shards"),
            None => PathBuf::from("target/rainbow_shards"),
        },
    };
    let mut cfg = shard::ShardConfig::with_store(shards, store, work_dir);
    cfg.parallel = args.get_usize("workers", 0)?;
    if let Some(cmd) = args.get("shard-cmd") {
        let cmd = cmd.trim();
        if cmd.is_empty() {
            return Err("--shard-cmd: empty command".into());
        }
        // Deprecated: the old whitespace splitting could not express a
        // path with spaces and invited quoting bugs. The whole value
        // is now a single program path (--specs/--store are still
        // appended); multi-host dispatch belongs to the queue worker.
        eprintln!(
            "warning: --shard-cmd is deprecated; its value is now a \
             single worker program path (the old whitespace splitting \
             was dropped). For remote workers prefer `sweep --queue` \
             with `rainbow queue-worker` on each host.");
        cfg.cmd = Some(vec![cmd.to_string()]);
    }
    Ok(cfg)
}

/// `shard-worker`: the child half of `sweep --shards` — execute a
/// spec-list file against the shared results store. Also usable
/// standalone (e.g. on another host against a shared directory, or
/// pointed at a cache server with `--store tcp://host:port`).
fn cmd_shard_worker(args: &Args) -> Result<(), String> {
    let specs = args
        .get("specs")
        .ok_or("shard-worker: --specs FILE required")?;
    let store = store_from_args(args)?;
    let n = shard::worker_run(Path::new(specs), &store)?;
    println!("shard-worker: {n} unique specs cached in {}", store.addr());
    Ok(())
}

/// `queue-worker`: lease specs one at a time from a cache server's
/// job queue, simulate each through the store, and acknowledge with
/// COMPLETE — until the queue reports itself drained. The standalone
/// remote half of `sweep --queue`: run it on any host with a route to
/// the server; no spec files, no shared filesystem.
fn cmd_queue_worker(args: &Args) -> Result<(), String> {
    let store = store_from_args(args)?;
    let hostport = match store.scheduler_hostport() {
        Some(hp) => hp.to_string(),
        None => {
            return Err("queue-worker: --store tcp://host:port (or a \
                        replicated tcp://a,tcp://b,... set, whose first \
                        endpoint schedules) required — the cache server \
                        is the scheduler".into())
        }
    };
    let worker_id = match args.get("worker-id") {
        Some(id) => id.to_string(),
        None => format!("w{}", std::process::id()),
    };
    if !queue::valid_worker_id(&worker_id) {
        return Err(format!(
            "queue-worker: malformed --worker-id {worker_id:?} (1-64 \
             chars, alphanumeric/._-)"));
    }
    // Per-worker deterministic jitter on connect retries: a fleet
    // reconnecting after a server restart fans out instead of
    // thundering-herding.
    let client = NetStore::new(&hostport).with_worker_jitter(&worker_id);
    let n = queue::worker_loop(&client, &store, &worker_id)?;
    println!("queue-worker {worker_id}: {n} job(s) completed; queue \
              drained at {}", store.addr());
    Ok(())
}

/// `cache-server`: serve any results store over TCP so sweeps and
/// shard workers can run with no shared filesystem. `--stop
/// tcp://host:port` instead asks a running server to shut down cleanly
/// (acknowledged, accept loop stopped, in-flight requests drained).
fn cmd_cache_server(args: &Args) -> Result<(), String> {
    if let Some(target) = args.get("stop") {
        let hostport = target.strip_prefix("tcp://").unwrap_or(target);
        NetStore::new(hostport)
            .shutdown_server()
            .map_err(|e| format!("cache-server --stop: {e}"))?;
        println!("cache-server at {hostport}: clean shutdown \
                  acknowledged");
        return Ok(());
    }
    let store = if let Some(log_path) = args.get("log") {
        if !args.flag("mem") {
            return Err("--log requires --mem (the log is the \
                        durability story for the in-memory store; a \
                        directory store is already durable)".into());
        }
        let (store, stats) = Store::logged(Path::new(log_path))?;
        println!(
            "cache-server: replayed {} record(s) from {log_path}\
             {}{}",
            stats.loaded,
            if stats.skipped_stale > 0 {
                format!(" ({} stale skipped)", stats.skipped_stale)
            } else {
                String::new()
            },
            if stats.truncated_bytes > 0 {
                format!(" ({} torn byte(s) truncated)",
                        stats.truncated_bytes)
            } else {
                String::new()
            });
        store
    } else if args.flag("mem") {
        Store::mem()
    } else {
        match args.get("store") {
            // Allows fronting another server too (a relay); the usual
            // backing store is a directory.
            Some(arg) => {
                Store::parse(arg).map_err(|e| format!("--store: {e}"))?
            }
            None => Store::fs(cache_dir_from_args(args)),
        }
    };
    let listen = args.get_or("listen", "127.0.0.1:7700");
    let lease_ms = args.get_u64("lease-ms", queue::DEFAULT_LEASE_MS)?;
    if lease_ms == 0 {
        return Err("--lease-ms: must be positive".into());
    }
    let server =
        CacheServer::bind(listen, store.clone())?.with_lease_ms(lease_ms);
    let addr = server.local_addr();
    if let Some(port_file) = args.get("port-file") {
        // Temp + rename so a script polling the file never reads a
        // half-written address.
        let tmp = format!("{port_file}.tmp.{}", std::process::id());
        std::fs::write(&tmp, addr.to_string())
            .and_then(|()| std::fs::rename(&tmp, port_file))
            .map_err(|e| format!("--port-file {port_file}: {e}"))?;
    }
    println!("cache-server: serving {} at tcp://{addr}", store.addr());
    println!("cache-server: stop with `rainbow cache-server --stop \
              tcp://{addr}`");
    server.serve()?;
    // Clean (--stop) shutdown: snapshot+compact the durability log,
    // if one backs this server, so the next startup replays one
    // record per live entry instead of the full append history.
    store.compact().map_err(|e| format!("cache-server: compact: {e}"))?;
    if let Some(log_path) = args.get("log") {
        println!("cache-server: log compacted at {log_path}");
    }
    println!("cache-server: clean shutdown");
    Ok(())
}

/// `sweep`: execute a workload x policy matrix — on scoped worker
/// threads (report::sweep), with `--shards N` across child
/// `shard-worker` processes merged through the shared cache
/// (report::shard), or with `--queue` dynamically dispatched through a
/// cache server's job queue (report::queue, work-stealing: each worker
/// leases one spec at a time, so skewed per-spec costs balance without
/// static partitioning) — print one row per cell, and optionally
/// verify the results byte-for-byte against a serial `run_uncached`
/// replay.
/// Specs, names, and every `--set` override are validated up front (in
/// `report::spec_cli`): an unknown name or knob inside a worker thread
/// would panic the scope instead of taking the CLI's error path.
fn cmd_sweep(args: &Args) -> Result<(), String> {
    let base = spec_from_args(args)?;
    let workloads = spec_cli::sweep_workloads(args)?;
    let policies = spec_cli::sweep_policies(args)?;
    let specs = sweep::matrix(&base, &workloads, &policies);
    let shards = args.get_usize("shards", 0)?;
    let queue_mode = args.flag("queue");
    if queue_mode && shards > 0 {
        return Err("sweep: --queue and --shards are mutually exclusive \
                    (dynamic dispatch replaces static partitioning)".into());
    }
    // rainbow-lint: allow(nondet-clock, operator-facing wall-clock display only)
    let t0 = Instant::now();
    let (metrics, unique_runs, exec_label) = if queue_mode {
        // Same rationale as --shards: the store IS the merge transport.
        if args.flag("no-cache") {
            return Err("sweep --queue uses the results store as its \
                        merge transport; --no-cache is incompatible \
                        (point --store at a fresh server instead)".into());
        }
        let store = store_from_args(args)?;
        if !store.is_remote() {
            return Err("sweep --queue: --store tcp://host:port required \
                        (the cache server is the scheduler)".into());
        }
        if args.flag("check") {
            let listed: std::collections::HashSet<String> =
                store.list().unwrap_or_default().into_iter().collect();
            let pre = specs
                .iter()
                .filter(|s| listed.contains(&s.fingerprint()))
                .count();
            if pre > 0 {
                println!(
                    "sweep --queue --check: {pre} of {} cells already \
                     cached in {} — a divergence may be a stale entry \
                     from an older build, not nondeterminism (use a \
                     fresh --store to rule that out)",
                    specs.len(), store.addr());
            }
        }
        let out =
            queue::run_queued(&specs, &store, args.get_usize("workers", 0)?)
                .map_err(|e| format!("sweep --queue: {e}"))?;
        let label = format!("{} queue workers", out.workers_used);
        (out.metrics, out.unique_runs, label)
    } else if shards > 0 {
        // The cache IS the shard transport: silently serving (possibly
        // stale) entries against an explicit --no-cache would be a lie.
        if args.flag("no-cache") {
            return Err("sweep --shards uses the results store as its \
                        merge transport; --no-cache is incompatible \
                        (point --cache-dir/--store at a fresh \
                        directory or server instead)".into());
        }
        let cfg = shard_config_from_args(args, shards)?;
        // Pre-existing entries are legitimate (the store is shared by
        // design) but under --check they make a divergence ambiguous:
        // call them out so a stale-entry failure isn't chased as a
        // cross-process determinism bug. (`list` is also the one
        // store round-trip the coordinator makes before fan-out.)
        if args.flag("check") {
            let listed: std::collections::HashSet<String> =
                cfg.store.list().unwrap_or_default().into_iter().collect();
            let pre = specs
                .iter()
                .filter(|s| listed.contains(&s.fingerprint()))
                .count();
            if pre > 0 {
                println!(
                    "sweep --shards --check: {pre} of {} cells already \
                     cached in {} — a divergence may be a stale entry \
                     from an older build, not nondeterminism (use a \
                     fresh --cache-dir/--store to rule that out)",
                    specs.len(), cfg.store.addr());
            }
        }
        let out = shard::run_sharded(&specs, &cfg)
            .map_err(|e| format!("sweep --shards: {e}"))?;
        let label = format!("{} shard processes", out.shards_run);
        (out.metrics, out.unique_runs, label)
    } else {
        let cfg = SweepConfig {
            workers: args.get_usize("workers", 0)?,
            // --check wants fresh simulations on both sides; stale
            // store entries would hide a divergence. (Under --shards
            // the store IS the transport, so --check verifies it.)
            disk_cache: !args.flag("no-cache") && !args.flag("check"),
            store: Some(store_from_args(args)?),
        };
        let out = sweep::run(&specs, &cfg);
        (out.metrics, out.unique_runs,
         format!("{} workers", out.workers_used))
    };
    let dt = t0.elapsed().as_secs_f64();

    // Raw pJ + per-tier row-hit rates so backend comparisons are
    // scriptable straight off `--csv` (no figure-text parsing).
    let mut t = Table::new(
        &format!("sweep: {} runs ({} unique) on {} in {:.1}s",
                 specs.len(), unique_runs, exec_label, dt),
        &["workload", "policy", "IPC", "MPKI", "migrations", "energy_pj",
          "dram_row_hit", "nvm_row_hit", "cycles"]);
    for (s, m) in specs.iter().zip(&metrics) {
        t.row(&[s.workload.clone(), s.policy.clone(),
                format!("{:.4}", m.ipc()),
                format!("{:.3}", m.mpki()),
                m.migrations.to_string(),
                format!("{:.0}", m.energy_pj),
                format!("{:.4}", m.dram_row_hit_rate()),
                format!("{:.4}", m.nvm_row_hit_rate()),
                m.cycles.to_string()]);
    }
    t.emit(csv_path(args, "sweep").as_deref());

    if let Some(path) = args.get("csv-series") {
        write_csv_series(path, &specs)?;
    }

    if args.flag("check") {
        use rainbow::report::serde_kv::metrics_to_kv;
        let side = if queue_mode {
            "queue-merged"
        } else if shards > 0 {
            "shard-merged"
        } else {
            "parallel"
        };
        let hint = if queue_mode || shards > 0 {
            " (a stale store entry from an older build also looks like \
             this; retry with a fresh --cache-dir/--store)"
        } else {
            ""
        };
        for (s, pm) in specs.iter().zip(&metrics) {
            let serial = report::run_uncached(s);
            if metrics_to_kv(&serial) != metrics_to_kv(pm) {
                return Err(format!(
                    "sweep check FAILED: {side} and serial metrics \
                     diverge for {} x {}{hint}", s.workload, s.policy));
            }
        }
        println!("sweep check: {side} metrics byte-identical to serial \
                  run_uncached for all {} runs", specs.len());
    }
    Ok(())
}

/// `sweep --csv-series FILE`: one CSV row per (cell, epoch), from a
/// deterministic traced re-run of every cell. Traces never land in the
/// results store (stored metrics carry no rings), so the series is
/// re-simulated here; determinism makes the re-run's epochs exactly
/// the ones the sweep's cells went through.
fn write_csv_series(path: &str, specs: &[RunSpec]) -> Result<(), String> {
    use std::fmt::Write as _;
    let mut out = String::from(
        "workload,policy,epoch,cycle,instructions,tlb_misses,\
         migrated_bytes,dram_row_hits,dram_row_misses,nvm_row_hits,\
         nvm_row_misses,dram_util_bp\n");
    let mut epochs = 0u64;
    for s in specs {
        let (_, tel) = report::run_traced(s);
        for e in tel.series() {
            epochs += 1;
            let _ = writeln!(
                out, "{},{},{},{},{},{},{},{},{},{},{},{}",
                s.workload, s.policy, e.epoch, e.cycle, e.instructions,
                e.tlb_misses, e.migrated_bytes, e.dram_row_hits,
                e.dram_row_misses, e.nvm_row_hits, e.nvm_row_misses,
                e.dram_util_bp);
        }
    }
    std::fs::write(path, &out)
        .map_err(|e| format!("--csv-series {path}: {e}"))?;
    println!("csv-series: {epochs} epoch row(s) across {} cell(s) \
              written to {path}", specs.len());
    Ok(())
}

/// `stats`: ask every cache-server endpoint of `--store` for its
/// fleet-stats snapshot (the protocol-v3 STATS opcode) and print one
/// row per server: per-opcode request counts, lease-latency quantiles,
/// WAL durability counters, and replica-degradation counters.
fn cmd_stats(args: &Args) -> Result<(), String> {
    let arg = args.get("store").ok_or(
        "stats: --store tcp://host:port (or a replicated \
         tcp://a,tcp://b,... set) required")?;
    let store = Store::parse(arg).map_err(|e| format!("--store: {e}"))?;
    if !store.is_remote() {
        return Err("stats: --store must be a tcp:// cache server (a \
                    directory store has no server to ask)".into());
    }
    let mut t = Table::new(
        "fleet stats (one row per cache-server endpoint)",
        &["endpoint", "gets", "puts", "lists", "pings", "leases",
          "completes", "requeues", "qstats", "stats",
          "lease ms p50/p95/p99", "wal app/fsync/replay",
          "degraded get/put/repair"]);
    for ep in arg.split(',') {
        let hostport = ep.strip_prefix("tcp://").unwrap_or(ep);
        let s = NetStore::new(hostport).server_stats()?;
        t.row(&[ep.to_string(), s.gets.to_string(), s.puts.to_string(),
                s.lists.to_string(), s.pings.to_string(),
                s.leases.to_string(), s.completes.to_string(),
                s.requeues.to_string(), s.qstats.to_string(),
                s.stats_reqs.to_string(),
                format!("{}/{}/{}", s.lease_ms_p50, s.lease_ms_p95,
                        s.lease_ms_p99),
                format!("{}/{}/{}", s.wal_appends, s.wal_fsyncs,
                        s.wal_replayed),
                format!("{}/{}/{}", s.degraded_gets, s.degraded_puts,
                        s.read_repairs)]);
    }
    t.emit(csv_path(args, "stats").as_deref());
    Ok(())
}

/// `trace-summary FILE`: strictly validate a `run --trace-out` file
/// (the same locked-schema reader CI's trace-smoke job uses) and print
/// its identity, end-of-run scalars, event counts, and per-epoch
/// time-series.
fn cmd_trace_summary(args: &Args) -> Result<(), String> {
    use rainbow::telemetry::{trace, EventKind, TRACE_VERSION};
    let path = args.positional.first().ok_or(
        "trace-summary: usage `rainbow trace-summary FILE` (a file \
         written by `run --trace-out`)")?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("trace-summary {path}: {e}"))?;
    let s = trace::read_trace(&text)
        .map_err(|e| format!("trace-summary {path}: {e}"))?;
    println!("trace {path}: {} on {} (fingerprint {}, interval {} \
              cycles, {} instructions)",
             s.meta.policy, s.meta.workload, s.meta.fingerprint,
             s.meta.interval_cycles, s.meta.instructions);
    println!("summary: {} cycles, IPC {:.4}, {} migration(s), \
              mig p99 {} cyc, ptw p99 {} cyc",
             s.cycles, s.ipc, s.migrations, s.mig_lat_p99,
             s.ptw_lat_p99);
    let counts: Vec<String> = EventKind::ALL
        .iter()
        .zip(s.event_counts)
        .map(|(k, n)| format!("{}={n}", k.name()))
        .collect();
    println!("events: {}", counts.join(" "));
    let mut t = Table::new(
        &format!("per-epoch series ({} epoch(s))", s.epochs.len()),
        &["epoch", "cycle", "instructions", "tlb_misses",
          "migrated_bytes", "dram_row_hits", "nvm_row_hits",
          "dram_util_bp"]);
    for e in &s.epochs {
        t.row(&[e.epoch.to_string(), e.cycle.to_string(),
                e.instructions.to_string(), e.tlb_misses.to_string(),
                e.migrated_bytes.to_string(),
                e.dram_row_hits.to_string(),
                e.nvm_row_hits.to_string(),
                e.dram_util_bp.to_string()]);
    }
    t.emit(csv_path(args, "trace_summary").as_deref());
    println!("trace-summary {path}: valid traceversion {TRACE_VERSION} \
              file ({} line(s))", text.lines().count());
    Ok(())
}

/// `backends`: the policy × NVM-device-profile matrix (Fig. 16) —
/// profile names are validated against the catalog here, before the
/// figure's sweep fans out.
fn cmd_backends(args: &Args) -> Result<(), String> {
    let mut ctx = ctx_from_args(args)?;
    // Same workload surface as `sweep`: --apps list, --all, or default.
    ctx.workloads = spec_cli::sweep_workloads(args)?;
    let profs: Vec<String> = match args.get("profiles") {
        Some(list) if list.eq_ignore_ascii_case("all") => {
            profiles::names().iter().map(|s| s.to_string()).collect()
        }
        Some(list) => spec_cli::comma_list(list),
        None => profiles::slow_tier_names()
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    if profs.is_empty() {
        return Err("backends: empty profile list".into());
    }
    for p in &profs {
        if profiles::by_name(p).is_none() {
            return Err(format!(
                "unknown device profile {p:?}; `rainbow list` shows the \
                 catalog"));
        }
    }
    let pols: Vec<String> = match args.get("policies") {
        Some(_) => spec_cli::sweep_policies(args)?,
        None => figures::BACKEND_POLICIES
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    figures::fig16_backends(&ctx, &profs, &pols)
        .emit(csv_path(args, "fig16_backends").as_deref());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<(), String> {
    let fig = args.get("fig").ok_or("--fig required (e.g. --fig 10)")?;
    let ctx = ctx_from_args(args)?;
    emit_figure(fig, &ctx, args)
}

fn emit_figure(fig: &str, ctx: &FigureCtx, args: &Args)
               -> Result<(), String> {
    let sens_apps = ["mcf", "soplex", "GUPS"];
    let t = match fig {
        "1" | "fig1" => figures::fig01_cdf(ctx),
        "t1" | "tab1" => figures::tab01_hotstats(ctx),
        "t2" | "tab2" => figures::tab02_hotdist(ctx),
        "7" => figures::fig07_mpki(ctx),
        "8" => figures::fig08_tlbcycles(ctx),
        "9" => figures::fig09_breakdown(ctx),
        "10" => figures::fig10_ipc(ctx),
        "11" => figures::fig11_traffic(ctx),
        "12" => figures::fig12_energy(ctx),
        "13" => figures::fig13_interval(ctx, &sens_apps),
        "14" => figures::fig14_topn(ctx, &sens_apps),
        "15" => figures::fig15_runtime(ctx),
        "16" => {
            // The default backend matrix; `rainbow backends` offers the
            // full --profiles/--policies surface.
            let profs: Vec<String> = profiles::slow_tier_names()
                .iter().map(|s| s.to_string()).collect();
            let pols: Vec<String> = figures::BACKEND_POLICIES
                .iter().map(|s| s.to_string()).collect();
            figures::fig16_backends(ctx, &profs, &pols)
        }
        "t6" | "tab6" => figures::tab06_storage(),
        "remap" => figures::ana_remap_cost(&Config::paper()),
        other => return Err(format!("unknown figure {other:?}")),
    };
    t.emit(csv_path(args, &format!("fig{fig}")).as_deref());
    Ok(())
}

fn cmd_suite(args: &Args) -> Result<(), String> {
    let ctx = ctx_from_args(args)?;
    // rainbow-lint: allow(nondet-clock, operator-facing wall-clock display only)
    let t0 = Instant::now();
    let shards = args.get_usize("shards", 0)?;
    if shards > 0 {
        // Pre-warm the whole headline matrix across shard processes;
        // the figure emitters below then render from the merged cache
        // (same --cache-dir) instead of simulating in-process. With
        // --no-cache the emitters would ignore that cache and simulate
        // everything a second time — reject the combination.
        if args.flag("no-cache") {
            return Err("suite --shards pre-warms the results store the \
                        figures then read; --no-cache is incompatible \
                        (point --cache-dir/--store at a fresh \
                        directory or server instead)".into());
        }
        let specs = figures::suite_specs(&ctx);
        let cfg = shard_config_from_args(args, shards)?;
        println!("suite: pre-warming {} matrix cells across {} shards...",
                 specs.len(), shards);
        shard::run_sharded(&specs, &cfg)
            .map_err(|e| format!("suite --shards: {e}"))?;
    }
    for fig in ["1", "t1", "t2", "7", "8", "9", "10", "11", "12", "13",
                "14", "15", "t6", "remap"] {
        emit_figure(fig, &ctx, args)?;
    }
    println!("suite complete in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<(), String> {
    let mut ctx = ctx_from_args(args)?;
    if let Some(app) = args.get("app") {
        ctx.workloads = vec![app.to_string()];
    }
    figures::fig01_cdf(&ctx).emit(csv_path(args, "fig01").as_deref());
    figures::tab01_hotstats(&ctx).emit(csv_path(args, "tab01").as_deref());
    figures::tab02_hotdist(&ctx).emit(csv_path(args, "tab02").as_deref());
    Ok(())
}

/// `rainbow lint`: run the static-analysis pass over `rust/src` (or
/// `--src DIR`) and exit non-zero on findings. See docs/MANUAL.md
/// §lint for the rule catalog and the schemas.lock workflow.
fn cmd_lint(args: &Args) -> Result<(), String> {
    if args.flag("list-rules") {
        for r in analysis::RULES {
            println!("{:<16} {:<13} {}{}", r.id, r.family, r.summary,
                     if r.suppressible { "" } else {
                         "  [not suppressible]"
                     });
        }
        return Ok(());
    }
    let src = args
        .get("src")
        .map(PathBuf::from)
        .unwrap_or_else(analysis::default_src_dir);
    let tree = analysis::SourceTree::from_dir(&src)?;

    if args.flag("update-schemas") {
        let old = analysis::load_lock(&src)?;
        let text = analysis::schema::update_lock(
            &tree, old.as_deref(), analysis::schema::TRACKED)?;
        let path = analysis::lock_path_for(&src);
        std::fs::write(&path, &text)
            .map_err(|e| format!("lint: write {}: {e}", path.display()))?;
        println!("schemas lock re-stamped: {}", path.display());
        return Ok(());
    }

    let cfg = analysis::LintConfig {
        stale_allows: args.flag("stale-allows"),
        schemas_lock: analysis::load_lock(&src)?,
    };
    let findings = analysis::lint_tree(&tree, &cfg);

    if args.flag("fix-allow") {
        let n = analysis::fix_allow(&src, &findings)?;
        println!("lint: stamped {n} allow marker(s); edit each TODO \
                  into an honest reason, then rerun `rainbow lint`");
        return Ok(());
    }

    for d in &findings {
        println!("{d}");
    }
    if findings.is_empty() {
        println!("lint clean: {} files, {} rules", tree.files.len(),
                 analysis::RULES.len());
        Ok(())
    } else {
        Err(format!("{} lint finding(s) across {} scanned files \
                     (suppress a justified exception with \
                     `rainbow-lint: allow(rule-id, reason)` or \
                     `--fix-allow`; see `--list-rules`)",
                    findings.len(), tree.files.len()))
    }
}
