//! Experiment harness shared by `main.rs` and every bench binary: runs
//! (workload x policy) simulations against a pluggable results store
//! ([`store::CacheStore`] — a local directory, an in-memory map, or a
//! `rainbow cache-server` over TCP) so a full figure suite only
//! simulates each pair once, and derives each paper table/figure from
//! the stored metrics.

use std::path::{Path, PathBuf};

use crate::policies::{self, Policy};
use crate::sim::{engine, EngineConfig, RunMetrics};
use crate::telemetry::{self, trace::TraceMeta, Telemetry};
use crate::util::log;
use crate::workloads::Workload;

pub mod figures;
pub mod netstore;
pub mod queue;
pub mod replica;
pub mod serde_kv;
pub mod shard;
pub mod spec;
pub mod spec_cli;
pub mod store;
pub mod sweep;
pub mod wal;

pub use spec::RunSpec;
pub use store::{CacheStore, FsStore, MemStore, Store, StoreKind, StoreObs};

/// Default on-disk results-cache directory: the `RAINBOW_CACHE` env var
/// if set (read-only — nothing in the crate mutates it), else
/// `target/rainbow_results`. Callers that need isolation pass an
/// explicit directory to [`run_cached_in`] or an explicit
/// `SweepConfig::store`.
pub fn default_cache_dir() -> PathBuf {
    std::env::var_os("RAINBOW_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/rainbow_results"))
}

/// Run the simulation described by `spec` (or load the cached result)
/// against the default cache directory.
pub fn run_cached(spec: &RunSpec) -> RunMetrics {
    run_cached_in(&default_cache_dir(), spec)
}

/// [`run_cached`] with an explicit cache directory — a thin wrapper
/// over [`run_stored`] with a directory-backed [`Store`], kept because
/// the local-directory case is the overwhelmingly common one in tests
/// and benches. Entry atomicity (temp file + rename) lives in
/// `store::FsStore`.
pub fn run_cached_in(dir: &Path, spec: &RunSpec) -> RunMetrics {
    run_stored(&Store::fs(dir), spec)
        .expect("local stores self-heal; run_stored only fails remotely")
}

/// Run the simulation described by `spec`, or serve it from `store`:
/// a hit returns the stored metrics, a miss simulates and publishes
/// the result.
///
/// Failure semantics follow the store kind. A *local* store is
/// best-effort, as the disk cache has always been: a corrupt entry is
/// warned about and re-simulated over (self-healing), an unwritable
/// directory costs re-simulation later, and the function cannot fail.
/// A *remote* store is a transport — the sharded sweep's merge depends
/// on every result landing in it — so any remote error (server down,
/// torn frame, corrupt entry server-side) is returned as a clean error
/// instead of silently degrading a shared-nothing sweep into
/// simulate-everything-locally.
pub fn run_stored(store: &Store, spec: &RunSpec)
                  -> Result<RunMetrics, String> {
    let fp = spec.fingerprint();
    match store.get(&fp) {
        Ok(Some(m)) => return Ok(m),
        Ok(None) => {}
        Err(e) => {
            if store.is_remote() {
                return Err(e);
            }
            log::warn(&format!("{e}; re-simulating"));
        }
    }
    let m = run_uncached(spec);
    if let Err(e) = store.put(&fp, &m) {
        if store.is_remote() {
            return Err(e);
        }
    }
    Ok(m)
}

/// Always simulate (no cache).
pub fn run_uncached(spec: &RunSpec) -> RunMetrics {
    let cfg = spec.config();
    let mut workload =
        Workload::by_name(&spec.workload, cfg.cores, spec.scale, spec.seed)
            .unwrap_or_else(|| panic!("unknown workload {}", spec.workload));
    let mut policy: Box<dyn Policy> =
        policies::from_name(&spec.policy, &cfg, spec.accel)
            .unwrap_or_else(|| panic!("unknown policy {}", spec.policy));
    let ecfg = EngineConfig::new(spec.instructions, cfg.interval_cycles);
    engine::run(policy.as_mut(), &mut workload, &ecfg).metrics
}

/// Always simulate with event/series telemetry enabled; returns the
/// run's metrics together with the captured [`Telemetry`] sink.
/// Bypasses every cache (stored metrics do not carry rings). The sink
/// never feeds back into timing, so the metrics equal an untraced
/// run's bit-for-bit — pinned in `rust/tests/sweep_determinism.rs`.
pub fn run_traced(spec: &RunSpec) -> (RunMetrics, Telemetry) {
    let cfg = spec.config();
    let mut workload =
        Workload::by_name(&spec.workload, cfg.cores, spec.scale, spec.seed)
            .unwrap_or_else(|| panic!("unknown workload {}", spec.workload));
    let mut policy: Box<dyn Policy> =
        policies::from_name(&spec.policy, &cfg, spec.accel)
            .unwrap_or_else(|| panic!("unknown policy {}", spec.policy));
    policy.machine_mut().tel.enable(telemetry::DEFAULT_EVENT_CAP,
                                    telemetry::DEFAULT_SERIES_CAP);
    let ecfg = EngineConfig::new(spec.instructions, cfg.interval_cycles);
    let metrics = engine::run(policy.as_mut(), &mut workload, &ecfg).metrics;
    let tel = std::mem::take(&mut policy.machine_mut().tel);
    (metrics, tel)
}

/// The trace-file identity header for a spec (the `meta` record of
/// `run --trace-out`).
pub fn trace_meta(spec: &RunSpec) -> TraceMeta {
    TraceMeta {
        workload: spec.workload.clone(),
        policy: spec.policy.clone(),
        fingerprint: spec.fingerprint(),
        interval_cycles: spec.config().interval_cycles,
        instructions: spec.instructions,
    }
}

/// The five evaluated systems in figure order.
pub fn policy_names() -> [&'static str; 5] {
    policies::all_names()
}

/// Default workload set for the headline figures (subset keeps a full
/// suite run in minutes; `--all` in the CLI uses every registered
/// workload — 14 apps plus the Table-V and 8-app mixes).
pub fn default_workloads() -> Vec<&'static str> {
    vec!["cactusADM", "mcf", "soplex", "streamcluster", "DICT",
         "setCover", "Graph500", "GUPS", "mix2"]
}

pub fn all_workloads() -> Vec<String> {
    Workload::all_names()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(w: &str, p: &str) -> RunSpec {
        RunSpec::new(w, p)
            .with_scale(64)
            .with_instructions(60_000)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 16u64)
    }

    #[test]
    fn cache_roundtrip_is_identical() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_cache_test_{}", std::process::id()));
        let spec = tiny_spec("DICT", "flat");
        let a = run_cached_in(&dir, &spec);
        let b = run_cached_in(&dir, &spec); // from cache
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
        assert!((a.energy_pj - b.energy_pj).abs() < 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uncached_run_produces_metrics() {
        let m = run_uncached(&tiny_spec("streamcluster", "rainbow"));
        assert_eq!(m.instructions, 60_000);
        assert!(m.cycles > 0);
    }

    #[test]
    fn run_stored_round_trips_through_a_mem_store() {
        let store = Store::mem();
        let spec = tiny_spec("DICT", "flat");
        let a = run_stored(&store, &spec).unwrap();
        let b = run_stored(&store, &spec).unwrap(); // served, not re-run
        assert_eq!(serde_kv::metrics_to_kv(&a), serde_kv::metrics_to_kv(&b));
        assert_eq!(store.list().unwrap(), vec![spec.fingerprint()]);
    }
}
