//! Experiment harness shared by `main.rs` and every bench binary: runs
//! (workload x policy) simulations with a persistent on-disk cache so a
//! full figure suite only simulates each pair once, and derives each
//! paper table/figure from the cached metrics.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::policies::{self, Policy};
use crate::sim::{engine, EngineConfig, RunMetrics};
use crate::workloads::Workload;

pub mod figures;
pub mod serde_kv;
pub mod shard;
pub mod spec;
pub mod spec_cli;
pub mod sweep;

pub use spec::RunSpec;

/// Default on-disk results-cache directory: the `RAINBOW_CACHE` env var
/// if set (read-only — nothing in the crate mutates it), else
/// `target/rainbow_results`. Callers that need isolation pass an
/// explicit directory to [`run_cached_in`] / `SweepConfig::cache_dir`.
pub fn default_cache_dir() -> PathBuf {
    std::env::var_os("RAINBOW_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/rainbow_results"))
}

/// Run the simulation described by `spec` (or load the cached result)
/// against the default cache directory.
pub fn run_cached(spec: &RunSpec) -> RunMetrics {
    run_cached_in(&default_cache_dir(), spec)
}

/// [`run_cached`] with an explicit cache directory, threaded through
/// `SweepConfig` by the sweep orchestrator and set directly by tests
/// (no process-global env-var mutation).
///
/// Entries become visible atomically (written to a per-process temp
/// file, then renamed into place): the cache directory is shared by
/// concurrent sweeps and shard-worker processes by design, and the
/// shard merge path (`sweep::collect_cached`) treats a torn entry as
/// fatal corruption, so a reader must never observe a half-written
/// file. Concurrent writers of the same fingerprint produce identical
/// bytes (determinism), so whichever rename lands last is fine.
pub fn run_cached_in(dir: &Path, spec: &RunSpec) -> RunMetrics {
    let path = dir.join(format!("{}.kv", spec.fingerprint()));
    if let Ok(text) = fs::read_to_string(&path) {
        if let Some(m) = serde_kv::metrics_from_kv(&text) {
            return m;
        }
    }
    let m = run_uncached(spec);
    let _ = fs::create_dir_all(dir);
    // pid + per-process sequence number: unique across processes AND
    // across threads of one process, so no two writers ever share a
    // temp file.
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    let tmp = dir.join(format!(
        "{}.kv.tmp.{}.{}", spec.fingerprint(), std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)));
    if fs::write(&tmp, serde_kv::metrics_to_kv(&m)).is_ok() {
        let _ = fs::rename(&tmp, &path);
    }
    m
}

/// Always simulate (no cache).
pub fn run_uncached(spec: &RunSpec) -> RunMetrics {
    let cfg = spec.config();
    let mut workload =
        Workload::by_name(&spec.workload, cfg.cores, spec.scale, spec.seed)
            .unwrap_or_else(|| panic!("unknown workload {}", spec.workload));
    let mut policy: Box<dyn Policy> =
        policies::by_name(&spec.policy, &cfg, spec.accel)
            .unwrap_or_else(|| panic!("unknown policy {}", spec.policy));
    let ecfg = EngineConfig::new(spec.instructions, cfg.interval_cycles);
    engine::run(policy.as_mut(), &mut workload, &ecfg).metrics
}

/// The five evaluated systems in figure order.
pub fn policy_names() -> [&'static str; 5] {
    policies::all_names()
}

/// Default workload set for the headline figures (subset keeps a full
/// suite run in minutes; `--all` in the CLI uses all 17).
pub fn default_workloads() -> Vec<&'static str> {
    vec!["cactusADM", "mcf", "soplex", "streamcluster", "DICT",
         "setCover", "Graph500", "GUPS", "mix2"]
}

pub fn all_workloads() -> Vec<String> {
    Workload::all_names()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(w: &str, p: &str) -> RunSpec {
        RunSpec::new(w, p)
            .with_scale(64)
            .with_instructions(60_000)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 16u64)
    }

    #[test]
    fn cache_roundtrip_is_identical() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_cache_test_{}", std::process::id()));
        let spec = tiny_spec("DICT", "flat");
        let a = run_cached_in(&dir, &spec);
        let b = run_cached_in(&dir, &spec); // from cache
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
        assert!((a.energy_pj - b.energy_pj).abs() < 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn uncached_run_produces_metrics() {
        let m = run_uncached(&tiny_spec("streamcluster", "rainbow"));
        assert_eq!(m.instructions, 60_000);
        assert!(m.cycles > 0);
    }
}
