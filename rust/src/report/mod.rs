//! Experiment harness shared by `main.rs` and every bench binary: runs
//! (workload x policy) simulations with a persistent on-disk cache so a
//! full figure suite only simulates each pair once, and derives each
//! paper table/figure from the cached metrics.

use std::fs;
use std::path::PathBuf;

use crate::config::Config;
use crate::policies::{self, Policy};
use crate::sim::{engine, EngineConfig, RunMetrics};
use crate::workloads::{AppProfile, Workload};

pub mod figures;
pub mod serde_kv;
pub mod sweep;

/// Parameters that identify an experiment run (cache key).
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub workload: String,
    pub policy: String,
    /// Memory-capacity scale divisor vs the paper's Table IV.
    pub scale: u64,
    pub instructions: u64,
    pub interval_cycles: u64,
    pub top_n: usize,
    pub seed: u64,
    /// Use the PJRT artifacts for Rainbow identification.
    pub accel: bool,
}

impl RunSpec {
    pub fn new(workload: &str, policy: &str) -> RunSpec {
        RunSpec {
            workload: workload.to_string(),
            policy: policy.to_string(),
            scale: 8,
            instructions: 4_000_000,
            interval_cycles: 0, // 0 = take from scaled config
            top_n: 0,           // 0 = take from scaled config
            seed: 0xEA7_BEEF,
            accel: false,
        }
    }

    pub fn config(&self) -> Config {
        let mut cfg = Config::scaled(self.scale);
        if self.interval_cycles > 0 {
            cfg.interval_cycles = self.interval_cycles;
        }
        if self.top_n > 0 {
            cfg.top_n = self.top_n;
        }
        cfg
    }

    /// Stable identity of this run: every knob that can change the
    /// simulation's outcome. Keys both the on-disk results cache and the
    /// in-memory result sharing of the parallel sweep orchestrator.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}_{}_s{}_i{}_v{}_n{}_r{}{}",
            self.workload, self.policy, self.scale, self.instructions,
            self.interval_cycles, self.top_n, self.seed,
            if self.accel { "_accel" } else { "" }
        )
    }

    /// Scaled footprint of the workload (for Fig. 11 normalization).
    pub fn footprint_bytes(&self) -> u64 {
        match AppProfile::by_name(&self.workload) {
            Some(p) => p.scaled(self.scale).footprint,
            None => {
                // A mix: sum of its apps.
                crate::workloads::mixes()
                    .into_iter()
                    .find(|(n, _)| n.eq_ignore_ascii_case(&self.workload))
                    .map(|(_, apps)| {
                        apps.iter()
                            .map(|a| {
                                AppProfile::by_name(a)
                                    .unwrap()
                                    .scaled(self.scale)
                                    .footprint
                            })
                            .sum()
                    })
                    .unwrap_or(0)
            }
        }
    }
}

fn cache_dir() -> PathBuf {
    std::env::var_os("RAINBOW_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/rainbow_results"))
}

/// Run the simulation described by `spec` (or load the cached result).
pub fn run_cached(spec: &RunSpec) -> RunMetrics {
    let dir = cache_dir();
    let path = dir.join(format!("{}.kv", spec.fingerprint()));
    if let Ok(text) = fs::read_to_string(&path) {
        if let Some(m) = serde_kv::metrics_from_kv(&text) {
            return m;
        }
    }
    let m = run_uncached(spec);
    let _ = fs::create_dir_all(&dir);
    let _ = fs::write(&path, serde_kv::metrics_to_kv(&m));
    m
}

/// Always simulate (no cache).
pub fn run_uncached(spec: &RunSpec) -> RunMetrics {
    let cfg = spec.config();
    let mut workload =
        Workload::by_name(&spec.workload, cfg.cores, spec.scale, spec.seed)
            .unwrap_or_else(|| panic!("unknown workload {}", spec.workload));
    let mut policy: Box<dyn Policy> =
        policies::by_name(&spec.policy, &cfg, spec.accel)
            .unwrap_or_else(|| panic!("unknown policy {}", spec.policy));
    let ecfg = EngineConfig::new(spec.instructions, cfg.interval_cycles);
    engine::run(policy.as_mut(), &mut workload, &ecfg).metrics
}

/// The five evaluated systems in figure order.
pub fn policy_names() -> [&'static str; 5] {
    policies::all_names()
}

/// Default workload set for the headline figures (subset keeps a full
/// suite run in minutes; `--all` in the CLI uses all 17).
pub fn default_workloads() -> Vec<&'static str> {
    vec!["cactusADM", "mcf", "soplex", "streamcluster", "DICT",
         "setCover", "Graph500", "GUPS", "mix2"]
}

pub fn all_workloads() -> Vec<String> {
    Workload::all_names()
}

/// Serializes tests that mutate the RAINBOW_CACHE env var.
#[cfg(test)]
pub(crate) static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(w: &str, p: &str) -> RunSpec {
        let mut s = RunSpec::new(w, p);
        s.scale = 64;
        s.instructions = 60_000;
        s.interval_cycles = 100_000;
        s.top_n = 16;
        s
    }

    #[test]
    fn cache_roundtrip_is_identical() {
        let _guard = ENV_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join(format!(
            "rainbow_cache_test_{}", std::process::id()));
        std::env::set_var("RAINBOW_CACHE", &dir);
        let spec = tiny_spec("DICT", "flat");
        let a = run_cached(&spec);
        let b = run_cached(&spec); // from cache
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
        assert!((a.energy_pj - b.energy_pj).abs() < 1.0);
        std::env::remove_var("RAINBOW_CACHE");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn footprints_resolve_for_apps_and_mixes() {
        assert!(tiny_spec("mcf", "flat").footprint_bytes() > 0);
        assert!(tiny_spec("mix1", "flat").footprint_bytes() > 0);
    }

    #[test]
    fn uncached_run_produces_metrics() {
        let m = run_uncached(&tiny_spec("streamcluster", "rainbow"));
        assert_eq!(m.instructions, 60_000);
        assert!(m.cycles > 0);
    }
}
