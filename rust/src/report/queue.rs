//! Work-stealing job queue: the subsystem that turns `rainbow
//! cache-server` into a sweep *scheduler*. The coordinator enqueues a
//! checksummed spec-list job set (`REQUEUE`), workers on any host
//! lease one spec at a time (`LEASE`), simulate it, push the metrics
//! entry through the ordinary `PUT` path, and acknowledge
//! (`COMPLETE`); `QSTAT` reports drain progress. Against static
//! round-robin partitioning (`report::shard`) this keeps every worker
//! busy until the queue is dry, so a matrix with 10:1 per-spec cost
//! skew is no longer dominated by whichever shard drew the expensive
//! cells.
//!
//! ## Straggler recovery
//!
//! Every lease carries a deadline (server-relative milliseconds). A
//! worker that dies — or just straggles — past its deadline has its
//! spec returned to the pending set and re-leased to the next idle
//! worker, in deterministic (fingerprint-sorted) order. Because
//! simulations are bit-deterministic, the recovery paths all converge
//! on identical bytes:
//!
//! * death *before* `PUT`: the re-leased worker simulates from
//!   scratch and publishes the entry;
//! * death *between* `PUT` and `COMPLETE`: the re-leased worker's
//!   `run_stored` hits the published entry and merely acknowledges;
//! * a straggler finishing *after* its spec was re-leased and
//!   completed elsewhere: its duplicate `COMPLETE` is idempotent —
//!   the server keys completions by fingerprint, first write wins,
//!   and asserts byte-identity (the stored entry's checksum) so a
//!   *divergent* duplicate is a loud determinism violation, never a
//!   silent overwrite.
//!
//! ## State machine ([`QueueState`])
//!
//! Jobs move `pending -> leased -> completed`, with `leased ->
//! pending` on deadline expiry. All transitions take an injected
//! `now_ms` (the server's monotonic epoch-relative clock) — the state
//! machine itself never reads a clock, so every transition is unit
//! testable deterministically. Collections are ordered (`BTreeMap` /
//! `BTreeSet`): grant order, re-lease order, and `QSTAT` snapshots
//! are reproducible.
//!
//! The wire records below ride the framed netstore protocol
//! (`report::netstore`, protocol v3) as versioned `key=value` text,
//! guarded by [`serde_kv::QUEUE_WIRE_VERSION`] and schema-locked like
//! every other serialized struct in the crate.

use std::collections::{BTreeMap, BTreeSet};
use std::process::{Child, Command};
use std::thread;
use std::time::Duration;

use crate::sim::RunMetrics;
use crate::telemetry::Hist;
use crate::util::log;

use super::netstore::NetStore;
use super::serde_kv::{self, QUEUE_WIRE_VERSION};
use super::spec::fnv1a;
use super::spec_cli;
use super::store::{Store, StoreKind};
use super::sweep::{self, SweepOutcome};
use super::{run_stored, RunSpec};

/// Default lease deadline: how long a worker may hold a spec before
/// the server re-leases it (`cache-server --lease-ms` overrides).
/// Generous — paper-scale specs take minutes; an expiry only delays
/// recovery, it never loses work.
pub const DEFAULT_LEASE_MS: u64 = 60_000;

/// How long the coordinator sleeps between `QSTAT` polls.
const POLL_MS: u64 = 25;

/// Worker identities ride wire records as single `key=value` lines
/// and appear in operator-facing logs; keep them token-shaped.
pub fn valid_worker_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id.bytes().all(|b| {
            b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b'-'
        })
}

// ------------------------------------------------------- wire records

/// `LEASE` request payload: which worker is asking for work.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeaseRequest {
    pub worker: String,
}

/// What a `LEASE` reply tells the worker to do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseState {
    /// A spec is attached; simulate it, `PUT` the entry, `COMPLETE`.
    Granted,
    /// Nothing pending but leases are outstanding — work may come
    /// back on expiry. Retry after `retry_ms`.
    Wait,
    /// Every job is completed (or the queue is empty); exit cleanly.
    Drained,
}

impl LeaseState {
    fn as_str(self) -> &'static str {
        match self {
            LeaseState::Granted => "granted",
            LeaseState::Wait => "wait",
            LeaseState::Drained => "drained",
        }
    }

    fn parse(s: &str) -> Result<LeaseState, String> {
        match s {
            "granted" => Ok(LeaseState::Granted),
            "wait" => Ok(LeaseState::Wait),
            "drained" => Ok(LeaseState::Drained),
            other => Err(format!("lease reply: unknown state {other:?}")),
        }
    }
}

/// `LEASE` reply payload. `lease_id`/`deadline_ms` are meaningful for
/// `Granted` (deadline is server-epoch-relative — workers treat it as
/// informational, the server enforces it); `retry_ms` for `Wait`;
/// `spec` is attached iff `Granted`.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseReply {
    pub state: LeaseState,
    pub lease_id: u64,
    pub deadline_ms: u64,
    pub retry_ms: u64,
    pub spec: Option<RunSpec>,
}

/// `COMPLETE` request payload: worker acknowledges that the entry for
/// `fingerprint` is in the store. The server verifies that claim
/// against the store itself — the request carries no metrics. Wire v2:
/// when the results store is *replicated*, the ring may have placed
/// the entry on servers other than the scheduler, so the worker
/// declares the entry's [`entry_checksum`] and the scheduler verifies
/// against that (its own store, when it does hold the entry, remains
/// authoritative and the declared checksum must agree).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompleteRequest {
    pub worker: String,
    pub fingerprint: String,
    pub lease_id: u64,
    /// Declared [`entry_checksum`] of the completed entry. `None`
    /// preserves the v1 semantics: the scheduler's own store is the
    /// sole witness, and an entry it cannot see is a rejected
    /// completion.
    pub checksum: Option<u64>,
}

/// Queue counters: a `QSTAT` (and `REQUEUE`) reply. `total` counts
/// every job ever enqueued; `expired` counts lease expiries and
/// `requeued` (wire v3) counts re-grants of a previously expired job —
/// together they say how often straggler recovery actually fired, not
/// just how often deadlines lapsed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStat {
    pub total: u64,
    pub pending: u64,
    pub leased: u64,
    pub completed: u64,
    pub expired: u64,
    pub requeued: u64,
}

impl QueueStat {
    /// Nothing pending and nothing leased: every enqueued job has a
    /// completed entry (vacuously true for an empty queue).
    pub fn drained(&self) -> bool {
        self.pending == 0 && self.leased == 0
    }
}

// -------------------------------------------- wire (de)serialization

fn kv_header() -> String {
    format!("queuewireversion={QUEUE_WIRE_VERSION}\n")
}

/// Strict header/field parser shared by the queue records: versioned,
/// every key known, every required key present — same contract as the
/// spec/metrics readers.
fn parse_kv_fields(text: &str, what: &str)
                   -> Result<BTreeMap<String, String>, String> {
    let mut fields = BTreeMap::new();
    let mut version = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("{what} line {}: expected key=value, got {line:?}",
                    lineno + 1)
        })?;
        let (k, v) = (k.trim(), v.trim());
        if k == "queuewireversion" {
            version = Some(v.parse::<u64>().map_err(|_| {
                format!("{what}: bad queuewireversion {v:?}")
            })?);
        } else {
            fields.insert(k.to_string(), v.to_string());
        }
    }
    match version {
        Some(QUEUE_WIRE_VERSION) => Ok(fields),
        Some(v) => Err(format!(
            "{what}: queue wire version {v} unsupported \
             (expected {QUEUE_WIRE_VERSION})")),
        None => Err(format!("{what}: missing queuewireversion")),
    }
}

fn take_field(fields: &mut BTreeMap<String, String>, what: &str,
              key: &str) -> Result<String, String> {
    fields
        .remove(key)
        .ok_or_else(|| format!("{what}: missing {key}"))
}

fn take_u64(fields: &mut BTreeMap<String, String>, what: &str,
            key: &str) -> Result<u64, String> {
    let v = take_field(fields, what, key)?;
    v.parse::<u64>()
        .map_err(|_| format!("{what}: {key}: expected integer, got {v:?}"))
}

fn reject_unknown(fields: &BTreeMap<String, String>, what: &str)
                  -> Result<(), String> {
    match fields.keys().next() {
        Some(k) => Err(format!("{what}: unknown key {k:?}")),
        None => Ok(()),
    }
}

pub fn lease_request_to_kv(r: &LeaseRequest) -> String {
    format!("{}worker={}\n", kv_header(), r.worker)
}

pub fn lease_request_from_kv(text: &str) -> Result<LeaseRequest, String> {
    const WHAT: &str = "lease request";
    let mut f = parse_kv_fields(text, WHAT)?;
    let worker = take_field(&mut f, WHAT, "worker")?;
    reject_unknown(&f, WHAT)?;
    if !valid_worker_id(&worker) {
        return Err(format!("{WHAT}: malformed worker id {worker:?}"));
    }
    Ok(LeaseRequest { worker })
}

/// A granted reply embeds the spec as a canonical spec block after a
/// `---` separator (the spec-list convention, minus the list header).
pub fn lease_reply_to_kv(r: &LeaseReply) -> String {
    let mut out = format!(
        "{}state={}\nleaseid={}\ndeadlinems={}\nretryms={}\n",
        kv_header(), r.state.as_str(), r.lease_id, r.deadline_ms,
        r.retry_ms);
    if let Some(spec) = &r.spec {
        out.push_str("---\n");
        out.push_str(&serde_kv::spec_to_kv(spec));
    }
    out
}

pub fn lease_reply_from_kv(text: &str) -> Result<LeaseReply, String> {
    const WHAT: &str = "lease reply";
    let (head, spec_block) = match text.split_once("---\n") {
        Some((h, s)) => (h, Some(s)),
        None => (text, None),
    };
    let mut f = parse_kv_fields(head, WHAT)?;
    let state = LeaseState::parse(&take_field(&mut f, WHAT, "state")?)?;
    let lease_id = take_u64(&mut f, WHAT, "leaseid")?;
    let deadline_ms = take_u64(&mut f, WHAT, "deadlinems")?;
    let retry_ms = take_u64(&mut f, WHAT, "retryms")?;
    reject_unknown(&f, WHAT)?;
    let spec = match spec_block {
        Some(block) => Some(
            serde_kv::spec_from_kv(block)
                .map_err(|e| format!("{WHAT}: embedded spec: {e}"))?),
        None => None,
    };
    match (state, &spec) {
        (LeaseState::Granted, None) => {
            Err(format!("{WHAT}: granted but no spec attached"))
        }
        (LeaseState::Wait | LeaseState::Drained, Some(_)) => Err(format!(
            "{WHAT}: spec attached to a {} reply", state.as_str())),
        _ => Ok(LeaseReply { state, lease_id, deadline_ms, retry_ms, spec }),
    }
}

pub fn complete_request_to_kv(r: &CompleteRequest) -> String {
    let mut out = format!("{}worker={}\nfingerprint={}\nleaseid={}\n",
                          kv_header(), r.worker, r.fingerprint, r.lease_id);
    if let Some(sum) = r.checksum {
        out.push_str(&format!("checksum={sum:016x}\n"));
    }
    out
}

pub fn complete_request_from_kv(text: &str)
                                -> Result<CompleteRequest, String> {
    const WHAT: &str = "complete request";
    let mut f = parse_kv_fields(text, WHAT)?;
    let worker = take_field(&mut f, WHAT, "worker")?;
    let fingerprint = take_field(&mut f, WHAT, "fingerprint")?;
    let lease_id = take_u64(&mut f, WHAT, "leaseid")?;
    let checksum = match f.remove("checksum") {
        Some(v) => Some(u64::from_str_radix(&v, 16).map_err(|_| {
            format!("{WHAT}: checksum: expected 16 hex digits, got {v:?}")
        })?),
        None => None,
    };
    reject_unknown(&f, WHAT)?;
    if !valid_worker_id(&worker) {
        return Err(format!("{WHAT}: malformed worker id {worker:?}"));
    }
    Ok(CompleteRequest { worker, fingerprint, lease_id, checksum })
}

pub fn queue_stat_to_kv(s: &QueueStat) -> String {
    format!(
        "{}total={}\npending={}\nleased={}\ncompleted={}\nexpired={}\n\
         requeued={}\n",
        kv_header(), s.total, s.pending, s.leased, s.completed, s.expired,
        s.requeued)
}

pub fn queue_stat_from_kv(text: &str) -> Result<QueueStat, String> {
    const WHAT: &str = "queue stat";
    let mut f = parse_kv_fields(text, WHAT)?;
    let stat = QueueStat {
        total: take_u64(&mut f, WHAT, "total")?,
        pending: take_u64(&mut f, WHAT, "pending")?,
        leased: take_u64(&mut f, WHAT, "leased")?,
        completed: take_u64(&mut f, WHAT, "completed")?,
        expired: take_u64(&mut f, WHAT, "expired")?,
        requeued: take_u64(&mut f, WHAT, "requeued")?,
    };
    reject_unknown(&f, WHAT)?;
    Ok(stat)
}

/// The byte-identity key a `COMPLETE` is verified against: the
/// checksum of the entry's canonical serialization. Two workers
/// completing one fingerprint must have produced identical bytes.
pub fn entry_checksum(metrics: &RunMetrics) -> u64 {
    fnv1a(serde_kv::metrics_to_kv(metrics).as_bytes())
}

// ------------------------------------------------------ state machine

#[derive(Clone, Debug)]
struct LeaseInfo {
    lease_id: u64,
    worker: String,
    deadline_ms: u64,
    /// When the lease was granted; grant-to-complete feeds the
    /// lease-latency histogram surfaced by the `STATS` opcode.
    granted_ms: u64,
}

/// Outcome of a `COMPLETE`, for callers that want to distinguish the
/// idempotent-duplicate path (tests, logs) from the first write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompleteOutcome {
    /// First completion of this fingerprint.
    Recorded,
    /// Already completed with identical bytes — idempotent no-op.
    Duplicate,
}

/// The server-side job queue: fingerprint-keyed jobs moving
/// `pending -> leased -> completed` (and back to `pending` on lease
/// expiry). Every method takes the caller's `now_ms`; the state
/// machine holds no clock. Ordered collections make grant and
/// re-lease order deterministic: always the lexicographically
/// smallest pending fingerprint.
#[derive(Debug)]
pub struct QueueState {
    lease_ms: u64,
    jobs: BTreeMap<String, RunSpec>,
    pending: BTreeSet<String>,
    leased: BTreeMap<String, LeaseInfo>,
    completed: BTreeMap<String, u64>,
    next_lease_id: u64,
    expired_total: u64,
    requeued_total: u64,
    /// Fingerprints whose lease has expired at least once; a later
    /// grant of one of these is a *requeue* (straggler recovery that
    /// actually fired, vs an expiry whose job completed anyway).
    expired_fps: BTreeSet<String>,
    /// Grant-to-complete latency (ms) of first completions.
    lease_lat: Hist,
}

impl QueueState {
    pub fn new(lease_ms: u64) -> QueueState {
        QueueState {
            lease_ms: lease_ms.max(1),
            jobs: BTreeMap::new(),
            pending: BTreeSet::new(),
            leased: BTreeMap::new(),
            completed: BTreeMap::new(),
            next_lease_id: 0,
            expired_total: 0,
            requeued_total: 0,
            expired_fps: BTreeSet::new(),
            lease_lat: Hist::new(),
        }
    }

    /// The `Wait` retry interval: a fraction of the lease deadline, so
    /// an idle worker notices an expiry-driven re-lease promptly
    /// without hammering the server.
    fn retry_ms(&self) -> u64 {
        (self.lease_ms / 4).clamp(10, 1_000)
    }

    /// Add a job set. Idempotent by fingerprint: a job already
    /// pending, leased, or completed is left exactly as it is — the
    /// coordinator can re-submit its spec list after a reconnect
    /// without double-scheduling or re-running finished work.
    pub fn enqueue(&mut self, specs: &[RunSpec], now_ms: u64) -> QueueStat {
        for s in specs {
            let fp = s.fingerprint();
            if self.jobs.contains_key(&fp) {
                continue;
            }
            self.jobs.insert(fp.clone(), s.clone());
            self.pending.insert(fp);
        }
        self.stat(now_ms)
    }

    /// Return expired leases to the pending set. Called by every
    /// other transition, so no caller observes a stale lease.
    fn expire(&mut self, now_ms: u64) {
        let dead: Vec<String> = self
            .leased
            .iter()
            .filter(|(_, l)| l.deadline_ms <= now_ms)
            .map(|(fp, _)| fp.clone())
            .collect();
        for fp in dead {
            self.leased.remove(&fp);
            self.expired_fps.insert(fp.clone());
            self.pending.insert(fp);
            self.expired_total += 1;
        }
    }

    /// Grant the smallest pending fingerprint to `worker`, or tell it
    /// to wait (leases outstanding) or exit (drained).
    pub fn lease(&mut self, worker: &str, now_ms: u64) -> LeaseReply {
        self.expire(now_ms);
        if let Some(fp) = self.pending.iter().next().cloned() {
            self.pending.remove(&fp);
            if self.expired_fps.remove(&fp) {
                self.requeued_total += 1;
            }
            self.next_lease_id += 1;
            let lease_id = self.next_lease_id;
            let deadline_ms = now_ms.saturating_add(self.lease_ms);
            let spec = self.jobs.get(&fp).cloned();
            self.leased.insert(fp, LeaseInfo {
                lease_id,
                worker: worker.to_string(),
                deadline_ms,
                granted_ms: now_ms,
            });
            return LeaseReply {
                state: LeaseState::Granted,
                lease_id,
                deadline_ms,
                retry_ms: 0,
                spec,
            };
        }
        let state = if self.leased.is_empty() {
            LeaseState::Drained
        } else {
            LeaseState::Wait
        };
        LeaseReply {
            state,
            lease_id: 0,
            deadline_ms: 0,
            retry_ms: self.retry_ms(),
            spec: None,
        }
    }

    /// Record a completion. `checksum` is the stored entry's
    /// [`entry_checksum`]; a duplicate with the same checksum is an
    /// idempotent no-op (first write wins), a duplicate with a
    /// *different* checksum is a determinism violation and errors
    /// loudly. Stale lease ids are accepted: a straggler whose lease
    /// expired (even one re-leased elsewhere) still simulated the
    /// same deterministic bytes, and the checksum proves it.
    pub fn complete(&mut self, fingerprint: &str, _lease_id: u64,
                    checksum: u64, now_ms: u64)
                    -> Result<CompleteOutcome, String> {
        self.expire(now_ms);
        if !self.jobs.contains_key(fingerprint) {
            return Err(format!(
                "COMPLETE {fingerprint}: not a queued job"));
        }
        if let Some(&prev) = self.completed.get(fingerprint) {
            return if prev == checksum {
                Ok(CompleteOutcome::Duplicate)
            } else {
                Err(format!(
                    "COMPLETE {fingerprint}: entry checksum \
                     {checksum:016x} diverges from the first \
                     completion's {prev:016x} — determinism violation \
                     (two workers produced different bytes for one \
                     spec)"))
            };
        }
        if let Some(info) = self.leased.remove(fingerprint) {
            self.lease_lat
                .record(now_ms.saturating_sub(info.granted_ms));
        }
        self.pending.remove(fingerprint);
        self.completed.insert(fingerprint.to_string(), checksum);
        Ok(CompleteOutcome::Recorded)
    }

    /// Counter snapshot (expires stale leases first, so `leased`
    /// never counts a dead worker past its deadline).
    pub fn stat(&mut self, now_ms: u64) -> QueueStat {
        self.expire(now_ms);
        QueueStat {
            total: self.jobs.len() as u64,
            pending: self.pending.len() as u64,
            leased: self.leased.len() as u64,
            completed: self.completed.len() as u64,
            expired: self.expired_total,
            requeued: self.requeued_total,
        }
    }

    /// Which worker currently holds `fingerprint`, if any (tests,
    /// diagnostics).
    pub fn holder_of(&self, fingerprint: &str) -> Option<&str> {
        self.leased.get(fingerprint).map(|l| l.worker.as_str())
    }

    /// Grant-to-complete latency histogram (ms), for the `STATS`
    /// fleet surface.
    pub fn lease_latency(&self) -> &Hist {
        &self.lease_lat
    }
}

// ------------------------------------------------------- worker loop

/// The queue-worker main loop (`rainbow queue-worker`): lease from
/// the scheduler `client`, simulate through `run_stored` against
/// `store` (which publishes the entry via the ordinary `PUT` path —
/// or serves a cache hit, which is exactly how a re-leased spec whose
/// first worker died after `PUT` avoids re-simulating), acknowledge
/// with `COMPLETE`, repeat until the queue reports `Drained`. Returns
/// the number of jobs this worker completed.
///
/// `store` is usually `Store::from_net(client.clone())` — the
/// scheduler doubling as the results store — but a replicated
/// `tcp://a,tcp://b,...` store also works: results then land on their
/// ring replicas, and the `COMPLETE` carries the entry's declared
/// checksum so the scheduler can verify entries its own store never
/// sees.
pub fn worker_loop(client: &NetStore, store: &Store, worker_id: &str)
                   -> Result<usize, String> {
    if !valid_worker_id(worker_id) {
        return Err(format!(
            "queue-worker: malformed worker id {worker_id:?} (1-64 \
             chars, alphanumeric/._-)"));
    }
    let mut done = 0usize;
    loop {
        let reply = client.lease_job(worker_id)?;
        match reply.state {
            LeaseState::Granted => {
                let Some(spec) = reply.spec else {
                    return Err(format!(
                        "queue-worker {worker_id}: lease granted \
                         without a spec"));
                };
                // Same pre-flight the shard worker runs: a server
                // handing out a spec this binary cannot simulate must
                // be a clean error, not a panic mid-lease.
                spec_cli::validate_spec(&spec).map_err(|e| {
                    format!("queue-worker {worker_id}: leased spec: {e}")
                })?;
                let fp = spec.fingerprint();
                let m = run_stored(store, &spec)?;
                // Single-server stores keep the v1 contract (the
                // scheduler's store is the sole witness); a replicated
                // store declares the checksum because the ring may
                // have placed the entry away from the scheduler.
                let declared = (store.kind() == StoreKind::Repl)
                    .then(|| entry_checksum(&m));
                client.complete_job(
                    worker_id, &fp, reply.lease_id, declared)?;
                done += 1;
                println!("[{worker_id}] {} x {} done ({fp})",
                         spec.workload, spec.policy);
            }
            LeaseState::Wait => {
                thread::sleep(Duration::from_millis(reply.retry_ms.max(1)));
            }
            LeaseState::Drained => return Ok(done),
        }
    }
}

// -------------------------------------------------------- coordinator

fn scheduler_hostport(store: &Store) -> Result<&str, String> {
    store.scheduler_hostport().ok_or_else(|| {
        format!(
            "dynamic dispatch requires a tcp:// store (the cache \
             server doubles as the scheduler; for a replicated store \
             the first listed endpoint schedules); got {}", store.addr())
    })
}

/// Dynamic-dispatch sweep (`sweep --queue`): enqueue the deduplicated
/// spec matrix on the cache server at `store`, spawn `workers` local
/// child `rainbow queue-worker` processes (0 = one per core), poll
/// `QSTAT` until the queue drains, and merge the results purely from
/// the store — the same merge path as a sharded sweep. Child deaths
/// mid-sweep are tolerated (their leases expire and re-issue to the
/// survivors); only all-local-workers-dead with jobs remaining is an
/// error, because then nothing local can drain the queue (remote
/// `queue-worker`s, if any, still could — but the CLI cannot know,
/// so it fails loudly rather than poll forever).
pub fn run_queued(specs: &[RunSpec], store: &Store, workers: usize)
                  -> Result<SweepOutcome, String> {
    let hostport = scheduler_hostport(store)?;
    let client = NetStore::new(hostport);
    let stat = client.enqueue_jobs(specs)?;
    let mut uniq = BTreeSet::new();
    for s in specs {
        uniq.insert(s.fingerprint());
    }
    let unique_runs = uniq.len();
    let n = (if workers == 0 { sweep::auto_workers() } else { workers })
        .clamp(1, unique_runs.max(1));
    let exe = std::env::current_exe()
        .map_err(|e| format!("queue: locate own binary: {e}"))?;
    println!(
        "queue: {} job(s) on {} ({} already complete); spawning {n} \
         local worker(s)",
        stat.total, store.addr(), stat.completed);
    let mut children: Vec<(String, Option<Child>)> = Vec::new();
    for i in 0..n {
        let wid = format!("q{}-{i}", std::process::id());
        let child = Command::new(&exe)
            .arg("queue-worker")
            .arg("--store")
            .arg(store.addr())
            .arg("--worker-id")
            .arg(&wid)
            .spawn()
            .map_err(|e| format!("queue: spawn worker {wid}: {e}"))?;
        children.push((wid, Some(child)));
    }
    // Progress cadence: every ~40th poll (~1 s at POLL_MS = 25),
    // counted in iterations — no wall-clock read, so the coordinator
    // stays `nondet-clock`-clean.
    const POLLS_PER_PROGRESS: u64 = 40;
    let mut polls = 0u64;
    let drained = loop {
        let stat = client.queue_stat()?;
        if stat.drained() {
            break stat;
        }
        polls += 1;
        if polls % POLLS_PER_PROGRESS == 0 {
            println!(
                "queue: {}/{} complete ({} pending, {} leased, {} \
                 expired, {} requeued)",
                stat.completed, stat.total, stat.pending, stat.leased,
                stat.expired, stat.requeued);
        }
        let mut alive = 0usize;
        for (wid, slot) in children.iter_mut() {
            let Some(child) = slot else { continue };
            match child.try_wait() {
                Ok(Some(status)) => {
                    if !status.success() {
                        log::warn(&format!(
                            "queue: worker {wid} exited ({status}) with \
                             jobs remaining — its lease(s) will re-issue \
                             on deadline expiry"));
                    }
                    *slot = None;
                }
                Ok(None) => alive += 1,
                Err(e) => {
                    return Err(format!("queue: reap worker {wid}: {e}"))
                }
            }
        }
        if alive == 0 {
            return Err(format!(
                "queue: all {n} local workers exited but {} job(s) \
                 remain ({} pending, {} leased) on {}",
                stat.pending + stat.leased, stat.pending, stat.leased,
                store.addr()));
        }
        thread::sleep(Duration::from_millis(POLL_MS));
    };
    // Drained: surviving children will observe it on their next lease
    // and exit; a straggler mid-simulation of an already-completed
    // spec would only burn time, so reap it now — the queue holds
    // every result and duplicate COMPLETEs are idempotent anyway.
    for (_, slot) in children.iter_mut() {
        if let Some(child) = slot {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
    if drained.expired > 0 {
        println!(
            "queue: drained with {} lease expiry(ies), {} requeue(s) — \
             straggler or dead-worker recovery re-leased those jobs",
            drained.expired, drained.requeued);
    }
    let metrics = sweep::collect_stored(store, specs)
        .map_err(|e| format!("queue merge: {e}"))?;
    Ok(SweepOutcome { metrics, unique_runs, workers_used: n })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(w: &str, p: &str) -> RunSpec {
        RunSpec::new(w, p)
            .with_scale(64)
            .with_instructions(20_000)
            .with_seed(7)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 8u64)
    }

    fn three_specs() -> Vec<RunSpec> {
        vec![tiny("DICT", "flat"), tiny("DICT", "rainbow"),
             tiny("GUPS", "flat")]
    }

    fn sorted_fps(specs: &[RunSpec]) -> Vec<String> {
        let mut fps: Vec<String> =
            specs.iter().map(|s| s.fingerprint()).collect();
        fps.sort();
        fps
    }

    #[test]
    fn wire_records_round_trip_and_reject_version_skew() {
        let req = LeaseRequest { worker: "w-1".to_string() };
        assert_eq!(lease_request_from_kv(&lease_request_to_kv(&req))
                       .unwrap(), req);
        let spec = tiny("DICT", "flat");
        let granted = LeaseReply {
            state: LeaseState::Granted,
            lease_id: 42,
            deadline_ms: 9_000,
            retry_ms: 0,
            spec: Some(spec),
        };
        assert_eq!(lease_reply_from_kv(&lease_reply_to_kv(&granted))
                       .unwrap(), granted);
        let drained = LeaseReply {
            state: LeaseState::Drained,
            lease_id: 0,
            deadline_ms: 0,
            retry_ms: 50,
            spec: None,
        };
        assert_eq!(lease_reply_from_kv(&lease_reply_to_kv(&drained))
                       .unwrap(), drained);
        let comp = CompleteRequest {
            worker: "w-1".to_string(),
            fingerprint: "v2_DICT_flat_s64".to_string(),
            lease_id: 42,
            checksum: None,
        };
        assert_eq!(complete_request_from_kv(&complete_request_to_kv(&comp))
                       .unwrap(), comp);
        // v2: the optional declared checksum rides only when present.
        assert!(!complete_request_to_kv(&comp).contains("checksum="));
        let comp = CompleteRequest {
            checksum: Some(0x00ab_cdef_0123_4567),
            ..comp
        };
        let text = complete_request_to_kv(&comp);
        assert!(text.contains("checksum=00abcdef01234567"), "{text}");
        assert_eq!(complete_request_from_kv(&text).unwrap(), comp);
        let e = complete_request_from_kv(
            &text.replace("checksum=00abcdef01234567", "checksum=zz"))
            .unwrap_err();
        assert!(e.contains("checksum"), "got: {e}");
        let stat = QueueStat {
            total: 8, pending: 3, leased: 2, completed: 3, expired: 1,
            requeued: 1,
        };
        assert_eq!(queue_stat_from_kv(&queue_stat_to_kv(&stat)).unwrap(),
                   stat);
        // Version skew and malformed input are loud.
        let skew = lease_request_to_kv(&req)
            .replace("queuewireversion=3", "queuewireversion=99");
        let e = lease_request_from_kv(&skew).unwrap_err();
        assert!(e.contains("unsupported"), "got: {e}");
        let e = queue_stat_from_kv("total=1\n").unwrap_err();
        assert!(e.contains("queuewireversion"), "got: {e}");
        // Wire v2 (no requeued counter) is a version-skew error, not a
        // silent zero.
        let e = queue_stat_from_kv(
            "queuewireversion=2\ntotal=1\npending=0\nleased=0\n\
             completed=1\nexpired=0\n").unwrap_err();
        assert!(e.contains("unsupported"), "got: {e}");
        let e = queue_stat_from_kv(
            "queuewireversion=3\ntotal=1\npending=0\nleased=0\n\
             completed=1\nexpired=0\nrequeued=0\nbogus=7\n").unwrap_err();
        assert!(e.contains("unknown key"), "got: {e}");
    }

    #[test]
    fn malformed_lease_replies_fail_loudly() {
        // granted without a spec block
        let e = lease_reply_from_kv(
            "queuewireversion=3\nstate=granted\nleaseid=1\n\
             deadlinems=5\nretryms=0\n").unwrap_err();
        assert!(e.contains("no spec"), "got: {e}");
        // spec attached to a drained reply
        let text = format!(
            "queuewireversion=3\nstate=drained\nleaseid=0\n\
             deadlinems=0\nretryms=5\n---\n{}",
            serde_kv::spec_to_kv(&tiny("DICT", "flat")));
        let e = lease_reply_from_kv(&text).unwrap_err();
        assert!(e.contains("drained"), "got: {e}");
        // unknown state
        let e = lease_reply_from_kv(
            "queuewireversion=3\nstate=maybe\nleaseid=0\n\
             deadlinems=0\nretryms=5\n").unwrap_err();
        assert!(e.contains("unknown state"), "got: {e}");
    }

    #[test]
    fn worker_ids_are_validated() {
        assert!(valid_worker_id("q123-0"));
        assert!(valid_worker_id("host.7_a"));
        for bad in ["", "a b", "a\nb", "a/b", &"x".repeat(65)] {
            assert!(!valid_worker_id(bad), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn leases_grant_in_fingerprint_order() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(1_000);
        q.enqueue(&specs, 0);
        for (i, fp) in fps.iter().enumerate() {
            let r = q.lease("w", 0);
            assert_eq!(r.state, LeaseState::Granted);
            assert_eq!(r.spec.unwrap().fingerprint(), *fp, "grant {i}");
            assert_eq!(r.deadline_ms, 1_000);
        }
        // Everything leased: wait, not drained.
        let r = q.lease("w", 1);
        assert_eq!(r.state, LeaseState::Wait);
        assert!(r.retry_ms > 0);
    }

    #[test]
    fn expired_leases_rejoin_pending_and_release_in_order() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(500);
        q.enqueue(&specs, 0);
        let a = q.lease("victim", 0);
        let b = q.lease("victim", 0);
        assert_eq!(a.spec.unwrap().fingerprint(), fps[0]);
        assert_eq!(b.spec.unwrap().fingerprint(), fps[1]);
        assert_eq!(q.holder_of(&fps[0]), Some("victim"));
        // Just before the deadline nothing expires...
        let s = q.stat(499);
        assert_eq!((s.pending, s.leased, s.expired), (1, 2, 0));
        // ...at the deadline both leases return to pending, and the
        // re-lease order is fingerprint order again. Expiry alone is
        // not a requeue yet — the re-grant is.
        let s = q.stat(500);
        assert_eq!((s.pending, s.leased, s.expired), (3, 0, 2));
        assert_eq!(s.requeued, 0);
        assert_eq!(q.holder_of(&fps[0]), None);
        let r = q.lease("rescuer", 500);
        assert_eq!(r.spec.unwrap().fingerprint(), fps[0]);
        assert_eq!(r.deadline_ms, 1_000);
        assert_eq!(q.holder_of(&fps[0]), Some("rescuer"));
        assert_eq!(q.stat(500).requeued, 1);
        // fps[1] had also expired: its re-grant is the second requeue.
        let r = q.lease("rescuer", 500);
        assert_eq!(r.spec.unwrap().fingerprint(), fps[1]);
        assert_eq!(q.stat(500).requeued, 2);
        // fps[2] never expired: its first grant is not a requeue.
        let r = q.lease("rescuer", 500);
        assert_eq!(r.spec.unwrap().fingerprint(), fps[2]);
        assert_eq!(q.stat(500).requeued, 2);
    }

    #[test]
    fn duplicate_complete_is_idempotent_and_divergence_is_loud() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(100);
        q.enqueue(&specs, 0);
        let lease = q.lease("w1", 0);
        assert_eq!(q.complete(&fps[0], lease.lease_id, 0xAB, 1).unwrap(),
                   CompleteOutcome::Recorded);
        // Identical duplicate (stale lease id, late straggler): no-op.
        assert_eq!(q.complete(&fps[0], 999, 0xAB, 2).unwrap(),
                   CompleteOutcome::Duplicate);
        // Divergent duplicate: determinism violation, loud.
        let e = q.complete(&fps[0], 999, 0xCD, 3).unwrap_err();
        assert!(e.contains("determinism violation"), "got: {e}");
        // First write won: the recorded checksum is unchanged.
        assert_eq!(q.complete(&fps[0], 1, 0xAB, 4).unwrap(),
                   CompleteOutcome::Duplicate);
        // Unknown fingerprint: not a queued job.
        let e = q.complete("not_a_job", 1, 0xAB, 5).unwrap_err();
        assert!(e.contains("not a queued job"), "got: {e}");
    }

    #[test]
    fn lease_latency_records_first_completions_only() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(1_000);
        q.enqueue(&specs, 0);
        let a = q.lease("w", 0);
        q.complete(&fps[0], a.lease_id, 1, 40).unwrap();
        assert_eq!(q.lease_latency().count(), 1);
        // 40 ms grant-to-complete lands in the [32, 64) bucket; the
        // quantile reports that bucket's upper bound.
        assert_eq!(q.lease_latency().quantile(99), 63);
        // A duplicate completion records nothing.
        q.complete(&fps[0], a.lease_id, 1, 500).unwrap();
        assert_eq!(q.lease_latency().count(), 1);
        assert_eq!(q.lease_latency().max(), 40);
    }

    #[test]
    fn straggler_completion_after_expiry_still_counts_once() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(100);
        q.enqueue(&specs, 0);
        let old = q.lease("straggler", 0);
        // Lease expires; the job is re-leased to a rescuer.
        let release = q.lease("rescuer", 100);
        assert_eq!(release.spec.as_ref().unwrap().fingerprint(), fps[0]);
        // The straggler finishes anyway (identical bytes) — accepted,
        // and the rescuer's later COMPLETE is the idempotent duplicate.
        assert_eq!(q.complete(&fps[0], old.lease_id, 0x11, 150).unwrap(),
                   CompleteOutcome::Recorded);
        assert_eq!(q.complete(&fps[0], release.lease_id, 0x11, 160)
                       .unwrap(),
                   CompleteOutcome::Duplicate);
        let s = q.stat(160);
        assert_eq!((s.completed, s.pending, s.leased), (1, 2, 0));
    }

    #[test]
    fn enqueue_is_idempotent_and_drained_when_all_complete() {
        let specs = three_specs();
        let fps = sorted_fps(&specs);
        let mut q = QueueState::new(100);
        let s = q.enqueue(&specs, 0);
        assert_eq!((s.total, s.pending), (3, 3));
        // Re-enqueue: no duplicates.
        let s = q.enqueue(&specs, 0);
        assert_eq!((s.total, s.pending), (3, 3));
        for fp in &fps {
            let lease = q.lease("w", 0);
            q.complete(fp, lease.lease_id, 1, 0).unwrap();
        }
        let s = q.stat(0);
        assert!(s.drained());
        assert_eq!(s.completed, 3);
        // Completed jobs stay completed across a re-enqueue.
        let s = q.enqueue(&specs, 0);
        assert!(s.drained());
        assert_eq!(q.lease("w", 0).state, LeaseState::Drained);
        // An empty queue is trivially drained.
        let mut empty = QueueState::new(100);
        assert_eq!(empty.lease("w", 0).state, LeaseState::Drained);
    }

    // ---------------------------------- end-to-end over a live server

    #[test]
    fn queue_round_trips_through_a_live_cache_server() {
        use super::super::netstore::CacheServer;
        let server = CacheServer::bind("127.0.0.1:0", Store::mem())
            .unwrap()
            .with_lease_ms(60_000);
        let handle = server.spawn();
        let hostport = handle.host_port();
        let client = NetStore::new(&hostport);
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "rainbow")];
        let stat = client.enqueue_jobs(&specs).unwrap();
        assert_eq!((stat.total, stat.pending), (2, 2));
        // An in-process worker drains the queue.
        let wstore = Store::from_net(client.clone());
        let done = worker_loop(&client, &wstore, "t-worker").unwrap();
        assert_eq!(done, 2);
        let stat = client.queue_stat().unwrap();
        assert!(stat.drained());
        assert_eq!(stat.completed, 2);
        // The results merged from the store are byte-identical to
        // serial uncached runs.
        let store = Store::net(&hostport);
        let merged = sweep::collect_stored(&store, &specs).unwrap();
        for (s, m) in specs.iter().zip(&merged) {
            assert_eq!(serde_kv::metrics_to_kv(&super::super::run_uncached(s)),
                       serde_kv::metrics_to_kv(m),
                       "{} x {}", s.workload, s.policy);
        }
        // Duplicate COMPLETE over the wire: idempotent.
        let fp = specs[0].fingerprint();
        client.complete_job("t-worker", &fp, 1, None).unwrap();
        // A declared checksum that matches the stored entry is also
        // accepted; a divergent one is a determinism violation.
        let stored = store.get(&fp).unwrap().unwrap();
        let sum = entry_checksum(&stored);
        client.complete_job("t-worker", &fp, 1, Some(sum)).unwrap();
        let e = client
            .complete_job("t-worker", &fp, 1, Some(sum ^ 1))
            .unwrap_err();
        assert!(e.contains("diverges"), "got: {e}");
        // COMPLETE without a store entry is rejected server-side
        // (v1 semantics: no declared checksum, the store is the sole
        // witness).
        let mut orphan = tiny("GUPS", "rainbow");
        orphan.instructions = 30_000;
        client.enqueue_jobs(&[orphan.clone()]).unwrap();
        let e = client
            .complete_job("t-worker", &orphan.fingerprint(), 7, None)
            .unwrap_err();
        assert!(e.contains("no metrics entry"), "got: {e}");
        // Leave the queue drained so the server can stop cleanly.
        let done = worker_loop(&client, &wstore, "t-worker2").unwrap();
        assert_eq!(done, 1);
        handle.stop().unwrap();
    }
}
