//! Replicated results store: `--store tcp://a,tcp://b,tcp://c` places
//! every fingerprint on [`REPLICATION`] servers of a consistent-hash
//! ring ([`Ring`]: FNV-1a over `endpoint#vnode`, [`VNODES`] virtual
//! nodes per endpoint) and fronts them with a [`ReplStore`] that:
//!
//! * **writes through** to every placed replica, succeeding (with a
//!   loud warning) while at least one replica takes the write;
//! * **reads from the primary** (the first placed replica), falling
//!   back along the placement order, and **read-repairs** a replica
//!   that missed when a later one hits;
//! * **degrades gracefully**: a dead replica is a warning, not a
//!   failure, for every operation that another replica can serve —
//!   only when *all* placed replicas fail does an operation error.
//!
//! Placement hashes endpoint *addresses*, not list positions, so it is
//! deterministic and independent of the order endpoints are listed in
//! (property-tested below). The listed order still matters for one
//! thing: the **first** endpoint is the queue scheduler for
//! `sweep --queue` (see [`Store::scheduler_hostport`]).
//!
//! Determinism makes this replication scheme unusually simple: every
//! writer of a fingerprint writes identical bytes, so there are no
//! write conflicts to resolve, read-repair can never propagate a wrong
//! value, and a fingerprint missing from every live replica is healed
//! by re-simulation rather than data loss.
//!
//! [`Store::scheduler_hostport`]: super::store::Store::scheduler_hostport

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sim::RunMetrics;
use crate::util::log;

use super::netstore::NetStore;
use super::spec::fnv1a;
use super::store::{CacheStore, StoreObs};

/// Virtual nodes per endpoint on the ring. Enough that a 10k-sample
/// keyspace splits near-evenly across a handful of servers; cheap
/// enough that ring construction stays trivial.
pub const VNODES: usize = 64;

/// Replicas per fingerprint (clamped to the endpoint count). Two
/// copies means any single replica can die mid-sweep without losing
/// an entry.
pub const REPLICATION: usize = 2;

/// Consistent-hash ring over endpoint addresses. Each endpoint
/// contributes [`VNODES`] points at `fnv1a("addr#v")`; a fingerprint
/// lands at `fnv1a(fp)` and its replicas are the first `r` *distinct*
/// endpoints clockwise from there.
pub struct Ring {
    /// `(point, endpoint index)`, sorted by point.
    points: Vec<(u64, usize)>,
}

impl Ring {
    pub fn new(addrs: &[String]) -> Ring {
        let mut points = Vec::with_capacity(addrs.len() * VNODES);
        for (i, addr) in addrs.iter().enumerate() {
            for v in 0..VNODES {
                points.push((fnv1a(format!("{addr}#{v}").as_bytes()), i));
            }
        }
        points.sort_unstable();
        Ring { points }
    }

    /// The first `r` distinct endpoint indices clockwise from the
    /// fingerprint's hash — `replicas(..)[0]` is the primary. Returns
    /// fewer than `r` only when the ring has fewer endpoints.
    pub fn replicas(&self, fingerprint: &str, r: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(r);
        if self.points.is_empty() || r == 0 {
            return out;
        }
        let h = fnv1a(fingerprint.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        for k in 0..self.points.len() {
            let (_, idx) = self.points[(start + k) % self.points.len()];
            if !out.contains(&idx) {
                out.push(idx);
                if out.len() == r {
                    break;
                }
            }
        }
        out
    }
}

/// [`CacheStore`] over N cache servers with ring placement,
/// write-through replication, primary-first reads with read-repair,
/// and warn-don't-fail degradation. Built by
/// `Store::parse("tcp://a,tcp://b,...")`.
pub struct ReplStore {
    /// Clients in the order the user listed them (index 0 doubles as
    /// the queue scheduler); ring placement is order-independent.
    endpoints: Vec<NetStore>,
    ring: Ring,
    replication: usize,
    /// Reads served despite at least one failed replica.
    degraded_gets: AtomicU64,
    /// Writes acked with less than full replication.
    degraded_puts: AtomicU64,
    /// Read-repair writes that landed on a lagging replica.
    read_repairs: AtomicU64,
}

impl ReplStore {
    pub fn new(endpoints: Vec<NetStore>) -> ReplStore {
        let addrs: Vec<String> = endpoints
            .iter()
            .map(|e| e.addr().to_string())
            .collect();
        let ring = Ring::new(&addrs);
        let replication = REPLICATION.clamp(1, endpoints.len().max(1));
        ReplStore {
            endpoints,
            ring,
            replication,
            degraded_gets: AtomicU64::new(0),
            degraded_puts: AtomicU64::new(0),
            read_repairs: AtomicU64::new(0),
        }
    }

    /// Endpoint indices holding `fingerprint`, primary first.
    pub fn placement(&self, fingerprint: &str) -> Vec<usize> {
        self.ring.replicas(fingerprint, self.replication)
    }

    fn addr_of(&self, idx: usize) -> &str {
        self.endpoints
            .get(idx)
            .map(|e| e.addr())
            .unwrap_or("<unknown replica>")
    }
}

impl CacheStore for ReplStore {
    /// Primary-first read with fallback and read-repair: the first
    /// placed replica that holds the entry answers, and every
    /// earlier replica that reported a miss is repaired with it
    /// (best-effort — a failed repair is a warning). All placed
    /// replicas missing is a plain miss; a mix of misses and dead
    /// replicas is a *degraded* miss (warned, then re-simulated by the
    /// caller — determinism makes that equivalent to a read); only
    /// every placed replica failing is an error.
    fn get(&self, fingerprint: &str)
           -> Result<Option<RunMetrics>, String> {
        let placed = self.placement(fingerprint);
        let mut missed: Vec<usize> = Vec::new();
        let mut errors: Vec<String> = Vec::new();
        for &i in &placed {
            match self.endpoints[i].get(fingerprint) {
                Ok(Some(m)) => {
                    for &j in &missed {
                        match self.endpoints[j].put(fingerprint, &m) {
                            Ok(()) => {
                                self.read_repairs
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => log::warn(&format!(
                                "replica {}: read-repair \
                                 {fingerprint}: {e}",
                                self.addr_of(j))),
                        }
                    }
                    if !errors.is_empty() {
                        self.degraded_gets
                            .fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(Some(m));
                }
                Ok(None) => missed.push(i),
                Err(e) => {
                    log::warn(&format!(
                        "replica {} failed GET {fingerprint} \
                         (degraded read): {e}",
                        self.addr_of(i)));
                    errors.push(format!("{}: {e}", self.addr_of(i)));
                }
            }
        }
        if missed.is_empty() {
            Err(format!(
                "GET {fingerprint}: all {} placed replica(s) failed: {}",
                placed.len(), errors.join("; ")))
        } else {
            if !errors.is_empty() {
                self.degraded_gets.fetch_add(1, Ordering::Relaxed);
            }
            Ok(None)
        }
    }

    /// Write-through to every placed replica. Succeeds while at least
    /// one replica takes the write (the others are warned about);
    /// errors only when all of them fail.
    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String> {
        let placed = self.placement(fingerprint);
        let mut ok = 0usize;
        let mut errors: Vec<String> = Vec::new();
        for &i in &placed {
            match self.endpoints[i].put(fingerprint, metrics) {
                Ok(()) => ok += 1,
                Err(e) => {
                    errors.push(format!("{}: {e}", self.addr_of(i)))
                }
            }
        }
        if ok == 0 {
            Err(format!(
                "PUT {fingerprint}: all {} placed replica(s) failed: {}",
                placed.len(), errors.join("; ")))
        } else {
            if !errors.is_empty() {
                self.degraded_puts.fetch_add(1, Ordering::Relaxed);
                log::warn(&format!(
                    "PUT {fingerprint} degraded to {ok} of {} \
                     replica(s): {}",
                    placed.len(), errors.join("; ")));
            }
            Ok(())
        }
    }

    /// Union of every reachable endpoint's listing (an entry may live
    /// on any subset of replicas while repairs are pending), sorted.
    fn list(&self) -> Result<Vec<String>, String> {
        let mut all: BTreeSet<String> = BTreeSet::new();
        let mut live = 0usize;
        let mut errors: Vec<String> = Vec::new();
        for ep in &self.endpoints {
            match ep.list() {
                Ok(fps) => {
                    live += 1;
                    all.extend(fps);
                }
                Err(e) => errors.push(format!("{}: {e}", ep.addr())),
            }
        }
        if live == 0 {
            return Err(format!(
                "LIST: all {} replica(s) failed: {}",
                self.endpoints.len(), errors.join("; ")));
        }
        if !errors.is_empty() {
            log::warn(&format!(
                "LIST degraded to {live} of {} replica(s): {}",
                self.endpoints.len(), errors.join("; ")));
        }
        Ok(all.into_iter().collect())
    }

    /// Alive while at least one replica answers (each dead one is
    /// warned about) — a sweep must be able to start, and its children
    /// must pass their store pre-flight, while the set is degraded.
    fn ping(&self) -> Result<(), String> {
        let mut live = 0usize;
        let mut errors: Vec<String> = Vec::new();
        for ep in &self.endpoints {
            match ep.ping() {
                Ok(()) => live += 1,
                Err(e) => errors.push(format!("{}: {e}", ep.addr())),
            }
        }
        if live == 0 {
            return Err(format!(
                "PING: all {} replica(s) failed: {}",
                self.endpoints.len(), errors.join("; ")));
        }
        if !errors.is_empty() {
            log::warn(&format!(
                "{live} of {} replica(s) alive; dead: {}",
                self.endpoints.len(), errors.join("; ")));
        }
        Ok(())
    }

    fn obs(&self) -> StoreObs {
        StoreObs {
            degraded_gets: self.degraded_gets.load(Ordering::Relaxed),
            degraded_puts: self.degraded_puts.load(Ordering::Relaxed),
            read_repairs: self.read_repairs.load(Ordering::Relaxed),
            ..StoreObs::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{}:7700", i + 1)).collect()
    }

    fn sample_fps(n: usize) -> Vec<String> {
        (0..n)
            .map(|i| format!("v2_app{i}_x_s{}_i{}_r0", i % 7, i * 131))
            .collect()
    }

    #[test]
    fn placement_is_deterministic_and_distinct() {
        let ring = Ring::new(&addrs(3));
        for fp in sample_fps(100) {
            let a = ring.replicas(&fp, 2);
            let b = ring.replicas(&fp, 2);
            assert_eq!(a, b, "placement must be deterministic");
            assert_eq!(a.len(), 2);
            assert_ne!(a[0], a[1], "replicas must be distinct endpoints");
        }
        // Asking for more replicas than endpoints yields all of them.
        assert_eq!(ring.replicas("fp", 9).len(), 3);
        assert!(Ring::new(&[]).replicas("fp", 2).is_empty());
    }

    #[test]
    fn placement_is_order_independent_across_permutations() {
        // Property: placement depends on endpoint *addresses*, never
        // on the order the user listed them in.
        let base = addrs(3);
        let perms: Vec<Vec<String>> = vec![
            vec![base[0].clone(), base[1].clone(), base[2].clone()],
            vec![base[2].clone(), base[0].clone(), base[1].clone()],
            vec![base[1].clone(), base[2].clone(), base[0].clone()],
            vec![base[2].clone(), base[1].clone(), base[0].clone()],
        ];
        let fps = sample_fps(1_000);
        let canonical: Vec<Vec<String>> = {
            let ring = Ring::new(&perms[0]);
            fps.iter()
                .map(|fp| {
                    ring.replicas(fp, 2)
                        .into_iter()
                        .map(|i| perms[0][i].clone())
                        .collect()
                })
                .collect()
        };
        for perm in &perms[1..] {
            let ring = Ring::new(perm);
            for (fp, want) in fps.iter().zip(&canonical) {
                let got: Vec<String> = ring
                    .replicas(fp, 2)
                    .into_iter()
                    .map(|i| perm[i].clone())
                    .collect();
                assert_eq!(
                    &got, want,
                    "{fp}: placement must not depend on listing order");
            }
        }
    }

    #[test]
    fn adding_an_endpoint_remaps_a_bounded_fraction() {
        // Property: growing a 3-ring to 4 endpoints remaps ~1/N of the
        // keyspace, and every remapped primary moves TO the new
        // endpoint (consistent hashing's whole point — a naive
        // `hash % n` would reshuffle nearly everything).
        let three = addrs(3);
        let mut four = three.clone();
        four.push("10.0.0.99:7700".to_string());
        let ring3 = Ring::new(&three);
        let ring4 = Ring::new(&four);
        let fps = sample_fps(10_000);
        let mut moved = 0usize;
        for fp in &fps {
            let before = &three[ring3.replicas(fp, 1)[0]];
            let after = &four[ring4.replicas(fp, 1)[0]];
            if before != after {
                moved += 1;
                assert_eq!(
                    after, "10.0.0.99:7700",
                    "{fp}: a remapped primary must move to the new \
                     endpoint, not shuffle among survivors");
            }
        }
        let frac = moved as f64 / fps.len() as f64;
        assert!(
            frac > 0.05 && frac < 0.45,
            "expected ~1/4 of primaries to move, got {frac:.3}");
    }

    #[test]
    fn vnodes_spread_load_roughly_evenly() {
        let ring = Ring::new(&addrs(3));
        let fps = sample_fps(10_000);
        let mut counts = [0usize; 3];
        for fp in &fps {
            counts[ring.replicas(fp, 1)[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let share = c as f64 / fps.len() as f64;
            assert!(
                share > 0.15 && share < 0.55,
                "endpoint {i} holds {share:.3} of primaries — vnodes \
                 should spread load, got {counts:?}");
        }
    }
}
