//! Figure/table generators: each function reproduces one table or figure
//! of the paper's evaluation from cached simulation runs, emitting the
//! same rows/series the paper reports (shape comparison, DESIGN.md §4).

use crate::config::{profiles, Config, PAGE_SIZE};
use crate::rainbow::counters::TwoStageCounters;
use crate::rainbow::remap;
use crate::util::stats::{cdf_at, geomean};
use crate::util::tables::{f2, f3, pct, Table};
use crate::workloads::{analyze, AppProfile, Synth, HOT_HIST_BOUNDS};

use super::sweep::{self, SweepConfig};
use super::RunSpec;
use crate::sim::RunMetrics;

/// Shared context for the figure suite.
#[derive(Clone, Debug)]
pub struct FigureCtx {
    pub workloads: Vec<String>,
    pub base: RunSpec,
    /// Sweep execution knobs for every simulating figure: disk-cached by
    /// default so a `suite` run shares each simulation across figures;
    /// tests point `store` at a temp-dir store instead of mutating env.
    pub sweep: SweepConfig,
}

impl FigureCtx {
    pub fn new(workloads: Vec<String>, base: RunSpec) -> FigureCtx {
        let sweep = SweepConfig { disk_cache: true, ..SweepConfig::default() };
        FigureCtx { workloads, base, sweep }
    }

    fn spec(&self, workload: &str, policy: &str) -> RunSpec {
        self.base.clone().with_workload(workload).with_policy(policy)
    }

    /// Run a spec matrix on the sweep orchestrator with this context's
    /// execution knobs; metrics come back in input order.
    fn run(&self, specs: &[RunSpec]) -> Vec<RunMetrics> {
        sweep::run(specs, &self.sweep).metrics
    }
}

/// Every (workload × policy) spec the simulating headline figures
/// (Figs. 7–12, 15) will request from the cache — the matrix to
/// pre-warm before a `suite` run. With these fingerprints already
/// cached (e.g. merged from a sharded sweep, `rainbow sweep --shards`
/// or `suite --shards`), those figures only render; they simulate
/// nothing. The sensitivity figures (13/14) layer override-bearing
/// variants on top and warm their own cells on first run.
pub fn suite_specs(ctx: &FigureCtx) -> Vec<RunSpec> {
    let pols: Vec<String> =
        crate::policies::all_names().iter().map(|s| s.to_string()).collect();
    sweep::matrix(&ctx.base, &ctx.workloads, &pols)
}

/// Number of memory accesses to sample for the generator-analytics
/// figures (Fig. 1 / Tables I-II).
const ANALYZE_ACCESSES: u64 = 400_000;

/// Fig. 1: CDF of superpages vs touched 4 KB pages per interval.
pub fn fig01_cdf(ctx: &FigureCtx) -> Table {
    let points: Vec<u64> = vec![1, 8, 32, 64, 128, 256, 384, 512];
    let mut header: Vec<String> = vec!["app".into()];
    header.extend(points.iter().map(|p| format!("<={p}")));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 1: CDF of superpages vs touched 4KB pages/interval",
        &hdr_refs);
    for w in &ctx.workloads {
        let Some(p) = AppProfile::by_name(w) else { continue };
        let mut s = Synth::new(p.scaled(ctx.base.scale), 0, ctx.base.seed);
        let st = analyze::IntervalStats::collect(&mut s, ANALYZE_ACCESSES);
        let touched = st.touched_per_sp();
        let cdf = cdf_at(&touched, &points);
        let mut row = vec![w.to_string()];
        row.extend(cdf.iter().map(|&c| f3(c)));
        t.row(&row);
    }
    t
}

/// Table I: hot-page access statistics.
pub fn tab01_hotstats(ctx: &FigureCtx) -> Table {
    let mut t = Table::new(
        "Table I: Hot Page (4KB) Access Statistics (scaled)",
        &["app", "hot min#access", "working set (MB)", "hot %",
          "footprint (MB)"]);
    for w in &ctx.workloads {
        let Some(p) = AppProfile::by_name(w) else { continue };
        let r = analyze::table1_row(&p, ctx.base.scale, ctx.base.seed,
                                    ANALYZE_ACCESSES);
        t.row(&[r.app, r.hot_min_access.to_string(),
                f2(r.working_set_mb), f2(r.hot_percent),
                f2(r.footprint_mb)]);
    }
    t
}

/// Table II: distribution of hot 4 KB pages within superpages.
pub fn tab02_hotdist(ctx: &FigureCtx) -> Table {
    let mut header: Vec<String> = vec!["app".into()];
    let mut lo = 1u64;
    for &hi in HOT_HIST_BOUNDS.iter() {
        header.push(format!("{lo}-{hi}"));
        lo = hi + 1;
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table II: Hot 4KB pages per superpage (fraction of superpages)",
        &hdr);
    for w in &ctx.workloads {
        let Some(p) = AppProfile::by_name(w) else { continue };
        let scaled = p.scaled(ctx.base.scale);
        let mut s = Synth::new(scaled.clone(), 0, ctx.base.seed);
        let st = analyze::IntervalStats::collect(&mut s, ANALYZE_ACCESSES);
        let dist = st.hot_dist_per_sp(scaled.hot_access_share);
        let mut row = vec![w.to_string()];
        row.extend(dist.iter().map(|&d| pct(d)));
        t.row(&row);
    }
    t
}

/// Fig. 7: MPKI per policy.
pub fn fig07_mpki(ctx: &FigureCtx) -> Table {
    per_policy_table(ctx, "Fig 7: TLB misses per kilo-instruction (MPKI)",
                     |m, _| format!("{:.3}", m.mpki()))
}

/// Fig. 8: % of cycles servicing TLB misses.
pub fn fig08_tlbcycles(ctx: &FigureCtx) -> Table {
    per_policy_table(ctx, "Fig 8: % cycles servicing TLB misses",
                     |m, _| pct(m.tlb_miss_cycle_frac()))
}

/// Fig. 9: Rainbow's address-translation overhead breakdown.
pub fn fig09_breakdown(ctx: &FigureCtx) -> Table {
    let specs = sweep::matrix(&ctx.base, &ctx.workloads,
                              &["rainbow".to_string()]);
    let metrics = ctx.run(&specs);
    let mut t = Table::new(
        "Fig 9: Rainbow address translation breakdown (% of xlat cycles)",
        &["app", "split TLBs", "bitmap cache", "SPTW", "remap",
          "xlat % of cycles", "SP hit rate"]);
    for (w, m) in ctx.workloads.iter().zip(&metrics) {
        let x = &m.xlat;
        let tot = x.total().max(1) as f64;
        t.row(&[w.to_string(),
                pct(x.tlb_cycles as f64 / tot),
                pct(x.bitmap_cycles as f64 / tot),
                pct(x.sptw_cycles as f64 / tot),
                pct(x.remap_cycles as f64 / tot),
                pct(m.xlat_frac()),
                pct(m.sp_hit_rate)]);
    }
    t
}

/// Fig. 10: IPC normalized to Flat-static — the headline figure.
pub fn fig10_ipc(ctx: &FigureCtx) -> Table {
    // all_names() order is the column order: flat, hscc4k, hscc2m,
    // rainbow, dram.
    let pols: Vec<String> =
        crate::policies::all_names().iter().map(|s| s.to_string()).collect();
    let specs = sweep::matrix(&ctx.base, &ctx.workloads, &pols);
    let metrics = ctx.run(&specs);
    let mut t = Table::new(
        "Fig 10: Normalized IPC (relative to Flat-static)",
        &["app", "Flat-static", "HSCC-4KB", "HSCC-2MB", "Rainbow",
          "DRAM-only"]);
    let mut vs_flat = Vec::new();
    let mut vs_hscc4k = Vec::new();
    for (wi, w) in ctx.workloads.iter().enumerate() {
        let row_m = &metrics[wi * pols.len()..(wi + 1) * pols.len()];
        let base = row_m[0].ipc();
        let mut row = vec![w.to_string(), "1.00".to_string()];
        for m in &row_m[1..] {
            row.push(f2(m.ipc() / base.max(1e-12)));
        }
        let hscc4k_ipc = row_m[1].ipc();
        let rainbow_ipc = row_m[3].ipc();
        vs_flat.push(rainbow_ipc / base.max(1e-12));
        vs_hscc4k.push(rainbow_ipc / hscc4k_ipc.max(1e-12));
        t.row(&row);
    }
    t.row(&["geomean Rainbow/Flat".into(), f2(geomean(&vs_flat)),
            "".into(), "".into(), "".into(), "".into()]);
    t.row(&["geomean Rainbow/HSCC-4KB".into(), f2(geomean(&vs_hscc4k)),
            "".into(), "".into(), "".into(), "".into()]);
    t
}

/// Fig. 11: migration traffic normalized to footprint.
pub fn fig11_traffic(ctx: &FigureCtx) -> Table {
    let pols: Vec<String> =
        ["hscc4k", "hscc2m", "rainbow"].iter().map(|s| s.to_string()).collect();
    let specs = sweep::matrix(&ctx.base, &ctx.workloads, &pols);
    let metrics = ctx.run(&specs);
    let mut t = Table::new(
        "Fig 11: Page migration traffic / total memory footprint",
        &["app", "HSCC-4KB", "HSCC-2MB", "Rainbow"]);
    for (wi, w) in ctx.workloads.iter().enumerate() {
        let fp = ctx.spec(w, "flat").footprint_bytes();
        let mut row = vec![w.to_string()];
        for m in &metrics[wi * pols.len()..(wi + 1) * pols.len()] {
            row.push(f3(m.migration_traffic_ratio(fp)));
        }
        t.row(&row);
    }
    t
}

/// Fig. 12: energy normalized to Flat-static.
pub fn fig12_energy(ctx: &FigureCtx) -> Table {
    per_policy_table_base(ctx,
        "Fig 12: Normalized energy (relative to Flat-static)",
        |m, base| f2(m.energy_pj / base.energy_pj.max(1.0)))
}

/// Fig. 13: sensitivity to the sampling interval.
pub fn fig13_interval(ctx: &FigureCtx, apps: &[&str]) -> Table {
    let mut t = Table::new(
        "Fig 13: migration traffic + IPC vs sampling interval (Rainbow)",
        &["app", "interval", "traffic (norm)", "IPC (norm)"]);
    // Paper sweeps 1e5..1e9 at full scale; we sweep the same factors
    // around the scaled default.
    let base_cfg = ctx.base.config();
    let (base_interval, cfg_top) = (base_cfg.interval_cycles, base_cfg.top_n);
    let factors = [0.01, 0.1, 1.0, 10.0];
    let mut specs = Vec::with_capacity(apps.len() * factors.len());
    for app in apps {
        for f in factors.iter() {
            // Paper: top-N grows with the interval by the same factor.
            specs.push(ctx.spec(app, "rainbow")
                .with("rainbow.interval_cycles",
                      ((base_interval as f64 * f) as u64).max(10_000))
                .with("rainbow.top_n",
                      ((cfg_top as f64 * f).ceil() as usize).clamp(4, 128)));
        }
    }
    let metrics = ctx.run(&specs);
    for (ai, app) in apps.iter().enumerate() {
        let mut base_traffic = 0.0;
        let mut base_ipc = 0.0;
        for (i, f) in factors.iter().enumerate() {
            let m = &metrics[ai * factors.len() + i];
            let traffic = (m.migrated_bytes + m.writeback_bytes) as f64;
            let ipc = m.ipc();
            if i == 0 {
                base_traffic = traffic.max(1.0);
                base_ipc = ipc.max(1e-12);
            }
            t.row(&[app.to_string(),
                    format!("{:.0e}", base_interval as f64 * f),
                    f3(traffic / base_traffic),
                    f3(ipc / base_ipc)]);
        }
    }
    t
}

/// Fig. 14: sensitivity to top-N.
pub fn fig14_topn(ctx: &FigureCtx, apps: &[&str]) -> Table {
    let mut t = Table::new(
        "Fig 14: migration traffic + IPC vs top-N hot superpages (Rainbow)",
        &["app", "N", "traffic (norm)", "IPC (norm)"]);
    let ns = [4usize, 10, 25, 50, 100];
    let mut specs = Vec::with_capacity(apps.len() * ns.len());
    for app in apps {
        for &n in ns.iter() {
            specs.push(ctx.spec(app, "rainbow").with("rainbow.top_n", n));
        }
    }
    let metrics = ctx.run(&specs);
    for (ai, app) in apps.iter().enumerate() {
        let mut base_traffic = 0.0;
        let mut base_ipc = 0.0;
        for (i, &n) in ns.iter().enumerate() {
            let m = &metrics[ai * ns.len() + i];
            let traffic = (m.migrated_bytes + m.writeback_bytes) as f64;
            let ipc = m.ipc();
            if i == 0 {
                base_traffic = traffic.max(1.0);
                base_ipc = ipc.max(1e-12);
            }
            t.row(&[app.to_string(), n.to_string(),
                    f3(traffic / base_traffic), f3(ipc / base_ipc)]);
        }
    }
    t
}

/// Fig. 15: runtime overhead breakdown in Rainbow.
pub fn fig15_runtime(ctx: &FigureCtx) -> Table {
    let specs = sweep::matrix(&ctx.base, &ctx.workloads,
                              &["rainbow".to_string()]);
    let metrics = ctx.run(&specs);
    let mut t = Table::new(
        "Fig 15: Rainbow runtime overhead breakdown (% of total cycles)",
        &["app", "remap", "bitmap", "migration", "shootdown", "clflush",
          "identify", "total %"]);
    for (w, m) in ctx.workloads.iter().zip(&metrics) {
        let c = m.cycles.max(1) as f64;
        let total = (m.rt.total() + m.xlat.remap_cycles
                     + m.xlat.bitmap_cycles) as f64;
        t.row(&[w.to_string(),
                pct(m.xlat.remap_cycles as f64 / c),
                pct(m.xlat.bitmap_cycles as f64 / c),
                pct(m.rt.migration_cycles as f64 / c),
                pct(m.rt.shootdown_cycles as f64 / c),
                pct(m.rt.clflush_cycles as f64 / c),
                pct(m.rt.identify_cycles as f64 / c),
                pct(total / c)]);
    }
    t
}

/// Default policy columns for the Fig. 16 backend matrix (one list for
/// the `backends` CLI, `--fig 16`, and the bench driver): the four
/// migrating-vs-static systems — DRAM-only ignores the NVM backend
/// entirely, so it tells the matrix nothing (opt in with --policies).
pub const BACKEND_POLICIES: [&str; 4] = ["flat", "hscc4k", "hscc2m",
                                         "rainbow"];

/// Fig. 16 (beyond the paper): the policy × NVM-backend matrix. Every
/// (profile, policy, workload) cell is one spec carrying an
/// `nvm.profile` override, all executed as one batch on the parallel
/// sweep; rows aggregate over the context's workloads. Answers whether
/// Rainbow's win over the HSCC baselines survives when the slow tier is
/// STT-RAM-, Optane-, or CXL-class instead of the paper's PCM.
pub fn fig16_backends(ctx: &FigureCtx, nvm_profiles: &[String],
                      policies: &[String]) -> Table {
    let (nw, np) = (ctx.workloads.len(), policies.len());
    let mut specs = Vec::with_capacity(nvm_profiles.len() * nw * np);
    for prof in nvm_profiles {
        for w in &ctx.workloads {
            for p in policies {
                specs.push(ctx.spec(w, p).with_raw("nvm.profile", prof));
            }
        }
    }
    let metrics = ctx.run(&specs);

    let base_pol = policies.first().map(|s| s.as_str()).unwrap_or("-");
    let header: Vec<String> = vec![
        "NVM profile".into(), "tech".into(), "policy".into(),
        "IPC (geomean)".into(), format!("vs {base_pol}"),
        "energy mJ".into(), "DRAM row-hit".into(), "NVM row-hit".into(),
        "migrations".into(),
    ];
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 16: policy x NVM backend matrix (aggregated over workloads)",
        &hdr);
    let rate = crate::sim::metrics::hit_rate;
    for (pi, prof) in nvm_profiles.iter().enumerate() {
        let tech = profiles::by_name(prof)
            .map(|p| p.tech.name())
            .unwrap_or("?");
        let cell = |poli: usize, wi: usize| -> &RunMetrics {
            &metrics[(pi * nw + wi) * np + poli]
        };
        for (poli, pol) in policies.iter().enumerate() {
            let mut ipcs = Vec::with_capacity(nw);
            let mut rel = Vec::with_capacity(nw);
            let (mut energy, mut migrations) = (0.0, 0u64);
            let (mut dh, mut dm, mut nh, mut nm) = (0u64, 0u64, 0u64, 0u64);
            for wi in 0..nw {
                let m = cell(poli, wi);
                let base = cell(0, wi);
                ipcs.push(m.ipc().max(1e-12));
                rel.push(m.ipc().max(1e-12) / base.ipc().max(1e-12));
                energy += m.energy_pj;
                migrations += m.migrations;
                dh += m.dram_row_hits;
                dm += m.dram_row_misses;
                nh += m.nvm_row_hits;
                nm += m.nvm_row_misses;
            }
            t.row(&[prof.clone(), tech.to_string(), pol.clone(),
                    f3(geomean(&ipcs)), f2(geomean(&rel)),
                    f2(energy / 1e9),
                    pct(rate(dh, dm)), pct(rate(nh, nm)),
                    migrations.to_string()]);
        }
    }
    t
}

/// Table VI: storage overhead at 1 TB PCM.
pub fn tab06_storage() -> Table {
    let mut t = Table::new(
        "Table VI: Storage overhead of Rainbow with 1TB PCM",
        &["structure", "bytes", "note"]);
    let n_sp_1tb = (1u64 << 40) / (2 << 20);
    let top_n = 100usize;
    let counters = TwoStageCounters::new(n_sp_1tb as usize, top_n);
    let bitmap_cache = 272_000u64;
    let sp_counters = n_sp_1tb * 2;
    let psn = top_n as u64 * 4;
    let small_counters = top_n as u64 * 1024;
    t.row(&["Migration bitmap cache".into(), bitmap_cache.to_string(),
            "272 KB SRAM (paper)".into()]);
    t.row(&["Superpage access counters".into(), sp_counters.to_string(),
            "2 B per 2 MB superpage = 1 MB".into()]);
    t.row(&["PSN of top-N superpages".into(), psn.to_string(),
            "4 B x N (N=100)".into()]);
    t.row(&["Small-page counters".into(), small_counters.to_string(),
            "2 B x 512 x N = 100 KB".into()]);
    let total = bitmap_cache + counters.sram_bytes();
    t.row(&["Total".into(), total.to_string(),
            format!("{:.3} MB SRAM (paper: 1.372 MB)",
                    total as f64 / (1 << 20) as f64)]);
    t
}

/// §III-E analytic remap-cost model: the crossover at R_hit ≈ 67%.
pub fn ana_remap_cost(cfg: &Config) -> Table {
    let mut t = Table::new(
        "Analytic: DRAM page addressing cost (cycles), Rainbow vs 4-level PTW",
        &["R_hit", "Rainbow", "PTW", "Rainbow wins"]);
    let t_nr = cfg.nvm.read_cycles as f64;
    let t_dr = cfg.dram.read_cycles as f64;
    for r in [0.0, 0.25, 0.50, 0.67, 0.80, 0.95, 0.99, 1.0] {
        let rb = remap::rainbow_addressing_cost(r, t_nr);
        let walk = remap::ptw_addressing_cost(t_dr);
        t.row(&[f2(r), f2(rb), f2(walk),
                (if rb < walk { "yes" } else { "no" }).into()]);
    }
    t.row(&["crossover".into(),
            f3(remap::crossover_r_hit(t_nr, t_dr)),
            "(paper: ~0.67)".into(), "".into()]);
    t
}

// ---------------------------------------------------------------- shared

fn per_policy_table<F>(ctx: &FigureCtx, title: &str, cell: F) -> Table
where
    F: Fn(&crate::sim::RunMetrics, &crate::sim::RunMetrics) -> String,
{
    per_policy_table_base(ctx, title, cell)
}

fn per_policy_table_base<F>(ctx: &FigureCtx, title: &str, cell: F) -> Table
where
    F: Fn(&crate::sim::RunMetrics, &crate::sim::RunMetrics) -> String,
{
    // The whole workload x policy matrix runs on parallel sweep workers;
    // the row loop below only renders. all_names() order matches the
    // column order, with flat (index 0) doubling as the baseline.
    let pols: Vec<String> =
        crate::policies::all_names().iter().map(|s| s.to_string()).collect();
    let specs = sweep::matrix(&ctx.base, &ctx.workloads, &pols);
    let metrics = ctx.run(&specs);
    let mut t = Table::new(title,
        &["app", "Flat-static", "HSCC-4KB", "HSCC-2MB", "Rainbow",
          "DRAM-only"]);
    for (wi, w) in ctx.workloads.iter().enumerate() {
        let row_m = &metrics[wi * pols.len()..(wi + 1) * pols.len()];
        let base = &row_m[0];
        let mut row = vec![w.to_string()];
        for m in row_m {
            row.push(cell(m, base));
        }
        t.row(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx(workloads: &[&str]) -> FigureCtx {
        let base = RunSpec::new("", "")
            .with_scale(64)
            .with_instructions(50_000)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 8u64);
        FigureCtx::new(workloads.iter().map(|s| s.to_string()).collect(),
                       base)
    }

    #[test]
    fn tab06_matches_paper_total() {
        let t = tab06_storage();
        let r = t.render();
        assert!(r.contains("1.372 MB") || r.contains("1.37"),
                "storage total drifted:\n{r}");
    }

    #[test]
    fn ana_remap_matches_paper_crossover() {
        let t = ana_remap_cost(&Config::paper());
        let r = t.render();
        assert!(r.contains("0.6"), "crossover missing:\n{r}");
    }

    #[test]
    fn fig01_and_tables_render() {
        let ctx = tiny_ctx(&["DICT"]);
        assert_eq!(fig01_cdf(&ctx).n_rows(), 1);
        assert_eq!(tab01_hotstats(&ctx).n_rows(), 1);
        assert_eq!(tab02_hotdist(&ctx).n_rows(), 1);
    }

    #[test]
    fn fig16_backends_renders_profile_x_policy_matrix() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_fig16_test_{}", std::process::id()));
        let mut ctx = tiny_ctx(&["DICT"]);
        ctx.sweep.store = Some(crate::report::Store::fs(dir.clone()));
        let profs: Vec<String> = ["pcm-paper", "cxl-remote"]
            .iter().map(|s| s.to_string()).collect();
        let pols: Vec<String> = ["flat", "rainbow"]
            .iter().map(|s| s.to_string()).collect();
        let t = fig16_backends(&ctx, &profs, &pols);
        assert_eq!(t.n_rows(), 4); // 2 profiles x 2 policies
        let r = t.render();
        assert!(r.contains("cxl-dram"), "tech column missing:\n{r}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn figures_render_from_a_prewarmed_merged_cache() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_prewarm_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut ctx = tiny_ctx(&["DICT"]);
        ctx.sweep.store = Some(crate::report::Store::fs(dir.clone()));
        let specs = suite_specs(&ctx);
        assert_eq!(specs.len(), crate::policies::all_names().len());
        // Pre-warm the cache the way a sharded sweep's merge leaves it:
        // one fingerprint-named entry per unique spec.
        sweep::run(&specs, &ctx.sweep);
        for s in &specs {
            assert!(dir.join(format!("{}.kv", s.fingerprint())).is_file(),
                    "pre-warm must cover every suite cell");
        }
        // The merge path serves every cell without simulating...
        let merged = sweep::collect_cached(&dir, &specs).unwrap();
        assert_eq!(merged.len(), specs.len());
        // ...and the figure rendered from the warm cache is identical
        // to a fresh simulation of the same matrix.
        let mut fresh = ctx.clone();
        fresh.sweep.disk_cache = false;
        assert_eq!(fig10_ipc(&ctx).render(), fig10_ipc(&fresh).render());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fig10_includes_geomeans() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_fig_test_{}", std::process::id()));
        let mut ctx = tiny_ctx(&["streamcluster"]);
        // Isolated cache dir, passed explicitly (no env mutation).
        ctx.sweep.store = Some(crate::report::Store::fs(dir.clone()));
        let t = fig10_ipc(&ctx);
        assert_eq!(t.n_rows(), 3); // 1 app + 2 geomean rows
        let _ = std::fs::remove_dir_all(&dir);
    }
}
