//! CLI → [`RunSpec`] plumbing: builds specs from parsed arguments,
//! loads `--spec` files, applies `--set key=value` overrides, and
//! validates workload/policy names — all BEFORE any sweep fans out to
//! worker threads, so every bad input takes the CLI's error path
//! instead of panicking a thread scope. Lives in the library (not
//! `main.rs`) so the argument surface is integration-testable.

use std::path::Path;

use crate::config::knobs::KnobValue;
use crate::report::{self, serde_kv, RunSpec};
use crate::util::cli::Args;

/// Build the base spec: start from `--spec file.kv` when given (else
/// defaults), then layer explicitly passed CLI options on top, then
/// `--set` overrides (highest precedence).
pub fn spec_from_args(args: &Args) -> Result<RunSpec, String> {
    let mut s = match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("--spec {path}: {e}"))?;
            serde_kv::spec_from_kv(&text)
                .map_err(|e| format!("--spec {path}: {e}"))?
        }
        None => RunSpec::new("mcf", "rainbow"),
    };
    if let Some(app) = args.get("app") {
        s = s.with_workload(app);
    }
    if let Some(policy) = args.get("policy") {
        s = s.with_policy(policy);
    }
    if args.flag("paper-scale") {
        s = s.with_scale(1);
    } else if args.get("scale").is_some() {
        s = s.with_scale(args.get_u64("scale", 8)?);
    }
    if args.get("instructions").is_some() {
        s = s.with_instructions(args.get_u64("instructions", 0)?);
    }
    if args.get("seed").is_some() {
        s = s.with_seed(args.get_u64("seed", 0)?);
    }
    if args.flag("accel") {
        s = s.with_accel(true);
    }
    if args.flag("no-accel") {
        s = s.with_accel(false); // e.g. to negate a spec file's accel=1
    }
    // --interval / --top-n are sugar for the corresponding knobs; 0 is
    // the historical sentinel for "use the scaled config's default",
    // so it REMOVES the override (a spec file's included).
    if let Some(interval) = explicit_u64(args, "interval")? {
        match interval {
            0 => s.overrides.remove("rainbow.interval_cycles"),
            v => {
                s = s.try_with("rainbow.interval_cycles", KnobValue::U64(v))?
            }
        }
    }
    if let Some(top_n) = explicit_u64(args, "top-n")? {
        match top_n {
            0 => s.overrides.remove("rainbow.top_n"),
            v => s = s.try_with("rainbow.top_n", KnobValue::U64(v))?,
        }
    }
    for set in args.get_all("set") {
        s = s.try_set_arg(set).map_err(|e| format!("--set: {e}"))?;
    }
    validate_spec(&s)?;
    Ok(s)
}

/// Validate a spec's non-knob identity fields (knob overrides are
/// already registry-checked at set time): an unknown workload/policy
/// would panic `run_uncached` — possibly inside a sweep worker thread
/// or a shard child process — and `Config::scaled` panics on a bad
/// scale (non-power-of-two, or so large the DRAM tier degenerates).
/// Shared by `--spec`/option parsing and shard-worker spec-list loading
/// so every entry surface rejects bad input identically.
pub fn validate_spec(s: &RunSpec) -> Result<(), String> {
    crate::config::Config::try_scaled(s.scale)
        .map_err(|e| format!("scale: {e}"))?;
    let known = crate::workloads::Workload::all_names();
    if !known.iter().any(|n| n.eq_ignore_ascii_case(&s.workload)) {
        return Err(format!(
            "unknown workload {:?}; `rainbow list` shows them", s.workload));
    }
    if !crate::policies::is_valid_name(&s.policy) {
        return Err(format!(
            "unknown policy {:?}; `rainbow list` shows them", s.policy));
    }
    Ok(())
}

/// Load and fully validate a multi-spec list file (the shard-worker
/// `--specs` surface): strict parse (version, block count, checksum)
/// through `serde_kv::specs_from_kv`, then [`validate_spec`] on every
/// entry — a bad list fails here, before the worker simulates anything.
pub fn load_spec_list(path: &Path) -> Result<Vec<RunSpec>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("spec list {}: {e}", path.display()))?;
    let specs = serde_kv::specs_from_kv(&text)
        .map_err(|e| format!("spec list {}: {e}", path.display()))?;
    for (i, s) in specs.iter().enumerate() {
        validate_spec(s).map_err(|e| {
            format!("spec list {} block {}: {e}", path.display(), i + 1)
        })?;
    }
    Ok(specs)
}

/// The value of `--name` when explicitly passed, `None` otherwise.
fn explicit_u64(args: &Args, name: &str) -> Result<Option<u64>, String> {
    match args.get(name) {
        None => Ok(None),
        Some(_) => args.get_u64(name, 0).map(Some),
    }
}

/// Split a comma-separated CLI list, dropping empty segments.
pub fn comma_list(raw: &str) -> Vec<String> {
    raw.split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Resolve the sweep's workload list from `--apps`/`--all` and validate
/// every name. `Workload::all_names` covers exactly what
/// `Workload::by_name` accepts (apps and mixes, case-insensitive).
pub fn sweep_workloads(args: &Args) -> Result<Vec<String>, String> {
    let workloads: Vec<String> = match args.get("apps") {
        Some(list) if list.eq_ignore_ascii_case("all") => {
            report::all_workloads()
        }
        Some(list) => comma_list(list),
        None if args.flag("all") => report::all_workloads(),
        None => report::default_workloads()
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    if workloads.is_empty() {
        return Err("sweep: empty workload list".into());
    }
    let known = crate::workloads::Workload::all_names();
    for w in &workloads {
        if !known.iter().any(|n| n.eq_ignore_ascii_case(w)) {
            return Err(format!(
                "unknown workload {w:?}; `rainbow list` shows them"));
        }
    }
    Ok(workloads)
}

/// Resolve the sweep's policy list from `--policies` and validate it.
pub fn sweep_policies(args: &Args) -> Result<Vec<String>, String> {
    let policies: Vec<String> = match args.get("policies") {
        Some(list) => comma_list(list),
        None => report::policy_names().iter().map(|s| s.to_string()).collect(),
    };
    if policies.is_empty() {
        return Err("sweep: empty policy list".into());
    }
    for p in &policies {
        if !crate::policies::is_valid_name(p) {
            return Err(format!(
                "unknown policy {p:?}; `rainbow list` shows them"));
        }
    }
    Ok(policies)
}
