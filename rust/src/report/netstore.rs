//! Networked results store: the wire protocol, the multi-threaded
//! [`CacheServer`] (the `rainbow cache-server` subcommand), and the
//! [`NetStore`] client — the transport that lets a sharded sweep run
//! with ZERO shared filesystem between coordinator and workers.
//!
//! ## Wire format
//!
//! Request/response exchanges (a connection may carry several back to
//! back), each side a single length-prefixed frame:
//!
//! ```text
//! magic    4 bytes  b"RBKV"
//! version  u16 LE   PROTOCOL_VERSION (bumped on incompatible change)
//! opcode   u8       request: GET/PUT/LIST/PING/SHUTDOWN
//!                            LEASE/COMPLETE/REQUEUE/QSTAT
//!                   response: R_OK/R_MISSING/R_ERR
//! length   u32 LE   payload bytes that follow (capped — untrusted)
//! checksum u64 LE   FNV-1a over the payload
//! payload  length bytes
//! ```
//!
//! Payloads reuse the `serde_kv` text encodings: GET carries a
//! fingerprint, its `R_OK` reply a full metrics entry (which carries
//! its OWN version + checksum header, so entry integrity is checked
//! end to end, independent of the frame); PUT carries
//! `fingerprint\n<metrics entry>`; LIST's reply is newline-joined
//! fingerprints. A torn or tampered frame fails the checksum and is a
//! loud error — the same contract spec-list files already enforce.
//!
//! Protocol v2 adds the job-queue opcodes (the work-stealing sweep
//! scheduler in [`super::queue`]): REQUEUE enqueues a checksummed
//! spec-list job set, LEASE hands one spec to a worker under a
//! deadline, COMPLETE acknowledges a stored result (idempotent,
//! byte-identity asserted), QSTAT snapshots the queue counters. Their
//! payloads are the versioned `key=value` records of
//! `report::queue` (`queuewireversion=`).
//!
//! Protocol v3 adds STATS, the fleet observability surface
//! (`rainbow stats --store tcp://...`): a [`ServerStats`] snapshot of
//! per-opcode request counts, the job queue's grant-to-complete
//! latency quantiles, the backing store's durability-log counters
//! (appends/fsyncs/replayed records, when it is a `--log` store) and
//! replica degradation counters (when it is replicated). The reply is
//! a versioned `key=value` record guarded by
//! [`serde_kv::STATS_WIRE_VERSION`] and schema-locked.
//!
//! ## Failure modes
//!
//! The client fails *loudly*: connect timeouts with bounded retries
//! (a worker racing a server still starting up gets a grace window),
//! read/write timeouts, `R_ERR` surfaced verbatim with the server
//! address. Callers treat any remote error as fatal for the run — a
//! flaky transport must never silently degrade a shared-nothing sweep
//! into simulate-everything-locally.
//!
//! The server validates everything it is handed: fingerprints must be
//! fingerprint-shaped (no path separators — a `GET ../../x` cannot
//! escape an `FsStore` directory), PUT payloads must parse as intact
//! metrics entries, and unknown opcodes get `R_ERR`, not a crash. A
//! `SHUTDOWN` request stops the accept loop, drains in-flight
//! connections, and lets `serve` return `Ok` — the clean-shutdown path
//! the CI smoke job asserts.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::sim::RunMetrics;
use crate::util::log;

use super::queue::{self, QueueState};
use super::serde_kv::{self, STATS_WIRE_VERSION};
use super::spec::fnv1a;
use super::store::{CacheStore, Store};

/// Version of the framed request/response protocol.
/// v2: job-queue opcodes (LEASE/COMPLETE/REQUEUE/QSTAT).
/// v3: STATS opcode (fleet observability snapshot).
pub const PROTOCOL_VERSION: u16 = 3;

const MAGIC: [u8; 4] = *b"RBKV";
const HEADER_LEN: usize = 4 + 2 + 1 + 4 + 8;

/// Cap on any frame payload. The largest legitimate payload is a LIST
/// reply (~60 bytes per fingerprint — tens of thousands of entries fit
/// comfortably); the length prefix is untrusted input, so an absurd
/// value must be a clean error, not an allocator abort.
const MAX_PAYLOAD: usize = 64 << 20;

/// Protocol opcodes (requests < 0x80, responses >= 0x80).
pub mod op {
    pub const GET: u8 = 1;
    pub const PUT: u8 = 2;
    pub const LIST: u8 = 3;
    pub const PING: u8 = 4;
    pub const SHUTDOWN: u8 = 5;
    /// Job queue (protocol v2): lease one spec under a deadline.
    pub const LEASE: u8 = 6;
    /// Job queue: acknowledge a stored result (idempotent).
    pub const COMPLETE: u8 = 7;
    /// Job queue: enqueue a checksummed spec-list job set.
    pub const REQUEUE: u8 = 8;
    /// Job queue: snapshot the queue counters.
    pub const QSTAT: u8 = 9;
    /// Fleet stats (protocol v3): snapshot the server's observability
    /// counters ([`super::ServerStats`]).
    pub const STATS: u8 = 10;
    pub const R_OK: u8 = 0x80;
    pub const R_MISSING: u8 = 0x81;
    pub const R_ERR: u8 = 0x82;
}

fn write_frame(w: &mut impl Write, opcode: u8, payload: &[u8])
               -> io::Result<()> {
    if payload.len() > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame payload {} bytes exceeds cap {MAX_PAYLOAD}",
                    payload.len()),
        ));
    }
    let mut hdr = [0u8; HEADER_LEN];
    hdr[..4].copy_from_slice(&MAGIC);
    hdr[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    hdr[6] = opcode;
    hdr[7..11].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    hdr[11..19].copy_from_slice(&fnv1a(payload).to_le_bytes());
    w.write_all(&hdr)?;
    w.write_all(payload)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>), String> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)
        .map_err(|e| format!("read frame header: {e}"))?;
    if hdr[..4] != MAGIC {
        return Err("bad frame magic (peer is not a rainbow \
                    cache server?)".to_string());
    }
    let version = u16::from_le_bytes([hdr[4], hdr[5]]);
    if version != PROTOCOL_VERSION {
        return Err(format!(
            "protocol version {version} unsupported \
             (expected {PROTOCOL_VERSION})"));
    }
    let opcode = hdr[6];
    let len =
        u32::from_le_bytes([hdr[7], hdr[8], hdr[9], hdr[10]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(format!(
            "frame payload {len} bytes exceeds cap {MAX_PAYLOAD} \
             (corrupt length prefix?)"));
    }
    let declared = u64::from_le_bytes([
        hdr[11], hdr[12], hdr[13], hdr[14], hdr[15], hdr[16], hdr[17],
        hdr[18],
    ]);
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| format!("read frame payload ({len} bytes): {e}"))?;
    let actual = fnv1a(&payload);
    if actual != declared {
        return Err(format!(
            "frame checksum mismatch (declared {declared:016x}, \
             payload hashes to {actual:016x}): torn or tampered"));
    }
    Ok((opcode, payload))
}

/// Fingerprints are %-escaped filesystem-safe tokens
/// (`RunSpec::fingerprint`); anything else — in particular path
/// separators — is rejected server-side so a hostile `GET`/`PUT` can
/// never address files outside an `FsStore` directory.
fn valid_fingerprint(fp: &str) -> bool {
    !fp.is_empty()
        && fp.len() <= 512
        && !fp.contains("..")
        && fp.bytes().all(|b| {
            b.is_ascii_alphanumeric()
                || b == b'_'
                || b == b'.'
                || b == b'-'
                || b == b'%'
        })
}

// ---------------------------------------------------------- fleet stats

/// Snapshot of one cache server's observability counters — the `STATS`
/// reply (protocol v3) and the row format of `rainbow stats`.
/// Serialized as a versioned `key=value` record
/// ([`server_stats_to_kv`], `statswireversion=`) and schema-locked
/// against [`STATS_WIRE_VERSION`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Requests served since bind, by opcode. A `STATS` request counts
    /// itself (the bump lands before the reply is assembled).
    pub gets: u64,
    pub puts: u64,
    pub lists: u64,
    pub pings: u64,
    pub leases: u64,
    pub completes: u64,
    pub requeues: u64,
    pub qstats: u64,
    pub stats_reqs: u64,
    /// Lease grant-to-first-completion latency (ms): sample count and
    /// power-of-two bucket quantiles from the queue's histogram.
    pub lease_count: u64,
    pub lease_ms_p50: u64,
    pub lease_ms_p95: u64,
    pub lease_ms_p99: u64,
    /// Backing-store counters (`Store::obs`): durability-log activity
    /// and replica degradation; zero when the store has neither.
    pub wal_appends: u64,
    pub wal_fsyncs: u64,
    pub wal_replayed: u64,
    pub degraded_gets: u64,
    pub degraded_puts: u64,
    pub read_repairs: u64,
}

/// Serialize a [`ServerStats`] snapshot: versioned header line, then
/// one `key=value` per field in fixed order.
pub fn server_stats_to_kv(s: &ServerStats) -> String {
    format!(
        "statswireversion={STATS_WIRE_VERSION}\n\
         gets={}\nputs={}\nlists={}\npings={}\nleases={}\n\
         completes={}\nrequeues={}\nqstats={}\nstats_reqs={}\n\
         lease_count={}\nlease_ms_p50={}\nlease_ms_p95={}\n\
         lease_ms_p99={}\nwal_appends={}\nwal_fsyncs={}\n\
         wal_replayed={}\ndegraded_gets={}\ndegraded_puts={}\n\
         read_repairs={}\n",
        s.gets, s.puts, s.lists, s.pings, s.leases, s.completes,
        s.requeues, s.qstats, s.stats_reqs, s.lease_count,
        s.lease_ms_p50, s.lease_ms_p95, s.lease_ms_p99, s.wal_appends,
        s.wal_fsyncs, s.wal_replayed, s.degraded_gets, s.degraded_puts,
        s.read_repairs)
}

/// Strict parse of a [`server_stats_to_kv`] record: the version must
/// match, every field must be present exactly once, and unknown keys
/// are rejected — version skew or truncation is a loud error, never a
/// silently partial snapshot.
pub fn server_stats_from_kv(text: &str) -> Result<ServerStats, String> {
    let mut fields: BTreeMap<&str, u64> = BTreeMap::new();
    let mut version = None;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("server stats: expected key=value, got {line:?}")
        })?;
        let v = v.parse::<u64>().map_err(|_| {
            format!("server stats: {k}: expected integer, got {v:?}")
        })?;
        if k == "statswireversion" {
            version = Some(v);
        } else if fields.insert(k, v).is_some() {
            return Err(format!("server stats: duplicate key {k:?}"));
        }
    }
    match version {
        Some(STATS_WIRE_VERSION) => {}
        Some(v) => {
            return Err(format!(
                "server stats version {v} unsupported \
                 (expected {STATS_WIRE_VERSION})"))
        }
        None => {
            return Err(
                "server stats missing statswireversion".to_string())
        }
    }
    let mut take = |k: &str| {
        fields.remove(k)
            .ok_or_else(|| format!("server stats missing field {k:?}"))
    };
    let s = ServerStats {
        gets: take("gets")?,
        puts: take("puts")?,
        lists: take("lists")?,
        pings: take("pings")?,
        leases: take("leases")?,
        completes: take("completes")?,
        requeues: take("requeues")?,
        qstats: take("qstats")?,
        stats_reqs: take("stats_reqs")?,
        lease_count: take("lease_count")?,
        lease_ms_p50: take("lease_ms_p50")?,
        lease_ms_p95: take("lease_ms_p95")?,
        lease_ms_p99: take("lease_ms_p99")?,
        wal_appends: take("wal_appends")?,
        wal_fsyncs: take("wal_fsyncs")?,
        wal_replayed: take("wal_replayed")?,
        degraded_gets: take("degraded_gets")?,
        degraded_puts: take("degraded_puts")?,
        read_repairs: take("read_repairs")?,
    };
    if let Some(k) = fields.keys().next() {
        return Err(format!("server stats: unknown key {k:?}"));
    }
    Ok(s)
}

/// Per-opcode request counters shared by every connection handler of
/// one server.
#[derive(Debug, Default)]
struct OpCounters {
    gets: AtomicU64,
    puts: AtomicU64,
    lists: AtomicU64,
    pings: AtomicU64,
    leases: AtomicU64,
    completes: AtomicU64,
    requeues: AtomicU64,
    qstats: AtomicU64,
    stats: AtomicU64,
}

impl OpCounters {
    /// Count a request frame. Unknown (and response) opcodes are not
    /// counted — they answer `R_ERR` and say nothing about load.
    fn bump(&self, opcode: u8) {
        let c = match opcode {
            op::GET => &self.gets,
            op::PUT => &self.puts,
            op::LIST => &self.lists,
            op::PING => &self.pings,
            op::LEASE => &self.leases,
            op::COMPLETE => &self.completes,
            op::REQUEUE => &self.requeues,
            op::QSTAT => &self.qstats,
            op::STATS => &self.stats,
            _ => return,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------- client

/// TCP client half of the protocol. One connection per request (the
/// exchanges are tiny and a sweep's workers are long-lived processes);
/// connection establishment gets `connect_retries` extra attempts with
/// `retry_backoff` between them, so a worker spawned alongside a
/// still-starting server converges instead of failing its whole shard.
#[derive(Clone, Debug)]
pub struct NetStore {
    addr: String,
    pub connect_timeout: Duration,
    pub io_timeout: Duration,
    pub connect_retries: u32,
    pub retry_backoff: Duration,
}

impl NetStore {
    /// Client for the server at `host:port` with default timeouts.
    pub fn new(hostport: &str) -> NetStore {
        NetStore {
            addr: hostport.to_string(),
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(60),
            connect_retries: 3,
            retry_backoff: Duration::from_millis(200),
        }
    }

    /// The `host:port` this client targets.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Spread this worker's connect-retry backoff deterministically:
    /// base backoff plus a jitter in `[0, base)` derived from the
    /// worker id's FNV-1a hash — no clock, no RNG, so the same worker
    /// always retries on the same schedule, but a fleet reconnecting
    /// after a server restart fans out instead of thundering-herding.
    pub fn with_worker_jitter(mut self, worker_id: &str) -> NetStore {
        let base = self.retry_backoff.as_millis() as u64;
        let jitter = fnv1a(worker_id.as_bytes()) % base.max(1);
        self.retry_backoff = Duration::from_millis(base + jitter);
        self
    }

    fn connect(&self) -> Result<TcpStream, String> {
        let addrs: Vec<SocketAddr> = self
            .addr
            .to_socket_addrs()
            .map_err(|e| {
                format!("cache server {}: resolve: {e}", self.addr)
            })?
            .collect();
        if addrs.is_empty() {
            return Err(format!(
                "cache server {}: resolved to no addresses", self.addr));
        }
        let mut last = String::new();
        for attempt in 0..=self.connect_retries {
            if attempt > 0 {
                thread::sleep(self.retry_backoff);
            }
            for a in &addrs {
                match TcpStream::connect_timeout(a, self.connect_timeout)
                {
                    Ok(s) => {
                        let _ = s.set_read_timeout(Some(self.io_timeout));
                        let _ =
                            s.set_write_timeout(Some(self.io_timeout));
                        let _ = s.set_nodelay(true);
                        return Ok(s);
                    }
                    Err(e) => last = e.to_string(),
                }
            }
        }
        Err(format!(
            "cache server {} unreachable after {} attempts: {last}",
            self.addr,
            self.connect_retries + 1))
    }

    fn request(&self, opcode: u8, payload: &[u8])
               -> Result<(u8, Vec<u8>), String> {
        let mut stream = self.connect()?;
        write_frame(&mut stream, opcode, payload)
            .map_err(|e| format!("cache server {}: send: {e}", self.addr))?;
        let (rop, rpayload) = read_frame(&mut stream)
            .map_err(|e| format!("cache server {}: {e}", self.addr))?;
        if rop == op::R_ERR {
            return Err(format!(
                "cache server {}: {}",
                self.addr,
                String::from_utf8_lossy(&rpayload)));
        }
        Ok((rop, rpayload))
    }

    /// Ask a running server to shut down cleanly (acknowledged before
    /// the server's accept loop stops).
    pub fn shutdown_server(&self) -> Result<(), String> {
        match self.request(op::SHUTDOWN, &[])? {
            (op::R_OK, _) => Ok(()),
            (other, _) => Err(format!(
                "cache server {}: unexpected shutdown reply {other:#04x}",
                self.addr)),
        }
    }

    // ------------------------------------------ job-queue client half

    fn queue_text_reply(&self, opcode: u8, what: &str, payload: &[u8])
                        -> Result<String, String> {
        let (rop, rpayload) = self.request(opcode, payload)?;
        if rop != op::R_OK {
            return Err(format!(
                "cache server {}: {what}: unexpected reply {rop:#04x}",
                self.addr));
        }
        String::from_utf8(rpayload).map_err(|_| {
            format!("cache server {}: {what}: non-UTF8 reply", self.addr)
        })
    }

    /// `REQUEUE`: submit a job set as a checksummed spec list. The
    /// server deduplicates by fingerprint and never re-runs completed
    /// work; the reply is the post-enqueue counter snapshot.
    pub fn enqueue_jobs(&self, specs: &[super::RunSpec])
                        -> Result<queue::QueueStat, String> {
        let payload = serde_kv::specs_to_kv(specs);
        let text = self.queue_text_reply(
            op::REQUEUE, "REQUEUE", payload.as_bytes())?;
        queue::queue_stat_from_kv(&text)
            .map_err(|e| format!("cache server {}: REQUEUE: {e}", self.addr))
    }

    /// `LEASE`: ask for one spec to work on.
    pub fn lease_job(&self, worker: &str)
                     -> Result<queue::LeaseReply, String> {
        let req = queue::LeaseRequest { worker: worker.to_string() };
        let payload = queue::lease_request_to_kv(&req);
        let text = self.queue_text_reply(
            op::LEASE, "LEASE", payload.as_bytes())?;
        queue::lease_reply_from_kv(&text)
            .map_err(|e| format!("cache server {}: LEASE: {e}", self.addr))
    }

    /// `COMPLETE`: acknowledge that `fingerprint`'s entry is in the
    /// store (the server verifies and records its checksum; duplicate
    /// completions with identical bytes are accepted idempotently).
    /// `checksum` is the worker's declared [`queue::entry_checksum`]
    /// — required when the results store is replicated (the
    /// scheduler's own store may not be a ring replica for this
    /// fingerprint), omitted for single-server stores where the
    /// scheduler's store is the sole witness.
    pub fn complete_job(&self, worker: &str, fingerprint: &str,
                        lease_id: u64, checksum: Option<u64>)
                        -> Result<(), String> {
        let req = queue::CompleteRequest {
            worker: worker.to_string(),
            fingerprint: fingerprint.to_string(),
            lease_id,
            checksum,
        };
        let payload = queue::complete_request_to_kv(&req);
        self.queue_text_reply(op::COMPLETE, "COMPLETE",
                              payload.as_bytes())?;
        Ok(())
    }

    /// `QSTAT`: the queue's counter snapshot.
    pub fn queue_stat(&self) -> Result<queue::QueueStat, String> {
        let text = self.queue_text_reply(op::QSTAT, "QSTAT", &[])?;
        queue::queue_stat_from_kv(&text)
            .map_err(|e| format!("cache server {}: QSTAT: {e}", self.addr))
    }

    /// `STATS`: the server's observability snapshot (`rainbow stats`).
    pub fn server_stats(&self) -> Result<ServerStats, String> {
        let text = self.queue_text_reply(op::STATS, "STATS", &[])?;
        server_stats_from_kv(&text)
            .map_err(|e| format!("cache server {}: STATS: {e}", self.addr))
    }
}

impl CacheStore for NetStore {
    fn get(&self, fingerprint: &str)
           -> Result<Option<RunMetrics>, String> {
        let (rop, payload) =
            self.request(op::GET, fingerprint.as_bytes())?;
        match rop {
            op::R_MISSING => Ok(None),
            op::R_OK => {
                let text = String::from_utf8(payload).map_err(|_| {
                    format!(
                        "cache server {}: GET {fingerprint}: non-UTF8 \
                         metrics payload", self.addr)
                })?;
                match serde_kv::metrics_from_kv_checked(&text) {
                    Ok(m) => Ok(Some(m)),
                    // Version skew between this binary and the server
                    // (e.g. a long-lived server holding entries from
                    // an older METRICS_VERSION) is a stale entry, not
                    // corruption: a miss, so re-simulation heals it —
                    // the same contract as a directory store.
                    Err(serde_kv::MetricsError::Stale { .. }) => Ok(None),
                    Err(e) => Err(format!(
                        "cache server {}: GET {fingerprint}: corrupt \
                         metrics payload: {e}", self.addr)),
                }
            }
            other => Err(format!(
                "cache server {}: GET {fingerprint}: unexpected reply \
                 {other:#04x}", self.addr)),
        }
    }

    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String> {
        let entry = serde_kv::metrics_to_kv(metrics);
        let mut payload =
            Vec::with_capacity(fingerprint.len() + 1 + entry.len());
        payload.extend_from_slice(fingerprint.as_bytes());
        payload.push(b'\n');
        payload.extend_from_slice(entry.as_bytes());
        match self.request(op::PUT, &payload)? {
            (op::R_OK, _) => Ok(()),
            (other, _) => Err(format!(
                "cache server {}: PUT {fingerprint}: unexpected reply \
                 {other:#04x}", self.addr)),
        }
    }

    fn list(&self) -> Result<Vec<String>, String> {
        let (rop, payload) = self.request(op::LIST, &[])?;
        if rop != op::R_OK {
            return Err(format!(
                "cache server {}: LIST: unexpected reply {rop:#04x}",
                self.addr));
        }
        let text = String::from_utf8(payload).map_err(|_| {
            format!("cache server {}: LIST: non-UTF8 payload", self.addr)
        })?;
        Ok(text.lines().map(str::to_string).collect())
    }

    fn ping(&self) -> Result<(), String> {
        match self.request(op::PING, &[])? {
            (op::R_OK, _) => Ok(()),
            (other, _) => Err(format!(
                "cache server {}: PING: unexpected reply {other:#04x}",
                self.addr)),
        }
    }
}

// ---------------------------------------------------------------- server

/// Multi-threaded cache server fronting any [`Store`]: one handler
/// thread per connection, the backing store shared behind its `Arc`.
/// `FsStore` writes stay atomic (temp + rename) and `MemStore` is
/// mutexed, so concurrent PUTs of one fingerprint are safe end to end.
///
/// Since protocol v2 the server also hosts the job queue
/// ([`queue::QueueState`] behind a mutex): lease deadlines are
/// measured against a private monotonic epoch captured at bind time,
/// so queue time never depends on wall-clock adjustments and is never
/// compared across hosts.
pub struct CacheServer {
    listener: TcpListener,
    store: Store,
    local: SocketAddr,
    queue: Arc<Mutex<QueueState>>,
    counters: Arc<OpCounters>,
    epoch: Instant,
}

impl CacheServer {
    /// Bind to `addr` (e.g. `127.0.0.1:0` for an ephemeral port —
    /// [`CacheServer::local_addr`] reports what was actually bound).
    pub fn bind(addr: &str, store: Store) -> Result<CacheServer, String> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| format!("cache-server: bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("cache-server: local address: {e}"))?;
        Ok(CacheServer {
            listener,
            store,
            local,
            queue: Arc::new(Mutex::new(QueueState::new(
                queue::DEFAULT_LEASE_MS))),
            counters: Arc::new(OpCounters::default()),
            // rainbow-lint: allow(nondet-clock, lease deadlines are relative to a private server epoch; never serialized into results or compared across hosts)
            epoch: Instant::now(),
        })
    }

    /// Override the job-queue lease deadline (`--lease-ms`).
    pub fn with_lease_ms(self, lease_ms: u64) -> CacheServer {
        CacheServer {
            queue: Arc::new(Mutex::new(QueueState::new(lease_ms))),
            ..self
        }
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Serve until a `SHUTDOWN` request arrives, then drain in-flight
    /// handlers and return `Ok(())` — the clean-shutdown contract.
    pub fn serve(self) -> Result<(), String> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
        for conn in self.listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    log::warn(&format!("cache-server: accept: {e}"));
                    continue;
                }
            };
            let store = self.store.clone();
            let sd = Arc::clone(&shutdown);
            let local = self.local;
            let queue = Arc::clone(&self.queue);
            let counters = Arc::clone(&self.counters);
            let epoch = self.epoch;
            handlers.push(thread::spawn(move || {
                handle_conn(stream, &store, &sd, local, &queue,
                            &counters, epoch)
            }));
            handlers.retain(|h| !h.is_finished());
        }
        for h in handlers {
            let _ = h.join();
        }
        Ok(())
    }

    /// [`CacheServer::serve`] on a background thread — the in-process
    /// form tests use (server on an ephemeral port, client in the same
    /// process, child shard-workers across the process boundary).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.local;
        let join = thread::spawn(move || self.serve());
        ServerHandle { addr, join }
    }
}

/// Handle to a background [`CacheServer`]; [`ServerHandle::stop`]
/// performs the clean-shutdown round-trip and joins the server thread.
pub struct ServerHandle {
    addr: SocketAddr,
    join: thread::JoinHandle<Result<(), String>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `host:port` for clients ([`Store::net`] / `--store tcp://...`).
    pub fn host_port(&self) -> String {
        self.addr.to_string()
    }

    /// Request shutdown, then join the server thread; `Ok` only when
    /// the server acknowledged and exited cleanly.
    pub fn stop(self) -> Result<(), String> {
        NetStore::new(&self.addr.to_string())
            .shutdown_server()
            .map_err(|e| format!("cache-server stop: {e}"))?;
        match self.join.join() {
            Ok(r) => r,
            Err(_) => Err("cache-server thread panicked".to_string()),
        }
    }
}

fn handle_conn(mut stream: TcpStream, store: &Store,
               shutdown: &AtomicBool, local: SocketAddr,
               queue: &Mutex<QueueState>, counters: &OpCounters,
               epoch: Instant) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(120)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(120)));
    // A connection may carry several exchanges back to back; EOF (or
    // any frame error — this is untrusted input) drops it.
    loop {
        let (opcode, payload) = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(_) => return,
        };
        let now_ms = epoch.elapsed().as_millis() as u64;
        counters.bump(opcode);
        let sent = match opcode {
            op::GET => serve_get(&mut stream, store, &payload),
            op::PUT => serve_put(&mut stream, store, &payload),
            op::LIST => match store.list() {
                Ok(fps) => write_frame(&mut stream, op::R_OK,
                                       fps.join("\n").as_bytes()),
                Err(e) => write_frame(&mut stream, op::R_ERR,
                                      e.as_bytes()),
            },
            op::PING => write_frame(&mut stream, op::R_OK, &[]),
            op::LEASE => serve_lease(&mut stream, queue, &payload, now_ms),
            op::COMPLETE => {
                serve_complete(&mut stream, store, queue, &payload, now_ms)
            }
            op::REQUEUE => {
                serve_requeue(&mut stream, queue, &payload, now_ms)
            }
            op::QSTAT => serve_qstat(&mut stream, queue, now_ms),
            op::STATS => {
                serve_stats(&mut stream, store, queue, counters)
            }
            op::SHUTDOWN => {
                // Flag first, acknowledge second, then poke the accept
                // loop awake so it observes the flag and exits. A
                // wildcard bind (0.0.0.0 / ::) is poked via loopback.
                shutdown.store(true, Ordering::SeqCst);
                let _ = write_frame(&mut stream, op::R_OK, &[]);
                let mut wake = local;
                if wake.ip().is_unspecified() {
                    wake.set_ip(if wake.is_ipv4() {
                        std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                    } else {
                        std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                    });
                }
                let _ = TcpStream::connect(wake);
                return;
            }
            other => write_frame(
                &mut stream,
                op::R_ERR,
                format!("unknown opcode {other:#04x}").as_bytes()),
        };
        if sent.is_err() {
            return;
        }
    }
}

fn serve_get(stream: &mut TcpStream, store: &Store, payload: &[u8])
             -> io::Result<()> {
    let fp = match std::str::from_utf8(payload) {
        Ok(fp) if valid_fingerprint(fp) => fp,
        _ => {
            return write_frame(stream, op::R_ERR,
                               b"GET: malformed fingerprint")
        }
    };
    match store.get(fp) {
        Ok(Some(m)) => write_frame(
            stream, op::R_OK, serde_kv::metrics_to_kv(&m).as_bytes()),
        Ok(None) => write_frame(stream, op::R_MISSING, &[]),
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

fn serve_put(stream: &mut TcpStream, store: &Store, payload: &[u8])
             -> io::Result<()> {
    let text = match std::str::from_utf8(payload) {
        Ok(t) => t,
        Err(_) => {
            return write_frame(stream, op::R_ERR,
                               b"PUT: non-UTF8 payload")
        }
    };
    let Some((fp, entry)) = text.split_once('\n') else {
        return write_frame(stream, op::R_ERR,
                           b"PUT: missing fingerprint line");
    };
    if !valid_fingerprint(fp) {
        return write_frame(stream, op::R_ERR,
                           b"PUT: malformed fingerprint");
    }
    // Parse-before-store: the entry must be an intact, current-version
    // metrics serialization, so a corrupt PUT is rejected at the door
    // instead of poisoning the store for every later reader.
    match serde_kv::metrics_from_kv_checked(entry) {
        Ok(m) => match store.put(fp, &m) {
            Ok(()) => write_frame(stream, op::R_OK, &[]),
            Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
        },
        Err(e) => write_frame(
            stream,
            op::R_ERR,
            format!("PUT {fp}: rejected metrics payload: {e}").as_bytes()),
    }
}

// --------------------------------------------------- queue handlers

/// Lock the queue, mapping a poisoned mutex (a panicked handler) to a
/// clean protocol error instead of a server-side panic cascade.
fn lock_queue<'q>(queue: &'q Mutex<QueueState>)
                  -> Result<std::sync::MutexGuard<'q, QueueState>, String> {
    queue.lock().map_err(|_| {
        "job queue mutex poisoned by a panicked handler".to_string()
    })
}

fn serve_lease(stream: &mut TcpStream, queue: &Mutex<QueueState>,
               payload: &[u8], now_ms: u64) -> io::Result<()> {
    let reply = std::str::from_utf8(payload)
        .map_err(|_| "LEASE: non-UTF8 payload".to_string())
        .and_then(queue::lease_request_from_kv)
        .and_then(|req| {
            let mut q = lock_queue(queue)?;
            Ok(q.lease(&req.worker, now_ms))
        });
    match reply {
        Ok(r) => write_frame(stream, op::R_OK,
                             queue::lease_reply_to_kv(&r).as_bytes()),
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

fn serve_requeue(stream: &mut TcpStream, queue: &Mutex<QueueState>,
                 payload: &[u8], now_ms: u64) -> io::Result<()> {
    // The job set arrives as a checksummed spec list — the same
    // strict, integrity-checked format shard files use, so a torn or
    // tampered submission is rejected before anything is scheduled.
    let stat = std::str::from_utf8(payload)
        .map_err(|_| "REQUEUE: non-UTF8 payload".to_string())
        .and_then(|text| {
            serde_kv::specs_from_kv(text)
                .map_err(|e| format!("REQUEUE: {e}"))
        })
        .and_then(|specs| {
            let mut q = lock_queue(queue)?;
            Ok(q.enqueue(&specs, now_ms))
        });
    match stat {
        Ok(s) => write_frame(stream, op::R_OK,
                             queue::queue_stat_to_kv(&s).as_bytes()),
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

/// `COMPLETE` trusts the store over the worker: when the backing
/// store holds the claimed entry, its canonical checksum is
/// authoritative — it is what the completion is recorded (and, on
/// duplicates, compared) against, and a *declared* checksum (wire v2)
/// must agree with it. When the store does **not** hold the entry,
/// a declared checksum stands in — that is the replicated-store case,
/// where the consistent-hash ring may have placed the entry on
/// replicas other than this scheduler. With neither a stored entry
/// nor a declared checksum the completion is rejected — `PUT` must
/// land first.
fn serve_complete(stream: &mut TcpStream, store: &Store,
                  queue: &Mutex<QueueState>, payload: &[u8],
                  now_ms: u64) -> io::Result<()> {
    let outcome = std::str::from_utf8(payload)
        .map_err(|_| "COMPLETE: non-UTF8 payload".to_string())
        .and_then(queue::complete_request_from_kv)
        .and_then(|req| {
            if !valid_fingerprint(&req.fingerprint) {
                return Err("COMPLETE: malformed fingerprint".to_string());
            }
            let checksum = match store.get(&req.fingerprint) {
                Ok(Some(m)) => {
                    let own = queue::entry_checksum(&m);
                    if let Some(declared) = req.checksum {
                        if declared != own {
                            return Err(format!(
                                "COMPLETE {}: declared checksum \
                                 {declared:016x} diverges from the \
                                 stored entry's {own:016x} — \
                                 determinism violation",
                                req.fingerprint));
                        }
                    }
                    own
                }
                Ok(None) => match req.checksum {
                    Some(declared) => declared,
                    None => {
                        return Err(format!(
                            "COMPLETE {}: no metrics entry in the \
                             store (PUT must precede COMPLETE)",
                            req.fingerprint))
                    }
                },
                Err(e) => {
                    return Err(format!(
                        "COMPLETE {}: {e}", req.fingerprint))
                }
            };
            let mut q = lock_queue(queue)?;
            q.complete(&req.fingerprint, req.lease_id, checksum, now_ms)
        });
    match outcome {
        Ok(_) => write_frame(stream, op::R_OK, &[]),
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

fn serve_qstat(stream: &mut TcpStream, queue: &Mutex<QueueState>,
               now_ms: u64) -> io::Result<()> {
    match lock_queue(queue) {
        Ok(mut q) => {
            let s = q.stat(now_ms);
            write_frame(stream, op::R_OK,
                        queue::queue_stat_to_kv(&s).as_bytes())
        }
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

/// `STATS`: assemble the observability snapshot from the per-opcode
/// counters, the queue's lease-latency histogram, and the backing
/// store's own counters.
fn serve_stats(stream: &mut TcpStream, store: &Store,
               queue: &Mutex<QueueState>, counters: &OpCounters)
               -> io::Result<()> {
    let stats = lock_queue(queue).map(|q| {
        let lat = q.lease_latency();
        let obs = store.obs();
        ServerStats {
            gets: counters.gets.load(Ordering::Relaxed),
            puts: counters.puts.load(Ordering::Relaxed),
            lists: counters.lists.load(Ordering::Relaxed),
            pings: counters.pings.load(Ordering::Relaxed),
            leases: counters.leases.load(Ordering::Relaxed),
            completes: counters.completes.load(Ordering::Relaxed),
            requeues: counters.requeues.load(Ordering::Relaxed),
            qstats: counters.qstats.load(Ordering::Relaxed),
            stats_reqs: counters.stats.load(Ordering::Relaxed),
            lease_count: lat.count(),
            lease_ms_p50: lat.quantile(50),
            lease_ms_p95: lat.quantile(95),
            lease_ms_p99: lat.quantile(99),
            wal_appends: obs.wal_appends,
            wal_fsyncs: obs.wal_fsyncs,
            wal_replayed: obs.wal_replayed,
            degraded_gets: obs.degraded_gets,
            degraded_puts: obs.degraded_puts,
            read_repairs: obs.read_repairs,
        }
    });
    match stats {
        Ok(s) => write_frame(stream, op::R_OK,
                             server_stats_to_kv(&s).as_bytes()),
        Err(e) => write_frame(stream, op::R_ERR, e.as_bytes()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, op::PUT, b"hello world").unwrap();
        let (opc, payload) = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(opc, op::PUT);
        assert_eq!(payload, b"hello world");
        // Empty payloads are legal (PING, R_MISSING).
        let mut buf = Vec::new();
        write_frame(&mut buf, op::PING, &[]).unwrap();
        let (opc, payload) = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(opc, op::PING);
        assert!(payload.is_empty());
    }

    #[test]
    fn tampered_and_truncated_frames_fail_loudly() {
        let mut buf = Vec::new();
        write_frame(&mut buf, op::GET, b"v2_mcf_rainbow_s8").unwrap();
        // Flip a payload byte: checksum mismatch.
        let mut bad = buf.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        let e = read_frame(&mut Cursor::new(&bad)).unwrap_err();
        assert!(e.contains("checksum mismatch"), "got: {e}");
        // Truncate the payload: clean read error, not a hang/panic.
        let e = read_frame(&mut Cursor::new(&buf[..buf.len() - 3]))
            .unwrap_err();
        assert!(e.contains("payload"), "got: {e}");
        // Wrong magic.
        let mut bad = buf.clone();
        bad[0] = b'X';
        let e = read_frame(&mut Cursor::new(&bad)).unwrap_err();
        assert!(e.contains("magic"), "got: {e}");
        // Unsupported protocol version.
        let mut bad = buf.clone();
        bad[4] = 0xFF;
        let e = read_frame(&mut Cursor::new(&bad)).unwrap_err();
        assert!(e.contains("protocol version"), "got: {e}");
        // Absurd length prefix: clean error, no allocation attempt.
        let mut bad = buf.clone();
        bad[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = read_frame(&mut Cursor::new(&bad)).unwrap_err();
        assert!(e.contains("exceeds cap"), "got: {e}");
    }

    #[test]
    fn worker_jitter_is_deterministic_and_spreads_backoff() {
        let base = NetStore::new("127.0.0.1:7700").retry_backoff;
        let a = NetStore::new("127.0.0.1:7700").with_worker_jitter("w-0");
        let a2 = NetStore::new("127.0.0.1:7700").with_worker_jitter("w-0");
        let b = NetStore::new("127.0.0.1:7700").with_worker_jitter("w-1");
        // Same worker id -> same schedule (no clock, no RNG).
        assert_eq!(a.retry_backoff, a2.retry_backoff);
        // Distinct ids spread out (these two differ by construction).
        assert_ne!(a.retry_backoff, b.retry_backoff);
        for j in [&a, &b] {
            assert!(j.retry_backoff >= base, "jitter only adds delay");
            assert!(j.retry_backoff < base * 2, "jitter < one base step");
        }
    }

    #[test]
    fn server_stats_kv_round_trips_and_parses_strictly() {
        let s = ServerStats {
            gets: 1, puts: 2, lists: 3, pings: 4, leases: 5,
            completes: 6, requeues: 7, qstats: 8, stats_reqs: 9,
            lease_count: 10, lease_ms_p50: 63, lease_ms_p95: 127,
            lease_ms_p99: 255, wal_appends: 11, wal_fsyncs: 12,
            wal_replayed: 13, degraded_gets: 14, degraded_puts: 15,
            read_repairs: 16,
        };
        let kv = server_stats_to_kv(&s);
        assert!(kv.starts_with(&format!(
            "statswireversion={STATS_WIRE_VERSION}\n")));
        assert_eq!(server_stats_from_kv(&kv).unwrap(), s);
        // Version skew is a loud error.
        let skew = kv.replace(
            &format!("statswireversion={STATS_WIRE_VERSION}"),
            "statswireversion=99");
        let e = server_stats_from_kv(&skew).unwrap_err();
        assert!(e.contains("unsupported"), "got: {e}");
        // A dropped field, an unknown key, a duplicate, and a
        // non-integer value are all rejected.
        let e = server_stats_from_kv(&kv.replace("wal_fsyncs=12\n", ""))
            .unwrap_err();
        assert!(e.contains("missing field"), "got: {e}");
        let e = server_stats_from_kv(&format!("{kv}bogus=1\n"))
            .unwrap_err();
        assert!(e.contains("unknown key"), "got: {e}");
        let e = server_stats_from_kv(&format!("{kv}gets=1\n"))
            .unwrap_err();
        assert!(e.contains("duplicate"), "got: {e}");
        let e = server_stats_from_kv(&kv.replace("puts=2", "puts=x"))
            .unwrap_err();
        assert!(e.contains("integer"), "got: {e}");
        assert!(server_stats_from_kv("gets=1\n").is_err());
    }

    #[test]
    fn stats_surface_counts_requests_and_reads_back_zeroed_histograms() {
        let server =
            CacheServer::bind("127.0.0.1:0", Store::mem()).unwrap();
        let handle = server.spawn();
        let client = NetStore::new(&handle.host_port());
        client.ping().unwrap();
        client.ping().unwrap();
        assert!(client.get("v2_mcf_rainbow_s8").unwrap().is_none());
        let s = client.server_stats().unwrap();
        assert_eq!(s.pings, 2);
        assert_eq!(s.gets, 1);
        assert_eq!(s.puts, 0);
        // The STATS request counts itself.
        assert_eq!(s.stats_reqs, 1);
        // No leases completed, no durability log: zeros, not garbage.
        assert_eq!(s.lease_count, 0);
        assert_eq!(s.lease_ms_p99, 0);
        assert_eq!(s.wal_appends, 0);
        assert_eq!(s.degraded_gets, 0);
        let s2 = client.server_stats().unwrap();
        assert_eq!(s2.stats_reqs, 2);
        assert_eq!(s2.pings, 2);
        handle.stop().unwrap();
    }

    #[test]
    fn fingerprint_validation_blocks_path_shapes() {
        assert!(valid_fingerprint("v2_mcf_rainbow_s8_i4000000_r1"));
        assert!(valid_fingerprint("v2_a%5Fb_c_s8_i1_r0_o2x00ff00ff00ff00ff"));
        for bad in ["", "../etc/passwd", "a/b", "a\\b", "a..b",
                    "fp with spaces"] {
            assert!(!valid_fingerprint(bad), "{bad:?} must be rejected");
        }
        let long = "a".repeat(513);
        assert!(!valid_fingerprint(&long));
    }
}
