//! Parallel experiment-sweep orchestrator — the scaling layer the figure
//! emitters, the `sweep` CLI subcommand, and the bench/example drivers
//! all ride on.
//!
//! A sweep is a matrix of [`RunSpec`]s (workload × policy × scale/seed).
//! [`run`] executes the *unique* specs concurrently on
//! `std::thread::scope` workers: a bounded worker count pulls indices off
//! a shared atomic cursor, and each finished result lands in a
//! mutex-protected map keyed by the spec's stable
//! [`RunSpec::fingerprint`], so duplicate specs are simulated exactly
//! once. Every simulation is bit-deterministic given its spec (each run
//! owns its seeded RNGs and machine state; nothing is shared), which
//! makes the parallel path byte-identical to serial `run_uncached`
//! calls — `tests/sweep_determinism.rs` locks that contract in.
//!
//! The module exposes the three pieces process-level orchestration
//! composes from: [`matrix`] builds the spec matrix, [`run`] executes
//! it in-process, and [`collect_stored`] is the merge path — it
//! assembles a result set purely from fingerprint-keyed store entries
//! (written by [`super::run_stored`]) without simulating anything,
//! which is how [`super::shard`] folds the work of N child worker
//! processes back into one metrics vector.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::RunMetrics;

use super::{default_cache_dir, run_stored, run_uncached, RunSpec, Store};

/// Execution knobs for a sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepConfig {
    /// Worker threads; 0 = one per available core.
    pub workers: usize,
    /// Route runs through the persistent results store (`run_stored`)
    /// instead of always simulating (`run_uncached`).
    pub disk_cache: bool,
    /// Results store when `disk_cache` is set; `None` uses a
    /// directory store at [`default_cache_dir`]. Threaded explicitly
    /// (`Store::fs(dir)` for a directory, `Store::parse` for the CLI's
    /// `--store DIR|tcp://host:port`) so tests and parallel callers
    /// never have to mutate the process-global env var.
    pub store: Option<Store>,
}

/// Worker count used when `SweepConfig::workers == 0`.
pub fn auto_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Cross product: every workload × policy, carrying `base`'s scale,
/// instruction budget, interval, top-N, seed, and backend knobs.
pub fn matrix(base: &RunSpec, workloads: &[String], policies: &[String])
              -> Vec<RunSpec> {
    let mut out = Vec::with_capacity(workloads.len() * policies.len());
    for w in workloads {
        for p in policies {
            out.push(base.clone().with_workload(w).with_policy(p));
        }
    }
    out
}

/// Result of a sweep: metrics in input order plus execution stats.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub metrics: Vec<RunMetrics>,
    /// Simulations actually executed (after fingerprint dedup).
    pub unique_runs: usize,
    pub workers_used: usize,
}

/// Run every spec concurrently; metrics come back in input order.
/// Duplicate fingerprints share one simulation through the mutexed
/// result cache.
pub fn run(specs: &[RunSpec], cfg: &SweepConfig) -> SweepOutcome {
    let keys: Vec<String> = specs.iter().map(|s| s.fingerprint()).collect();
    let mut seen = HashSet::new();
    let uniq: Vec<usize> =
        (0..specs.len()).filter(|&i| seen.insert(keys[i].as_str())).collect();
    let workers = (if cfg.workers == 0 { auto_workers() } else { cfg.workers })
        .clamp(1, uniq.len().max(1));
    let store = cfg
        .store
        .clone()
        .unwrap_or_else(|| Store::fs(default_cache_dir()));
    let results: Mutex<HashMap<&str, RunMetrics>> =
        Mutex::new(HashMap::with_capacity(uniq.len()));
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let u = cursor.fetch_add(1, Ordering::Relaxed);
                let Some(&i) = uniq.get(u) else { break };
                let m = if cfg.disk_cache {
                    // Store failures are remote-transport failures
                    // (local stores self-heal); callers with a remote
                    // store ping it before fanning out, so mid-sweep
                    // loss of the server is a loud panic, not a
                    // silently partial result set.
                    run_stored(&store, &specs[i])
                        .unwrap_or_else(|e| panic!("sweep worker: {e}"))
                } else {
                    run_uncached(&specs[i])
                };
                results.lock().unwrap().insert(keys[i].as_str(), m);
            });
        }
    });
    let results = results.into_inner().unwrap();
    let metrics = keys
        .iter()
        .map(|k| {
            results
                .get(k.as_str())
                .expect("sweep worker lost a result")
                .clone()
        })
        .collect();
    SweepOutcome { metrics, unique_runs: uniq.len(), workers_used: workers }
}

/// [`run`] without the stats — just the metrics, in input order.
pub fn run_parallel(specs: &[RunSpec], cfg: &SweepConfig) -> Vec<RunMetrics> {
    run(specs, cfg).metrics
}

/// The merge path: load every spec's metrics from its
/// fingerprint-keyed entry in `store`, in input order, WITHOUT
/// simulating. Duplicate fingerprints share one load. A missing or
/// corrupt entry is an error naming the spec and store — the shard
/// coordinator treats that as a failed shard, and callers pre-warming
/// a store for figures learn exactly which cell is absent.
pub fn collect_stored(store: &Store, specs: &[RunSpec])
                      -> Result<Vec<RunMetrics>, String> {
    let mut by_fp: HashMap<String, RunMetrics> = HashMap::new();
    let mut out = Vec::with_capacity(specs.len());
    for s in specs {
        let fp = s.fingerprint();
        if let Some(m) = by_fp.get(&fp) {
            out.push(m.clone());
            continue;
        }
        let m = match store.get(&fp) {
            Ok(Some(m)) => m,
            Ok(None) => {
                return Err(format!(
                    "missing cache entry for {} x {} ({fp} in {})",
                    s.workload, s.policy, store.addr()))
            }
            Err(e) => {
                return Err(format!(
                    "corrupt cache entry for {} x {}: {e}",
                    s.workload, s.policy))
            }
        };
        out.push(m.clone());
        by_fp.insert(fp, m);
    }
    Ok(out)
}

/// [`collect_stored`] against a cache directory (the common local
/// form).
pub fn collect_cached(dir: &Path, specs: &[RunSpec])
                      -> Result<Vec<RunMetrics>, String> {
    collect_stored(&Store::fs(dir), specs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::serde_kv::metrics_to_kv;

    fn tiny(w: &str, p: &str) -> RunSpec {
        RunSpec::new(w, p)
            .with_scale(64)
            .with_instructions(20_000)
            .with_seed(7)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 8u64)
    }

    #[test]
    fn matrix_builds_cross_product_in_order() {
        let ws: Vec<String> = ["a", "b"].iter().map(|s| s.to_string()).collect();
        let ps: Vec<String> =
            ["x", "y", "z"].iter().map(|s| s.to_string()).collect();
        let base = RunSpec::new("", "").with_seed(123);
        let m = matrix(&base, &ws, &ps);
        assert_eq!(m.len(), 6);
        assert_eq!((m[0].workload.as_str(), m[0].policy.as_str()), ("a", "x"));
        assert_eq!((m[4].workload.as_str(), m[4].policy.as_str()), ("b", "y"));
        assert!(m.iter().all(|s| s.seed == 123));
    }

    #[test]
    fn empty_matrix_is_fine() {
        let out = run(&[], &SweepConfig::default());
        assert!(out.metrics.is_empty());
        assert_eq!(out.unique_runs, 0);
    }

    #[test]
    fn duplicates_simulated_once_and_identical() {
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "flat"),
                         tiny("DICT", "rainbow")];
        let cfg = SweepConfig { workers: 2, ..SweepConfig::default() };
        let out = run(&specs, &cfg);
        assert_eq!(out.unique_runs, 2);
        assert_eq!(out.metrics.len(), 3);
        assert_eq!(metrics_to_kv(&out.metrics[0]),
                   metrics_to_kv(&out.metrics[1]));
        assert_ne!(metrics_to_kv(&out.metrics[0]),
                   metrics_to_kv(&out.metrics[2]));
    }

    #[test]
    fn worker_count_respects_request_and_bounds() {
        let specs = vec![tiny("DICT", "flat")];
        let cfg = SweepConfig { workers: 16, ..SweepConfig::default() };
        let out = run(&specs, &cfg);
        assert_eq!(out.workers_used, 1, "never more workers than work");
        assert!(auto_workers() >= 1);
    }

    #[test]
    fn collect_cached_merges_and_reports_missing_entries() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_collect_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // Duplicates in the request must be served from one entry.
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "rainbow"),
                         tiny("DICT", "flat")];
        // Nothing cached yet: the merge path must NOT simulate.
        let e = collect_cached(&dir, &specs).unwrap_err();
        assert!(e.contains("missing cache entry"), "got: {e}");
        let cfg = SweepConfig {
            workers: 2,
            disk_cache: true,
            store: Some(Store::fs(dir.clone())),
        };
        let ran = run(&specs, &cfg);
        let merged = collect_cached(&dir, &specs).unwrap();
        assert_eq!(merged.len(), specs.len());
        for (a, b) in ran.metrics.iter().zip(&merged) {
            assert_eq!(metrics_to_kv(a), metrics_to_kv(b),
                       "merge path must be byte-identical to the run");
        }
        // A corrupt (tampered) entry is a clean error naming the spec,
        // not a bad merge.
        let entry = dir.join(format!("{}.kv", specs[0].fingerprint()));
        let good = std::fs::read_to_string(&entry).unwrap();
        std::fs::write(&entry, good.replace("cycles=", "cycles=9"))
            .unwrap();
        let e = collect_cached(&dir, &specs).unwrap_err();
        assert!(e.contains("corrupt"), "got: {e}");
        // A stale-version entry (older build) reads as absent — the
        // merge reports it missing instead of blaming corruption.
        std::fs::write(&entry, "version=0\n").unwrap();
        let e = collect_cached(&dir, &specs).unwrap_err();
        assert!(e.contains("missing cache entry"), "got: {e}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn collect_stored_reads_any_store() {
        let store = Store::mem();
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "flat")];
        let e = collect_stored(&store, &specs).unwrap_err();
        assert!(e.contains("missing cache entry") && e.contains("mem"),
                "got: {e}");
        let cfg = SweepConfig {
            workers: 1,
            disk_cache: true,
            store: Some(store.clone()),
        };
        let ran = run(&specs, &cfg);
        let merged = collect_stored(&store, &specs).unwrap();
        assert_eq!(merged.len(), 2, "duplicates share one entry");
        assert_eq!(metrics_to_kv(&ran.metrics[0]),
                   metrics_to_kv(&merged[1]));
    }

    #[test]
    fn explicit_store_is_used_and_hit() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_sweep_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let specs = vec![tiny("DICT", "flat")];
        let cfg = SweepConfig {
            workers: 1,
            disk_cache: true,
            store: Some(Store::fs(dir.clone())),
        };
        let a = run(&specs, &cfg);
        let entry = dir.join(format!("{}.kv", specs[0].fingerprint()));
        assert!(entry.is_file(), "cache entry must land in the explicit dir");
        let b = run(&specs, &cfg); // served from the store
        assert_eq!(metrics_to_kv(&a.metrics[0]), metrics_to_kv(&b.metrics[0]));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
