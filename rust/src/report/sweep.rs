//! Parallel experiment-sweep orchestrator — the scaling layer the figure
//! emitters, the `sweep` CLI subcommand, and the bench/example drivers
//! all ride on.
//!
//! A sweep is a matrix of [`RunSpec`]s (workload × policy × scale/seed).
//! [`run`] executes the *unique* specs concurrently on
//! `std::thread::scope` workers: a bounded worker count pulls indices off
//! a shared atomic cursor, and each finished result lands in a
//! mutex-protected map keyed by the spec's stable
//! [`RunSpec::fingerprint`], so duplicate specs are simulated exactly
//! once. Every simulation is bit-deterministic given its spec (each run
//! owns its seeded RNGs and machine state; nothing is shared), which
//! makes the parallel path byte-identical to serial `run_uncached`
//! calls — `tests/sweep_determinism.rs` locks that contract in.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::RunMetrics;

use super::{run_cached, run_uncached, RunSpec};

/// Execution knobs for a sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    /// Worker threads; 0 = one per available core.
    pub workers: usize,
    /// Route runs through the persistent on-disk results cache
    /// (`run_cached`) instead of always simulating (`run_uncached`).
    pub disk_cache: bool,
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig { workers: 0, disk_cache: false }
    }
}

/// Worker count used when `SweepConfig::workers == 0`.
pub fn auto_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Cross product: every workload × policy, carrying `base`'s scale,
/// instruction budget, interval, top-N, seed, and backend knobs.
pub fn matrix(base: &RunSpec, workloads: &[String], policies: &[String])
              -> Vec<RunSpec> {
    let mut out = Vec::with_capacity(workloads.len() * policies.len());
    for w in workloads {
        for p in policies {
            let mut s = base.clone();
            s.workload = w.clone();
            s.policy = p.clone();
            out.push(s);
        }
    }
    out
}

/// Result of a sweep: metrics in input order plus execution stats.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub metrics: Vec<RunMetrics>,
    /// Simulations actually executed (after fingerprint dedup).
    pub unique_runs: usize,
    pub workers_used: usize,
}

/// Run every spec concurrently; metrics come back in input order.
/// Duplicate fingerprints share one simulation through the mutexed
/// result cache.
pub fn run(specs: &[RunSpec], cfg: &SweepConfig) -> SweepOutcome {
    let keys: Vec<String> = specs.iter().map(|s| s.fingerprint()).collect();
    let mut seen = HashSet::new();
    let uniq: Vec<usize> =
        (0..specs.len()).filter(|&i| seen.insert(keys[i].as_str())).collect();
    let workers = (if cfg.workers == 0 { auto_workers() } else { cfg.workers })
        .clamp(1, uniq.len().max(1));
    let results: Mutex<HashMap<&str, RunMetrics>> =
        Mutex::new(HashMap::with_capacity(uniq.len()));
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let u = cursor.fetch_add(1, Ordering::Relaxed);
                let Some(&i) = uniq.get(u) else { break };
                let m = if cfg.disk_cache {
                    run_cached(&specs[i])
                } else {
                    run_uncached(&specs[i])
                };
                results.lock().unwrap().insert(keys[i].as_str(), m);
            });
        }
    });
    let results = results.into_inner().unwrap();
    let metrics = keys
        .iter()
        .map(|k| {
            results
                .get(k.as_str())
                .expect("sweep worker lost a result")
                .clone()
        })
        .collect();
    SweepOutcome { metrics, unique_runs: uniq.len(), workers_used: workers }
}

/// [`run`] without the stats — just the metrics, in input order.
pub fn run_parallel(specs: &[RunSpec], cfg: &SweepConfig) -> Vec<RunMetrics> {
    run(specs, cfg).metrics
}

/// Parallel, disk-cached run — the figure emitters' entry point. Consumes
/// the persistent results cache where populated (so a `suite` run shares
/// each (workload, policy) simulation across every figure that needs it)
/// and returns the metrics in input order for direct row rendering.
pub fn run_many_cached(specs: &[RunSpec]) -> Vec<RunMetrics> {
    run(specs, &SweepConfig { workers: 0, disk_cache: true }).metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::serde_kv::metrics_to_kv;

    fn tiny(w: &str, p: &str) -> RunSpec {
        let mut s = RunSpec::new(w, p);
        s.scale = 64;
        s.instructions = 20_000;
        s.interval_cycles = 100_000;
        s.top_n = 8;
        s.seed = 7;
        s
    }

    #[test]
    fn matrix_builds_cross_product_in_order() {
        let ws: Vec<String> = ["a", "b"].iter().map(|s| s.to_string()).collect();
        let ps: Vec<String> =
            ["x", "y", "z"].iter().map(|s| s.to_string()).collect();
        let mut base = RunSpec::new("", "");
        base.seed = 123;
        let m = matrix(&base, &ws, &ps);
        assert_eq!(m.len(), 6);
        assert_eq!((m[0].workload.as_str(), m[0].policy.as_str()), ("a", "x"));
        assert_eq!((m[4].workload.as_str(), m[4].policy.as_str()), ("b", "y"));
        assert!(m.iter().all(|s| s.seed == 123));
    }

    #[test]
    fn empty_matrix_is_fine() {
        let out = run(&[], &SweepConfig::default());
        assert!(out.metrics.is_empty());
        assert_eq!(out.unique_runs, 0);
    }

    #[test]
    fn duplicates_simulated_once_and_identical() {
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "flat"),
                         tiny("DICT", "rainbow")];
        let out = run(&specs, &SweepConfig { workers: 2, disk_cache: false });
        assert_eq!(out.unique_runs, 2);
        assert_eq!(out.metrics.len(), 3);
        assert_eq!(metrics_to_kv(&out.metrics[0]),
                   metrics_to_kv(&out.metrics[1]));
        assert_ne!(metrics_to_kv(&out.metrics[0]),
                   metrics_to_kv(&out.metrics[2]));
    }

    #[test]
    fn worker_count_respects_request_and_bounds() {
        let specs = vec![tiny("DICT", "flat")];
        let out = run(&specs, &SweepConfig { workers: 16, disk_cache: false });
        assert_eq!(out.workers_used, 1, "never more workers than work");
        assert!(auto_workers() >= 1);
    }
}
