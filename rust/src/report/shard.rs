//! Multi-process shard orchestrator: scales a sweep past one process by
//! partitioning its spec matrix into N shards, executing each shard as
//! a child `rainbow shard-worker` process, and merging the results back
//! through the shared on-disk cache.
//!
//! The contracts the in-process sweep established carry across the
//! process boundary unchanged:
//!
//! * **Determinism** — every simulation is bit-deterministic given its
//!   spec, so shard-merged metrics are byte-identical (via the kv
//!   serialization) to a serial `run_uncached` replay of the same spec
//!   list; `tests/sweep_determinism.rs` locks this in across a real
//!   child process.
//! * **Fingerprint/cache identity** — shards communicate results ONLY
//!   through fingerprint-keyed entries of the configured results
//!   [`Store`] (a shared cache directory, or a `rainbow cache-server`
//!   reached over TCP for shared-nothing clusters); the merge is
//!   [`sweep::collect_stored`], which never simulates. Duplicate specs
//!   are deduplicated BEFORE partitioning, so no two shards ever run
//!   (or write) the same fingerprint.
//! * **Order-independence** — [`partition`] sorts the unique specs by
//!   fingerprint before round-robin assignment, so the shard layout is
//!   a pure function of the spec *set*, not of matrix construction
//!   order.
//!
//! On-disk artifacts (all formats versioned, see `report::serde_kv`
//! and docs/MANUAL.md): each shard's spec list is a `.kv` spec-list
//! file (`shard-000.kv`, ...), and [`write_shards`] drops a
//! `manifest.kv` ([`ShardManifest`]) describing the layout — enough for
//! an operator (or a future multi-host scheduler) to ship shard files
//! to other machines, run `rainbow shard-worker --specs FILE --store
//! DIR|tcp://host:port` anywhere, and merge wherever the store is
//! reachable.

use std::collections::HashSet;
use std::fs;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::Duration;

use crate::sim::RunMetrics;

use super::{run_stored, serde_kv, spec_cli, sweep, RunSpec, Store};

/// Version of the shard-manifest serialization.
pub const MANIFEST_VERSION: u64 = 1;

/// Poll interval while waiting for child workers.
const REAP_POLL: Duration = Duration::from_millis(25);

/// Execution knobs for a sharded sweep.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Requested shard count (clamped to the unique-spec count; >= 1).
    pub shards: usize,
    /// Maximum concurrently running child processes; 0 = one per
    /// available core (like `SweepConfig::workers`).
    pub parallel: usize,
    /// Results store — the transport of the sharded sweep: children
    /// write fingerprint-keyed entries into it, the merge reads them
    /// back. A shared cache directory, or a `tcp://host:port` cache
    /// server when coordinator and workers share no filesystem. Its
    /// textual address is re-serialized onto each child's command line
    /// as `--store <addr>`.
    pub store: Store,
    /// Directory for the shard spec-list files and the manifest
    /// (coordinator-local; only the store must be shared).
    pub work_dir: PathBuf,
    /// Override the worker command (argv prefix — e.g. a wrapper script
    /// that ships the shard file to another host). `--specs FILE
    /// --store ADDR` is appended. `None` runs this binary's own
    /// `shard-worker` subcommand.
    pub cmd: Option<Vec<String>>,
}

impl ShardConfig {
    /// Defaults for `n` shards over the given cache directory; shard
    /// files land in `<cache_dir>/shards`.
    pub fn new(shards: usize, cache_dir: PathBuf) -> ShardConfig {
        let work_dir = cache_dir.join("shards");
        ShardConfig {
            shards,
            parallel: 0,
            store: Store::fs(cache_dir),
            work_dir,
            cmd: None,
        }
    }

    /// Defaults for `n` shards over an arbitrary results store (e.g.
    /// `Store::net` for a shared-nothing sweep through a cache
    /// server), with an explicit shard-file directory.
    pub fn with_store(shards: usize, store: Store, work_dir: PathBuf)
                      -> ShardConfig {
        ShardConfig { shards, parallel: 0, store, work_dir, cmd: None }
    }

    fn worker_command(&self, specs_file: &Path) -> Result<Command, String> {
        let mut c = match &self.cmd {
            Some(argv) if !argv.is_empty() => {
                let mut c = Command::new(&argv[0]);
                c.args(&argv[1..]);
                c
            }
            Some(_) => return Err("shard: empty --shard-cmd".to_string()),
            None => {
                let exe = std::env::current_exe().map_err(|e| {
                    format!("shard: cannot resolve current executable \
                             (pass an explicit worker command): {e}")
                })?;
                let mut c = Command::new(exe);
                c.arg("shard-worker");
                c
            }
        };
        c.arg("--specs").arg(specs_file);
        c.arg("--store").arg(self.store.addr());
        Ok(c)
    }
}

/// Result of a sharded sweep: metrics in input order plus layout stats.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    pub metrics: Vec<RunMetrics>,
    /// Unique fingerprints actually executed (after dedup).
    pub unique_runs: usize,
    /// Shard processes run (may be fewer than requested when the
    /// unique-spec count is smaller).
    pub shards_run: usize,
}

/// Layout record written next to the shard files as `manifest.kv`.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    /// Input specs, duplicates included.
    pub total_specs: usize,
    /// Distinct fingerprints (what the shards actually simulate).
    pub unique_specs: usize,
    /// Per-shard `(file name, spec count)`, in shard order.
    pub shard_files: Vec<(String, usize)>,
}

/// Serialize a [`ShardManifest`] (versioned kv, one `shard.<i>.*` pair
/// per shard).
pub fn manifest_to_kv(m: &ShardManifest) -> String {
    let mut out = format!(
        "manifestversion={MANIFEST_VERSION}\ntotalspecs={}\n\
         uniquespecs={}\nshards={}\n",
        m.total_specs, m.unique_specs, m.shard_files.len());
    for (i, (file, n)) in m.shard_files.iter().enumerate() {
        out.push_str(&format!("shard.{i}.file={file}\n"));
        out.push_str(&format!("shard.{i}.specs={n}\n"));
    }
    out
}

/// Parse a manifest. Strict: version must match, every shard index in
/// range must carry both its `file` and `specs` keys.
pub fn manifest_from_kv(text: &str) -> Result<ShardManifest, String> {
    let mut version = None;
    let (mut total, mut unique, mut shards) = (None, None, None);
    let mut files: Vec<Option<String>> = Vec::new();
    let mut counts: Vec<Option<usize>> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("manifest line {}: expected key=value, got {line:?}",
                    lineno + 1)
        })?;
        let (k, v) = (k.trim(), v.trim());
        let int = |what: &str| -> Result<usize, String> {
            v.parse::<usize>().map_err(|_| {
                format!("manifest line {}: {what}: expected integer, \
                         got {v:?}", lineno + 1)
            })
        };
        match k {
            "manifestversion" => version = Some(int("manifestversion")? as u64),
            "totalspecs" => total = Some(int("totalspecs")?),
            "uniquespecs" => unique = Some(int("uniquespecs")?),
            "shards" => {
                if shards.is_some() {
                    return Err(format!(
                        "manifest line {}: duplicate shards= key",
                        lineno + 1));
                }
                let n = int("shards")?;
                // The header is untrusted input: a manifest with n
                // shards carries two lines per shard, so an absurd
                // count must error here, not abort the allocator.
                if n > text.lines().count() {
                    return Err(format!(
                        "manifest line {}: shards={n} exceeds what the \
                         file could hold (corrupt?)", lineno + 1));
                }
                files.resize(n, None);
                counts.resize(n, None);
                shards = Some(n);
            }
            _ => match k.strip_prefix("shard.") {
                Some(rest) => {
                    let (idx, field) = rest.split_once('.').ok_or_else(|| {
                        format!("manifest line {}: bad shard key {k:?}",
                                lineno + 1)
                    })?;
                    let i: usize = idx.parse().map_err(|_| {
                        format!("manifest line {}: bad shard index {idx:?}",
                                lineno + 1)
                    })?;
                    let n = shards.ok_or_else(|| {
                        format!("manifest line {}: shard.{idx} before the \
                                 shards= count", lineno + 1)
                    })?;
                    if i >= n {
                        return Err(format!(
                            "manifest line {}: shard index {i} out of \
                             range (shards={n})", lineno + 1));
                    }
                    match field {
                        "file" => files[i] = Some(v.to_string()),
                        "specs" => counts[i] = Some(int("shard specs")?),
                        _ => return Err(format!(
                            "manifest line {}: unknown shard field \
                             {field:?}", lineno + 1)),
                    }
                }
                None => return Err(format!(
                    "manifest line {}: unknown manifest key {k:?}",
                    lineno + 1)),
            },
        }
    }
    match version {
        Some(MANIFEST_VERSION) => {}
        Some(v) => return Err(format!(
            "manifest version {v} unsupported (expected {MANIFEST_VERSION})")),
        None => return Err("manifest missing manifestversion".to_string()),
    }
    let total = total.ok_or("manifest missing totalspecs")?;
    let unique = unique.ok_or("manifest missing uniquespecs")?;
    let n = shards.ok_or("manifest missing shards")?;
    let mut shard_files = Vec::with_capacity(n);
    for (i, (file, count)) in files.iter().zip(&counts).enumerate() {
        let file = file.clone().ok_or_else(|| {
            format!("manifest missing shard.{i}.file")
        })?;
        let count = (*count).ok_or_else(|| {
            format!("manifest missing shard.{i}.specs")
        })?;
        shard_files.push((file, count));
    }
    Ok(ShardManifest {
        total_specs: total,
        unique_specs: unique,
        shard_files,
    })
}

/// Partition a spec list for sharded execution: deduplicate by
/// fingerprint, sort the unique specs by fingerprint, and deal them
/// round-robin across `min(shards, unique)` shards. Deterministic and
/// order-independent (the layout depends only on the spec *set*), with
/// shard sizes differing by at most one. Never returns an empty shard;
/// an empty spec list yields zero shards.
pub fn partition(specs: &[RunSpec], shards: usize) -> Vec<Vec<RunSpec>> {
    let mut seen = HashSet::new();
    let mut uniq: Vec<(String, &RunSpec)> = specs
        .iter()
        .filter_map(|s| {
            let fp = s.fingerprint();
            seen.insert(fp.clone()).then_some((fp, s))
        })
        .collect();
    uniq.sort_by(|a, b| a.0.cmp(&b.0));
    let n = uniq.len().min(shards.max(1));
    let mut out: Vec<Vec<RunSpec>> = (0..n).map(|_| Vec::new()).collect();
    for (i, (_, s)) in uniq.iter().enumerate() {
        out[i % n].push((*s).clone());
    }
    out
}

/// Write the shard spec-list files plus `manifest.kv` into
/// `cfg.work_dir`; returns the shard file paths in shard order.
/// `total_specs` is the pre-dedup input length recorded in the
/// manifest.
pub fn write_shards(parts: &[Vec<RunSpec>], total_specs: usize,
                    cfg: &ShardConfig) -> Result<Vec<PathBuf>, String> {
    fs::create_dir_all(&cfg.work_dir).map_err(|e| {
        format!("shard: create {}: {e}", cfg.work_dir.display())
    })?;
    let mut paths = Vec::with_capacity(parts.len());
    let mut manifest = ShardManifest {
        total_specs,
        unique_specs: parts.iter().map(|p| p.len()).sum(),
        shard_files: Vec::with_capacity(parts.len()),
    };
    for (i, part) in parts.iter().enumerate() {
        let name = format!("shard-{i:03}.kv");
        let path = cfg.work_dir.join(&name);
        fs::write(&path, serde_kv::specs_to_kv(part)).map_err(|e| {
            format!("shard: write {}: {e}", path.display())
        })?;
        manifest.shard_files.push((name, part.len()));
        paths.push(path);
    }
    let mpath = cfg.work_dir.join("manifest.kv");
    fs::write(&mpath, manifest_to_kv(&manifest)).map_err(|e| {
        format!("shard: write {}: {e}", mpath.display())
    })?;
    Ok(paths)
}

/// One running child worker plus the thread streaming its stdout.
struct Running {
    idx: usize,
    child: Child,
    pump: thread::JoinHandle<()>,
}

fn spawn_shard(cfg: &ShardConfig, idx: usize, specs_file: &Path)
               -> Result<Running, String> {
    let mut cmd = cfg.worker_command(specs_file)?;
    cmd.stdout(Stdio::piped()).stderr(Stdio::inherit());
    let mut child = cmd.spawn().map_err(|e| {
        format!("shard {idx}: spawn {cmd:?}: {e}")
    })?;
    let stdout = child.stdout.take().ok_or_else(|| {
        format!("shard {idx}: spawned worker has no piped stdout")
    })?;
    // Stream the worker's progress lines as they arrive, tagged with
    // the shard index, so a long sweep is observable per shard.
    let pump = thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            match line {
                Ok(l) => println!("[shard {idx}] {l}"),
                Err(_) => break,
            }
        }
    });
    Ok(Running { idx, child, pump })
}

/// Reap every finished child in `running`; failures are recorded, not
/// returned early (remaining shards keep running so one bad shard
/// reports alongside the others' completion). Returns whether anything
/// was reaped.
fn reap_finished(running: &mut Vec<Running>, failures: &mut Vec<String>)
                 -> bool {
    let mut reaped = false;
    let mut i = 0;
    while i < running.len() {
        match running[i].child.try_wait() {
            Ok(Some(status)) => {
                let r = running.swap_remove(i);
                let _ = r.pump.join();
                if !status.success() {
                    failures.push(format!("shard {}: {status}", r.idx));
                }
                reaped = true;
            }
            Ok(None) => i += 1,
            Err(e) => {
                let mut r = running.swap_remove(i);
                let _ = r.child.kill();
                let _ = r.child.wait();
                let _ = r.pump.join();
                failures.push(format!("shard {}: wait failed: {e}", r.idx));
                reaped = true;
            }
        }
    }
    reaped
}

fn kill_all(running: &mut Vec<Running>) {
    for r in running.iter_mut() {
        let _ = r.child.kill();
        let _ = r.child.wait();
    }
    while let Some(r) = running.pop() {
        let _ = r.pump.join();
    }
}

/// Execute a spec matrix across child worker processes and merge the
/// results: [`partition`] → [`write_shards`] → bounded-parallel
/// `shard-worker` children → [`sweep::collect_cached`] merge. Metrics
/// come back in input order, byte-identical to a serial `run_uncached`
/// replay. Any failed shard (non-zero exit, spawn error) fails the
/// whole sweep with the shard named; remaining children are reaped
/// first.
pub fn run_sharded(specs: &[RunSpec], cfg: &ShardConfig)
                   -> Result<ShardOutcome, String> {
    if specs.is_empty() {
        return Ok(ShardOutcome {
            metrics: Vec::new(),
            unique_runs: 0,
            shards_run: 0,
        });
    }
    let parts = partition(specs, cfg.shards);
    let unique_runs: usize = parts.iter().map(|p| p.len()).sum();
    let files = write_shards(&parts, specs.len(), cfg)?;
    // Fail fast on an unusable transport BEFORE spawning children. A
    // directory store must exist up front (a worker failing before its
    // first write would otherwise leave the merge with a confusing "no
    // such directory" instead of "missing entry"); a networked store
    // gets a PING round-trip, so an unreachable server is one clear
    // error instead of N identical worker failures.
    match cfg.store.fs_dir() {
        Some(dir) => fs::create_dir_all(dir).map_err(|e| {
            format!("shard: create {}: {e}", dir.display())
        })?,
        None => cfg.store.ping().map_err(|e| {
            format!("shard: results store unavailable: {e}")
        })?,
    }
    let limit = (if cfg.parallel == 0 {
        sweep::auto_workers()
    } else {
        cfg.parallel
    })
    .clamp(1, files.len());
    let mut next = 0;
    let mut running: Vec<Running> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    while next < files.len() || !running.is_empty() {
        while next < files.len() && running.len() < limit {
            match spawn_shard(cfg, next, &files[next]) {
                Ok(r) => running.push(r),
                Err(e) => {
                    kill_all(&mut running);
                    return Err(e);
                }
            }
            next += 1;
        }
        if !reap_finished(&mut running, &mut failures)
            && !running.is_empty()
        {
            thread::sleep(REAP_POLL);
        }
    }
    if !failures.is_empty() {
        return Err(format!(
            "{} of {} shard workers failed: {} (shard files kept in {})",
            failures.len(), files.len(), failures.join("; "),
            cfg.work_dir.display()));
    }
    let metrics = sweep::collect_stored(&cfg.store, specs)
        .map_err(|e| format!("shard merge: {e}"))?;
    Ok(ShardOutcome { metrics, unique_runs, shards_run: files.len() })
}

/// The worker half: load + validate a spec-list file, simulate every
/// unique spec through the shared results store (`run_stored`), and
/// stream one progress line per spec to stdout (the coordinator tags
/// and forwards them). Returns the number of unique specs processed.
/// A store failure (e.g. the cache server vanishing mid-shard) aborts
/// the worker with a clean error — the coordinator reports the shard
/// as failed instead of merging a silently partial result set.
///
/// Workers are deliberately serial within a shard: the shard count is
/// the parallelism knob, and a serial worker keeps per-shard output
/// ordered and its memory footprint to one simulation.
pub fn worker_run(specs_path: &Path, store: &Store)
                  -> Result<usize, String> {
    let specs = spec_cli::load_spec_list(specs_path)?;
    let mut seen = HashSet::new();
    let uniq: Vec<&RunSpec> = specs
        .iter()
        .filter(|s| seen.insert(s.fingerprint()))
        .collect();
    let total = uniq.len();
    for (i, s) in uniq.iter().enumerate() {
        let fp = s.fingerprint();
        run_stored(store, s)?;
        println!("[{}/{total}] {} x {} done ({fp})",
                 i + 1, s.workload, s.policy);
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(w: &str, p: &str) -> RunSpec {
        RunSpec::new(w, p)
            .with_scale(64)
            .with_instructions(20_000)
            .with_seed(7)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 8u64)
    }

    fn sample_specs() -> Vec<RunSpec> {
        vec![
            tiny("DICT", "flat"),
            tiny("DICT", "rainbow"),
            tiny("streamcluster", "flat"),
            tiny("streamcluster", "rainbow"),
            tiny("DICT", "flat").with("nvm.read_cycles", 248u64),
        ]
    }

    #[test]
    fn partition_is_deterministic_and_order_independent() {
        let specs = sample_specs();
        let mut reversed = specs.clone();
        reversed.reverse();
        let a = partition(&specs, 2);
        let b = partition(&reversed, 2);
        assert_eq!(a, b, "layout must depend on the spec set, not order");
        assert_eq!(a, partition(&specs, 2), "and must be deterministic");
        // Balanced: sizes differ by at most one, nothing lost.
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].len() + a[1].len(), specs.len());
        assert!(a[0].len().abs_diff(a[1].len()) <= 1);
    }

    #[test]
    fn partition_dedups_duplicate_fingerprints() {
        let mut specs = sample_specs();
        specs.extend(sample_specs()); // every fingerprint twice
        let parts = partition(&specs, 3);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, sample_specs().len(),
                   "duplicates must collapse before partitioning");
        let mut fps = HashSet::new();
        for p in &parts {
            for s in p {
                assert!(fps.insert(s.fingerprint()),
                        "no fingerprint may appear in two shards");
            }
        }
    }

    #[test]
    fn partition_clamps_to_unique_count_and_handles_empty() {
        let specs = vec![tiny("DICT", "flat"), tiny("DICT", "rainbow")];
        let parts = partition(&specs, 16);
        assert_eq!(parts.len(), 2, "never more shards than unique specs");
        assert!(parts.iter().all(|p| p.len() == 1));
        assert!(partition(&[], 4).is_empty());
    }

    #[test]
    fn manifest_roundtrip_and_rejection() {
        let m = ShardManifest {
            total_specs: 12,
            unique_specs: 10,
            shard_files: vec![("shard-000.kv".into(), 5),
                              ("shard-001.kv".into(), 5)],
        };
        let kv = manifest_to_kv(&m);
        assert_eq!(manifest_from_kv(&kv).unwrap(), m);
        // Wrong/missing version.
        assert!(manifest_from_kv(&kv.replace(
            "manifestversion=1", "manifestversion=9")).is_err());
        assert!(manifest_from_kv("totalspecs=1\n").is_err());
        // Missing per-shard keys and out-of-range indices are errors.
        let e = manifest_from_kv(&kv.replace("shard.1.specs=5\n", ""))
            .unwrap_err();
        assert!(e.contains("shard.1.specs"), "got: {e}");
        assert!(manifest_from_kv(&kv.replace("shard.1.", "shard.7."))
            .is_err());
        assert!(manifest_from_kv("manifestversion=1\nnope=3\n").is_err());
        // Untrusted header: an absurd shard count is a clean error
        // (never an allocator abort), and a duplicate shards= key
        // cannot silently truncate recorded entries.
        let e = manifest_from_kv(
            "manifestversion=1\ntotalspecs=1\nuniquespecs=1\n\
             shards=18446744073709551615\n").unwrap_err();
        assert!(e.contains("exceeds"), "got: {e}");
        assert!(manifest_from_kv(&kv.replace("shards=2", "shards=2\nshards=1"))
            .is_err());
    }

    #[test]
    fn write_shards_emits_lists_and_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_shard_write_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let cfg = ShardConfig {
            work_dir: dir.clone(),
            ..ShardConfig::new(2, dir.clone())
        };
        let specs = sample_specs();
        let parts = partition(&specs, 2);
        let files = write_shards(&parts, specs.len(), &cfg).unwrap();
        assert_eq!(files.len(), 2);
        // Every shard file round-trips through the strict list parser.
        let mut seen = 0;
        for (f, part) in files.iter().zip(&parts) {
            let text = fs::read_to_string(f).unwrap();
            let back = serde_kv::specs_from_kv(&text).unwrap();
            assert_eq!(&back, part);
            seen += back.len();
        }
        assert_eq!(seen, specs.len());
        let man = manifest_from_kv(
            &fs::read_to_string(dir.join("manifest.kv")).unwrap()).unwrap();
        assert_eq!(man.total_specs, specs.len());
        assert_eq!(man.unique_specs, specs.len());
        assert_eq!(man.shard_files.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_rejects_corrupt_and_invalid_lists() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_shard_worker_bad_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let cache = dir.join("cache");
        let store = Store::fs(cache.clone());
        // Truncated list file: clear parse error, nothing simulated.
        let full = serde_kv::specs_to_kv(&sample_specs());
        let path = dir.join("trunc.kv");
        fs::write(&path, &full[..full.len() - 25]).unwrap();
        let e = worker_run(&path, &store).unwrap_err();
        assert!(e.contains("spec list"), "got: {e}");
        assert!(!cache.exists(), "a bad list must not simulate anything");
        // Valid list format but unknown workload name: rejected by
        // validate_spec before any run.
        let bogus = serde_kv::specs_to_kv(
            &[RunSpec::new("notanapp", "rainbow")]);
        fs::write(&path, bogus).unwrap();
        let e = worker_run(&path, &store).unwrap_err();
        assert!(e.contains("unknown workload"), "got: {e}");
        // Missing file.
        assert!(worker_run(&dir.join("nope.kv"), &store).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_executes_a_list_and_fills_the_cache() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_shard_worker_ok_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let cache = dir.join("cache");
        let mut specs = vec![tiny("DICT", "flat"), tiny("DICT", "rainbow")];
        specs.push(specs[0].clone()); // duplicate runs once
        let path = dir.join("shard.kv");
        fs::write(&path, serde_kv::specs_to_kv(&specs)).unwrap();
        let n = worker_run(&path, &Store::fs(cache.clone())).unwrap();
        assert_eq!(n, 2, "duplicate fingerprints run once");
        // The merge path can now serve the full (duplicated) request.
        let merged = sweep::collect_cached(&cache, &specs).unwrap();
        assert_eq!(merged.len(), 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_runs_against_a_mem_store() {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_shard_worker_mem_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let store = Store::mem();
        let specs = vec![tiny("DICT", "flat")];
        let path = dir.join("shard.kv");
        fs::write(&path, serde_kv::specs_to_kv(&specs)).unwrap();
        assert_eq!(worker_run(&path, &store).unwrap(), 1);
        let merged = sweep::collect_stored(&store, &specs).unwrap();
        assert_eq!(merged.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
