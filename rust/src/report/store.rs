//! Pluggable results-store subsystem: every consumer of cached
//! [`RunMetrics`] — `run_cached_in`, the sweep orchestrator, the shard
//! coordinator/worker pair, the figure emitters — talks to a
//! [`CacheStore`] instead of touching `<cache_dir>/<fingerprint>.kv`
//! paths directly. Three implementations ship:
//!
//! * [`FsStore`] — today's directory layout, behavior-preserving:
//!   entries appear atomically (unique per-process temp file + rename),
//!   concurrent writers of the same fingerprint produce identical bytes
//!   (determinism), so whichever rename lands last is fine.
//! * [`MemStore`] — a mutex-protected map; the test double, and the
//!   backing store of an ephemeral `rainbow cache-server --mem`.
//! * `NetStore` (in [`super::netstore`]) — a TCP client speaking the
//!   framed cache-server protocol, for shared-nothing sweeps where
//!   workers and coordinator share no filesystem at all.
//! * `LogStore` (in [`super::wal`]) — [`MemStore`] plus an append-only
//!   durability log (`cache-server --mem --log PATH`): fsynced before
//!   ack, replayed on startup, compacted on clean shutdown.
//! * `ReplStore` (in [`super::replica`]) — N cache servers behind a
//!   consistent-hash ring (`--store tcp://a,tcp://b,...`): write-through
//!   replication, primary-first reads with read-repair, warn-don't-fail
//!   degradation while ≥1 replica holds an entry.
//!
//! [`Store`] is the cloneable handle the config structs carry: a
//! `CacheStore` behind an `Arc` plus the textual address
//! (`DIR` | `tcp://host:port` | `tcp://a,tcp://b,...`) it was built
//! from, so the shard coordinator can re-serialize the store location
//! onto a child worker's command line (`--store <addr>`).
//!
//! Error contract (the integrity satellite): `get` returns `Ok(None)`
//! for *absent* and for *stale* entries (an older `version=` — expected
//! after upgrading the simulator; re-simulation heals it), and `Err`
//! for *corrupt* ones (checksum mismatch, truncation, garbage) — a
//! clean error naming the entry, never a panic and never silently
//! different metrics.

use std::collections::HashMap;
use std::fmt;
use std::fs;
use std::io::ErrorKind;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::sim::RunMetrics;

use super::netstore::NetStore;
use super::serde_kv::{self, MetricsError};

/// The store interface. Implementations must be shareable across the
/// sweep's worker threads (`Send + Sync`); all methods take `&self`.
pub trait CacheStore: Send + Sync {
    /// Load the entry for `fingerprint`: `Ok(Some)` on a current,
    /// intact entry; `Ok(None)` when absent or stale (older
    /// serialization version — re-simulating heals it); `Err` when the
    /// entry exists but is corrupt or unreadable.
    fn get(&self, fingerprint: &str) -> Result<Option<RunMetrics>, String>;

    /// Store (or overwrite) the entry for `fingerprint`.
    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String>;

    /// Every fingerprint currently stored, sorted.
    fn list(&self) -> Result<Vec<String>, String>;

    /// Cheap liveness probe — a network round-trip for remote stores,
    /// trivially `Ok` for local ones.
    fn ping(&self) -> Result<(), String> {
        Ok(())
    }

    /// Snapshot/compact any durability log behind the store (a no-op
    /// for stores without one). The cache server calls this once after
    /// a clean `--stop` shutdown.
    fn compact(&self) -> Result<(), String> {
        Ok(())
    }

    /// Observability counters for the fleet stats surface (the STATS
    /// opcode / `rainbow stats`). All-zero by default; `LogStore`
    /// reports its durability-log activity, `ReplStore` its
    /// degradation counters.
    fn obs(&self) -> StoreObs {
        StoreObs::default()
    }
}

/// Counters a store implementation exports for the fleet stats surface.
/// Fields a given backend has no machinery for stay zero.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreObs {
    /// Durability-log records appended ([`super::wal::LogStore`]).
    pub wal_appends: u64,
    /// Durability-log fsyncs issued before acks.
    pub wal_fsyncs: u64,
    /// Records replayed from the log at startup.
    pub wal_replayed: u64,
    /// Reads that succeeded despite at least one failed replica
    /// ([`super::replica::ReplStore`]).
    pub degraded_gets: u64,
    /// Writes acknowledged with less than full replication.
    pub degraded_puts: u64,
    /// Read-repair writes issued to replicas that had missed an entry.
    pub read_repairs: u64,
}

/// Which transport a [`Store`] handle wraps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    /// A directory of `<fingerprint>.kv` files ([`FsStore`]).
    Fs,
    /// An in-process map ([`MemStore`]).
    Mem,
    /// A `rainbow cache-server` reached over TCP (`NetStore`).
    Net,
    /// [`MemStore`] plus an append-only durability log
    /// ([`super::wal::LogStore`], `cache-server --mem --log PATH`).
    Log,
    /// A replicated set of cache servers behind a consistent-hash ring
    /// ([`super::replica::ReplStore`], `tcp://a,tcp://b,...`).
    Repl,
}

/// Cloneable handle to a [`CacheStore`], carrying the textual address
/// it was parsed from (what `Store::parse` accepts and what the shard
/// coordinator hands to child workers as `--store <addr>`).
#[derive(Clone)]
pub struct Store {
    addr: String,
    kind: StoreKind,
    dir: Option<PathBuf>,
    backend: Arc<dyn CacheStore>,
}

impl fmt::Debug for Store {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Store")
            .field("addr", &self.addr)
            .field("kind", &self.kind)
            .finish()
    }
}

impl Store {
    /// Directory-backed store (the default transport).
    pub fn fs(dir: impl Into<PathBuf>) -> Store {
        let dir = dir.into();
        Store {
            addr: dir.display().to_string(),
            kind: StoreKind::Fs,
            backend: Arc::new(FsStore::new(dir.clone())),
            dir: Some(dir),
        }
    }

    /// Fresh in-memory store (tests, `cache-server --mem`).
    pub fn mem() -> Store {
        Store {
            addr: "mem".to_string(),
            kind: StoreKind::Mem,
            dir: None,
            backend: Arc::new(MemStore::new()),
        }
    }

    /// Networked store talking to a cache server at `host:port`
    /// (default client timeouts; [`Store::from_net`] takes a tuned
    /// `NetStore`).
    pub fn net(hostport: &str) -> Store {
        Store::from_net(NetStore::new(hostport))
    }

    /// Networked store from an explicitly configured client.
    pub fn from_net(client: NetStore) -> Store {
        Store {
            addr: format!("tcp://{}", client.addr()),
            kind: StoreKind::Net,
            dir: None,
            backend: Arc::new(client),
        }
    }

    /// In-memory store backed by an append-only durability log
    /// (`cache-server --mem --log PATH`): the log is replayed here,
    /// and the returned stats say what survived. This handle never
    /// rides a child's `--store` argument — the log belongs to exactly
    /// one server process.
    pub fn logged(path: &Path)
                  -> Result<(Store, super::wal::ReplayStats), String> {
        let (backend, stats) = super::wal::LogStore::open(path)?;
        let store = Store {
            addr: format!("mem+log:{}", path.display()),
            kind: StoreKind::Log,
            dir: None,
            backend: Arc::new(backend),
        };
        Ok((store, stats))
    }

    /// Replicated store over N cache servers (consistent-hash
    /// placement, write-through, read-repair — see [`super::replica`]).
    /// The first endpoint doubles as the queue scheduler.
    pub fn repl(hostports: &[String]) -> Store {
        let clients: Vec<NetStore> =
            hostports.iter().map(|hp| NetStore::new(hp)).collect();
        let addr = hostports
            .iter()
            .map(|hp| format!("tcp://{hp}"))
            .collect::<Vec<_>>()
            .join(",");
        Store {
            addr,
            kind: StoreKind::Repl,
            dir: None,
            backend: Arc::new(super::replica::ReplStore::new(clients)),
        }
    }

    /// Parse the CLI `--store` form: `tcp://host:port` for a single
    /// cache server, `tcp://a,tcp://b,...` (every endpoint with its
    /// own prefix) for a replicated set, anything else (scheme-free)
    /// is a cache directory.
    pub fn parse(arg: &str) -> Result<Store, String> {
        let arg = arg.trim();
        if arg.is_empty() {
            return Err("store: empty address".to_string());
        }
        if arg.starts_with("tcp://") && arg.contains(',') {
            let mut hostports: Vec<String> = Vec::new();
            for part in arg.split(',') {
                let part = part.trim();
                let hp = part.strip_prefix("tcp://").ok_or_else(|| {
                    format!(
                        "store {arg:?}: every replica endpoint needs \
                         its own tcp:// prefix, got {part:?}")
                })?;
                tcp_hostport(hp).map_err(|_| {
                    format!(
                        "store {arg:?}: expected tcp://host:port for \
                         endpoint {part:?}")
                })?;
                if hostports.iter().any(|h| h == hp) {
                    return Err(format!(
                        "store {arg:?}: duplicate endpoint {part:?}"));
                }
                hostports.push(hp.to_string());
            }
            return Ok(Store::repl(&hostports));
        }
        if let Some(hp) = arg.strip_prefix("tcp://") {
            match tcp_hostport(hp) {
                Ok(hp) => Ok(Store::net(hp)),
                Err(()) => Err(format!(
                    "store {arg:?}: expected tcp://host:port")),
            }
        } else if arg.contains("://") {
            Err(format!(
                "store {arg:?}: unsupported scheme (use a directory \
                 path, tcp://host:port, or tcp://a,tcp://b,...)"))
        } else {
            Ok(Store::fs(PathBuf::from(arg)))
        }
    }

    /// The textual address this handle was built from — round-trips
    /// through [`Store::parse`] for fs/net stores, so it can ride a
    /// child worker's `--store` argument.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn kind(&self) -> StoreKind {
        self.kind
    }

    /// Whether operations cross a network (failures must be fatal, not
    /// silently degraded to local simulation). A replicated store is
    /// remote, but only errors when *every* placed replica fails — a
    /// single dead replica degrades with warnings instead.
    pub fn is_remote(&self) -> bool {
        matches!(self.kind, StoreKind::Net | StoreKind::Repl)
    }

    /// The `host:port` the job queue lives on: the server itself for a
    /// single `tcp://` store, the **first listed** endpoint for a
    /// replicated one (placement is order-independent, so the listing
    /// order is free to carry exactly this one meaning). `None` for
    /// local stores, which have no scheduler.
    pub fn scheduler_hostport(&self) -> Option<&str> {
        if !self.is_remote() {
            return None;
        }
        self.addr
            .split(',')
            .next()
            .and_then(|a| a.strip_prefix("tcp://"))
    }

    /// The backing directory, for fs stores only (shard layout
    /// defaults, upfront `create_dir_all`).
    pub fn fs_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    pub fn get(&self, fingerprint: &str)
               -> Result<Option<RunMetrics>, String> {
        self.backend.get(fingerprint)
    }

    pub fn put(&self, fingerprint: &str, metrics: &RunMetrics)
               -> Result<(), String> {
        self.backend.put(fingerprint, metrics)
    }

    pub fn list(&self) -> Result<Vec<String>, String> {
        self.backend.list()
    }

    pub fn ping(&self) -> Result<(), String> {
        self.backend.ping()
    }

    /// Snapshot/compact the durability log, if the backend keeps one.
    pub fn compact(&self) -> Result<(), String> {
        self.backend.compact()
    }

    /// The backend's observability counters (fleet stats surface).
    pub fn obs(&self) -> StoreObs {
        self.backend.obs()
    }
}

/// Validate a `host:port` endpoint (the part after `tcp://`): host
/// nonempty, port a valid u16. IPv6 splits on the LAST colon.
fn tcp_hostport(hp: &str) -> Result<&str, ()> {
    match hp.rsplit_once(':') {
        Some((host, port))
            if !host.is_empty() && port.parse::<u16>().is_ok() =>
        {
            Ok(hp)
        }
        _ => Err(()),
    }
}

/// Directory of `<fingerprint>.kv` entries — the on-disk layout every
/// release so far has used, unchanged.
pub struct FsStore {
    dir: PathBuf,
}

impl FsStore {
    pub fn new(dir: impl Into<PathBuf>) -> FsStore {
        FsStore { dir: dir.into() }
    }

    fn entry_path(&self, fingerprint: &str) -> PathBuf {
        self.dir.join(format!("{fingerprint}.kv"))
    }
}

impl CacheStore for FsStore {
    fn get(&self, fingerprint: &str)
           -> Result<Option<RunMetrics>, String> {
        let path = self.entry_path(fingerprint);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(format!("cache entry {}: {e}", path.display()))
            }
        };
        match serde_kv::metrics_from_kv_checked(&text) {
            Ok(m) => Ok(Some(m)),
            // Older-version entries are expected after upgrading the
            // simulator; a miss re-simulates and overwrites.
            Err(MetricsError::Stale { .. }) => Ok(None),
            Err(e) => Err(format!(
                "corrupt cache entry {}: {e}", path.display())),
        }
    }

    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String> {
        fs::create_dir_all(&self.dir).map_err(|e| {
            format!("cache dir {}: {e}", self.dir.display())
        })?;
        // Entries become visible atomically (written to a per-process
        // temp file, then renamed into place): the directory is shared
        // by concurrent sweeps and shard-worker processes by design,
        // and the merge path treats a torn entry as fatal corruption,
        // so a reader must never observe a half-written file. pid +
        // per-process sequence number keeps temp names unique across
        // processes AND across threads of one process.
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = self.dir.join(format!(
            "{fingerprint}.kv.tmp.{}.{}", std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)));
        let path = self.entry_path(fingerprint);
        fs::write(&tmp, serde_kv::metrics_to_kv(metrics))
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        fs::rename(&tmp, &path).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            format!("rename {} -> {}: {e}", tmp.display(), path.display())
        })
    }

    fn list(&self) -> Result<Vec<String>, String> {
        let rd = match fs::read_dir(&self.dir) {
            Ok(rd) => rd,
            // A store nobody has written to yet is empty, not broken.
            Err(e) if e.kind() == ErrorKind::NotFound => {
                return Ok(Vec::new())
            }
            Err(e) => {
                return Err(format!(
                    "cache dir {}: {e}", self.dir.display()))
            }
        };
        let mut out = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| {
                format!("cache dir {}: {e}", self.dir.display())
            })?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            // In-flight temp files end in `.tmp.<pid>.<seq>`, so the
            // `.kv` suffix alone distinguishes committed entries.
            if let Some(fp) = name.strip_suffix(".kv") {
                out.push(fp.to_string());
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Mutex-protected in-memory store: the conformance-test double and
/// the backing store of an ephemeral `cache-server --mem`.
#[derive(Default)]
pub struct MemStore {
    entries: Mutex<HashMap<String, RunMetrics>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }

    /// A poisoned mutex means a writer panicked mid-insert; surface it
    /// as a store error (the loud-but-clean contract) instead of
    /// propagating the panic into every later caller.
    fn locked(&self)
              -> Result<std::sync::MutexGuard<'_, HashMap<String, RunMetrics>>,
                        String> {
        self.entries
            .lock()
            .map_err(|_| "mem store: mutex poisoned by a panicked \
                          writer"
                .to_string())
    }
}

impl CacheStore for MemStore {
    fn get(&self, fingerprint: &str)
           -> Result<Option<RunMetrics>, String> {
        Ok(self.locked()?.get(fingerprint).cloned())
    }

    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String> {
        // Last write wins: concurrent writers of one fingerprint carry
        // identical metrics (determinism), same as the fs rename race.
        self.locked()?
            .insert(fingerprint.to_string(), metrics.clone());
        Ok(())
    }

    fn list(&self) -> Result<Vec<String>, String> {
        let mut out: Vec<String> =
            self.locked()?.keys().cloned().collect();
        out.sort();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_dirs_and_tcp_and_rejects_junk() {
        let s = Store::parse("target/some_cache").unwrap();
        assert_eq!(s.kind(), StoreKind::Fs);
        assert_eq!(s.addr(), "target/some_cache");
        assert!(s.fs_dir().is_some());
        let s = Store::parse("tcp://127.0.0.1:7700").unwrap();
        assert_eq!(s.kind(), StoreKind::Net);
        assert_eq!(s.addr(), "tcp://127.0.0.1:7700");
        assert!(s.fs_dir().is_none());
        assert!(s.is_remote());
        // IPv6 host:port splits on the LAST colon.
        assert!(Store::parse("tcp://[::1]:7700").is_ok());
        for bad in ["", "  ", "tcp://", "tcp://nohost", "tcp://:7700",
                    "tcp://h:notaport", "tcp://h:99999", "udp://h:1"] {
            assert!(Store::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn parse_accepts_replica_sets_and_rejects_malformed_ones() {
        let s = Store::parse("tcp://a:1,tcp://b:2,tcp://c:3").unwrap();
        assert_eq!(s.kind(), StoreKind::Repl);
        assert!(s.is_remote());
        assert_eq!(s.addr(), "tcp://a:1,tcp://b:2,tcp://c:3");
        // The first listed endpoint is the queue scheduler.
        assert_eq!(s.scheduler_hostport(), Some("a:1"));
        assert_eq!(
            Store::parse("tcp://s:7700").unwrap().scheduler_hostport(),
            Some("s:7700"));
        assert_eq!(Store::mem().scheduler_hostport(), None);
        for bad in [
            "tcp://a:1,b:2",          // missing per-endpoint prefix
            "tcp://a:1,tcp://b",      // no port
            "tcp://a:1,tcp://",       // empty endpoint
            "tcp://a:1,",             // trailing comma
            "tcp://a:1,tcp://a:1",    // duplicate endpoint
            "tcp://a:1,tcp://b:bad",  // bad port
        ] {
            assert!(Store::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn store_addr_round_trips_through_parse() {
        for arg in ["target/cache_rt", "tcp://127.0.0.1:7700",
                    "tcp://a:1,tcp://b:2,tcp://c:3"] {
            let s = Store::parse(arg).unwrap();
            let t = Store::parse(s.addr()).unwrap();
            assert_eq!(s.kind(), t.kind());
            assert_eq!(s.addr(), t.addr());
        }
    }
}
