//! Typed experiment specification. A [`RunSpec`] names everything that
//! can change a simulation's outcome: the workload/policy pair, the
//! capacity scale, the instruction budget, the RNG seed, the Rainbow
//! identification backend, and an ordered [`Overrides`] map of config
//! knobs (`rainbow.migration_threshold`, `nvm.read_cycles`, ...) applied
//! onto `Config::scaled` through the registry in [`crate::config::knobs`]
//! — the same validated path the tomlite loader uses.
//!
//! Specs have a canonical, order-independent, versioned serialization
//! (`report::serde_kv::{spec_to_kv, spec_from_kv}`) that serves as the
//! on-disk spec-file format and the CLI `--spec` surface, and an escaped
//! [`RunSpec::fingerprint`] that keys the results cache and the sweep
//! orchestrator's dedup.

use crate::config::knobs::{KnobValue, Overrides};
use crate::config::Config;
use crate::workloads::AppProfile;

/// Parameters that identify an experiment run (cache key).
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    pub workload: String,
    pub policy: String,
    /// Memory-capacity scale divisor vs the paper's Table IV.
    pub scale: u64,
    pub instructions: u64,
    pub seed: u64,
    /// Use the PJRT artifacts for Rainbow identification.
    pub accel: bool,
    /// Config-knob overrides applied onto `Config::scaled(scale)`.
    pub overrides: Overrides,
}

impl RunSpec {
    pub fn new(workload: &str, policy: &str) -> RunSpec {
        RunSpec {
            workload: workload.to_string(),
            policy: policy.to_string(),
            scale: 8,
            instructions: 4_000_000,
            seed: 0xEA7_BEEF,
            accel: false,
            overrides: Overrides::new(),
        }
    }

    // ------------------------------------------------------- builders

    pub fn with_workload(mut self, workload: &str) -> RunSpec {
        self.workload = workload.to_string();
        self
    }

    pub fn with_policy(mut self, policy: &str) -> RunSpec {
        self.policy = policy.to_string();
        self
    }

    pub fn with_scale(mut self, scale: u64) -> RunSpec {
        self.scale = scale;
        self
    }

    pub fn with_instructions(mut self, instructions: u64) -> RunSpec {
        self.instructions = instructions;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> RunSpec {
        self.seed = seed;
        self
    }

    pub fn with_accel(mut self, accel: bool) -> RunSpec {
        self.accel = accel;
        self
    }

    /// Set a config-knob override. Panicking sugar for statically known
    /// keys (examples, benches, figure emitters); CLI/spec-file input
    /// goes through [`RunSpec::try_with`] / [`RunSpec::try_set_arg`].
    pub fn with(mut self, key: &str, value: impl Into<KnobValue>) -> RunSpec {
        self.overrides
            .set(key, value.into())
            .unwrap_or_else(|e| panic!("RunSpec::with: {e}"));
        self
    }

    /// [`RunSpec::with`] from a value's textual form — the panicking
    /// sugar for knob values that arrive as runtime strings from an
    /// already-validated surface (e.g. profile names the `backends`
    /// CLI/figure checked against the catalog).
    pub fn with_raw(mut self, key: &str, raw: &str) -> RunSpec {
        self.overrides
            .set_raw(key, raw)
            .unwrap_or_else(|e| panic!("RunSpec::with_raw: {e}"));
        self
    }

    /// Fallible [`RunSpec::with`] — unknown keys and ill-typed values
    /// come back as `Err` instead of panicking.
    pub fn try_with(
        mut self, key: &str, value: KnobValue,
    ) -> Result<RunSpec, String> {
        self.overrides.set(key, value)?;
        Ok(self)
    }

    /// Parse one `key=value` argument (the CLI `--set` form) into the
    /// overrides map, validating the key against the knob registry.
    pub fn try_set_arg(mut self, arg: &str) -> Result<RunSpec, String> {
        let (k, v) = arg
            .split_once('=')
            .ok_or_else(|| format!("expected key=value, got {arg:?}"))?;
        self.overrides.set_raw(k.trim(), v.trim())?;
        Ok(self)
    }

    // ------------------------------------------------------- identity

    /// The scaled config with this spec's overrides applied.
    pub fn config(&self) -> Config {
        let mut cfg = Config::scaled(self.scale);
        self.overrides.apply_to(&mut cfg);
        cfg
    }

    /// Stable identity of this run: every knob that can change the
    /// simulation's outcome. Keys both the on-disk results cache and the
    /// in-memory result sharing of the parallel sweep orchestrator.
    ///
    /// Fields are joined with `_` but individually %-escaped (workload
    /// and policy names may themselves contain `_`), so the scalar
    /// fields are encoded exactly and cannot alias one another.
    /// Overrides contribute their count plus a 64-bit FNV-1a hash of
    /// their canonical serialization — collision-resistant (~2^-64 per
    /// pair), not collision-proof; the exact override map lives in the
    /// spec's kv serialization. The `v2` prefix versions the scheme.
    pub fn fingerprint(&self) -> String {
        let mut f = format!(
            "v2_{}_{}_s{}_i{}_r{}",
            escape_field(&self.workload), escape_field(&self.policy),
            self.scale, self.instructions, self.seed,
        );
        if self.accel {
            f.push_str("_accel");
        }
        if !self.overrides.is_empty() {
            f.push_str(&format!(
                "_o{}x{:016x}",
                self.overrides.len(),
                fnv1a(self.overrides.canonical().as_bytes()),
            ));
        }
        f
    }

    /// Scaled footprint of the workload (for Fig. 11 normalization).
    pub fn footprint_bytes(&self) -> u64 {
        match AppProfile::by_name(&self.workload) {
            Some(p) => p.scaled(self.scale).footprint,
            None => {
                // A mix: sum of its apps.
                crate::workloads::mixes()
                    .into_iter()
                    .find(|(n, _)| n.eq_ignore_ascii_case(&self.workload))
                    .map(|(_, apps)| {
                        apps.iter()
                            .map(|a| {
                                AppProfile::by_name(a)
                                    .unwrap()
                                    .scaled(self.scale)
                                    .footprint
                            })
                            .sum()
                    })
                    .unwrap_or(0)
            }
        }
    }
}

/// Escape a fingerprint field so the `_` join is unambiguous and the
/// result is filesystem-safe: alphanumerics plus `.`/`-` pass through,
/// everything else (including `_` and `%`) becomes `%XX`.
fn escape_field(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'.' | b'-' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// FNV-1a 64-bit (dependency-free stable hash for override maps and the
/// spec-list checksum in `report::serde_kv`).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let s = RunSpec::new("mcf", "rainbow")
            .with_scale(64)
            .with_instructions(60_000)
            .with_seed(7)
            .with("rainbow.interval_cycles", 100_000u64)
            .with("rainbow.top_n", 16u64);
        assert_eq!(s.scale, 64);
        let cfg = s.config();
        assert_eq!(cfg.interval_cycles, 100_000);
        assert_eq!(cfg.top_n, 16);
    }

    #[test]
    fn overrides_flow_into_config() {
        let base = RunSpec::new("mcf", "rainbow").config();
        let s = RunSpec::new("mcf", "rainbow")
            .with("rainbow.migration_threshold", base.migration_threshold * 4.0)
            .with("nvm.read_cycles", base.nvm.read_cycles * 2);
        let cfg = s.config();
        assert_eq!(cfg.migration_threshold, base.migration_threshold * 4.0);
        assert_eq!(cfg.nvm.read_cycles, base.nvm.read_cycles * 2);
    }

    #[test]
    #[should_panic(expected = "unknown config knob")]
    fn with_unknown_knob_panics() {
        let _ = RunSpec::new("mcf", "rainbow").with("no.such_knob", 1u64);
    }

    #[test]
    fn try_set_arg_validates() {
        let s = RunSpec::new("mcf", "rainbow");
        assert!(s.clone().try_set_arg("rainbow.top_n=32").is_ok());
        assert!(s.clone().try_set_arg("rainbow.top_n").is_err());
        assert!(s.clone().try_set_arg("bogus.key=1").is_err());
        assert!(s.clone().try_set_arg("rainbow.top_n=abc").is_err());
    }

    #[test]
    fn fingerprint_distinguishes_every_field() {
        let s = RunSpec::new("mcf", "rainbow");
        let fp = s.fingerprint();
        assert_ne!(fp, s.clone().with_workload("soplex").fingerprint());
        assert_ne!(fp, s.clone().with_policy("flat").fingerprint());
        assert_ne!(fp, s.clone().with_scale(16).fingerprint());
        assert_ne!(fp, s.clone().with_instructions(1).fingerprint());
        assert_ne!(fp, s.clone().with_seed(1).fingerprint());
        assert_ne!(fp, s.clone().with_accel(true).fingerprint());
        assert_ne!(fp,
                   s.clone().with("rainbow.top_n", 32u64).fingerprint());
    }

    #[test]
    fn backend_profiles_ride_the_override_surface() {
        let s = RunSpec::new("mcf", "rainbow")
            .with("nvm.profile", "optane-dcpmm")
            .with_raw("dram.profile", "hbm-like");
        let cfg = s.config();
        assert_eq!(cfg.nvm.tech, crate::config::MemTech::Optane);
        assert_eq!(cfg.dram.tech, crate::config::MemTech::Hbm);
        // Two specs differing only in the backend must never share a
        // cache entry.
        let other = s.clone().with("nvm.profile", "cxl-remote");
        assert_ne!(s.fingerprint(), other.fingerprint());
    }

    #[test]
    fn fingerprint_underscore_fields_cannot_collide() {
        // Regression: the old format!-joined fingerprint collided when
        // the `_` field delimiter also appeared inside names — e.g.
        // ("a_b", "c") and ("a", "b_c") serialized identically.
        let a = RunSpec::new("a_b", "c").fingerprint();
        let b = RunSpec::new("a", "b_c").fingerprint();
        assert_ne!(a, b);
        // And fingerprints stay filesystem-safe.
        assert!(a.bytes().all(|c| c.is_ascii_alphanumeric()
            || c == b'_' || c == b'.' || c == b'-' || c == b'%'));
    }

    #[test]
    fn fingerprint_stable_under_override_insertion_order() {
        let a = RunSpec::new("mcf", "rainbow")
            .with("rainbow.top_n", 32u64)
            .with("nvm.read_cycles", 124u64);
        let b = RunSpec::new("mcf", "rainbow")
            .with("nvm.read_cycles", 124u64)
            .with("rainbow.top_n", 32u64);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a, b);
    }

    #[test]
    fn footprints_resolve_for_apps_and_mixes() {
        assert!(RunSpec::new("mcf", "flat").footprint_bytes() > 0);
        assert!(RunSpec::new("mix1", "flat").footprint_bytes() > 0);
    }
}
