//! Key=value (de)serialization for every on-disk experiment artifact:
//! `RunMetrics` (the results-cache entry format, one
//! `<fingerprint>.kv` file per unique spec), `RunSpec` (the canonical
//! spec-file format behind the CLI's `--spec`/`--save-spec`), and
//! multi-spec **spec-list** files (the shard-worker's `--specs`
//! surface, written by the shard coordinator in `report::shard`).
//! serde is unavailable offline; this is deliberately dumb and
//! versioned.
//!
//! Versioning contract: each format carries an explicit version key
//! ([`METRICS_VERSION`] as `version=`, [`SPEC_VERSION`] as
//! `specversion=`, [`SPEC_LIST_VERSION`] as `speclistversion=`) that
//! is bumped on any incompatible change. Readers are strict: a missing
//! or mismatched version is a parse failure, never a silent
//! best-effort load — a stale cache entry re-simulates, a stale spec
//! file errors out before any fan-out. The spec serialization is
//! canonical (fixed field order, overrides sorted by key), which is
//! what lets [`RunSpec::fingerprint`] hash it for cache identity.

use crate::report::RunSpec;
use crate::sim::metrics::{RunMetrics, RuntimeBreakdown, XlatBreakdown};

/// Version of the results-cache entry serialization.
/// v6: migration and page-walk latency quantiles (p50/p95/p99) from
/// the always-on telemetry histograms.
/// v5: versioned header + FNV-1a checksum line (same integrity
/// treatment as spec-list files — a torn or tampered entry fails
/// loudly instead of parsing into silently different metrics).
/// v4: per-tier row-buffer hit/miss counters (backend comparisons).
pub const METRICS_VERSION: u64 = 6;

// Internal alias so the (de)serializers below read naturally.
const VERSION: u64 = METRICS_VERSION;

/// Version of the spec-file serialization (bump on incompatible change).
pub const SPEC_VERSION: u64 = 1;

/// Version of the multi-spec list-file serialization.
pub const SPEC_LIST_VERSION: u64 = 1;

/// Version of the job-queue wire records (`report::queue`): lease
/// requests/replies, completion requests, and queue-stat snapshots
/// exchanged over the LEASE/COMPLETE/REQUEUE/QSTAT opcodes. Bump on
/// any incompatible change (the structs are schema-locked against it).
/// v3: `QueueStat` gains `expired` and `requeued` counters so
/// lease-expiry churn is visible in `QSTAT`.
/// v2: `CompleteRequest` carries an optional declared entry checksum so
/// a replicated store's scheduler can verify completions for entries
/// the consistent-hash ring placed on *other* replicas.
pub const QUEUE_WIRE_VERSION: u64 = 3;

/// Version of the server-stats snapshot (`report::netstore::ServerStats`)
/// returned by the `STATS` opcode. Bump on any incompatible change
/// (the struct is schema-locked against it).
pub const STATS_WIRE_VERSION: u64 = 1;

/// Version of the cache-server durability-log format (`report::wal`):
/// the header line (`cachelogversion=`) and the checksummed,
/// length-prefixed `put=` record framing around [`metrics_to_kv`]
/// payloads. Bump on any incompatible change (the [`report::wal::LogRecord`]
/// framing struct is schema-locked against it).
///
/// [`report::wal::LogRecord`]: crate::report::wal::LogRecord
pub const CACHE_LOG_VERSION: u64 = 1;

/// Canonical, order-independent serialization of a [`RunSpec`]: one
/// `key=value` per line, fixed field order, overrides as sorted
/// `set.<knob>` lines. Triple duty: on-disk spec-file format, `--spec`
/// CLI surface, and the content the fingerprint's override hash covers.
pub fn spec_to_kv(s: &RunSpec) -> String {
    let mut out = String::with_capacity(256);
    let mut put = |k: &str, v: String| {
        out.push_str(k);
        out.push('=');
        out.push_str(&v);
        out.push('\n');
    };
    put("specversion", SPEC_VERSION.to_string());
    put("workload", s.workload.clone());
    put("policy", s.policy.clone());
    put("scale", s.scale.to_string());
    put("instructions", s.instructions.to_string());
    put("seed", s.seed.to_string());
    put("accel", if s.accel { "1" } else { "0" }.to_string());
    for (k, v) in s.overrides.iter() {
        put(&format!("set.{k}"), v.to_string());
    }
    out
}

/// Parse a spec file. Strict by design: the version must match, every
/// key must be known (unknown `set.` knobs are rejected through the
/// registry, same as CLI `--set`), and workload/policy are required —
/// a bad spec file fails here, before any sweep fan-out.
pub fn spec_from_kv(text: &str) -> Result<RunSpec, String> {
    let mut s = RunSpec::new("", "");
    let mut version = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("spec line {}: expected key=value, got {line:?}",
                    lineno + 1)
        })?;
        let (k, v) = (k.trim(), v.trim());
        let err = |what: &str| {
            format!("spec line {}: {k}: expected {what}, got {v:?}",
                    lineno + 1)
        };
        match k {
            "specversion" => {
                version = Some(v.parse::<u64>().map_err(|_| err("integer"))?)
            }
            "workload" => s.workload = v.to_string(),
            "policy" => s.policy = v.to_string(),
            "scale" => s.scale = v.parse().map_err(|_| err("integer"))?,
            "instructions" => {
                s.instructions = v.parse().map_err(|_| err("integer"))?
            }
            "seed" => s.seed = v.parse().map_err(|_| err("integer"))?,
            "accel" => {
                s.accel = match v {
                    "1" | "true" => true,
                    "0" | "false" => false,
                    _ => return Err(err("0/1")),
                }
            }
            _ => match k.strip_prefix("set.") {
                Some(knob) => s
                    .overrides
                    .set_raw(knob, v)
                    .map_err(|e| format!("spec line {}: {e}", lineno + 1))?,
                None => {
                    return Err(format!(
                        "spec line {}: unknown spec key {k:?}", lineno + 1))
                }
            },
        }
    }
    match version {
        Some(SPEC_VERSION) => {}
        Some(v) => {
            return Err(format!(
                "spec version {v} unsupported (expected {SPEC_VERSION})"))
        }
        None => return Err("spec file missing specversion".to_string()),
    }
    if s.workload.is_empty() || s.policy.is_empty() {
        return Err("spec file must set workload and policy".to_string());
    }
    Ok(s)
}

/// Serialize a spec list: a versioned header (`speclistversion`,
/// `count`, `checksum`) followed by one [`spec_to_kv`] block per spec,
/// each introduced by a `---` separator line. The declared `count`
/// catches whole-block loss; the FNV-1a `checksum` over the specs'
/// canonical serializations catches mid-line truncation and value
/// tampering (a cut `instructions=4000000` would otherwise still parse
/// as a valid, silently different spec).
pub fn specs_to_kv(specs: &[RunSpec]) -> String {
    let mut out = format!(
        "speclistversion={SPEC_LIST_VERSION}\ncount={}\nchecksum={:016x}\n",
        specs.len(), spec_list_checksum(specs));
    for s in specs {
        out.push_str("---\n");
        out.push_str(&spec_to_kv(s));
    }
    out
}

/// Checksum over the canonical serialization of every spec, in order —
/// formatting-insensitive (comments and whitespace in a hand-edited
/// file don't matter) but value-sensitive.
fn spec_list_checksum(specs: &[RunSpec]) -> u64 {
    let mut bytes = Vec::new();
    for s in specs {
        bytes.extend_from_slice(spec_to_kv(s).as_bytes());
    }
    crate::report::spec::fnv1a(&bytes)
}

/// Parse a spec list. Strict like [`spec_from_kv`]: the list version
/// must match, every spec block must parse (with its own
/// `specversion`), and the block count must equal the header's declared
/// `count` — a truncated or garbled shard file is an error naming the
/// offending block, never a silently shorter sweep.
pub fn specs_from_kv(text: &str) -> Result<Vec<RunSpec>, String> {
    let mut sections: Vec<Vec<&str>> = vec![Vec::new()];
    for raw in text.lines() {
        if raw.trim() == "---" {
            sections.push(Vec::new());
        } else {
            sections.last_mut().unwrap().push(raw);
        }
    }
    let mut version = None;
    let mut count = None;
    let mut checksum = None;
    for raw in &sections[0] {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("spec list header: expected key=value, got {line:?}")
        })?;
        match (k.trim(), v.trim()) {
            ("speclistversion", v) => {
                version = Some(v.parse::<u64>().map_err(|_| {
                    format!("spec list: bad speclistversion {v:?}")
                })?)
            }
            ("count", v) => {
                count = Some(v.parse::<usize>().map_err(|_| {
                    format!("spec list: bad count {v:?}")
                })?)
            }
            ("checksum", v) => {
                checksum = Some(u64::from_str_radix(v, 16).map_err(|_| {
                    format!("spec list: bad checksum {v:?}")
                })?)
            }
            (k, _) => {
                return Err(format!("spec list header: unknown key {k:?}"))
            }
        }
    }
    match version {
        Some(SPEC_LIST_VERSION) => {}
        Some(v) => {
            return Err(format!(
                "spec list version {v} unsupported \
                 (expected {SPEC_LIST_VERSION})"))
        }
        None => {
            return Err("spec list missing speclistversion \
                        (is this a spec-list .kv file?)".to_string())
        }
    }
    let count = count
        .ok_or("spec list missing count (truncated header?)")?;
    // The header is untrusted input: cap the pre-allocation by the
    // actual block count so an absurd declared count takes the
    // mismatch-error path below instead of aborting the allocator.
    let mut specs = Vec::with_capacity(count.min(sections.len()));
    for (i, sec) in sections[1..].iter().enumerate() {
        let body = sec.join("\n");
        specs.push(spec_from_kv(&body).map_err(|e| {
            format!("spec block {} of {count}: {e}", i + 1)
        })?);
    }
    if specs.len() != count {
        return Err(format!(
            "spec list truncated or garbled: header declares {count} \
             specs, found {} blocks", specs.len()));
    }
    let declared = checksum
        .ok_or("spec list missing checksum (truncated header?)")?;
    let actual = spec_list_checksum(&specs);
    if actual != declared {
        return Err(format!(
            "spec list checksum mismatch (declared {declared:016x}, \
             content hashes to {actual:016x}): file corrupt or \
             truncated mid-value"));
    }
    Ok(specs)
}

/// Serialize metrics as a versioned, checksummed cache entry: a
/// two-line header (`version=`, `checksum=` — FNV-1a over every byte
/// after the checksum line) followed by the flat field body. The
/// checksum gives cache entries the same torn/tampered-file detection
/// as spec-list files: a half-written or bit-flipped entry is a loud
/// [`MetricsError::Corrupt`], never silently different metrics.
pub fn metrics_to_kv(m: &RunMetrics) -> String {
    let body = metrics_body_kv(m);
    format!("version={VERSION}\nchecksum={:016x}\n{body}",
            crate::report::spec::fnv1a(body.as_bytes()))
}

fn metrics_body_kv(m: &RunMetrics) -> String {
    let mut s = String::with_capacity(1024);
    let mut put = |k: &str, v: String| {
        s.push_str(k);
        s.push('=');
        s.push_str(&v);
        s.push('\n');
    };
    put("instructions", m.instructions.to_string());
    put("cycles", m.cycles.to_string());
    put("core_cycles", m.core_cycles.to_string());
    put("mem_ops", m.mem_ops.to_string());
    put("tlb_miss_4k", m.tlb_miss_4k.to_string());
    put("tlb_miss_2m", m.tlb_miss_2m.to_string());
    put("tlb_miss_cycles", m.tlb_miss_cycles.to_string());
    put("x_tlb", m.xlat.tlb_cycles.to_string());
    put("x_bitmap", m.xlat.bitmap_cycles.to_string());
    put("x_ptw", m.xlat.ptw_cycles.to_string());
    put("x_sptw", m.xlat.sptw_cycles.to_string());
    put("x_remap", m.xlat.remap_cycles.to_string());
    put("sp_hit_rate", format!("{:.6}", m.sp_hit_rate));
    put("bitmap_hits", m.bitmap_hits.to_string());
    put("bitmap_misses", m.bitmap_misses.to_string());
    put("remap_reads", m.remap_reads.to_string());
    put("migrations", m.migrations.to_string());
    put("migrated_bytes", m.migrated_bytes.to_string());
    put("writebacks", m.writebacks.to_string());
    put("writeback_bytes", m.writeback_bytes.to_string());
    put("shootdowns", m.shootdowns.to_string());
    put("rt_migration", m.rt.migration_cycles.to_string());
    put("rt_shootdown", m.rt.shootdown_cycles.to_string());
    put("rt_clflush", m.rt.clflush_cycles.to_string());
    put("rt_identify", m.rt.identify_cycles.to_string());
    put("dram_reads", m.dram_reads.to_string());
    put("dram_writes", m.dram_writes.to_string());
    put("nvm_reads", m.nvm_reads.to_string());
    put("nvm_writes", m.nvm_writes.to_string());
    put("dram_row_hits", m.dram_row_hits.to_string());
    put("dram_row_misses", m.dram_row_misses.to_string());
    put("nvm_row_hits", m.nvm_row_hits.to_string());
    put("nvm_row_misses", m.nvm_row_misses.to_string());
    put("energy_pj", format!("{:.3}", m.energy_pj));
    put("mem_stall_cycles", m.mem_stall_cycles.to_string());
    put("llc_misses", m.llc_misses.to_string());
    put("mig_lat_p50", m.mig_lat_p50.to_string());
    put("mig_lat_p95", m.mig_lat_p95.to_string());
    put("mig_lat_p99", m.mig_lat_p99.to_string());
    put("ptw_lat_p50", m.ptw_lat_p50.to_string());
    put("ptw_lat_p95", m.ptw_lat_p95.to_string());
    put("ptw_lat_p99", m.ptw_lat_p99.to_string());
    s
}

/// Why a metrics entry failed to load. The two cases demand opposite
/// handling: a *stale* entry (older `version=`) is the expected result
/// of upgrading the simulator — stores treat it as a miss and
/// re-simulation heals it — while a *corrupt* entry (bad checksum,
/// truncated header, garbled body) means the bytes themselves are
/// wrong and must be reported, never silently re-run over.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricsError {
    /// Entry written by an older (or newer) serialization version.
    Stale { found: u64 },
    /// Truncated, tampered, or not a metrics entry at all.
    Corrupt(String),
}

impl std::fmt::Display for MetricsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricsError::Stale { found } => write!(
                f, "stale metrics version {found} (current {VERSION})"),
            MetricsError::Corrupt(why) => write!(f, "{why}"),
        }
    }
}

/// Lenient load: `Some` on a current, intact entry; `None` otherwise.
/// Kept for callers that only need hit-or-miss; integrity-sensitive
/// paths (the stores, the shard merge) use
/// [`metrics_from_kv_checked`] to distinguish stale from corrupt.
pub fn metrics_from_kv(text: &str) -> Option<RunMetrics> {
    metrics_from_kv_checked(text).ok()
}

/// Strict load of a metrics cache entry: the `version=` line must lead
/// and match [`METRICS_VERSION`] (else [`MetricsError::Stale`]), the
/// `checksum=` line must follow and match the FNV-1a hash of the
/// remaining bytes, and every body line must parse — anything else is
/// [`MetricsError::Corrupt`] naming what broke.
pub fn metrics_from_kv_checked(text: &str)
                               -> Result<RunMetrics, MetricsError> {
    use MetricsError::Corrupt;
    let (vline, rest) = text.split_once('\n').ok_or_else(|| {
        Corrupt("truncated entry: missing version header".to_string())
    })?;
    let version = vline
        .strip_prefix("version=")
        .and_then(|v| v.trim().parse::<u64>().ok())
        .ok_or_else(|| {
            Corrupt(format!(
                "first line must be version=N, got {vline:?}"))
        })?;
    if version != VERSION {
        return Err(MetricsError::Stale { found: version });
    }
    let (cline, body) = rest.split_once('\n').ok_or_else(|| {
        Corrupt("truncated entry: missing checksum header".to_string())
    })?;
    let declared = cline
        .strip_prefix("checksum=")
        .and_then(|c| u64::from_str_radix(c.trim(), 16).ok())
        .ok_or_else(|| {
            Corrupt(format!(
                "second line must be checksum=HEX, got {cline:?}"))
        })?;
    let actual = crate::report::spec::fnv1a(body.as_bytes());
    if actual != declared {
        return Err(Corrupt(format!(
            "checksum mismatch (declared {declared:016x}, content \
             hashes to {actual:016x}): entry torn or tampered")));
    }
    let mut m = RunMetrics::default();
    for line in body.lines() {
        let (k, v) = line.split_once('=').ok_or_else(|| {
            Corrupt(format!("expected key=value, got {line:?}"))
        })?;
        let u = || {
            v.parse::<u64>().map_err(|_| {
                Corrupt(format!("{k}: expected integer, got {v:?}"))
            })
        };
        let f = || {
            v.parse::<f64>().map_err(|_| {
                Corrupt(format!("{k}: expected float, got {v:?}"))
            })
        };
        match k {
            "instructions" => m.instructions = u()?,
            "cycles" => m.cycles = u()?,
            "core_cycles" => m.core_cycles = u()?,
            "mem_ops" => m.mem_ops = u()?,
            "tlb_miss_4k" => m.tlb_miss_4k = u()?,
            "tlb_miss_2m" => m.tlb_miss_2m = u()?,
            "tlb_miss_cycles" => m.tlb_miss_cycles = u()?,
            "x_tlb" => m.xlat.tlb_cycles = u()?,
            "x_bitmap" => m.xlat.bitmap_cycles = u()?,
            "x_ptw" => m.xlat.ptw_cycles = u()?,
            "x_sptw" => m.xlat.sptw_cycles = u()?,
            "x_remap" => m.xlat.remap_cycles = u()?,
            "sp_hit_rate" => m.sp_hit_rate = f()?,
            "bitmap_hits" => m.bitmap_hits = u()?,
            "bitmap_misses" => m.bitmap_misses = u()?,
            "remap_reads" => m.remap_reads = u()?,
            "migrations" => m.migrations = u()?,
            "migrated_bytes" => m.migrated_bytes = u()?,
            "writebacks" => m.writebacks = u()?,
            "writeback_bytes" => m.writeback_bytes = u()?,
            "shootdowns" => m.shootdowns = u()?,
            "rt_migration" => m.rt.migration_cycles = u()?,
            "rt_shootdown" => m.rt.shootdown_cycles = u()?,
            "rt_clflush" => m.rt.clflush_cycles = u()?,
            "rt_identify" => m.rt.identify_cycles = u()?,
            "dram_reads" => m.dram_reads = u()?,
            "dram_writes" => m.dram_writes = u()?,
            "nvm_reads" => m.nvm_reads = u()?,
            "nvm_writes" => m.nvm_writes = u()?,
            "dram_row_hits" => m.dram_row_hits = u()?,
            "dram_row_misses" => m.dram_row_misses = u()?,
            "nvm_row_hits" => m.nvm_row_hits = u()?,
            "nvm_row_misses" => m.nvm_row_misses = u()?,
            "energy_pj" => m.energy_pj = f()?,
            "mem_stall_cycles" => m.mem_stall_cycles = u()?,
            "llc_misses" => m.llc_misses = u()?,
            "mig_lat_p50" => m.mig_lat_p50 = u()?,
            "mig_lat_p95" => m.mig_lat_p95 = u()?,
            "mig_lat_p99" => m.mig_lat_p99 = u()?,
            "ptw_lat_p50" => m.ptw_lat_p50 = u()?,
            "ptw_lat_p95" => m.ptw_lat_p95 = u()?,
            "ptw_lat_p99" => m.ptw_lat_p99 = u()?,
            _ => {} // forward-compatible: ignore unknown keys
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        RunMetrics {
            instructions: 123,
            cycles: 456,
            core_cycles: 3648,
            mem_ops: 78,
            tlb_miss_4k: 9,
            tlb_miss_2m: 8,
            tlb_miss_cycles: 1000,
            xlat: XlatBreakdown {
                tlb_cycles: 1, bitmap_cycles: 2, ptw_cycles: 3,
                sptw_cycles: 4, remap_cycles: 5,
            },
            sp_hit_rate: 0.991,
            bitmap_hits: 10,
            bitmap_misses: 2,
            remap_reads: 3,
            migrations: 4,
            migrated_bytes: 4096,
            writebacks: 1,
            writeback_bytes: 8,
            shootdowns: 1,
            rt: RuntimeBreakdown {
                migration_cycles: 11, shootdown_cycles: 12,
                clflush_cycles: 13, identify_cycles: 14,
            },
            dram_reads: 20,
            dram_writes: 21,
            nvm_reads: 22,
            nvm_writes: 23,
            dram_row_hits: 30,
            dram_row_misses: 31,
            nvm_row_hits: 32,
            nvm_row_misses: 33,
            energy_pj: 1234.5,
            mem_stall_cycles: 999,
            llc_misses: 55,
            mig_lat_p50: 511,
            mig_lat_p95: 1023,
            mig_lat_p99: 2047,
            ptw_lat_p50: 31,
            ptw_lat_p95: 63,
            ptw_lat_p99: 127,
        }
    }

    #[test]
    fn roundtrip_preserves_all_fields() {
        let m = sample();
        let kv = metrics_to_kv(&m);
        let n = metrics_from_kv(&kv).unwrap();
        assert_eq!(format!("{m:?}"), format!("{n:?}"));
    }

    #[test]
    fn version_mismatch_is_stale_not_corrupt() {
        let kv = metrics_to_kv(&sample()).replace(
            &format!("version={VERSION}"), "version=0");
        assert!(metrics_from_kv(&kv).is_none());
        assert!(matches!(metrics_from_kv_checked(&kv),
                         Err(MetricsError::Stale { found: 0 })));
    }

    #[test]
    fn garbage_rejected() {
        assert!(metrics_from_kv("not a kv file").is_none());
        assert!(matches!(metrics_from_kv_checked("not a kv file"),
                         Err(MetricsError::Corrupt(_))));
    }

    #[test]
    fn tampered_value_caught_by_checksum() {
        // A mid-line cut or bit flip that still parses as a (different)
        // integer must be caught by the checksum, not slip through.
        let kv = metrics_to_kv(&sample()).replace("cycles=456",
                                                  "cycles=4");
        match metrics_from_kv_checked(&kv) {
            Err(MetricsError::Corrupt(e)) => {
                assert!(e.contains("checksum mismatch"), "got: {e}")
            }
            other => panic!("tampered entry must be Corrupt, got {other:?}"),
        }
        assert!(metrics_from_kv(&kv).is_none());
    }

    #[test]
    fn truncated_entries_rejected() {
        let kv = metrics_to_kv(&sample());
        // Cut mid-body: the checksum no longer matches.
        match metrics_from_kv_checked(&kv[..kv.len() - 10]) {
            Err(MetricsError::Corrupt(e)) => {
                assert!(e.contains("checksum"), "got: {e}")
            }
            other => panic!("truncated entry must be Corrupt, got {other:?}"),
        }
        // Header-only truncations name the missing piece.
        let v_bare = format!("version={VERSION}");
        let v_line = format!("version={VERSION}\n");
        for frag in ["", v_bare.as_str(), v_line.as_str()] {
            assert!(matches!(metrics_from_kv_checked(frag),
                             Err(MetricsError::Corrupt(_))),
                    "fragment {frag:?} must be Corrupt");
        }
    }

    #[test]
    fn entry_header_leads_and_checksum_covers_the_body() {
        let kv = metrics_to_kv(&sample());
        let mut lines = kv.lines();
        assert_eq!(lines.next(), Some(format!("version={VERSION}").as_str()));
        assert!(lines.next().unwrap().starts_with("checksum="),
                "checksum must be the second line");
    }

    fn sample_spec() -> RunSpec {
        RunSpec::new("mix2", "rainbow")
            .with_scale(16)
            .with_instructions(123_456)
            .with_seed(99)
            .with("rainbow.migration_threshold", 512.5)
            .with("nvm.read_cycles", 124u64)
    }

    #[test]
    fn spec_roundtrip_preserves_identity() {
        let s = sample_spec();
        let kv = spec_to_kv(&s);
        let t = spec_from_kv(&kv).unwrap();
        assert_eq!(s, t);
        assert_eq!(s.fingerprint(), t.fingerprint());
    }

    #[test]
    fn spec_kv_is_canonical_under_override_order() {
        let a = RunSpec::new("mcf", "flat")
            .with("rainbow.top_n", 8u64)
            .with("dram.read_cycles", 50u64);
        let b = RunSpec::new("mcf", "flat")
            .with("dram.read_cycles", 50u64)
            .with("rainbow.top_n", 8u64);
        assert_eq!(spec_to_kv(&a), spec_to_kv(&b));
    }

    #[test]
    fn spec_profile_overrides_round_trip() {
        let s = RunSpec::new("mcf", "rainbow")
            .with("nvm.profile", "optane-dcpmm")
            .with("dram.profile", "hbm-like");
        let kv = spec_to_kv(&s);
        assert!(kv.contains("set.nvm.profile=optane-dcpmm"), "{kv}");
        let t = spec_from_kv(&kv).unwrap();
        assert_eq!(s, t);
        assert_eq!(s.fingerprint(), t.fingerprint());
        // Unknown profile names are rejected at parse time.
        assert!(spec_from_kv(
            "specversion=1\nworkload=a\npolicy=b\nset.nvm.profile=zzz")
            .is_err());
    }

    #[test]
    fn spec_comments_and_blanks_allowed() {
        let kv = format!("# a comment\n\n{}", spec_to_kv(&sample_spec()));
        assert!(spec_from_kv(&kv).is_ok());
    }

    #[test]
    fn spec_list_roundtrip_preserves_order_and_identity() {
        let specs = vec![
            sample_spec(),
            RunSpec::new("mcf", "flat"),
            RunSpec::new("GUPS", "hscc2m")
                .with("nvm.profile", "optane-dcpmm")
                .with("rainbow.top_n", 8u64),
        ];
        let kv = specs_to_kv(&specs);
        let back = specs_from_kv(&kv).unwrap();
        assert_eq!(specs, back);
        for (a, b) in specs.iter().zip(&back) {
            assert_eq!(a.fingerprint(), b.fingerprint());
        }
    }

    #[test]
    fn spec_list_empty_and_comments_ok() {
        let back = specs_from_kv(&specs_to_kv(&[])).unwrap();
        assert!(back.is_empty());
        let text = format!("# shard file\n\n{}", specs_to_kv(&[sample_spec()]));
        assert_eq!(specs_from_kv(&text).unwrap().len(), 1);
    }

    #[test]
    fn spec_list_rejects_truncation_and_corruption() {
        let specs = vec![sample_spec(), RunSpec::new("mcf", "flat")];
        let kv = specs_to_kv(&specs);
        // Cut mid-way through the second block: the block parse, the
        // count check, or the checksum fires — all are clear errors.
        let cut = &kv[..kv.len() - 30];
        let e = specs_from_kv(cut).unwrap_err();
        assert!(e.contains("spec block") || e.contains("truncated")
                    || e.contains("checksum"),
                "got: {e}");
        // A mid-line cut that still parses as a (different) integer
        // value must be caught by the checksum, not slip through.
        let mangled = kv.replace("instructions=4000000", "instructions=4");
        let e = specs_from_kv(&mangled).unwrap_err();
        assert!(e.contains("checksum mismatch"), "got: {e}");
        // Drop a whole block: the declared count no longer matches.
        let one_block = kv[..kv.rfind("---").unwrap()].to_string();
        let e = specs_from_kv(&one_block).unwrap_err();
        assert!(e.contains("truncated or garbled"), "got: {e}");
        // An absurd declared count is a clean error, not an allocator
        // abort (the header is untrusted input).
        let huge = kv.replace("count=2", "count=18446744073709551615");
        let e = specs_from_kv(&huge).unwrap_err();
        assert!(e.contains("truncated or garbled"), "got: {e}");
        // Wrong / missing list version, unknown header key.
        assert!(specs_from_kv("speclistversion=99\ncount=0\n").is_err());
        assert!(specs_from_kv("count=0\n").is_err());
        assert!(specs_from_kv("speclistversion=1\nshardid=3\ncount=0\n")
            .is_err());
        // Missing count is a truncated header.
        let e = specs_from_kv("speclistversion=1\n").unwrap_err();
        assert!(e.contains("count"), "got: {e}");
        // A plain single-spec file is not a spec list.
        let e = specs_from_kv(&spec_to_kv(&sample_spec())).unwrap_err();
        assert!(e.contains("speclistversion"), "got: {e}");
    }

    #[test]
    fn spec_rejects_bad_input() {
        // Unknown top-level key.
        assert!(spec_from_kv("specversion=1\nworkload=a\npolicy=b\nnope=1")
            .is_err());
        // Unknown override knob.
        assert!(spec_from_kv(
            "specversion=1\nworkload=a\npolicy=b\nset.no.such=1")
            .is_err());
        // Wrong version / missing version / missing identity.
        assert!(spec_from_kv("specversion=99\nworkload=a\npolicy=b").is_err());
        assert!(spec_from_kv("workload=a\npolicy=b").is_err());
        assert!(spec_from_kv("specversion=1\npolicy=b").is_err());
        // Malformed line.
        assert!(spec_from_kv("specversion=1\nworkload a").is_err());
    }
}
