//! Key=value (de)serialization for RunMetrics — the on-disk results cache
//! format (serde is unavailable offline; this is deliberately dumb and
//! versioned).

use crate::sim::metrics::{RunMetrics, RuntimeBreakdown, XlatBreakdown};

const VERSION: u64 = 3;

pub fn metrics_to_kv(m: &RunMetrics) -> String {
    let mut s = String::with_capacity(1024);
    let mut put = |k: &str, v: String| {
        s.push_str(k);
        s.push('=');
        s.push_str(&v);
        s.push('\n');
    };
    put("version", VERSION.to_string());
    put("instructions", m.instructions.to_string());
    put("cycles", m.cycles.to_string());
    put("core_cycles", m.core_cycles.to_string());
    put("mem_ops", m.mem_ops.to_string());
    put("tlb_miss_4k", m.tlb_miss_4k.to_string());
    put("tlb_miss_2m", m.tlb_miss_2m.to_string());
    put("tlb_miss_cycles", m.tlb_miss_cycles.to_string());
    put("x_tlb", m.xlat.tlb_cycles.to_string());
    put("x_bitmap", m.xlat.bitmap_cycles.to_string());
    put("x_ptw", m.xlat.ptw_cycles.to_string());
    put("x_sptw", m.xlat.sptw_cycles.to_string());
    put("x_remap", m.xlat.remap_cycles.to_string());
    put("sp_hit_rate", format!("{:.6}", m.sp_hit_rate));
    put("bitmap_hits", m.bitmap_hits.to_string());
    put("bitmap_misses", m.bitmap_misses.to_string());
    put("remap_reads", m.remap_reads.to_string());
    put("migrations", m.migrations.to_string());
    put("migrated_bytes", m.migrated_bytes.to_string());
    put("writebacks", m.writebacks.to_string());
    put("writeback_bytes", m.writeback_bytes.to_string());
    put("shootdowns", m.shootdowns.to_string());
    put("rt_migration", m.rt.migration_cycles.to_string());
    put("rt_shootdown", m.rt.shootdown_cycles.to_string());
    put("rt_clflush", m.rt.clflush_cycles.to_string());
    put("rt_identify", m.rt.identify_cycles.to_string());
    put("dram_reads", m.dram_reads.to_string());
    put("dram_writes", m.dram_writes.to_string());
    put("nvm_reads", m.nvm_reads.to_string());
    put("nvm_writes", m.nvm_writes.to_string());
    put("energy_pj", format!("{:.3}", m.energy_pj));
    put("mem_stall_cycles", m.mem_stall_cycles.to_string());
    put("llc_misses", m.llc_misses.to_string());
    s
}

pub fn metrics_from_kv(text: &str) -> Option<RunMetrics> {
    let mut m = RunMetrics::default();
    let mut version = 0u64;
    for line in text.lines() {
        let (k, v) = line.split_once('=')?;
        let u = || v.parse::<u64>().ok();
        let f = || v.parse::<f64>().ok();
        match k {
            "version" => version = u()?,
            "instructions" => m.instructions = u()?,
            "cycles" => m.cycles = u()?,
            "core_cycles" => m.core_cycles = u()?,
            "mem_ops" => m.mem_ops = u()?,
            "tlb_miss_4k" => m.tlb_miss_4k = u()?,
            "tlb_miss_2m" => m.tlb_miss_2m = u()?,
            "tlb_miss_cycles" => m.tlb_miss_cycles = u()?,
            "x_tlb" => m.xlat.tlb_cycles = u()?,
            "x_bitmap" => m.xlat.bitmap_cycles = u()?,
            "x_ptw" => m.xlat.ptw_cycles = u()?,
            "x_sptw" => m.xlat.sptw_cycles = u()?,
            "x_remap" => m.xlat.remap_cycles = u()?,
            "sp_hit_rate" => m.sp_hit_rate = f()?,
            "bitmap_hits" => m.bitmap_hits = u()?,
            "bitmap_misses" => m.bitmap_misses = u()?,
            "remap_reads" => m.remap_reads = u()?,
            "migrations" => m.migrations = u()?,
            "migrated_bytes" => m.migrated_bytes = u()?,
            "writebacks" => m.writebacks = u()?,
            "writeback_bytes" => m.writeback_bytes = u()?,
            "shootdowns" => m.shootdowns = u()?,
            "rt_migration" => m.rt.migration_cycles = u()?,
            "rt_shootdown" => m.rt.shootdown_cycles = u()?,
            "rt_clflush" => m.rt.clflush_cycles = u()?,
            "rt_identify" => m.rt.identify_cycles = u()?,
            "dram_reads" => m.dram_reads = u()?,
            "dram_writes" => m.dram_writes = u()?,
            "nvm_reads" => m.nvm_reads = u()?,
            "nvm_writes" => m.nvm_writes = u()?,
            "energy_pj" => m.energy_pj = f()?,
            "mem_stall_cycles" => m.mem_stall_cycles = u()?,
            "llc_misses" => m.llc_misses = u()?,
            _ => {} // forward-compatible: ignore unknown keys
        }
    }
    (version == VERSION).then_some(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        RunMetrics {
            instructions: 123,
            cycles: 456,
            core_cycles: 3648,
            mem_ops: 78,
            tlb_miss_4k: 9,
            tlb_miss_2m: 8,
            tlb_miss_cycles: 1000,
            xlat: XlatBreakdown {
                tlb_cycles: 1, bitmap_cycles: 2, ptw_cycles: 3,
                sptw_cycles: 4, remap_cycles: 5,
            },
            sp_hit_rate: 0.991,
            bitmap_hits: 10,
            bitmap_misses: 2,
            remap_reads: 3,
            migrations: 4,
            migrated_bytes: 4096,
            writebacks: 1,
            writeback_bytes: 8,
            shootdowns: 1,
            rt: RuntimeBreakdown {
                migration_cycles: 11, shootdown_cycles: 12,
                clflush_cycles: 13, identify_cycles: 14,
            },
            dram_reads: 20,
            dram_writes: 21,
            nvm_reads: 22,
            nvm_writes: 23,
            energy_pj: 1234.5,
            mem_stall_cycles: 999,
            llc_misses: 55,
        }
    }

    #[test]
    fn roundtrip_preserves_all_fields() {
        let m = sample();
        let kv = metrics_to_kv(&m);
        let n = metrics_from_kv(&kv).unwrap();
        assert_eq!(format!("{m:?}"), format!("{n:?}"));
    }

    #[test]
    fn version_mismatch_rejected() {
        let kv = metrics_to_kv(&sample()).replace(
            &format!("version={VERSION}"), "version=0");
        assert!(metrics_from_kv(&kv).is_none());
    }

    #[test]
    fn garbage_rejected() {
        assert!(metrics_from_kv("not a kv file").is_none());
    }
}
