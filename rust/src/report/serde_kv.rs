//! Key=value (de)serialization for RunMetrics (the on-disk results-cache
//! format) and RunSpec (the canonical spec-file format behind the CLI's
//! `--spec`). serde is unavailable offline; this is deliberately dumb
//! and versioned.

use crate::report::RunSpec;
use crate::sim::metrics::{RunMetrics, RuntimeBreakdown, XlatBreakdown};

// v4: per-tier row-buffer hit/miss counters (backend comparisons).
const VERSION: u64 = 4;

/// Version of the spec-file serialization (bump on incompatible change).
pub const SPEC_VERSION: u64 = 1;

/// Canonical, order-independent serialization of a [`RunSpec`]: one
/// `key=value` per line, fixed field order, overrides as sorted
/// `set.<knob>` lines. Triple duty: on-disk spec-file format, `--spec`
/// CLI surface, and the content the fingerprint's override hash covers.
pub fn spec_to_kv(s: &RunSpec) -> String {
    let mut out = String::with_capacity(256);
    let mut put = |k: &str, v: String| {
        out.push_str(k);
        out.push('=');
        out.push_str(&v);
        out.push('\n');
    };
    put("specversion", SPEC_VERSION.to_string());
    put("workload", s.workload.clone());
    put("policy", s.policy.clone());
    put("scale", s.scale.to_string());
    put("instructions", s.instructions.to_string());
    put("seed", s.seed.to_string());
    put("accel", if s.accel { "1" } else { "0" }.to_string());
    for (k, v) in s.overrides.iter() {
        put(&format!("set.{k}"), v.to_string());
    }
    out
}

/// Parse a spec file. Strict by design: the version must match, every
/// key must be known (unknown `set.` knobs are rejected through the
/// registry, same as CLI `--set`), and workload/policy are required —
/// a bad spec file fails here, before any sweep fan-out.
pub fn spec_from_kv(text: &str) -> Result<RunSpec, String> {
    let mut s = RunSpec::new("", "");
    let mut version = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            format!("spec line {}: expected key=value, got {line:?}",
                    lineno + 1)
        })?;
        let (k, v) = (k.trim(), v.trim());
        let err = |what: &str| {
            format!("spec line {}: {k}: expected {what}, got {v:?}",
                    lineno + 1)
        };
        match k {
            "specversion" => {
                version = Some(v.parse::<u64>().map_err(|_| err("integer"))?)
            }
            "workload" => s.workload = v.to_string(),
            "policy" => s.policy = v.to_string(),
            "scale" => s.scale = v.parse().map_err(|_| err("integer"))?,
            "instructions" => {
                s.instructions = v.parse().map_err(|_| err("integer"))?
            }
            "seed" => s.seed = v.parse().map_err(|_| err("integer"))?,
            "accel" => {
                s.accel = match v {
                    "1" | "true" => true,
                    "0" | "false" => false,
                    _ => return Err(err("0/1")),
                }
            }
            _ => match k.strip_prefix("set.") {
                Some(knob) => s
                    .overrides
                    .set_raw(knob, v)
                    .map_err(|e| format!("spec line {}: {e}", lineno + 1))?,
                None => {
                    return Err(format!(
                        "spec line {}: unknown spec key {k:?}", lineno + 1))
                }
            },
        }
    }
    match version {
        Some(SPEC_VERSION) => {}
        Some(v) => {
            return Err(format!(
                "spec version {v} unsupported (expected {SPEC_VERSION})"))
        }
        None => return Err("spec file missing specversion".to_string()),
    }
    if s.workload.is_empty() || s.policy.is_empty() {
        return Err("spec file must set workload and policy".to_string());
    }
    Ok(s)
}

pub fn metrics_to_kv(m: &RunMetrics) -> String {
    let mut s = String::with_capacity(1024);
    let mut put = |k: &str, v: String| {
        s.push_str(k);
        s.push('=');
        s.push_str(&v);
        s.push('\n');
    };
    put("version", VERSION.to_string());
    put("instructions", m.instructions.to_string());
    put("cycles", m.cycles.to_string());
    put("core_cycles", m.core_cycles.to_string());
    put("mem_ops", m.mem_ops.to_string());
    put("tlb_miss_4k", m.tlb_miss_4k.to_string());
    put("tlb_miss_2m", m.tlb_miss_2m.to_string());
    put("tlb_miss_cycles", m.tlb_miss_cycles.to_string());
    put("x_tlb", m.xlat.tlb_cycles.to_string());
    put("x_bitmap", m.xlat.bitmap_cycles.to_string());
    put("x_ptw", m.xlat.ptw_cycles.to_string());
    put("x_sptw", m.xlat.sptw_cycles.to_string());
    put("x_remap", m.xlat.remap_cycles.to_string());
    put("sp_hit_rate", format!("{:.6}", m.sp_hit_rate));
    put("bitmap_hits", m.bitmap_hits.to_string());
    put("bitmap_misses", m.bitmap_misses.to_string());
    put("remap_reads", m.remap_reads.to_string());
    put("migrations", m.migrations.to_string());
    put("migrated_bytes", m.migrated_bytes.to_string());
    put("writebacks", m.writebacks.to_string());
    put("writeback_bytes", m.writeback_bytes.to_string());
    put("shootdowns", m.shootdowns.to_string());
    put("rt_migration", m.rt.migration_cycles.to_string());
    put("rt_shootdown", m.rt.shootdown_cycles.to_string());
    put("rt_clflush", m.rt.clflush_cycles.to_string());
    put("rt_identify", m.rt.identify_cycles.to_string());
    put("dram_reads", m.dram_reads.to_string());
    put("dram_writes", m.dram_writes.to_string());
    put("nvm_reads", m.nvm_reads.to_string());
    put("nvm_writes", m.nvm_writes.to_string());
    put("dram_row_hits", m.dram_row_hits.to_string());
    put("dram_row_misses", m.dram_row_misses.to_string());
    put("nvm_row_hits", m.nvm_row_hits.to_string());
    put("nvm_row_misses", m.nvm_row_misses.to_string());
    put("energy_pj", format!("{:.3}", m.energy_pj));
    put("mem_stall_cycles", m.mem_stall_cycles.to_string());
    put("llc_misses", m.llc_misses.to_string());
    s
}

pub fn metrics_from_kv(text: &str) -> Option<RunMetrics> {
    let mut m = RunMetrics::default();
    let mut version = 0u64;
    for line in text.lines() {
        let (k, v) = line.split_once('=')?;
        let u = || v.parse::<u64>().ok();
        let f = || v.parse::<f64>().ok();
        match k {
            "version" => version = u()?,
            "instructions" => m.instructions = u()?,
            "cycles" => m.cycles = u()?,
            "core_cycles" => m.core_cycles = u()?,
            "mem_ops" => m.mem_ops = u()?,
            "tlb_miss_4k" => m.tlb_miss_4k = u()?,
            "tlb_miss_2m" => m.tlb_miss_2m = u()?,
            "tlb_miss_cycles" => m.tlb_miss_cycles = u()?,
            "x_tlb" => m.xlat.tlb_cycles = u()?,
            "x_bitmap" => m.xlat.bitmap_cycles = u()?,
            "x_ptw" => m.xlat.ptw_cycles = u()?,
            "x_sptw" => m.xlat.sptw_cycles = u()?,
            "x_remap" => m.xlat.remap_cycles = u()?,
            "sp_hit_rate" => m.sp_hit_rate = f()?,
            "bitmap_hits" => m.bitmap_hits = u()?,
            "bitmap_misses" => m.bitmap_misses = u()?,
            "remap_reads" => m.remap_reads = u()?,
            "migrations" => m.migrations = u()?,
            "migrated_bytes" => m.migrated_bytes = u()?,
            "writebacks" => m.writebacks = u()?,
            "writeback_bytes" => m.writeback_bytes = u()?,
            "shootdowns" => m.shootdowns = u()?,
            "rt_migration" => m.rt.migration_cycles = u()?,
            "rt_shootdown" => m.rt.shootdown_cycles = u()?,
            "rt_clflush" => m.rt.clflush_cycles = u()?,
            "rt_identify" => m.rt.identify_cycles = u()?,
            "dram_reads" => m.dram_reads = u()?,
            "dram_writes" => m.dram_writes = u()?,
            "nvm_reads" => m.nvm_reads = u()?,
            "nvm_writes" => m.nvm_writes = u()?,
            "dram_row_hits" => m.dram_row_hits = u()?,
            "dram_row_misses" => m.dram_row_misses = u()?,
            "nvm_row_hits" => m.nvm_row_hits = u()?,
            "nvm_row_misses" => m.nvm_row_misses = u()?,
            "energy_pj" => m.energy_pj = f()?,
            "mem_stall_cycles" => m.mem_stall_cycles = u()?,
            "llc_misses" => m.llc_misses = u()?,
            _ => {} // forward-compatible: ignore unknown keys
        }
    }
    (version == VERSION).then_some(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        RunMetrics {
            instructions: 123,
            cycles: 456,
            core_cycles: 3648,
            mem_ops: 78,
            tlb_miss_4k: 9,
            tlb_miss_2m: 8,
            tlb_miss_cycles: 1000,
            xlat: XlatBreakdown {
                tlb_cycles: 1, bitmap_cycles: 2, ptw_cycles: 3,
                sptw_cycles: 4, remap_cycles: 5,
            },
            sp_hit_rate: 0.991,
            bitmap_hits: 10,
            bitmap_misses: 2,
            remap_reads: 3,
            migrations: 4,
            migrated_bytes: 4096,
            writebacks: 1,
            writeback_bytes: 8,
            shootdowns: 1,
            rt: RuntimeBreakdown {
                migration_cycles: 11, shootdown_cycles: 12,
                clflush_cycles: 13, identify_cycles: 14,
            },
            dram_reads: 20,
            dram_writes: 21,
            nvm_reads: 22,
            nvm_writes: 23,
            dram_row_hits: 30,
            dram_row_misses: 31,
            nvm_row_hits: 32,
            nvm_row_misses: 33,
            energy_pj: 1234.5,
            mem_stall_cycles: 999,
            llc_misses: 55,
        }
    }

    #[test]
    fn roundtrip_preserves_all_fields() {
        let m = sample();
        let kv = metrics_to_kv(&m);
        let n = metrics_from_kv(&kv).unwrap();
        assert_eq!(format!("{m:?}"), format!("{n:?}"));
    }

    #[test]
    fn version_mismatch_rejected() {
        let kv = metrics_to_kv(&sample()).replace(
            &format!("version={VERSION}"), "version=0");
        assert!(metrics_from_kv(&kv).is_none());
    }

    #[test]
    fn garbage_rejected() {
        assert!(metrics_from_kv("not a kv file").is_none());
    }

    fn sample_spec() -> RunSpec {
        RunSpec::new("mix2", "rainbow")
            .with_scale(16)
            .with_instructions(123_456)
            .with_seed(99)
            .with("rainbow.migration_threshold", 512.5)
            .with("nvm.read_cycles", 124u64)
    }

    #[test]
    fn spec_roundtrip_preserves_identity() {
        let s = sample_spec();
        let kv = spec_to_kv(&s);
        let t = spec_from_kv(&kv).unwrap();
        assert_eq!(s, t);
        assert_eq!(s.fingerprint(), t.fingerprint());
    }

    #[test]
    fn spec_kv_is_canonical_under_override_order() {
        let a = RunSpec::new("mcf", "flat")
            .with("rainbow.top_n", 8u64)
            .with("dram.read_cycles", 50u64);
        let b = RunSpec::new("mcf", "flat")
            .with("dram.read_cycles", 50u64)
            .with("rainbow.top_n", 8u64);
        assert_eq!(spec_to_kv(&a), spec_to_kv(&b));
    }

    #[test]
    fn spec_profile_overrides_round_trip() {
        let s = RunSpec::new("mcf", "rainbow")
            .with("nvm.profile", "optane-dcpmm")
            .with("dram.profile", "hbm-like");
        let kv = spec_to_kv(&s);
        assert!(kv.contains("set.nvm.profile=optane-dcpmm"), "{kv}");
        let t = spec_from_kv(&kv).unwrap();
        assert_eq!(s, t);
        assert_eq!(s.fingerprint(), t.fingerprint());
        // Unknown profile names are rejected at parse time.
        assert!(spec_from_kv(
            "specversion=1\nworkload=a\npolicy=b\nset.nvm.profile=zzz")
            .is_err());
    }

    #[test]
    fn spec_comments_and_blanks_allowed() {
        let kv = format!("# a comment\n\n{}", spec_to_kv(&sample_spec()));
        assert!(spec_from_kv(&kv).is_ok());
    }

    #[test]
    fn spec_rejects_bad_input() {
        // Unknown top-level key.
        assert!(spec_from_kv("specversion=1\nworkload=a\npolicy=b\nnope=1")
            .is_err());
        // Unknown override knob.
        assert!(spec_from_kv(
            "specversion=1\nworkload=a\npolicy=b\nset.no.such=1")
            .is_err());
        // Wrong version / missing version / missing identity.
        assert!(spec_from_kv("specversion=99\nworkload=a\npolicy=b").is_err());
        assert!(spec_from_kv("workload=a\npolicy=b").is_err());
        assert!(spec_from_kv("specversion=1\npolicy=b").is_err());
        // Malformed line.
        assert!(spec_from_kv("specversion=1\nworkload a").is_err());
    }
}
