//! Append-only durability log for the in-memory cache server: a
//! `cache-server --mem --log PATH` wraps its [`MemStore`] in a
//! [`LogStore`] that appends every acked `PUT` to a checksummed,
//! length-prefixed log (fsynced *before* the ack), replays the log on
//! startup, and snapshot+compacts it on clean shutdown. SIGKILL the
//! server at any point and a restart on the same log serves every
//! entry that was ever acknowledged; a torn tail from a crash
//! mid-append is truncated with a loud warning, never parsed into
//! silently different metrics.
//!
//! On-disk format (versioned by [`serde_kv::CACHE_LOG_VERSION`], one
//! header line then zero or more records):
//!
//! ```text
//! cachelogversion=1
//! put=<fingerprint> len=<payload bytes> checksum=<fnv1a, 16 hex>
//! <payload: the metrics_to_kv entry, exactly len bytes>
//! <newline>
//! ```
//!
//! The record checksum is FNV-1a over `<fingerprint>\n<payload>`; the
//! payload is the same versioned, self-checksummed [`metrics_to_kv`]
//! text a [`FsStore`] writes to `<fingerprint>.kv`, so the log reuses
//! the serde_kv entry framing end to end. Replay is strict about
//! *complete* records (a full record with a bad checksum or garbage
//! header is corruption — a hard error naming the offset) and lenient
//! about the *tail* (fewer bytes than the last record declares is the
//! expected crash signature — truncate, warn, continue). Stale-version
//! payloads are skipped on replay exactly as [`FsStore`] treats stale
//! entries: re-simulation heals them, and the next compaction drops
//! them.
//!
//! [`metrics_to_kv`]: serde_kv::metrics_to_kv
//! [`FsStore`]: super::store::FsStore

use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sim::RunMetrics;
use crate::util::log;

use super::serde_kv::{self, MetricsError, CACHE_LOG_VERSION};
use super::spec::fnv1a;
use super::store::{CacheStore, MemStore, StoreObs};

/// Framing of one log record, as serialized on the `put=` header line
/// (schema-locked against [`serde_kv::CACHE_LOG_VERSION`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogRecord {
    /// Results-cache fingerprint this record (over)writes.
    pub fingerprint: String,
    /// Exact payload length in bytes (the `metrics_to_kv` text).
    pub len: u64,
    /// FNV-1a over `<fingerprint>\n<payload>`.
    pub checksum: u64,
}

impl LogRecord {
    fn checksum_of(fingerprint: &str, payload: &[u8]) -> u64 {
        let mut bytes =
            Vec::with_capacity(fingerprint.len() + 1 + payload.len());
        bytes.extend_from_slice(fingerprint.as_bytes());
        bytes.push(b'\n');
        bytes.extend_from_slice(payload);
        fnv1a(&bytes)
    }

    /// The full serialized record: header line + payload + newline.
    fn encode(fingerprint: &str, payload: &str) -> String {
        let rec = LogRecord {
            fingerprint: fingerprint.to_string(),
            len: payload.len() as u64,
            checksum: LogRecord::checksum_of(
                fingerprint, payload.as_bytes()),
        };
        format!(
            "put={} len={} checksum={:016x}\n{}\n",
            rec.fingerprint, rec.len, rec.checksum, payload)
    }

    /// Parse a *complete* header line (no trailing newline). A line
    /// that made it to its newline is never a torn tail, so any parse
    /// failure here is corruption, not a crash artifact.
    fn parse_header(line: &str) -> Result<LogRecord, String> {
        let mut fields = line.split(' ');
        let fp = fields
            .next()
            .and_then(|t| t.strip_prefix("put="))
            .ok_or_else(|| format!("expected put=<fp>, got {line:?}"))?;
        let len = fields
            .next()
            .and_then(|t| t.strip_prefix("len="))
            .and_then(|v| v.parse::<u64>().ok())
            .ok_or_else(|| format!("expected len=<bytes> in {line:?}"))?;
        let checksum = fields
            .next()
            .and_then(|t| t.strip_prefix("checksum="))
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(|| {
                format!("expected checksum=<16 hex> in {line:?}")
            })?;
        if fp.is_empty() || fields.next().is_some() {
            return Err(format!("malformed record header {line:?}"));
        }
        Ok(LogRecord {
            fingerprint: fp.to_string(),
            len,
            checksum,
        })
    }
}

/// What replaying a log found — surfaced by `cache-server --log` so an
/// operator restarting after a crash sees exactly what survived.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ReplayStats {
    /// Records applied (later records overwrite earlier ones, so this
    /// counts appends, not distinct fingerprints).
    pub loaded: usize,
    /// Records skipped because their payload carried an older
    /// `version=` (re-simulation heals; compaction drops them).
    pub skipped_stale: usize,
    /// Torn bytes truncated from the end of the log (crash mid-append).
    pub truncated_bytes: u64,
}

/// [`MemStore`] wrapped in an append-only log: every `put` is appended
/// and fsynced before it is acknowledged, so the entry survives
/// SIGKILL; `get`/`list` are served from memory. [`LogStore::compact`]
/// rewrites the log as one record per live entry (atomically, via
/// temp-file + rename).
pub struct LogStore {
    path: PathBuf,
    inner: MemStore,
    /// Appends are serialized (header + payload + fsync must land as
    /// one contiguous record) and the handle is swapped under this
    /// lock when compaction renames a fresh log into place.
    file: Mutex<File>,
    /// Records appended since open (fleet stats surface).
    appends: AtomicU64,
    /// fsyncs issued since open (one per append, plus compactions).
    fsyncs: AtomicU64,
    /// Records replayed from the log at open.
    replayed: u64,
}

/// Longest clean prefix of `bytes` (header + whole records), the
/// replayed records, and the per-record outcomes. Returns `Err` only
/// for *corruption* — a complete record that fails its checksum or a
/// header that is not a cache log; a short tail is normal crash
/// fallout and is reported via `ReplayStats::truncated_bytes`.
fn replay(
    bytes: &[u8],
    inner: &MemStore,
    path: &Path,
) -> Result<(usize, ReplayStats), String> {
    let mut stats = ReplayStats::default();
    if bytes.is_empty() {
        return Ok((0, stats));
    }
    let header = format!("cachelogversion={CACHE_LOG_VERSION}\n");
    let keep = if let Some(nl) = bytes.iter().position(|&b| b == b'\n') {
        let line = &bytes[..=nl];
        if line != header.as_bytes() {
            return Err(format!(
                "cache log {}: bad header {:?} (expected {:?}) — not a \
                 rainbow cache log of this version; refusing to touch it",
                path.display(),
                String::from_utf8_lossy(&bytes[..nl]),
                header.trim_end()));
        }
        nl + 1
    } else if bytes.len() < header.len() {
        // Crash while writing the very first header: nothing durable
        // was ever acked against this log, start over.
        stats.truncated_bytes = bytes.len() as u64;
        return Ok((0, stats));
    } else {
        return Err(format!(
            "cache log {}: no header line in the first {} bytes — not \
             a rainbow cache log; refusing to touch it",
            path.display(), header.len()));
    };

    let mut off = keep;
    let mut keep = keep;
    while off < bytes.len() {
        let rest = &bytes[off..];
        let Some(nl) = rest.iter().position(|&b| b == b'\n') else {
            // Header line never reached its newline: torn tail.
            break;
        };
        let line = match std::str::from_utf8(&rest[..nl]) {
            Ok(l) => l,
            Err(_) => {
                return Err(format!(
                    "cache log {}: non-UTF-8 record header at byte \
                     {off}", path.display()));
            }
        };
        let rec = LogRecord::parse_header(line).map_err(|e| {
            format!("cache log {}: byte {off}: {e}", path.display())
        })?;
        let len = rec.len as usize;
        let total = nl + 1 + len + 1;
        if rest.len() < total {
            // Payload (or its trailing newline) is short: torn tail.
            break;
        }
        let payload = &rest[nl + 1..nl + 1 + len];
        if rest[nl + 1 + len] != b'\n' {
            return Err(format!(
                "cache log {}: record at byte {off} is not \
                 newline-terminated after its declared {len} payload \
                 bytes — corrupt log", path.display()));
        }
        let got = LogRecord::checksum_of(&rec.fingerprint, payload);
        if got != rec.checksum {
            return Err(format!(
                "cache log {}: record {} at byte {off}: checksum \
                 mismatch (header says {:016x}, payload hashes to \
                 {got:016x}) — corrupt log",
                path.display(), rec.fingerprint, rec.checksum));
        }
        let text = match std::str::from_utf8(payload) {
            Ok(t) => t,
            Err(_) => {
                return Err(format!(
                    "cache log {}: record {} at byte {off}: non-UTF-8 \
                     payload", path.display(), rec.fingerprint));
            }
        };
        match serde_kv::metrics_from_kv_checked(text) {
            Ok(m) => {
                inner.put(&rec.fingerprint, &m)?;
                stats.loaded += 1;
            }
            Err(MetricsError::Stale { found }) => {
                log::warn(&format!(
                    "cache log {}: skipping stale entry {} \
                     (version {found}); re-simulation will heal it",
                    path.display(), rec.fingerprint));
                stats.skipped_stale += 1;
            }
            Err(e) => {
                return Err(format!(
                    "cache log {}: record {} at byte {off}: {e}",
                    path.display(), rec.fingerprint));
            }
        }
        off += total;
        keep = off;
    }
    if keep < bytes.len() {
        stats.truncated_bytes = (bytes.len() - keep) as u64;
    }
    Ok((keep, stats))
}

impl LogStore {
    /// Open (or create) a log, replaying every intact record into the
    /// in-memory store. A torn tail — the signature of a crash
    /// mid-append — is truncated from the file with a loud warning;
    /// mid-log corruption is a hard error (the log is the durability
    /// story, silently dropping acked entries would betray it).
    pub fn open(path: &Path) -> Result<(LogStore, ReplayStats), String> {
        let bytes = match fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Vec::new()
            }
            Err(e) => {
                return Err(format!(
                    "cache log {}: {e}", path.display()))
            }
        };
        let inner = MemStore::new();
        let (keep, stats) = replay(&bytes, &inner, path)?;
        if stats.truncated_bytes > 0 {
            log::warn(&format!(
                "cache log {}: truncating {} torn byte(s) at \
                 the end of the log (crash mid-append); {} intact \
                 record(s) retained",
                path.display(), stats.truncated_bytes, stats.loaded));
        }
        let mut file = OpenOptions::new()
            .create(true)
            .write(true)
            .open(path)
            .map_err(|e| format!("cache log {}: {e}", path.display()))?;
        file.set_len(keep as u64).map_err(|e| {
            format!("cache log {}: truncate: {e}", path.display())
        })?;
        if keep == 0 {
            let header = format!("cachelogversion={CACHE_LOG_VERSION}\n");
            file.write_all(header.as_bytes()).map_err(|e| {
                format!("cache log {}: write header: {e}", path.display())
            })?;
        }
        file.sync_data().map_err(|e| {
            format!("cache log {}: sync: {e}", path.display())
        })?;
        // Reopen in append mode so every write lands at the (possibly
        // truncated) end regardless of the handle's cursor.
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| format!("cache log {}: {e}", path.display()))?;
        let store = LogStore {
            path: path.to_path_buf(),
            inner,
            file: Mutex::new(file),
            appends: AtomicU64::new(0),
            fsyncs: AtomicU64::new(0),
            replayed: stats.loaded as u64,
        };
        Ok((store, stats))
    }

    /// The log path this store appends to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    fn file_locked(&self)
                   -> Result<std::sync::MutexGuard<'_, File>, String> {
        self.file.lock().map_err(|_| {
            format!(
                "cache log {}: mutex poisoned by a panicked writer",
                self.path.display())
        })
    }
}

impl CacheStore for LogStore {
    fn get(&self, fingerprint: &str)
           -> Result<Option<RunMetrics>, String> {
        self.inner.get(fingerprint)
    }

    fn put(&self, fingerprint: &str, metrics: &RunMetrics)
           -> Result<(), String> {
        let payload = serde_kv::metrics_to_kv(metrics);
        let rec = LogRecord::encode(fingerprint, &payload);
        {
            // Durability before acknowledgement: the record is on
            // stable storage before the entry becomes visible (and
            // before the server acks the PUT), so SIGKILL after an ack
            // can never lose the entry.
            let mut f = self.file_locked()?;
            f.write_all(rec.as_bytes()).map_err(|e| {
                format!(
                    "cache log {}: append {fingerprint}: {e}",
                    self.path.display())
            })?;
            f.sync_data().map_err(|e| {
                format!(
                    "cache log {}: sync {fingerprint}: {e}",
                    self.path.display())
            })?;
        }
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.fsyncs.fetch_add(1, Ordering::Relaxed);
        self.inner.put(fingerprint, metrics)
    }

    fn list(&self) -> Result<Vec<String>, String> {
        self.inner.list()
    }

    /// Snapshot + compact: rewrite the log as exactly one record per
    /// live entry (sorted by fingerprint), atomically via temp-file +
    /// rename. Overwritten duplicates and stale-version records are
    /// dropped. Called on the server's clean `--stop` shutdown.
    fn compact(&self) -> Result<(), String> {
        let mut text =
            format!("cachelogversion={CACHE_LOG_VERSION}\n");
        for fp in self.inner.list()? {
            let Some(m) = self.inner.get(&fp)? else {
                continue;
            };
            text.push_str(&LogRecord::encode(
                &fp, &serde_kv::metrics_to_kv(&m)));
        }
        let tmp = self.path.with_extension(
            format!("compact.{}", std::process::id()));
        let mut f = File::create(&tmp).map_err(|e| {
            format!("cache log compact {}: {e}", tmp.display())
        })?;
        f.write_all(text.as_bytes())
            .and_then(|()| f.sync_data())
            .map_err(|e| {
                let _ = fs::remove_file(&tmp);
                format!("cache log compact {}: {e}", tmp.display())
            })?;
        drop(f);
        // Swap under the append lock so no in-flight append can land
        // on the pre-compaction inode after the rename.
        let mut guard = self.file_locked()?;
        fs::rename(&tmp, &self.path).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            format!(
                "cache log compact: rename {} -> {}: {e}",
                tmp.display(), self.path.display())
        })?;
        *guard = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .map_err(|e| {
                format!("cache log {}: {e}", self.path.display())
            })?;
        self.fsyncs.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn obs(&self) -> StoreObs {
        StoreObs {
            wal_appends: self.appends.load(Ordering::Relaxed),
            wal_fsyncs: self.fsyncs.load(Ordering::Relaxed),
            wal_replayed: self.replayed,
            ..StoreObs::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_log(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "rainbow_wal_{tag}_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        dir.join("cache.log")
    }

    fn metrics(seed: u64) -> RunMetrics {
        RunMetrics {
            instructions: 1_000 + seed,
            cycles: 5_000 + seed * 3,
            mem_ops: 400 + seed,
            migrations: seed,
            energy_pj: 123.5 + seed as f64,
            sp_hit_rate: 0.5,
            ..RunMetrics::default()
        }
    }

    #[test]
    fn record_header_round_trips_and_rejects_junk() {
        let enc = LogRecord::encode("fp_x", "payload");
        let line = enc.lines().next().unwrap();
        let rec = LogRecord::parse_header(line).unwrap();
        assert_eq!(rec.fingerprint, "fp_x");
        assert_eq!(rec.len, 7);
        assert_eq!(
            rec.checksum,
            LogRecord::checksum_of("fp_x", b"payload"));
        for bad in [
            "", "put=", "put=fp", "put=fp len=3",
            "put=fp len=x checksum=0", "put=fp len=3 checksum=zz",
            "len=3 checksum=0 put=fp",
            "put=fp len=3 checksum=0 extra=1",
        ] {
            assert!(
                LogRecord::parse_header(bad).is_err(),
                "{bad:?} must be rejected");
        }
    }

    #[test]
    fn entries_survive_reopen_and_torn_tails_truncate() {
        let path = tmp_log("reopen");
        let _ = fs::remove_file(&path);
        let m_a = metrics(1);
        let m_b = metrics(2);
        {
            let (store, stats) = LogStore::open(&path).unwrap();
            assert_eq!(stats, ReplayStats::default());
            store.put("fp_a", &m_a).unwrap();
            store.put("fp_b", &m_b).unwrap();
            store.put("fp_a", &m_a).unwrap(); // overwrite appends
        }
        let clean_len = fs::metadata(&path).unwrap().len();
        // Simulate a crash mid-append: a record whose payload is short
        // of its declared length.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"put=fp_torn len=4096 checksum=0123456789abcdef\ntruncated")
            .unwrap();
        drop(f);
        let (store, stats) = LogStore::open(&path).unwrap();
        assert_eq!(stats.loaded, 3);
        assert!(stats.truncated_bytes > 0);
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
        assert_eq!(store.list().unwrap(), vec!["fp_a", "fp_b"]);
        let got = store.get("fp_a").unwrap().unwrap();
        assert_eq!(
            serde_kv::metrics_to_kv(&got), serde_kv::metrics_to_kv(&m_a));
        // Compaction drops the duplicate fp_a record.
        store.compact().unwrap();
        assert!(fs::metadata(&path).unwrap().len() < clean_len);
        drop(store);
        let (store, stats) = LogStore::open(&path).unwrap();
        assert_eq!(stats.loaded, 2);
        assert_eq!(stats.truncated_bytes, 0);
        assert_eq!(store.list().unwrap(), vec!["fp_a", "fp_b"]);
    }

    #[test]
    fn obs_counts_appends_fsyncs_and_replays() {
        let path = tmp_log("obs");
        let _ = fs::remove_file(&path);
        {
            let (store, _) = LogStore::open(&path).unwrap();
            assert_eq!(store.obs(), StoreObs::default());
            store.put("fp_a", &metrics(7)).unwrap();
            store.put("fp_b", &metrics(8)).unwrap();
            let o = store.obs();
            assert_eq!(o.wal_appends, 2);
            assert_eq!(o.wal_fsyncs, 2);
            assert_eq!(o.wal_replayed, 0);
            assert_eq!(o.degraded_gets, 0);
        }
        // A reopen replays what was appended; its own counters restart.
        let (store, _) = LogStore::open(&path).unwrap();
        let o = store.obs();
        assert_eq!(o.wal_replayed, 2);
        assert_eq!(o.wal_appends, 0);
        store.compact().unwrap();
        assert_eq!(store.obs().wal_fsyncs, 1);
    }

    #[test]
    fn mid_log_corruption_is_a_hard_error_not_a_truncation() {
        let path = tmp_log("corrupt");
        let _ = fs::remove_file(&path);
        {
            let (store, _) = LogStore::open(&path).unwrap();
            store.put("fp_a", &metrics(3)).unwrap();
        }
        let mut bytes = fs::read(&path).unwrap();
        // Flip a payload byte without touching the framing.
        let n = bytes.len();
        bytes[n - 10] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        let e = LogStore::open(&path).unwrap_err();
        assert!(e.contains("checksum mismatch"), "{e}");
    }

    #[test]
    fn foreign_files_are_refused_not_truncated() {
        let path = tmp_log("foreign");
        fs::write(&path, "this is not a cache log, honest\n").unwrap();
        let e = LogStore::open(&path).unwrap_err();
        assert!(e.contains("refusing"), "{e}");
        // The file was not modified.
        assert_eq!(
            fs::read_to_string(&path).unwrap(),
            "this is not a cache log, honest\n");
    }

    #[test]
    fn torn_header_on_a_fresh_log_restarts_empty() {
        let path = tmp_log("torn_header");
        fs::write(&path, "cachelogv").unwrap();
        let (store, stats) = LogStore::open(&path).unwrap();
        assert_eq!(stats.loaded, 0);
        assert_eq!(stats.truncated_bytes, 9);
        assert!(store.list().unwrap().is_empty());
        store.put("fp_a", &metrics(4)).unwrap();
        drop(store);
        let (store, stats) = LogStore::open(&path).unwrap();
        assert_eq!(stats.loaded, 1);
        assert_eq!(store.list().unwrap(), vec!["fp_a"]);
    }
}
