//! `rainbow lint` — a dependency-free static-analysis pass enforcing
//! the three invariant classes the simulator's correctness rests on:
//! the allocation-free hot path, byte-identical determinism, and
//! versioned wire formats (plus panic hygiene in protocol code).
//! See DESIGN.md §11 and docs/MANUAL.md §lint for the rule catalog,
//! the suppression-marker contract, and the `schemas.lock` workflow.
//!
//! Layering (all dependency-free, in the `util::json`/`tomlite`
//! style):
//!
//! * [`lexer`] — a small Rust lexer (comments, strings, raw strings,
//!   lifetime-vs-char disambiguation) so rules match tokens, not text.
//! * [`source`] — the source-tree walker ([`SourceTree`]), loadable
//!   from the committed tree or from in-memory fixtures.
//! * [`rules`] — the rule engine: per-token contexts (enclosing fn,
//!   test code), the four rule families, allow-marker parsing,
//!   suppression, and staleness.
//! * [`schema`] — the wire-format lock behind `rust/schemas.lock`.

pub mod lexer;
pub mod rules;
pub mod schema;
pub mod source;

use std::fs;
use std::path::{Path, PathBuf};

pub use rules::{lint_tree, Diagnostic, LintConfig, RuleInfo, RULES};
pub use source::SourceTree;

/// The lint root relative to the repository: where the crate sources
/// live.
pub const SRC_REL: &str = "rust/src";
/// The schema lock relative to the repository.
pub const LOCK_REL: &str = "rust/schemas.lock";

/// Locate the source tree: `rust/src` under the current directory if
/// present (running from a checkout), else the compile-time manifest
/// dir (running the test binary or an installed build from anywhere).
pub fn default_src_dir() -> PathBuf {
    let local = PathBuf::from(SRC_REL);
    if local.is_dir() {
        return local;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join(SRC_REL)
}

/// The lock that pairs with a source dir: `<src>/../schemas.lock`.
pub fn lock_path_for(src: &Path) -> PathBuf {
    match src.parent() {
        Some(p) => p.join("schemas.lock"),
        None => PathBuf::from("schemas.lock"),
    }
}

/// Load the lock next to `src` if it exists (a missing lock becomes a
/// `wire-schema` diagnostic, not an IO error — `rainbow lint` must
/// fail with a finding, not a crash, on a fresh tree).
pub fn load_lock(src: &Path) -> Result<Option<String>, String> {
    let path = lock_path_for(src);
    match fs::read_to_string(&path) {
        Ok(t) => Ok(Some(t)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(format!("lint: read {}: {e}", path.display())),
    }
}

/// `--fix-allow`: stamp a `rainbow-lint: allow(rule, TODO: justify
/// this exception)` marker above every suppressible finding, so a
/// tree full of findings can be quieted mechanically and each stamp
/// then edited into an honest reason (or a fix). Returns how many
/// markers were written. Findings for unsuppressible rules
/// (wire-schema, marker hygiene) are left alone.
pub fn fix_allow(src_root: &Path, findings: &[Diagnostic])
                 -> Result<usize, String> {
    let mut by_file: Vec<(&str, Vec<&Diagnostic>)> = Vec::new();
    for d in findings {
        let suppressible = rules::rule(d.rule)
            .map(|r| r.suppressible)
            .unwrap_or(false);
        if !suppressible {
            continue;
        }
        match by_file.iter().position(|(f, _)| *f == d.file) {
            Some(i) => by_file[i].1.push(d),
            None => by_file.push((d.file.as_str(), vec![d])),
        }
    }
    let mut stamped = 0usize;
    for (file, mut ds) in by_file {
        let path = src_root.join(file);
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("lint: read {}: {e}", path.display()))?;
        let mut lines: Vec<String> =
            text.lines().map(|l| l.to_string()).collect();
        // Bottom-up so earlier insertions do not shift later targets;
        // one marker per (line, rule).
        ds.sort_by(|a, b| (b.line, b.rule).cmp(&(a.line, a.rule)));
        ds.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
        for d in ds {
            let idx = (d.line as usize).saturating_sub(1);
            if idx >= lines.len() {
                continue;
            }
            let indent: String = lines[idx]
                .chars()
                .take_while(|c| c.is_whitespace())
                .collect();
            lines.insert(idx, format!(
                "{indent}// rainbow-lint: allow({}, TODO: justify this \
                 exception)", d.rule));
            stamped += 1;
        }
        let mut out = lines.join("\n");
        if text.ends_with('\n') {
            out.push('\n');
        }
        fs::write(&path, out)
            .map_err(|e| format!("lint: write {}: {e}", path.display()))?;
    }
    Ok(stamped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fix_allow_stamps_and_silences() {
        let dir = std::env::temp_dir()
            .join(format!("rainbow_fix_allow_{}", std::process::id()));
        fs::create_dir_all(dir.join("mem")).unwrap();
        let src = "fn access() {\n    let a = Vec::new();\n    \
                   let b = Vec::new();\n}\n";
        fs::write(dir.join("mem/x.rs"), src).unwrap();
        let tree = SourceTree::from_dir(&dir).unwrap();
        let cfg = LintConfig::default();
        let findings = lint_tree(&tree, &cfg);
        assert_eq!(findings.len(), 2);
        let n = fix_allow(&dir, &findings).unwrap();
        assert_eq!(n, 2);
        let stamped = fs::read_to_string(dir.join("mem/x.rs")).unwrap();
        assert_eq!(stamped.matches("rainbow-lint: allow(hot-alloc")
                   .count(), 2);
        // Indentation matches the finding line.
        assert!(stamped.contains("\n    // rainbow-lint: allow("));
        // The stamped tree lints clean (TODO reasons are valid
        // reasons; stale they are not, since they suppress findings).
        let tree2 = SourceTree::from_dir(&dir).unwrap();
        let d = lint_tree(&tree2, &LintConfig {
            stale_allows: true,
            ..Default::default()
        });
        assert!(d.is_empty(), "{d:?}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lock_path_sits_next_to_src() {
        assert_eq!(lock_path_for(Path::new("rust/src")),
                   PathBuf::from("rust/schemas.lock"));
    }

    #[test]
    fn every_rule_id_is_unique_and_kebab() {
        for (i, r) in RULES.iter().enumerate() {
            assert!(r.id.chars().all(
                |c| c.is_ascii_lowercase() || c == '-'), "{}", r.id);
            assert!(RULES[i + 1..].iter().all(|o| o.id != r.id),
                    "duplicate rule id {}", r.id);
        }
    }
}
