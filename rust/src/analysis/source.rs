//! Source-tree abstraction for the lint pass: a list of
//! `(relative path, content)` pairs, loadable from a real directory
//! (the committed tree) or built in memory (rule fixtures in tests).
//! Paths are `/`-separated and sorted, so diagnostics and the schema
//! lock are deterministic across platforms and filesystem orders.

use std::fs;
use std::path::{Path, PathBuf};

/// One `.rs` file, path relative to the lint root (e.g. `rust/src`).
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// The set of files one lint run sees.
#[derive(Clone, Debug, Default)]
pub struct SourceTree {
    pub files: Vec<SourceFile>,
}

impl SourceTree {
    /// Load every `*.rs` under `root`, recursively, sorted by relative
    /// path. Hidden directories and `target/` are skipped.
    pub fn from_dir(root: &Path) -> Result<SourceTree, String> {
        if !root.is_dir() {
            return Err(format!("lint: {} is not a directory",
                               root.display()));
        }
        let mut paths: Vec<PathBuf> = Vec::new();
        collect_rs(root, &mut paths)?;
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for p in paths {
            let text = fs::read_to_string(&p)
                .map_err(|e| format!("lint: read {}: {e}", p.display()))?;
            let rel = p
                .strip_prefix(root)
                .map_err(|_| format!("lint: {} escapes {}", p.display(),
                                     root.display()))?;
            let rel = rel
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            files.push(SourceFile { path: rel, text });
        }
        Ok(SourceTree { files })
    }

    /// In-memory tree for rule fixtures.
    pub fn from_files(files: &[(&str, &str)]) -> SourceTree {
        let mut files: Vec<SourceFile> = files
            .iter()
            .map(|(p, t)| SourceFile {
                path: p.to_string(),
                text: t.to_string(),
            })
            .collect();
        files.sort_by(|a, b| a.path.cmp(&b.path));
        SourceTree { files }
    }

    pub fn get(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("lint: read dir {}: {e}", dir.display()))?;
    for entry in rd {
        let entry =
            entry.map_err(|e| format!("lint: {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_trees_sort_and_lookup() {
        let t = SourceTree::from_files(&[("b.rs", "fn b() {}"),
                                         ("a/x.rs", "fn a() {}")]);
        assert_eq!(t.files[0].path, "a/x.rs");
        assert_eq!(t.files[1].path, "b.rs");
        assert!(t.get("b.rs").is_some());
        assert!(t.get("missing.rs").is_none());
    }

    #[test]
    fn from_dir_walks_recursively_and_relativizes() {
        let dir = std::env::temp_dir()
            .join(format!("rainbow_lint_src_{}", std::process::id()));
        let sub = dir.join("deep");
        fs::create_dir_all(&sub).unwrap();
        fs::write(dir.join("top.rs"), "fn t() {}").unwrap();
        fs::write(sub.join("leaf.rs"), "fn l() {}").unwrap();
        fs::write(dir.join("notes.txt"), "ignored").unwrap();
        let t = SourceTree::from_dir(&dir).unwrap();
        let paths: Vec<&str> =
            t.files.iter().map(|f| f.path.as_str()).collect();
        assert_eq!(paths, vec!["deep/leaf.rs", "top.rs"]);
        fs::remove_dir_all(&dir).unwrap();
        assert!(SourceTree::from_dir(&dir).is_err());
    }
}
