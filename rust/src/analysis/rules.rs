//! The lint rules and the engine that runs them over a [`SourceTree`].
//!
//! Five enforced invariant families (DESIGN.md §11):
//!
//! * **hot-path purity** (`hot-collections`, `hot-alloc`) — the
//!   per-access pipeline stays HashMap-free and allocation-free, the
//!   property the PR 6 throughput campaign bought.
//! * **determinism** (`nondet-clock`, `nondet-iter`) — no wall-clock
//!   reads outside the bench/perf harness, no unordered-map identifiers
//!   inside `*_to_kv` serialization functions, so byte-identical sweeps
//!   stay byte-identical.
//! * **wire-format lock** (`wire-schema`, in [`super::schema`]) — a
//!   serialized struct cannot change shape without its version
//!   constant changing too.
//! * **panic hygiene** (`panic-protocol`, `unsafe-audit`) — protocol
//!   code fails loud-but-clean (PR 5 contract), and any `unsafe` must
//!   carry a `SAFETY:` justification next to its `#[allow]`.
//! * **observability** (`raw-eprintln`) — report-layer diagnostics go
//!   through the leveled `util::log` sink, never bare `eprintln!`, so
//!   `RAINBOW_LOG` filtering and test capture see every message.
//!
//! Suppression: a finding on line `L` is silenced by a
//! `rainbow-lint: allow(rule-id, reason)` comment on line `L` or
//! `L-1`. The reason is mandatory (`allow-hygiene` fires otherwise)
//! and a marker that silences nothing is itself reportable
//! (`stale-allow`, behind [`LintConfig::stale_allows`]).

use super::lexer::{self, Comment, Tok, TokKind};
use super::source::SourceTree;

/// Static description of one rule, for `rainbow lint --list-rules`
/// and the MANUAL completeness guard.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    pub id: &'static str,
    pub family: &'static str,
    pub summary: &'static str,
    /// Whether an allow-marker may silence it. Schema and marker
    /// hygiene findings are not suppressible: their fix is a version
    /// bump or a better marker, not an exception.
    pub suppressible: bool,
}

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "hot-collections",
        family: "hot-path",
        summary: "HashMap/BTreeMap/HashSet types in a declared hot \
                  module (outside tests)",
        suppressible: true,
    },
    RuleInfo {
        id: "hot-alloc",
        family: "hot-path",
        summary: "Vec::new / vec![] / Box::new / format! / .to_string() \
                  / .clone() in a hot module's non-constructor, \
                  non-test function",
        suppressible: true,
    },
    RuleInfo {
        id: "nondet-clock",
        family: "determinism",
        summary: "SystemTime::now / Instant::now outside util/bench.rs \
                  and perf.rs",
        suppressible: true,
    },
    RuleInfo {
        id: "nondet-iter",
        family: "determinism",
        summary: "HashMap/HashSet inside a *_to_kv serialization \
                  function (unordered iteration feeding the wire)",
        suppressible: true,
    },
    RuleInfo {
        id: "wire-schema",
        family: "wire-format",
        summary: "serialized struct layout changed without its VERSION \
                  constant changing (schemas.lock)",
        suppressible: false,
    },
    RuleInfo {
        id: "panic-protocol",
        family: "panic-hygiene",
        summary: ".unwrap() / .expect( / panic! in protocol code \
                  (report/{netstore,store,shard,queue}.rs non-test \
                  paths)",
        suppressible: true,
    },
    RuleInfo {
        id: "unsafe-audit",
        family: "panic-hygiene",
        summary: "`unsafe` without an adjacent SAFETY: comment \
                  (the crate root denies unsafe_code)",
        suppressible: true,
    },
    RuleInfo {
        id: "raw-eprintln",
        family: "observability",
        summary: "eprintln! in report/ non-test code (route through \
                  util::log so RAINBOW_LOG leveling and test capture \
                  apply)",
        suppressible: true,
    },
    RuleInfo {
        id: "allow-hygiene",
        family: "lint",
        summary: "malformed allow marker: missing reason or unknown \
                  rule id",
        suppressible: false,
    },
    RuleInfo {
        id: "stale-allow",
        family: "lint",
        summary: "allow marker that suppresses nothing (--stale-allows)",
        suppressible: false,
    },
];

pub fn rule(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// One diagnostic, displayed as `file:line: [rule-id] message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule,
               self.msg)
    }
}

/// Hot modules: the per-access pipeline (ROADMAP "simulator-core
/// throughput"). Directory prefixes relative to the lint root.
const HOT_PREFIXES: &[&str] =
    &["tlb/", "cache/", "rainbow/", "mem/", "policies/"];
const HOT_FILES: &[&str] = &["os/page_table.rs"];

/// Files allowed to read wall clocks: the measurement harness itself.
const CLOCK_EXEMPT: &[&str] = &["util/bench.rs", "perf.rs"];

/// Protocol code bound to the loud-but-clean error contract.
const PROTOCOL_FILES: &[&str] = &["report/netstore.rs", "report/store.rs",
                                  "report/shard.rs", "report/queue.rs",
                                  "report/replica.rs", "report/wal.rs"];

fn is_hot(path: &str) -> bool {
    HOT_PREFIXES.iter().any(|p| path.starts_with(p))
        || HOT_FILES.contains(&path)
}

/// Constructor-shaped functions are exempt from `hot-alloc`: setup
/// allocation is the point of a constructor.
fn is_constructor_name(name: &str) -> bool {
    name == "new"
        || name == "default"
        || name.starts_with("new_")
        || name.starts_with("with_")
        || name.starts_with("from_")
}

// ---------------------------------------------------------------- context

/// Per-token context from a lightweight structural pass: enclosing
/// function name and whether the token sits in test code
/// (`#[cfg(test)]` module or `#[test]` function).
#[derive(Clone, Debug, Default)]
struct Ctx {
    fn_name: Option<String>,
    in_test: bool,
}

struct Scope {
    open_depth: u32,
    is_test: bool,
    fn_name: Option<String>,
}

/// Compute the context of every token. Single forward pass tracking
/// brace depth, `fn`/`mod` items, and their preceding attributes.
fn contexts(toks: &[Tok]) -> Vec<Ctx> {
    let mut ctxs: Vec<Ctx> = Vec::with_capacity(toks.len());
    let mut scopes: Vec<Scope> = Vec::new();
    let mut depth: u32 = 0;
    // Attribute state carried to the next `fn`/`mod` item.
    let mut pending_test_attr = false;
    // A seen `fn name` / `mod name` awaiting its opening `{`. Tokens
    // between the name and the body (parameters, return type) belong
    // to the pending function already — `fn spec_to_kv(m: &HashMap..)`
    // must attribute the signature to `spec_to_kv`.
    let mut pending_item: Option<(Option<String>, bool)> = None;
    // Paren/bracket nesting inside a pending signature, so the `;` in
    // `fn f(x: [u8; 4])` does not cancel the pending item.
    let mut pending_nest: i32 = 0;

    let current = |scopes: &[Scope],
                   pending: &Option<(Option<String>, bool)>|
     -> Ctx {
        let mut c = Ctx {
            in_test: scopes.iter().any(|s| s.is_test),
            fn_name: scopes
                .iter()
                .rev()
                .find_map(|s| s.fn_name.clone()),
        };
        if let Some((name, is_test)) = pending {
            if let Some(name) = name {
                c.fn_name = Some(name.clone());
            }
            if *is_test {
                c.in_test = true;
            }
        }
        c
    };

    let mut k = 0usize;
    while k < toks.len() {
        let t = &toks[k];
        ctxs.push(current(&scopes, &pending_item));
        if t.is_punct("#") {
            // Attribute: `#[...]` or `#![...]`. Consume to the
            // matching `]`; a bare `test` ident inside (and no `not`)
            // marks the next item as test code.
            let mut j = k + 1;
            if toks.get(j).map(|t| t.is_punct("!")).unwrap_or(false) {
                ctxs.push(current(&scopes, &pending_item));
                j += 1;
            }
            if toks.get(j).map(|t| t.is_punct("[")).unwrap_or(false) {
                let mut nest = 0i32;
                let mut saw_test = false;
                let mut saw_not = false;
                while j < toks.len() {
                    let a = &toks[j];
                    if j > k {
                        ctxs.push(current(&scopes, &pending_item));
                    }
                    if a.is_punct("[") {
                        nest += 1;
                    } else if a.is_punct("]") {
                        nest -= 1;
                        if nest == 0 {
                            break;
                        }
                    } else if a.is_ident("test") {
                        saw_test = true;
                    } else if a.is_ident("not") {
                        saw_not = true;
                    }
                    j += 1;
                }
                if saw_test && !saw_not {
                    pending_test_attr = true;
                }
                k = j + 1;
                continue;
            }
            k = j;
            continue;
        }
        if t.is_ident("fn") {
            if let Some(name) =
                toks.get(k + 1).filter(|n| n.kind == TokKind::Ident)
            {
                pending_item =
                    Some((Some(name.text.clone()), pending_test_attr));
                pending_test_attr = false;
            }
        } else if t.is_ident("mod") {
            if toks.get(k + 1).map(|n| n.kind == TokKind::Ident)
                == Some(true)
            {
                pending_item = Some((None, pending_test_attr));
                pending_test_attr = false;
            }
        } else if t.is_punct("(") || t.is_punct("[") {
            if pending_item.is_some() {
                pending_nest += 1;
            }
        } else if t.is_punct(")") || t.is_punct("]") {
            if pending_item.is_some() {
                pending_nest -= 1;
            }
        } else if t.is_punct(";") {
            // `mod name;` / bodyless trait fn: the pending item never
            // opens a scope. A `;` nested inside the signature (array
            // types like `[u8; 4]`) is not a terminator.
            if pending_nest == 0 {
                pending_item = None;
            }
        } else if t.is_punct("{") {
            depth += 1;
            if let Some((fn_name, is_test)) = pending_item.take() {
                pending_nest = 0;
                scopes.push(Scope { open_depth: depth, is_test, fn_name });
            }
        } else if t.is_punct("}") {
            while scopes
                .last()
                .map(|s| s.open_depth == depth)
                .unwrap_or(false)
            {
                scopes.pop();
            }
            depth = depth.saturating_sub(1);
        }
        k += 1;
    }
    ctxs
}

// ------------------------------------------------------------- markers

/// A parsed `rainbow-lint: allow(rule, reason)` marker.
#[derive(Clone, Debug)]
pub struct AllowMarker {
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

const MARKER_PREFIX: &str = "rainbow-lint:";

/// Extract markers from a file's comments. Malformed markers (no
/// `allow(...)`, empty reason, unknown rule id) come back as
/// `allow-hygiene` diagnostics instead.
fn parse_markers(path: &str, comments: &[Comment])
                 -> (Vec<AllowMarker>, Vec<Diagnostic>) {
    let mut markers = Vec::new();
    let mut diags = Vec::new();
    for c in comments {
        let text = c.text.trim();
        let Some(rest) = text.strip_prefix(MARKER_PREFIX) else {
            continue;
        };
        let bad = |msg: String| Diagnostic {
            file: path.to_string(),
            line: c.line,
            rule: "allow-hygiene",
            msg,
        };
        let rest = rest.trim();
        let Some(inner) = rest
            .strip_prefix("allow(")
            .and_then(|r| r.rfind(')').map(|end| &r[..end]))
        else {
            diags.push(bad(format!(
                "malformed marker {text:?}: expected \
                 `rainbow-lint: allow(rule-id, reason)`")));
            continue;
        };
        let Some((id, reason)) = inner.split_once(',') else {
            diags.push(bad(format!(
                "allow({inner}) has no reason; every exception must \
                 say why (`allow(rule-id, reason)`)")));
            continue;
        };
        let id = id.trim();
        let reason = reason.trim();
        match rule(id) {
            None => diags.push(bad(format!(
                "allow({id}, ...): unknown rule id (see \
                 `rainbow lint --list-rules`)"))),
            Some(info) if !info.suppressible => diags.push(bad(format!(
                "allow({id}, ...): rule {id} is not suppressible"))),
            Some(_) if reason.is_empty() => diags.push(bad(format!(
                "allow({id}, ...): empty reason"))),
            Some(_) => markers.push(AllowMarker {
                line: c.line,
                rule: id.to_string(),
                reason: reason.to_string(),
            }),
        }
    }
    (markers, diags)
}

// ------------------------------------------------------------- patterns

fn path2(toks: &[Tok], k: usize, a: &str, b: &str) -> bool {
    toks[k].is_ident(a)
        && toks.get(k + 1).map(|t| t.is_punct("::")).unwrap_or(false)
        && toks.get(k + 2).map(|t| t.is_ident(b)).unwrap_or(false)
}

fn macro_call(toks: &[Tok], k: usize, name: &str) -> bool {
    toks[k].is_ident(name)
        && toks.get(k + 1).map(|t| t.is_punct("!")).unwrap_or(false)
}

fn method_call(toks: &[Tok], k: usize, name: &str) -> bool {
    toks[k].is_punct(".")
        && toks.get(k + 1).map(|t| t.is_ident(name)).unwrap_or(false)
        && toks.get(k + 2).map(|t| t.is_punct("(")).unwrap_or(false)
}

// --------------------------------------------------------------- engine

/// Everything the token rules produced for one file.
pub struct FileLint {
    pub findings: Vec<Diagnostic>,
    pub markers: Vec<AllowMarker>,
    pub marker_diags: Vec<Diagnostic>,
}

/// Run every token-level rule over one file.
pub fn lint_file(path: &str, text: &str) -> FileLint {
    let lexed = lexer::lex(text);
    let toks = &lexed.toks;
    let ctxs = contexts(toks);
    let mut findings: Vec<Diagnostic> = Vec::new();
    let mut push = |line: u32, rule: &'static str, msg: String| {
        findings.push(Diagnostic { file: path.to_string(), line, rule, msg })
    };

    let hot = is_hot(path);
    let clock_exempt = CLOCK_EXEMPT.contains(&path);
    let protocol = PROTOCOL_FILES.contains(&path);
    let report_layer = path.starts_with("report/");

    for (k, t) in toks.iter().enumerate() {
        let ctx = &ctxs[k];
        if ctx.in_test {
            continue;
        }

        if hot && t.kind == TokKind::Ident {
            if matches!(t.text.as_str(), "HashMap" | "BTreeMap" | "HashSet")
            {
                push(t.line, "hot-collections", format!(
                    "{} in hot module {path}: the per-access pipeline \
                     is flat-array only (flatten like RemapTable, or \
                     justify with an allow marker)", t.text));
            }
        }
        if hot {
            let in_plain_fn = ctx
                .fn_name
                .as_deref()
                .map(|n| !is_constructor_name(n))
                .unwrap_or(false);
            if in_plain_fn {
                let hit = if path2(toks, k, "Vec", "new") {
                    Some("Vec::new")
                } else if macro_call(toks, k, "vec") {
                    Some("vec![]")
                } else if path2(toks, k, "Box", "new") {
                    Some("Box::new")
                } else if macro_call(toks, k, "format") {
                    Some("format!")
                } else if method_call(toks, k, "to_string") {
                    Some(".to_string()")
                } else if method_call(toks, k, "clone") {
                    Some(".clone()")
                } else {
                    None
                };
                if let Some(what) = hit {
                    let f = ctx.fn_name.as_deref().unwrap_or("?");
                    push(t.line, "hot-alloc", format!(
                        "{what} in hot function {f}() of {path}: \
                         per-access paths must not allocate \
                         (preallocate in the constructor, or justify \
                         with an allow marker)"));
                }
            }
        }
        if !clock_exempt
            && (path2(toks, k, "Instant", "now")
                || path2(toks, k, "SystemTime", "now"))
        {
            push(t.line, "nondet-clock", format!(
                "{}::now in {path}: wall-clock reads outside \
                 util/bench.rs and perf.rs break byte-identical \
                 replays", t.text));
        }
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "HashMap" | "HashSet")
        {
            if let Some(f) = ctx.fn_name.as_deref() {
                if f.ends_with("to_kv") {
                    push(t.line, "nondet-iter", format!(
                        "{} inside serialization function {f}(): \
                         unordered iteration feeding the wire format \
                         is nondeterministic (use a sorted or ordered \
                         structure)", t.text));
                }
            }
        }
        if protocol {
            let hit = if method_call(toks, k, "unwrap") {
                Some(".unwrap()")
            } else if method_call(toks, k, "expect") {
                Some(".expect(")
            } else if macro_call(toks, k, "panic") {
                Some("panic!")
            } else {
                None
            };
            if let Some(what) = hit {
                push(t.line, "panic-protocol", format!(
                    "{what} in protocol code {path}: a malformed peer \
                     or poisoned lock must surface as a propagated \
                     error, not a process abort (PR 5 contract)"));
            }
        }
        if report_layer && macro_call(toks, k, "eprintln") {
            push(t.line, "raw-eprintln", format!(
                "eprintln! in {path}: report-layer diagnostics go \
                 through util::log::{{warn,info,debug}} so RAINBOW_LOG \
                 leveling and test capture apply"));
        }
        if t.is_ident("unsafe") {
            let has_safety = lexed.comments.iter().any(|c| {
                c.line + 3 >= t.line
                    && c.line <= t.line
                    && c.text.contains("SAFETY:")
            });
            if !has_safety {
                push(t.line, "unsafe-audit", format!(
                    "`unsafe` in {path} without an adjacent SAFETY: \
                     comment (the crate root denies unsafe_code; each \
                     surviving site needs #[allow(unsafe_code)] plus \
                     a SAFETY: justification)"));
            }
        }
    }

    let (markers, marker_diags) = parse_markers(path, &lexed.comments);
    FileLint { findings, markers, marker_diags }
}

/// Lint configuration (what `rainbow lint`'s flags toggle).
#[derive(Clone, Debug, Default)]
pub struct LintConfig {
    /// Report valid markers that suppress nothing (`--stale-allows`).
    pub stale_allows: bool,
    /// The committed `schemas.lock` content; `None` skips the
    /// wire-schema rule (fixture runs that do not care about it).
    pub schemas_lock: Option<String>,
}

/// Run the full pass: token rules per file, marker suppression,
/// marker hygiene, staleness, and the wire-schema lock. Diagnostics
/// come back sorted by (file, line, rule) — deterministic output is a
/// lint-tool wire format too.
pub fn lint_tree(tree: &SourceTree, cfg: &LintConfig) -> Vec<Diagnostic> {
    let mut out: Vec<Diagnostic> = Vec::new();
    for f in &tree.files {
        let fl = lint_file(&f.path, &f.text);
        let mut used = vec![false; fl.markers.len()];
        for d in fl.findings {
            let suppressed = fl.markers.iter().enumerate().any(
                |(i, m)| {
                    let hit = m.rule == d.rule
                        && (m.line == d.line || m.line + 1 == d.line);
                    if hit {
                        used[i] = true;
                    }
                    hit
                });
            if !suppressed {
                out.push(d);
            }
        }
        out.extend(fl.marker_diags);
        if cfg.stale_allows {
            for (i, m) in fl.markers.iter().enumerate() {
                if !used[i] {
                    out.push(Diagnostic {
                        file: f.path.clone(),
                        line: m.line,
                        rule: "stale-allow",
                        msg: format!(
                            "allow({}, ...) suppresses nothing on line \
                             {} or {}; remove the stale marker",
                            m.rule, m.line, m.line + 1),
                    });
                }
            }
        }
    }
    if let Some(lock) = &cfg.schemas_lock {
        out.extend(super::schema::check(tree, Some(lock.as_str()),
                                        super::schema::TRACKED));
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Vec<Diagnostic> {
        lint_tree(&SourceTree::from_files(&[(path, src)]),
                  &LintConfig::default())
    }

    #[test]
    fn contexts_track_fns_mods_and_tests() {
        let src = "fn hot() { body(); }\n\
                   #[cfg(test)]\nmod tests {\n  #[test]\n  fn case() { \
                   t(); }\n}\nfn after() { b(); }";
        let lexed = lexer::lex(src);
        let ctxs = contexts(&lexed.toks);
        let at = |name: &str| {
            let k = lexed.toks.iter().position(|t| t.is_ident(name))
                .unwrap();
            ctxs[k].clone()
        };
        assert_eq!(at("body").fn_name.as_deref(), Some("hot"));
        assert!(!at("body").in_test);
        assert!(at("t").in_test);
        assert_eq!(at("t").fn_name.as_deref(), Some("case"));
        assert!(!at("b").in_test, "scope must close after the test mod");
        assert_eq!(at("b").fn_name.as_deref(), Some("after"));
    }

    #[test]
    fn cfg_not_test_is_not_test_code() {
        let src = "#[cfg(not(test))]\nfn shipping() { \
                   let m: HashMap<u8, u8>; }";
        let d = one("rainbow/x.rs", src);
        assert!(d.iter().any(|d| d.rule == "hot-collections"), "{d:?}");
    }

    #[test]
    fn constructor_and_test_exemptions() {
        let src = "impl X {\n  fn new() -> X { let v = Vec::new(); }\n  \
                   fn with_capacity(n: usize) { let v = vec![0; n]; }\n  \
                   fn access(&mut self) { let v = Vec::new(); }\n}\n\
                   #[cfg(test)]\nmod tests {\n  fn helper() { \
                   let v = Vec::new(); }\n}";
        let d = one("tlb/x.rs", src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "hot-alloc");
        assert_eq!(d[0].line, 4);
    }

    #[test]
    fn nonhot_files_allocate_freely() {
        let d = one("report/x.rs",
                    "fn f() { let v = Vec::new(); let s = x.clone(); }");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn strings_and_comments_never_fire() {
        let d = one("cache/x.rs",
                    "fn f() { // HashMap Vec::new()\n  \
                     let s = \"Instant::now HashMap\"; }");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn suppression_same_line_and_preceding_line() {
        let src = "fn access() {\n  \
                   // rainbow-lint: allow(hot-alloc, bounded burst)\n  \
                   let v = Vec::new();\n  \
                   let w = Vec::new(); // rainbow-lint: allow(hot-alloc, x)\n\
                   }";
        let d = one("mem/x.rs", src);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn marker_for_other_rule_does_not_suppress() {
        let src = "fn access() {\n  \
                   // rainbow-lint: allow(nondet-clock, wrong rule)\n  \
                   let v = Vec::new();\n}";
        let d = one("mem/x.rs", src);
        assert!(d.iter().any(|d| d.rule == "hot-alloc"), "{d:?}");
    }

    #[test]
    fn marker_hygiene() {
        // No reason.
        let d = one("a.rs", "// rainbow-lint: allow(hot-alloc)\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "allow-hygiene");
        // Unknown rule.
        let d = one("a.rs", "// rainbow-lint: allow(no-such-rule, x)\n");
        assert_eq!(d[0].rule, "allow-hygiene");
        // Unsuppressible rule.
        let d = one("a.rs", "// rainbow-lint: allow(wire-schema, x)\n");
        assert_eq!(d[0].rule, "allow-hygiene");
        // Garbage after the prefix.
        let d = one("a.rs", "// rainbow-lint: disable everything\n");
        assert_eq!(d[0].rule, "allow-hygiene");
        // Empty reason.
        let d = one("a.rs", "// rainbow-lint: allow(hot-alloc,  )\n");
        assert_eq!(d[0].rule, "allow-hygiene");
    }

    #[test]
    fn stale_allows_only_with_flag() {
        let src = "// rainbow-lint: allow(hot-alloc, nothing here)\n\
                   fn quiet() {}\n";
        let tree = SourceTree::from_files(&[("mem/x.rs", src)]);
        assert!(lint_tree(&tree, &LintConfig::default()).is_empty());
        let d = lint_tree(&tree, &LintConfig {
            stale_allows: true,
            ..Default::default()
        });
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "stale-allow");
    }

    #[test]
    fn clock_rule_exempts_the_harness() {
        let src = "fn f() { let t = Instant::now(); }";
        assert!(one("util/bench.rs", src).is_empty());
        assert!(one("perf.rs", src).is_empty());
        let d = one("report/sweep.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "nondet-clock");
        let d = one("report/sweep.rs",
                    "fn f() { let t = SystemTime::now(); }");
        assert_eq!(d[0].rule, "nondet-clock");
    }

    #[test]
    fn to_kv_functions_reject_unordered_maps() {
        let d = one("report/serde_kv.rs",
                    "fn widget_to_kv(m: &HashMap<String, u64>) {}");
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "nondet-iter");
        // Same type in a non-serialization fn: quiet.
        assert!(one("report/serde_kv.rs",
                    "fn order(m: &HashMap<String, u64>) {}").is_empty());
    }

    #[test]
    fn panic_rule_scoped_to_protocol_files() {
        let src = "fn f() { x.unwrap(); y.expect(\"m\"); panic!(\"b\"); }";
        let d = one("report/netstore.rs", src);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().all(|d| d.rule == "panic-protocol"));
        assert!(one("sim/engine.rs", src).is_empty());
        // Test code in protocol files may unwrap.
        let d = one("report/store.rs",
                    "#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn raw_eprintln_scoped_to_report_files() {
        let src = "fn f(e: u8) { eprintln!(\"cache: {e}\"); }";
        let d = one("report/queue.rs", src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "raw-eprintln");
        // The log sink itself and other layers may write to stderr.
        assert!(one("util/log.rs", src).is_empty());
        assert!(one("main.rs", src).is_empty());
        // Test code in report files may print directly.
        let d = one("report/queue.rs",
                    "#[cfg(test)]\nmod tests {\n  fn t() { \
                     eprintln!(\"dbg\"); }\n}");
        assert!(d.is_empty(), "{d:?}");
        // Suppressible with a reasoned marker.
        let d = one("report/queue.rs",
                    "// rainbow-lint: allow(raw-eprintln, boot banner)\n\
                     fn f() { eprintln!(\"up\"); }");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn unsafe_needs_safety_comment() {
        let d = one("util/x.rs", "fn f() { unsafe { g(); } }");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "unsafe-audit");
        let d = one("util/x.rs",
                    "fn f() {\n  // SAFETY: g is infallible here\n  \
                     unsafe { g(); }\n}");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn diagnostics_sorted_and_displayable() {
        let tree = SourceTree::from_files(&[
            ("mem/b.rs", "fn f() { let v = Vec::new(); }"),
            ("cache/a.rs", "fn f() { let v = Vec::new(); }"),
        ]);
        let d = lint_tree(&tree, &LintConfig::default());
        assert_eq!(d.len(), 2);
        assert!(d[0].file < d[1].file);
        let shown = d[0].to_string();
        assert!(shown.starts_with("cache/a.rs:1: [hot-alloc]"), "{shown}");
    }
}
