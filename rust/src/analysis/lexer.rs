//! A minimal Rust lexer for the lint pass (`super`): just enough to
//! token-match rule patterns without false positives from comments,
//! string literals, raw strings, or lifetimes-vs-char-literals — the
//! classic traps of grep-based linting. Dependency-free by design
//! (the same constraint as `util::json` / `util::tomlite`).
//!
//! The output is a flat token stream plus the comment list (comments
//! carry the `rainbow-lint: allow(...)` suppression markers and the
//! `SAFETY:` justifications the `unsafe-audit` rule looks for).

/// Token class. Rules match on `Ident`/`Punct` text; literals exist so
/// their *content* can never be mistaken for code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub line: u32,
    pub text: String,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// One comment (line `//...` or block `/*...*/`), with the leading
/// `//`/`///`/`//!`/`/*` decoration stripped and content trimmed.
/// Block comments are anchored at their starting line.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lex result: the token stream and the comments, both in source order.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src`. Never fails: unrecognized bytes become single-char
/// `Punct` tokens, an unterminated literal simply ends at EOF — a lint
/// pass must degrade gracefully on code mid-edit.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0usize;

    // Raw-string opener at `i` (after an optional `b`): `r#*"`.
    // Returns the number of `#`s when it is one.
    let raw_open = |cs: &[char], i: usize| -> Option<usize> {
        if cs.get(i) != Some(&'r') {
            return None;
        }
        let mut j = i + 1;
        while cs.get(j) == Some(&'#') {
            j += 1;
        }
        (cs.get(j) == Some(&'"')).then_some(j - (i + 1))
    };

    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments /// and //!).
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let mut j = i + 2;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            let body: String = cs[i + 2..j].iter().collect();
            let body = body.trim_start_matches(['/', '!']).trim();
            out.comments.push(Comment { line, text: body.to_string() });
            i = j;
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut body = String::new();
            while j < cs.len() && depth > 0 {
                if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                body.push(cs[j]);
                j += 1;
            }
            out.comments.push(Comment {
                line: start_line,
                text: body.trim_matches(['*', ' ', '\n', '!']).to_string(),
            });
            i = j;
            continue;
        }
        // Raw strings r"..." / r#"..."#, byte strings b"...", raw
        // byte strings br#"..."#, and raw identifiers r#ident.
        if c == 'r' || c == 'b' {
            let after_b = if c == 'b' { i + 1 } else { i };
            let raw_at = if c == 'b' && cs.get(i + 1) == Some(&'r') {
                i + 1
            } else {
                i
            };
            if let Some(hashes) = raw_open(&cs, raw_at) {
                // Scan to `"` followed by `hashes` x `#`.
                let start_line = line;
                let mut j = raw_at + 1 + hashes + 1;
                while j < cs.len() {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    if cs[j] == '"'
                        && cs[j + 1..].iter().take(hashes).filter(|&&h| h == '#')
                            .count() == hashes
                    {
                        j += 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    line: start_line,
                    text: String::new(),
                });
                i = j;
                continue;
            }
            if c == 'b' && cs.get(after_b) == Some(&'"') {
                // Fall through to the string scanner below from the
                // quote position.
                i = after_b;
                // (handled by the '"' arm on the next loop turn)
                continue;
            }
            if c == 'r'
                && cs.get(i + 1) == Some(&'#')
                && cs.get(i + 2).copied().is_some_and(is_ident_start)
            {
                let mut j = i + 2;
                while j < cs.len() && is_ident_continue(cs[j]) {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    line,
                    text: cs[i + 2..j].iter().collect(),
                });
                i = j;
                continue;
            }
        }
        if c == '"' {
            let start_line = line;
            let mut j = i + 1;
            let mut body = String::new();
            while j < cs.len() {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '"' {
                    j += 1;
                    break;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                body.push(cs[j]);
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Str,
                line: start_line,
                text: body,
            });
            i = j;
            continue;
        }
        if c == '\'' {
            // Lifetime ('a, 'static) iff an identifier follows and the
            // char after it is NOT a closing quote ('a' is a char).
            let mut j = i + 1;
            if cs.get(j).copied().is_some_and(is_ident_start) {
                let mut k = j + 1;
                while k < cs.len() && is_ident_continue(cs[k]) {
                    k += 1;
                }
                if cs.get(k) != Some(&'\'') {
                    out.toks.push(Tok {
                        kind: TokKind::Lifetime,
                        line,
                        text: cs[j..k].iter().collect(),
                    });
                    i = k;
                    continue;
                }
            }
            // Char literal, escapes included ('\'', '\n', '\u{1F980}').
            while j < cs.len() {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\'' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                line,
                text: String::new(),
            });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < cs.len() && is_ident_continue(cs[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                line,
                text: cs[i..j].iter().collect(),
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            // Numbers loosely: digits, letters, `_`, and `.` only when
            // a digit follows — so `x.0.clone()` and `0..n` tokenize
            // as Num / Punct / Ident, not one blob.
            let mut j = i + 1;
            while j < cs.len() {
                let d = cs[j];
                if d == '.' {
                    if cs.get(j + 1).copied().is_some_and(|n| n.is_ascii_digit())
                    {
                        j += 2;
                        continue;
                    }
                    break;
                }
                if is_ident_continue(d) {
                    j += 1;
                    continue;
                }
                break;
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                line,
                text: cs[i..j].iter().collect(),
            });
            i = j;
            continue;
        }
        // Punctuation: `::` and `->` fuse (path / fn-pointer matching
        // stays single-token), everything else is one char.
        if c == ':' && cs.get(i + 1) == Some(&':') {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                line,
                text: "::".to_string(),
            });
            i += 2;
            continue;
        }
        if c == '-' && cs.get(i + 1) == Some(&'>') {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                line,
                text: "->".to_string(),
            });
            i += 2;
            continue;
        }
        out.toks.push(Tok {
            kind: TokKind::Punct,
            line,
            text: c.to_string(),
        });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_are_not_tokens() {
        let l = lex("let x = 1; // HashMap in a comment\n/* Vec::new */");
        assert!(l.toks.iter().all(|t| t.text != "HashMap"));
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].text, "HashMap in a comment");
        assert_eq!(l.comments[1].text, "Vec::new");
    }

    #[test]
    fn doc_comment_decoration_stripped() {
        let l = lex("/// doc line\n//! inner doc\ncode();");
        assert_eq!(l.comments[0].text, "doc line");
        assert_eq!(l.comments[1].text, "inner doc");
    }

    #[test]
    fn strings_hide_their_content() {
        let l = lex(r#"let s = "HashMap::new() \" quoted"; x();"#);
        assert!(l.toks.iter().all(|t| !t.text.contains("HashMap")
            || t.kind == TokKind::Str));
        // Content is carried on the Str token only.
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let l = lex("let s = r#\"unwrap() \"# ; let r#type = 1;");
        assert!(l.toks.iter().all(|t| t.text != "unwrap"));
        assert!(l.toks.iter().any(|t| t.is_ident("type")));
        // A multi-line raw string advances line accounting.
        let l2 = lex("r\"a\nb\"\nx");
        let x = l2.toks.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!(x.line, 3);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let q = '\\''; }");
        let lifetimes: Vec<_> = l.toks.iter()
            .filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars = l.toks.iter()
            .filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 2);
    }

    #[test]
    fn paths_and_arrows_fuse() {
        assert_eq!(texts("Vec::new() -> X"),
                   vec!["Vec", "::", "new", "(", ")", "->", "X"]);
    }

    #[test]
    fn numbers_do_not_eat_method_calls() {
        assert_eq!(texts("x.0.clone()"),
                   vec!["x", ".", "0", ".", "clone", "(", ")"]);
        assert_eq!(texts("for i in 0..10 {}"),
                   vec!["for", "i", "in", "0", ".", ".", "10", "{", "}"]);
        assert_eq!(texts("1.5e3 0xFF_u64"), vec!["1.5e3", "0xFF_u64"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let l = lex("a\n\nb /* x\ny */ c");
        let a = l.toks.iter().find(|t| t.is_ident("a")).unwrap();
        let b = l.toks.iter().find(|t| t.is_ident("b")).unwrap();
        let c = l.toks.iter().find(|t| t.is_ident("c")).unwrap();
        assert_eq!((a.line, b.line, c.line), (1, 3, 4));
    }
}
