//! The wire-format lock (`wire-schema` rule): fingerprints of the
//! field lists of every struct that crosses a serialization boundary
//! — serde_kv results/specs, the binary trace format, the
//! rainbow-bench JSON report — committed to `rust/schemas.lock`
//! together with the version constant guarding each format.
//!
//! The invariant: **a tracked struct's layout may not change unless
//! its version constant changes in the same diff.** The last two
//! silent-corruption bugs (the trace meta-layout bit-63 collision and
//! the counter 0x8000 overflow aliasing, PR 6) were exactly layout
//! drift nothing enforced; this rule turns that class of bug into a
//! lint failure.
//!
//! Workflow when a layout legitimately changes:
//! 1. edit the struct, 2. bump its version constant
//! (`METRICS_VERSION`, trace `VERSION`, perf `SCHEMA`, ...),
//! 3. run `rainbow lint --update-schemas` to re-stamp the lock,
//! 4. commit the lock with the code. Step 3 *refuses* to run if the
//! version was not bumped — the lock can never paper over drift.

use super::lexer::{self, Tok, TokKind};
use super::rules::Diagnostic;
use super::source::SourceTree;

/// First line of every lock file; bump if the lock format itself
/// changes (it is a wire format too, after all).
pub const LOCK_VERSION: u64 = 1;

/// One struct ↔ version-constant binding.
#[derive(Clone, Copy, Debug)]
pub struct Tracked {
    /// File holding the struct, relative to the lint root.
    pub struct_file: &'static str,
    pub struct_name: &'static str,
    /// File holding the guarding version constant.
    pub version_file: &'static str,
    pub version_const: &'static str,
}

/// Every struct that crosses a serialization boundary today. Adding a
/// serialized struct means adding a row here and re-stamping the lock.
pub const TRACKED: &[Tracked] = &[
    // serde_kv metrics entries (cache/store wire + on-disk format).
    Tracked {
        struct_file: "sim/metrics.rs",
        struct_name: "RunMetrics",
        version_file: "report/serde_kv.rs",
        version_const: "METRICS_VERSION",
    },
    Tracked {
        struct_file: "sim/metrics.rs",
        struct_name: "XlatBreakdown",
        version_file: "report/serde_kv.rs",
        version_const: "METRICS_VERSION",
    },
    Tracked {
        struct_file: "sim/metrics.rs",
        struct_name: "RuntimeBreakdown",
        version_file: "report/serde_kv.rs",
        version_const: "METRICS_VERSION",
    },
    // Spec files / spec-list shard files.
    Tracked {
        struct_file: "report/spec.rs",
        struct_name: "RunSpec",
        version_file: "report/serde_kv.rs",
        version_const: "SPEC_VERSION",
    },
    // Binary trace format (meta-layout v2).
    Tracked {
        struct_file: "workloads/trace.rs",
        struct_name: "TraceRec",
        version_file: "workloads/trace.rs",
        version_const: "VERSION",
    },
    // rainbow-bench-v1 JSON report.
    Tracked {
        struct_file: "perf.rs",
        struct_name: "PerfConfig",
        version_file: "perf.rs",
        version_const: "SCHEMA",
    },
    Tracked {
        struct_file: "perf.rs",
        struct_name: "BenchEntry",
        version_file: "perf.rs",
        version_const: "SCHEMA",
    },
    Tracked {
        struct_file: "perf.rs",
        struct_name: "PerfReport",
        version_file: "perf.rs",
        version_const: "SCHEMA",
    },
    // Job-queue wire records (LEASE/COMPLETE/QSTAT payloads).
    Tracked {
        struct_file: "report/queue.rs",
        struct_name: "LeaseRequest",
        version_file: "report/serde_kv.rs",
        version_const: "QUEUE_WIRE_VERSION",
    },
    Tracked {
        struct_file: "report/queue.rs",
        struct_name: "LeaseReply",
        version_file: "report/serde_kv.rs",
        version_const: "QUEUE_WIRE_VERSION",
    },
    Tracked {
        struct_file: "report/queue.rs",
        struct_name: "CompleteRequest",
        version_file: "report/serde_kv.rs",
        version_const: "QUEUE_WIRE_VERSION",
    },
    Tracked {
        struct_file: "report/queue.rs",
        struct_name: "QueueStat",
        version_file: "report/serde_kv.rs",
        version_const: "QUEUE_WIRE_VERSION",
    },
    // Cache-server durability-log record framing (--log).
    Tracked {
        struct_file: "report/wal.rs",
        struct_name: "LogRecord",
        version_file: "report/serde_kv.rs",
        version_const: "CACHE_LOG_VERSION",
    },
    // Telemetry trace records (`run --trace-out` JSON-lines).
    Tracked {
        struct_file: "telemetry/mod.rs",
        struct_name: "Event",
        version_file: "telemetry/mod.rs",
        version_const: "TRACE_VERSION",
    },
    Tracked {
        struct_file: "telemetry/mod.rs",
        struct_name: "EpochSample",
        version_file: "telemetry/mod.rs",
        version_const: "TRACE_VERSION",
    },
    Tracked {
        struct_file: "telemetry/trace.rs",
        struct_name: "TraceMeta",
        version_file: "telemetry/mod.rs",
        version_const: "TRACE_VERSION",
    },
    // Fleet stats snapshot (STATS opcode / `rainbow stats`).
    Tracked {
        struct_file: "report/netstore.rs",
        struct_name: "ServerStats",
        version_file: "report/serde_kv.rs",
        version_const: "STATS_WIRE_VERSION",
    },
];

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Extract `struct name { field: Type, ... }` field descriptors from a
/// token stream: `name:Type tokens` joined, one string per field
/// (tuple structs yield `0:Type`, `1:Type`, ...). Comments,
/// whitespace, and attributes never affect the result — only real
/// layout does.
pub fn struct_fields(toks: &[Tok], name: &str) -> Option<Vec<String>> {
    let mut k = 0usize;
    while k + 1 < toks.len() {
        if toks[k].is_ident("struct") && toks[k + 1].is_ident(name) {
            break;
        }
        k += 1;
    }
    if k + 1 >= toks.len() {
        return None;
    }
    // Skip generics to the body opener.
    let mut j = k + 2;
    let mut angle = 0i32;
    loop {
        let t = toks.get(j)?;
        if t.is_punct("<") {
            angle += 1;
        } else if t.is_punct(">") {
            angle -= 1;
        } else if angle == 0 && (t.is_punct("{") || t.is_punct("(")) {
            break;
        } else if angle == 0 && t.is_punct(";") {
            return Some(Vec::new()); // unit struct
        }
        j += 1;
    }
    let tuple = toks[j].is_punct("(");
    let close = if tuple { ")" } else { "}" };
    let open = if tuple { "(" } else { "{" };
    j += 1;

    let mut fields = Vec::new();
    let mut cur: Vec<String> = Vec::new();
    let mut depth = 0i32; // nesting of any bracket kind inside a type
    let mut idx = 0usize;
    let flush = |cur: &mut Vec<String>, fields: &mut Vec<String>,
                 idx: &mut usize, tuple: bool| {
        // Drop visibility modifiers and (named case) split name: type.
        let mut parts: &[String] = cur;
        while parts.first().map(|p| p == "pub").unwrap_or(false) {
            parts = &parts[1..];
            // pub(crate) / pub(super): the paren group is one token
            // sequence ( crate ) — drop it too.
            if parts.first().map(|p| p == "(").unwrap_or(false) {
                if let Some(close) =
                    parts.iter().position(|p| p == ")")
                {
                    parts = &parts[close + 1..];
                }
            }
        }
        if parts.is_empty() {
            cur.clear();
            return;
        }
        let desc = if tuple {
            format!("{}:{}", idx, parts.join(" "))
        } else {
            parts.join(" ")
        };
        fields.push(desc);
        *idx += 1;
        cur.clear();
    };
    while let Some(t) = toks.get(j) {
        if t.is_punct("#") {
            // Field attribute: skip the [ ... ] group.
            let mut nest = 0i32;
            j += 1;
            while let Some(a) = toks.get(j) {
                if a.is_punct("[") {
                    nest += 1;
                } else if a.is_punct("]") {
                    nest -= 1;
                    if nest == 0 {
                        break;
                    }
                }
                j += 1;
            }
            j += 1;
            continue;
        }
        if depth == 0 && t.is_punct(close) {
            if !cur.is_empty() {
                flush(&mut cur, &mut fields, &mut idx, tuple);
            }
            return Some(fields);
        }
        if t.is_punct("<") || t.is_punct("[") || t.is_punct("(")
            || t.is_punct(open)
        {
            depth += 1;
        } else if t.is_punct(">") || t.is_punct("]") || t.is_punct(")") {
            depth -= 1;
        } else if depth == 0 && t.is_punct(",") {
            flush(&mut cur, &mut fields, &mut idx, tuple);
            j += 1;
            continue;
        }
        cur.push(t.text.clone());
        j += 1;
    }
    None // unterminated body: treat as not found
}

/// Fingerprint a field list (order-sensitive — field order IS layout
/// for every format we serialize).
pub fn fingerprint(fields: &[String]) -> u64 {
    let mut buf = String::new();
    for f in fields {
        buf.push_str(f);
        buf.push(';');
    }
    fnv1a(buf.as_bytes())
}

/// Extract the value of `const NAME: T = <literal>;` — integer
/// constants yield their digits, string constants their content.
pub fn const_value(toks: &[Tok], name: &str) -> Option<String> {
    let mut k = 0usize;
    while k + 1 < toks.len() {
        if toks[k].is_ident("const") && toks[k + 1].is_ident(name) {
            // Find the `=`, then the literal.
            let mut j = k + 2;
            while let Some(t) = toks.get(j) {
                if t.is_punct("=") {
                    let v = toks.get(j + 1)?;
                    return match v.kind {
                        TokKind::Num | TokKind::Ident => {
                            Some(v.text.clone())
                        }
                        TokKind::Str => Some(v.text.clone()),
                        _ => None,
                    };
                }
                if t.is_punct(";") {
                    break;
                }
                j += 1;
            }
        }
        k += 1;
    }
    None
}

/// One parsed lock entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LockEntry {
    pub key: String, // "<struct_file>::<struct_name>"
    pub n_fields: usize,
    pub fp: u64,
    pub version_key: String, // "<version_file>::<version_const>"
    pub value: String,
}

fn entry_key(t: &Tracked) -> String {
    format!("{}::{}", t.struct_file, t.struct_name)
}

/// Parse a lock file; returns entries or a description of what is
/// wrong with it (a corrupt lock is a loud error, like every other
/// versioned file in this repo).
pub fn parse_lock(text: &str) -> Result<Vec<LockEntry>, String> {
    let mut lines = text.lines().filter(|l| {
        let l = l.trim();
        !l.is_empty() && !l.starts_with('#')
    });
    let head = lines.next().ok_or("schemas.lock: empty file")?;
    let ver = head
        .strip_prefix("schemalockversion=")
        .and_then(|v| v.parse::<u64>().ok())
        .ok_or_else(|| format!(
            "schemas.lock: bad header {head:?} (expected \
             schemalockversion={LOCK_VERSION})"))?;
    if ver != LOCK_VERSION {
        return Err(format!(
            "schemas.lock: version {ver} unsupported (expected \
             {LOCK_VERSION}); regenerate with --update-schemas"));
    }
    let mut out = Vec::new();
    for line in lines {
        let mut key = None;
        let mut n_fields = None;
        let mut fp = None;
        let mut version_key = None;
        let mut value = None;
        for part in line.split_whitespace() {
            let Some((k, v)) = part.split_once('=') else {
                return Err(format!("schemas.lock: bad token {part:?} \
                                    in line {line:?}"));
            };
            match k {
                "struct" => key = Some(v.to_string()),
                "fields" => n_fields = v.parse::<usize>().ok(),
                "fp" => fp = u64::from_str_radix(v, 16).ok(),
                "version" => version_key = Some(v.to_string()),
                "value" => value = Some(v.to_string()),
                _ => {
                    return Err(format!(
                        "schemas.lock: unknown key {k:?} in {line:?}"))
                }
            }
        }
        match (key, n_fields, fp, version_key, value) {
            (Some(key), Some(n_fields), Some(fp), Some(version_key),
             Some(value)) => out.push(LockEntry {
                key, n_fields, fp, version_key, value,
            }),
            _ => {
                return Err(format!(
                    "schemas.lock: incomplete entry {line:?}"))
            }
        }
    }
    Ok(out)
}

/// Current (tree-derived) state of one tracked struct.
struct Current {
    n_fields: usize,
    fp: u64,
    value: String,
}

fn current_of(tree: &SourceTree, t: &Tracked)
              -> Result<Current, Diagnostic> {
    let diag = |file: &str, msg: String| Diagnostic {
        file: file.to_string(),
        line: 1,
        rule: "wire-schema",
        msg,
    };
    let sf = tree.get(t.struct_file).ok_or_else(|| {
        diag(t.struct_file, format!(
            "tracked file {} missing from the tree", t.struct_file))
    })?;
    let toks = lexer::lex(&sf.text).toks;
    let fields = struct_fields(&toks, t.struct_name).ok_or_else(|| {
        diag(t.struct_file, format!(
            "tracked struct {} not found in {}", t.struct_name,
            t.struct_file))
    })?;
    let vf = tree.get(t.version_file).ok_or_else(|| {
        diag(t.version_file, format!(
            "version file {} missing from the tree", t.version_file))
    })?;
    let vtoks = lexer::lex(&vf.text).toks;
    let value =
        const_value(&vtoks, t.version_const).ok_or_else(|| {
            diag(t.version_file, format!(
                "version constant {} not found in {}", t.version_const,
                t.version_file))
        })?;
    Ok(Current { n_fields: fields.len(), fp: fingerprint(&fields), value })
}

/// Check a tree against a lock. `lock: None` means the lock file is
/// missing — one diagnostic says so. Every mismatch explains the
/// repair (bump the version, or re-stamp the lock).
pub fn check(tree: &SourceTree, lock: Option<&str>, tracked: &[Tracked])
             -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let entries = match lock {
        None => {
            out.push(Diagnostic {
                file: "schemas.lock".to_string(),
                line: 1,
                rule: "wire-schema",
                msg: "schemas.lock missing; generate it with \
                      `rainbow lint --update-schemas` and commit it"
                    .to_string(),
            });
            return out;
        }
        Some(text) => match parse_lock(text) {
            Ok(e) => e,
            Err(msg) => {
                out.push(Diagnostic {
                    file: "schemas.lock".to_string(),
                    line: 1,
                    rule: "wire-schema",
                    msg,
                });
                return out;
            }
        },
    };
    for t in tracked {
        let cur = match current_of(tree, t) {
            Ok(c) => c,
            Err(d) => {
                out.push(d);
                continue;
            }
        };
        let key = entry_key(t);
        let Some(e) = entries.iter().find(|e| e.key == key) else {
            out.push(Diagnostic {
                file: t.struct_file.to_string(),
                line: 1,
                rule: "wire-schema",
                msg: format!(
                    "{key} is tracked but absent from schemas.lock; \
                     run `rainbow lint --update-schemas`"),
            });
            continue;
        };
        let layout_changed = cur.fp != e.fp;
        let version_changed = cur.value != e.value;
        match (layout_changed, version_changed) {
            (false, false) => {}
            (true, false) => out.push(Diagnostic {
                file: t.struct_file.to_string(),
                line: 1,
                rule: "wire-schema",
                msg: format!(
                    "{} changed layout ({} -> {} fields, fp \
                     {:016x} -> {:016x}) but {} is still {:?}: bump \
                     the version constant, then re-stamp with \
                     `rainbow lint --update-schemas`",
                    key, e.n_fields, cur.n_fields, e.fp, cur.fp,
                    e.version_key, e.value),
            }),
            (true, true) | (false, true) => out.push(Diagnostic {
                file: t.struct_file.to_string(),
                line: 1,
                rule: "wire-schema",
                msg: format!(
                    "schemas.lock is stale for {} ({} now {:?}, locked \
                     {:?}); run `rainbow lint --update-schemas` and \
                     commit the lock",
                    key, e.version_key, cur.value, e.value),
            }),
        }
    }
    // Lock entries for structs no longer tracked are noise that hides
    // real drift — flag them too.
    for e in &entries {
        if !tracked.iter().any(|t| entry_key(t) == e.key) {
            out.push(Diagnostic {
                file: "schemas.lock".to_string(),
                line: 1,
                rule: "wire-schema",
                msg: format!(
                    "lock entry {} matches no tracked struct; \
                     re-stamp with `rainbow lint --update-schemas`",
                    e.key),
            });
        }
    }
    out
}

/// Render a fresh lock for `tree`. Fails with a readable message if a
/// tracked struct or version constant cannot be found.
pub fn render_lock(tree: &SourceTree, tracked: &[Tracked])
                   -> Result<String, String> {
    let mut out = format!(
        "# rainbow lint wire-format lock — generated by \
         `rainbow lint --update-schemas`.\n\
         # A tracked struct's layout may not change unless its version \
         constant changes too.\n\
         schemalockversion={LOCK_VERSION}\n");
    for t in tracked {
        let cur = current_of(tree, t).map_err(|d| d.to_string())?;
        out.push_str(&format!(
            "struct={} fields={} fp={:016x} version={}::{} value={}\n",
            entry_key(t), cur.n_fields, cur.fp, t.version_file,
            t.version_const, cur.value));
    }
    Ok(out)
}

/// `--update-schemas`: regenerate the lock, but REFUSE if any struct's
/// layout drifted while its version constant did not — re-stamping
/// would silently bless exactly the drift the rule exists to catch.
pub fn update_lock(tree: &SourceTree, old_lock: Option<&str>,
                   tracked: &[Tracked]) -> Result<String, String> {
    if let Some(old) = old_lock {
        if let Ok(entries) = parse_lock(old) {
            for t in tracked {
                let Ok(cur) = current_of(tree, t) else { continue };
                let key = entry_key(t);
                if let Some(e) = entries.iter().find(|e| e.key == key) {
                    if cur.fp != e.fp && cur.value == e.value {
                        return Err(format!(
                            "--update-schemas refused: {} changed \
                             layout but {} is still {:?}; bump the \
                             version constant first",
                            key, e.version_key, e.value));
                    }
                }
            }
        }
        // An unparseable old lock is fine to overwrite: regenerating
        // is exactly how a corrupt lock is repaired.
    }
    render_lock(tree, tracked)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lexer::lex(src).toks
    }

    #[test]
    fn named_struct_fields_extracted() {
        let src = "/// doc\npub struct Rec {\n  /// doc\n  pub a: u64,\n  \
                   b: Vec<(u32, String)>,\n  #[allow(dead_code)]\n  \
                   pub(crate) c: bool,\n}";
        let f = struct_fields(&toks(src), "Rec").unwrap();
        assert_eq!(f, vec!["a : u64", "b : Vec < ( u32 , String ) >",
                           "c : bool"]);
    }

    #[test]
    fn tuple_and_unit_structs() {
        let f = struct_fields(&toks("struct P(pub u64, bool);"), "P")
            .unwrap();
        assert_eq!(f, vec!["0:u64", "1:bool"]);
        let f = struct_fields(&toks("struct U;"), "U").unwrap();
        assert!(f.is_empty());
    }

    #[test]
    fn generic_struct_body_found_past_bounds() {
        let src = "struct W<T: Ord, const N: usize> { x: [T; N] }";
        let f = struct_fields(&toks(src), "W").unwrap();
        assert_eq!(f, vec!["x : [ T ; N ]"]);
    }

    #[test]
    fn formatting_and_comments_do_not_change_fingerprint() {
        let a = struct_fields(
            &toks("struct S { a: u64, b: f64 }"), "S").unwrap();
        let b = struct_fields(
            &toks("pub struct S {\n  // why a exists\n  pub a: u64,\n\n  \
                   b:   f64,\n}"), "S").unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
        // ...but renames, reorders, retypes all do.
        for other in ["struct S { a2: u64, b: f64 }",
                      "struct S { b: f64, a: u64 }",
                      "struct S { a: u32, b: f64 }",
                      "struct S { a: u64, b: f64, c: u8 }"] {
            let o = struct_fields(&toks(other), "S").unwrap();
            assert_ne!(fingerprint(&a), fingerprint(&o), "{other}");
        }
    }

    #[test]
    fn const_values_int_and_str() {
        let src = "pub const METRICS_VERSION: u64 = 5;\n\
                   const VERSION: u64 = 2;\n\
                   pub const SCHEMA: &str = \"rainbow-bench-v1\";";
        let t = toks(src);
        assert_eq!(const_value(&t, "METRICS_VERSION").unwrap(), "5");
        assert_eq!(const_value(&t, "VERSION").unwrap(), "2");
        assert_eq!(const_value(&t, "SCHEMA").unwrap(), "rainbow-bench-v1");
        assert!(const_value(&t, "MISSING").is_none());
    }

    #[test]
    fn lock_round_trips() {
        let tracked: &[Tracked] = &[Tracked {
            struct_file: "w.rs",
            struct_name: "Wire",
            version_file: "w.rs",
            version_const: "V",
        }];
        let tree = SourceTree::from_files(&[(
            "w.rs", "pub const V: u64 = 1;\nstruct Wire { a: u64 }")]);
        let lock = render_lock(&tree, tracked).unwrap();
        let entries = parse_lock(&lock).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].key, "w.rs::Wire");
        assert_eq!(entries[0].value, "1");
        assert!(check(&tree, Some(&lock), tracked).is_empty());
    }

    #[test]
    fn corrupt_and_missing_locks_are_loud() {
        let tree = SourceTree::from_files(&[("a.rs", "")]);
        let d = check(&tree, None, &[]);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("missing"));
        let d = check(&tree, Some("schemalockversion=99\n"), &[]);
        assert!(d[0].msg.contains("unsupported"), "{d:?}");
        let d = check(&tree, Some("garbage"), &[]);
        assert_eq!(d[0].rule, "wire-schema");
    }
}
