//! Statistics helpers: histograms, CDFs, online means, percentiles.
//!
//! Used by the workload analyzers (Fig. 1 CDF, Table II histograms) and by
//! the bench harness.

/// Fixed-bucket histogram over `u64` samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Upper bounds (inclusive) of each bucket; the last bucket is open.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Bucket upper bounds, e.g. `[32, 64, 128, 256, 384, 512]` = Table II.
    pub fn with_bounds(bounds: &[u64]) -> Self {
        assert!(!bounds.is_empty());
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            total: 0,
        }
    }

    pub fn add(&mut self, x: u64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count(&self, bucket: usize) -> u64 {
        self.counts[bucket]
    }

    pub fn n_buckets(&self) -> usize {
        self.counts.len()
    }

    /// Fraction of samples in each bucket (0.0 if empty).
    pub fn fractions(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }
}

/// Empirical CDF: fraction of samples `<= x` at chosen evaluation points.
pub fn cdf_at(samples: &[u64], points: &[u64]) -> Vec<f64> {
    if samples.is_empty() {
        return vec![0.0; points.len()];
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    points
        .iter()
        .map(|&p| {
            let cnt = sorted.partition_point(|&s| s <= p);
            cnt as f64 / sorted.len() as f64
        })
        .collect()
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

pub fn stddev(samples: &[f64]) -> f64 {
    if samples.len() < 2 {
        return 0.0;
    }
    let m = mean(samples);
    (samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (samples.len() - 1) as f64)
        .sqrt()
}

/// Geometric mean — used for cross-workload speedup summaries.
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let s: f64 = samples.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / samples.len() as f64).exp()
}

/// Numerically-stable online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_like_table2() {
        let mut h = Histogram::with_bounds(&[32, 64, 128, 256, 384, 512]);
        for x in [1, 32, 33, 64, 100, 200, 300, 400, 512, 600] {
            h.add(x);
        }
        assert_eq!(h.total(), 10);
        assert_eq!(h.count(0), 2); // 1, 32
        assert_eq!(h.count(1), 2); // 33, 64
        assert_eq!(h.count(2), 1); // 100
        assert_eq!(h.count(3), 1); // 200
        assert_eq!(h.count(4), 1); // 300
        assert_eq!(h.count(5), 2); // 400, 512
        assert_eq!(h.count(6), 1); // 600 (open bucket)
    }

    #[test]
    fn histogram_fractions_sum_to_one() {
        let mut h = Histogram::with_bounds(&[10, 20]);
        for x in 0..100 {
            h.add(x);
        }
        let s: f64 = h.fractions().iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let samples: Vec<u64> = (0..1000).map(|i| i % 97).collect();
        let pts: Vec<u64> = (0..100).collect();
        let cdf = cdf_at(&samples, &pts);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert!((cdf[99] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_extremes() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37).collect();
        let mut o = Online::default();
        for &x in &xs {
            o.add(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-9);
        assert!((o.stddev() - stddev(&xs)).abs() < 1e-9);
    }

    #[test]
    fn geomean_of_constants() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-9);
    }
}
