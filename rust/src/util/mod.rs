//! Offline substrates: PRNG, stats, CLI, config parsing, property testing,
//! bench harness, and table emission. These replace the crates.io
//! dependencies (rand, clap, toml, proptest, criterion) that are
//! unavailable in this environment.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tables;
pub mod tomlite;
