//! TOML-subset config parser (serde/toml unavailable offline).
//!
//! Supports: `[section]` headers, `key = value` with integers (incl. `_`
//! separators and k/m/g suffixes), floats, booleans, quoted strings, and
//! `#` comments. Flat `section.key` namespacing — enough for the system
//! config files in `configs/`.

use std::collections::BTreeMap;

use super::cli::parse_u64;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(u64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: keys are `section.key` (or bare `key` before any header).
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unclosed [section]", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .ok_or_else(|| format!("line {}: bad value {:?}", lineno + 1, v.trim()))?;
            map.insert(key, value);
        }
        Ok(Doc { map })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Value::as_u64).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"') {
        return body.strip_suffix('"').map(|b| Value::Str(b.to_string()));
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Some(v) = parse_u64(&cleaned) {
        // Distinguish "1e8" style floats written as ints: parse_u64 handles it.
        return Some(Value::Int(v));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # top comment
            name = "rainbow"
            [dram]
            size = 4g          # with suffix
            read_ns = 13.5
            enabled = true
            rows = 32_768
            [nvm]
            size = 32g
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "rainbow");
        assert_eq!(doc.u64_or("dram.size", 0), 4 << 30);
        assert_eq!(doc.f64_or("dram.read_ns", 0.0), 13.5);
        assert!(doc.bool_or("dram.enabled", false));
        assert_eq!(doc.u64_or("dram.rows", 0), 32768);
        assert_eq!(doc.u64_or("nvm.size", 0), 32 << 30);
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = Doc::parse("").unwrap();
        assert_eq!(doc.u64_or("x", 9), 9);
        assert_eq!(doc.str_or("y", "z"), "z");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("key value-without-equals").is_err());
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("k = @@@").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Doc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }
}
