//! Leveled diagnostics sink for the report/fleet layer.
//!
//! Replaces the ad-hoc `eprintln!` warnings that used to be scattered
//! through `report/{queue,replica,wal,netstore}.rs` (the `raw-eprintln`
//! lint rule now bans them there). Three levels, filtered by the
//! `RAINBOW_LOG` environment variable (`warn` | `info` | `debug`;
//! unset or unknown means `warn`, preserving the old always-on warning
//! behaviour). Output goes to stderr so machine-readable stdout
//! (tables, JSON traces) stays clean.
//!
//! Tests capture instead of printing: [`capture`] installs a global
//! buffer for the duration of a closure and returns every message
//! emitted, bypassing the level filter so assertions do not depend on
//! the caller's environment. Captures are serialized by a global gate
//! so parallel tests cannot interleave buffers.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// Message severity, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Warn = 0,
    Info = 1,
    Debug = 2,
}

impl Level {
    /// Stderr prefix for the level.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Warn => "warning",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Cached threshold: 0..=2 is a [`Level`], `UNSET` means the env var
/// has not been consulted yet.
const UNSET: u8 = u8::MAX;
static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != UNSET {
        return t;
    }
    let t = match std::env::var("RAINBOW_LOG").ok().as_deref() {
        Some("debug") => Level::Debug as u8,
        Some("info") => Level::Info as u8,
        // Unset or unrecognized: warnings only, the old behaviour.
        _ => Level::Warn as u8,
    };
    THRESHOLD.store(t, Ordering::Relaxed);
    t
}

/// Test-only capture buffer; `None` means "print to stderr".
static CAPTURE: Mutex<Option<Vec<(Level, String)>>> = Mutex::new(None);
/// Serializes concurrent [`capture`] calls (tests run in parallel).
static CAPTURE_GATE: Mutex<()> = Mutex::new(());

fn emit(level: Level, msg: &str) {
    {
        let mut cap = match CAPTURE.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(buf) = cap.as_mut() {
            buf.push((level, msg.to_string()));
            return;
        }
    }
    if (level as u8) <= threshold() {
        eprintln!("{}: {}", level.tag(), msg);
    }
}

/// Something went wrong but the operation degraded instead of failing
/// (replica down, stale log record, worker exit). Printed by default.
pub fn warn(msg: &str) {
    emit(Level::Warn, msg);
}

/// Progress and lifecycle notes (`RAINBOW_LOG=info`).
pub fn info(msg: &str) {
    emit(Level::Info, msg);
}

/// High-volume diagnostics (`RAINBOW_LOG=debug`).
pub fn debug(msg: &str) {
    emit(Level::Debug, msg);
}

/// Run `f` with all log output captured; returns `f`'s result and the
/// messages emitted, regardless of the `RAINBOW_LOG` threshold.
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<(Level, String)>) {
    let _gate = match CAPTURE_GATE.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    {
        let mut cap = match CAPTURE.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        *cap = Some(Vec::new());
    }
    let r = f();
    let logs = {
        let mut cap = match CAPTURE.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        cap.take().unwrap_or_default()
    };
    (r, logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_collects_all_levels_in_order() {
        let ((), logs) = capture(|| {
            warn("a failed");
            info("b progressed");
            debug("c detailed");
        });
        assert_eq!(logs.len(), 3);
        assert_eq!(logs[0], (Level::Warn, "a failed".to_string()));
        assert_eq!(logs[1].0, Level::Info);
        assert_eq!(logs[2].0, Level::Debug);
    }

    #[test]
    fn capture_is_scoped() {
        let ((), logs) = capture(|| warn("inside"));
        assert_eq!(logs.len(), 1);
        // After the capture ends the buffer is gone; this emit goes to
        // stderr (or is filtered) and must not leak into a later capture.
        debug("outside");
        let ((), logs) = capture(|| {});
        assert!(logs.is_empty());
    }

    #[test]
    fn levels_order_and_tags() {
        assert!(Level::Warn < Level::Info && Level::Info < Level::Debug);
        assert_eq!(Level::Warn.tag(), "warning");
        assert_eq!(Level::Info.tag(), "info");
        assert_eq!(Level::Debug.tag(), "debug");
    }
}
