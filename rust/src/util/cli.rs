//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, subcommands, and auto-generated `--help` text.

use std::collections::HashMap;
use std::fmt::Write as _;

/// Declarative option spec used for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments: subcommand, `--key value` options (repeatable —
/// `get` returns the last occurrence, `get_all` every one, so options
/// like `--set knob=value` can stack), positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: HashMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding argv[0]). `specs` marks which options are
    /// boolean flags; unknown options are accepted as strings.
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let flag_names: Vec<&str> =
            specs.iter().filter(|s| s.is_flag).map(|s| s.name).collect();
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts
                        .entry(k.to_string())
                        .or_default()
                        .push(v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        // treat as flag even if undeclared
                        out.flags.push(body.to_string());
                    } else {
                        out.opts
                            .entry(body.to_string())
                            .or_default()
                            .push(it.next().unwrap().clone());
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable option, in argv order.
    pub fn get_all(&self, name: &str) -> &[String] {
        self.opts.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => parse_u64(v)
                .ok_or_else(|| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{name}: expected float, got {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }
}

/// Parse integers with optional `k`/`m`/`g` (binary) or `e`-notation
/// suffixes: "4096", "64k", "2m", "1e8".
pub fn parse_u64(s: &str) -> Option<u64> {
    let s = s.trim();
    if let Ok(v) = s.parse::<u64>() {
        return Some(v);
    }
    if s.contains('e') || s.contains('E') {
        let f = s.parse::<f64>().ok()?;
        if f >= 0.0 && f.fract() == 0.0 {
            return Some(f as u64);
        }
        return None;
    }
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1u64 << 10),
        'm' | 'M' => (&s[..s.len() - 1], 1u64 << 20),
        'g' | 'G' => (&s[..s.len() - 1], 1u64 << 30),
        _ => return None,
    };
    num.trim().parse::<u64>().ok().map(|v| v * mult)
}

/// Render a help screen from specs.
pub fn help_text(prog: &str, about: &str, commands: &[(&str, &str)],
                 specs: &[OptSpec]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{prog} — {about}\n");
    if !commands.is_empty() {
        let _ = writeln!(s, "COMMANDS:");
        for (c, h) in commands {
            let _ = writeln!(s, "  {c:<18} {h}");
        }
        let _ = writeln!(s);
    }
    let _ = writeln!(s, "OPTIONS:");
    for o in specs {
        let d = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        let _ = writeln!(s, "  --{:<20} {}{}", o.name, o.help, d);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[OptSpec] = &[OptSpec {
        name: "verbose",
        help: "",
        default: None,
        is_flag: true,
    }];

    #[test]
    fn parse_command_opts_flags() {
        let a = Args::parse(
            &sv(&["run", "--app", "mcf", "--policy=rainbow", "--verbose",
                  "extra"]),
            SPECS,
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("app"), Some("mcf"));
        assert_eq!(a.get("policy"), Some("rainbow"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(&sv(&["run", "--fast"]), &[]).unwrap();
        assert!(a.flag("fast"));
    }

    #[test]
    fn adjacent_flags() {
        let a = Args::parse(&sv(&["--a", "--b", "val"]), &[]).unwrap();
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = Args::parse(
            &sv(&["sweep", "--set", "a=1", "--set=b=2", "--set", "c=3"]),
            &[],
        )
        .unwrap();
        assert_eq!(a.get_all("set"), &["a=1", "b=2", "c=3"]);
        assert_eq!(a.get("set"), Some("c=3"), "get returns the last");
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn numeric_parsing_with_suffixes() {
        assert_eq!(parse_u64("4096"), Some(4096));
        assert_eq!(parse_u64("64k"), Some(64 << 10));
        assert_eq!(parse_u64("2M"), Some(2 << 20));
        assert_eq!(parse_u64("1g"), Some(1 << 30));
        assert_eq!(parse_u64("1e8"), Some(100_000_000));
        assert_eq!(parse_u64("oops"), None);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse(&sv(&["run", "--n", "50"]), &[]).unwrap();
        assert_eq!(a.get_u64("n", 7).unwrap(), 50);
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
        assert!(a.get_f64("n", 0.0).unwrap() == 50.0);
        assert!(Args::parse(&sv(&["run", "--n", "x"]), &[])
            .unwrap()
            .get_u64("n", 0)
            .is_err());
    }
}
