//! Criterion-subset benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` binary is declared with `harness = false` and drives
//! this module: warmup, fixed-sample measurement, mean/median/stddev
//! reporting, and (for the experiment benches) pretty table emission via
//! [`super::tables`].

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        super::stats::mean(&self.samples_ns)
    }

    pub fn median_ns(&self) -> f64 {
        super::stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn stddev_ns(&self) -> f64 {
        super::stats::stddev(&self.samples_ns)
    }

    pub fn report(&self) {
        println!(
            "bench {:<40} mean {:>12}  median {:>12}  stddev {:>10}",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.stddev_ns()),
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup + sampling, criterion-style.
pub struct Bencher {
    warmup: Duration,
    samples: usize,
    min_iters_per_sample: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            samples: 20,
            min_iters_per_sample: 1,
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    /// Measure `f`, auto-scaling iterations per sample to ~10ms.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + estimate cost.
        let wstart = Instant::now();
        let mut iters = 0u64;
        while wstart.elapsed() < self.warmup || iters == 0 {
            f();
            iters += 1;
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / iters as f64;
        let target_ns = 10e6; // 10 ms per sample
        let iters_per_sample =
            ((target_ns / per_iter.max(1.0)) as u64).max(self.min_iters_per_sample);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let m = Measurement { name: name.to_string(), samples_ns: samples };
        m.report();
        m
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher::new()
            .warmup(Duration::from_millis(5))
            .samples(3);
        let m = b.run("spin", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert_eq!(m.samples_ns.len(), 3);
        assert!(m.mean_ns() > 0.0);
        assert!(m.median_ns() > 0.0);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }
}
