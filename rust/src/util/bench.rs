//! Criterion-subset benchmark harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` binary is declared with `harness = false` and drives
//! this module: warmup, fixed-sample measurement, mean/median/stddev
//! reporting, and (for the experiment benches) pretty table emission via
//! [`super::tables`].

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Iterations timed per sample (total work = this × samples). The
    /// machine-readable perf reports record it so a reader can tell a
    /// 10-iteration flier from a million-iteration steady state.
    pub iters_per_sample: u64,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        super::stats::mean(&self.samples_ns)
    }

    pub fn median_ns(&self) -> f64 {
        super::stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn stddev_ns(&self) -> f64 {
        super::stats::stddev(&self.samples_ns)
    }

    /// The headline per-operation cost: the median sample (robust to
    /// scheduler fliers, the figure `BENCH_*.json` publishes).
    pub fn ns_per_op(&self) -> f64 {
        self.median_ns()
    }

    /// Operations per second implied by [`Measurement::ns_per_op`].
    pub fn ops_per_sec(&self) -> f64 {
        let ns = self.ns_per_op();
        if ns > 0.0 { 1e9 / ns } else { 0.0 }
    }

    /// Total iterations timed across all samples.
    pub fn total_iters(&self) -> u64 {
        self.iters_per_sample * self.samples_ns.len() as u64
    }

    pub fn report(&self) {
        println!(
            "bench {:<40} mean {:>12}  median {:>12}  stddev {:>10}",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.median_ns()),
            fmt_ns(self.stddev_ns()),
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with warmup + sampling, criterion-style.
pub struct Bencher {
    warmup: Duration,
    samples: usize,
    target_per_sample: Duration,
    min_iters_per_sample: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            samples: 20,
            target_per_sample: Duration::from_millis(10),
            min_iters_per_sample: 1,
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Honor the `RAINBOW_BENCH_SAMPLES` / `RAINBOW_BENCH_WARMUP_MS` /
    /// `RAINBOW_BENCH_TARGET_MS` env caps on top of the defaults, so CI
    /// smoke jobs can run the same harness in milliseconds.
    pub fn from_env() -> Self {
        fn env_u64(key: &str) -> Option<u64> {
            std::env::var(key).ok().and_then(|v| v.parse().ok())
        }
        let mut b = Bencher::default();
        if let Some(n) = env_u64("RAINBOW_BENCH_SAMPLES") {
            b = b.samples(n as usize);
        }
        if let Some(ms) = env_u64("RAINBOW_BENCH_WARMUP_MS") {
            b = b.warmup(Duration::from_millis(ms));
        }
        if let Some(ms) = env_u64("RAINBOW_BENCH_TARGET_MS") {
            b = b.target_per_sample(Duration::from_millis(ms));
        }
        b
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    /// Per-sample time budget iterations are auto-scaled toward.
    pub fn target_per_sample(mut self, d: Duration) -> Self {
        self.target_per_sample = d;
        self
    }

    /// Measure `f`, auto-scaling iterations per sample to the target
    /// per-sample budget (default ~10 ms).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + estimate cost.
        let wstart = Instant::now();
        let mut iters = 0u64;
        while wstart.elapsed() < self.warmup || iters == 0 {
            f();
            iters += 1;
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / iters as f64;
        let target_ns = self.target_per_sample.as_nanos() as f64;
        let iters_per_sample =
            ((target_ns / per_iter.max(1.0)) as u64).max(self.min_iters_per_sample);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            samples_ns: samples,
            iters_per_sample,
        };
        m.report();
        m
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher::new()
            .warmup(Duration::from_millis(5))
            .samples(3);
        let m = b.run("spin", || {
            black_box((0..100u64).sum::<u64>());
        });
        assert_eq!(m.samples_ns.len(), 3);
        assert!(m.mean_ns() > 0.0);
        assert!(m.median_ns() > 0.0);
        assert!(m.iters_per_sample >= 1);
        assert_eq!(m.total_iters(), m.iters_per_sample * 3);
        // ns/op and ops/sec are reciprocal views of the same median.
        let product = m.ns_per_op() * m.ops_per_sec();
        assert!((product - 1e9).abs() < 1.0, "got {product}");
    }

    #[test]
    fn env_caps_parse() {
        // from_env with no vars set equals the defaults (tier-1 never
        // sets the caps; CI smoke does).
        let b = Bencher::from_env();
        let m = b
            .warmup(Duration::from_millis(1))
            .samples(2)
            .target_per_sample(Duration::from_millis(1))
            .run("spin-env", || {
                black_box((0..10u64).sum::<u64>());
            });
        assert_eq!(m.samples_ns.len(), 2);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }
}
