//! Aligned text tables + CSV emission for regenerating the paper's
//! tables/figures from bench binaries.

use std::fmt::Write as _;

/// Column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(),
                   "row arity mismatch in table {:?}", self.title);
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with padding; first column left-aligned, rest right-aligned.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    let _ = write!(out, "{:<w$}", c, w = widths[0]);
                } else {
                    let _ = write!(out, "  {:>w$}", c, w = widths[i]);
                }
            }
            let _ = writeln!(out);
        };
        line(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// CSV form (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}",
            self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and optionally persist CSV next to the results dir.
    pub fn emit(&self, csv_path: Option<&str>) {
        print!("{}", self.render());
        println!();
        if let Some(path) = csv_path {
            if let Some(dir) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(path, self.to_csv()) {
                eprintln!("warn: failed to write {path}: {e}");
            } else {
                println!("csv -> {path}");
            }
        }
    }
}

/// Format helpers shared by report/bench code.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn mb(bytes: u64) -> String {
    if bytes >= 1 << 30 {
        format!("{:.1} GB", bytes as f64 / (1u64 << 30) as f64)
    } else {
        format!("{:.1} MB", bytes as f64 / (1u64 << 20) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("T", &["app", "ipc"]);
        t.row_str(&["mcf", "1.25"]);
        t.row_str(&["graph500", "0.33"]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // right alignment: both value cells end at the same column
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_str(&["x,y", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(pct(0.4305), "43.05%");
        assert_eq!(mb(3 << 20), "3.0 MB");
        assert_eq!(mb(2 << 30), "2.0 GB");
    }
}
