//! Deterministic PRNG + distributions.
//!
//! crates.io is unavailable offline, so the simulator carries its own
//! xoshiro256** generator (Blackman/Vigna) seeded via SplitMix64, plus the
//! distributions the workload generators need (uniform, Zipf, shuffle).
//! Every simulation component owns a seeded `Rng`, which makes whole
//! experiments bit-reproducible.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = splitmix64(&mut x);
        }
        // Avoid the all-zero state (cannot occur from splitmix64, but be safe).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's unbiased multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use a set-based pick; else shuffle.
        if k * 4 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n as u64) as usize;
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

/// Zipf(α) sampler over `[0, n)` by rejection-inversion (Hörmann &
/// Derflinger; same scheme as Apache Commons' sampler).
///
/// Hot-page skew in the workload generators is Zipfian: rank-r page gets
/// probability ∝ 1/(r+1)^α. Deterministic given the `Rng` stream.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1, "Zipf needs n >= 1");
        assert!(alpha > 0.0, "alpha must be > 0");
        let h_integral_x1 = h_integral(alpha, 1.5) - 1.0;
        let h_integral_n = h_integral(alpha, n as f64 + 0.5);
        let s = 2.0
            - h_integral_inv(alpha,
                             h_integral(alpha, 2.5) - h(alpha, 2.0));
        Zipf { n, alpha, h_integral_x1, h_integral_n, s }
    }

    /// Draw a rank in `[0, n)` (0 = hottest).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_integral_n
                + rng.f64() * (self.h_integral_x1 - self.h_integral_n);
            let x = h_integral_inv(self.alpha, u);
            let k = x.round().clamp(1.0, self.n as f64);
            if k - x <= self.s
                || u >= h_integral(self.alpha, k + 0.5) - h(self.alpha, k)
            {
                return (k as u64) - 1;
            }
        }
    }
}

/// ∫ t^-α dt from 1 to x (log form at α = 1 for numerical stability).
#[inline]
fn h_integral(alpha: f64, x: f64) -> f64 {
    if (alpha - 1.0).abs() < 1e-9 {
        x.ln()
    } else {
        (x.powf(1.0 - alpha) - 1.0) / (1.0 - alpha)
    }
}

#[inline]
fn h(alpha: f64, x: f64) -> f64 {
    x.powf(-alpha)
}

#[inline]
fn h_integral_inv(alpha: f64, v: f64) -> f64 {
    if (alpha - 1.0).abs() < 1e-9 {
        v.exp()
    } else {
        (1.0 + (1.0 - alpha) * v).powf(1.0 / (1.0 - alpha)).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(99);
        let mean: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} not ~0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100usize, 10usize), (100, 90), (16, 16), (1000, 3)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_skew_orders_ranks() {
        let z = Zipf::new(1000, 0.99);
        let mut r = Rng::new(11);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // rank 0 clearly hotter than rank 10, which beats rank 100.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[100]);
        // top-10 ranks carry a large fraction (zipf 0.99 over 1000: ~45%+)
        let top10: u32 = counts[..10].iter().sum();
        assert!(top10 as f64 > 0.3 * 100_000.0, "top10={top10}");
    }

    #[test]
    fn zipf_in_range() {
        let z = Zipf::new(7, 1.2);
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 7);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
