//! Minimal JSON reader/writer (serde_json is unavailable offline).
//!
//! Backs the machine-readable perf reports (`BENCH_*.json`, see
//! [`crate::perf`]): the emitter serializes through [`Json`] and the
//! schema validator parses through it, so the two can never drift on
//! syntax. Objects preserve insertion order; numbers are f64 (ample for
//! iteration counts and ns/op figures).

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0
                && *x <= 9_007_199_254_740_992.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation and a trailing newline (the
    /// committed `BENCH_*.json` files are meant to be diffed).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    /// Serialize on one line with no insignificant whitespace — the
    /// JSON-lines trace format (`run --trace-out`) emits one compact
    /// document per line, so records must never contain raw newlines.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) if items.is_empty() => out.push_str("[]"),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    item.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) if fields.is_empty() => out.push_str("{}"),
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; the emitter never produces them, but a
        // value sneaking in must not yield an unparsable file.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        // {:?} is Rust's shortest round-trip f64 form.
        let _ = write!(out, "{x:?}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json: {msg} at byte {}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!(
                "unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-') {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii digits are utf-8");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Reject surrogates rather than pairing them:
                            // our emitter never splits astral chars.
                            match char::from_u32(cp) {
                                Some(c) => s.push(c),
                                None => return Err(self.err(
                                    "unsupported \\u surrogate")),
                            }
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one full UTF-8 character.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        for (text, v) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5", Json::Num(-3.5)),
            ("1e6", Json::Num(1e6)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(parse(text).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn nested_document_roundtrips_through_pretty() {
        let doc = Json::Obj(vec![
            ("schema".into(), Json::Str("rainbow-bench-v1".into())),
            ("n".into(), Json::Num(3.0)),
            ("ns_per_op".into(), Json::Num(41.25)),
            ("tags".into(), Json::Arr(vec![
                Json::Str("a \"quoted\" name".into()),
                Json::Null,
                Json::Bool(false),
            ])),
            ("empty_arr".into(), Json::Arr(vec![])),
            ("empty_obj".into(), Json::Obj(vec![])),
        ]);
        let text = doc.pretty();
        assert!(text.ends_with('\n'));
        assert_eq!(parse(&text).unwrap(), doc);
    }

    #[test]
    fn object_order_and_lookup() {
        let j = parse(r#"{"b": 1, "a": 2}"#).unwrap();
        assert_eq!(j.get("a").and_then(Json::as_u64), Some(2));
        assert_eq!(j.get("b").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("c"), None);
        let keys: Vec<&str> = j.as_obj().unwrap()
            .iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["b", "a"], "insertion order preserved");
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\nb\t\"c\"\u0041""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\"A"));
        // Writer escapes control characters back out.
        let text = Json::Str("x\u{0001}y".into()).pretty();
        assert!(text.contains("\\u0001"));
        assert_eq!(parse(&text).unwrap().as_str(), Some("x\u{0001}y"));
    }

    #[test]
    fn unicode_passthrough() {
        let doc = Json::Str("π ≈ 3.14159".into());
        assert_eq!(parse(&doc.pretty()).unwrap(), doc);
    }

    #[test]
    fn malformed_documents_rejected() {
        for bad in ["", "{", "[1,", "{\"a\":}", "\"unterminated",
                    "nul", "01x", "{\"a\":1}garbage", "[1 2]",
                    "\"bad \\q escape\"", "\"\\ud800\""] {
            assert!(parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn as_u64_guards_domain() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("12345").unwrap().as_u64(), Some(12345));
    }
}
