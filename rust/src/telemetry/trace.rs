//! JSON-lines trace emission and strict read-back.
//!
//! `rainbow run --trace-out PATH` writes one compact JSON document per
//! line through [`crate::util::json`]; `rainbow trace-summary PATH`
//! parses it back with the strict reader here, which doubles as the
//! schema validator the CI `trace-smoke` job runs. Record catalog
//! (documented in `docs/MANUAL.md` §Observability):
//!
//! * `meta`    — one per file, first line: trace version + run identity.
//! * `epoch`   — one per sampling interval: [`EpochSample`] deltas.
//! * `event`   — one per held ring entry: [`Event`] (cycle, kind, a, b).
//! * `summary` — one per file, last line: end-of-run scalars and the
//!   mergeable latency quantiles.
//!
//! Emission is deterministic: records are ordered (meta, epochs by
//! epoch index, events oldest-to-newest, summary) and every number is
//! an exact integer except the summary's `ipc`, so two runs of the
//! same spec produce byte-identical files (pinned in
//! `rust/tests/sweep_determinism.rs`).

use crate::sim::metrics::RunMetrics;
use crate::util::json::Json;

use super::{EpochSample, Event, EventKind, Telemetry, TRACE_VERSION};

/// Run-identity header of a trace file (the `meta` record). Schema-
/// locked against [`TRACE_VERSION`] in `rust/schemas.lock`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceMeta {
    pub workload: String,
    pub policy: String,
    /// Spec fingerprint (cache identity of the run).
    pub fingerprint: String,
    pub interval_cycles: u64,
    pub instructions: u64,
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

fn meta_line(meta: &TraceMeta, tel: &Telemetry) -> Json {
    obj(vec![
        ("type", Json::Str("meta".into())),
        ("traceversion", num(TRACE_VERSION)),
        ("workload", Json::Str(meta.workload.clone())),
        ("policy", Json::Str(meta.policy.clone())),
        ("fingerprint", Json::Str(meta.fingerprint.clone())),
        ("interval_cycles", num(meta.interval_cycles)),
        ("instructions", num(meta.instructions)),
        ("events_dropped", num(tel.events_dropped())),
        ("epochs_dropped", num(tel.series_dropped())),
    ])
}

fn epoch_line(s: &EpochSample) -> Json {
    obj(vec![
        ("type", Json::Str("epoch".into())),
        ("epoch", num(s.epoch)),
        ("cycle", num(s.cycle)),
        ("instructions", num(s.instructions)),
        ("tlb_misses", num(s.tlb_misses)),
        ("migrated_bytes", num(s.migrated_bytes)),
        ("dram_row_hits", num(s.dram_row_hits)),
        ("dram_row_misses", num(s.dram_row_misses)),
        ("nvm_row_hits", num(s.nvm_row_hits)),
        ("nvm_row_misses", num(s.nvm_row_misses)),
        ("dram_util_bp", num(s.dram_util_bp)),
    ])
}

fn event_line(e: &Event) -> Json {
    obj(vec![
        ("type", Json::Str("event".into())),
        ("cycle", num(e.cycle)),
        ("kind", Json::Str(e.kind.name().into())),
        ("a", num(e.a)),
        ("b", num(e.b)),
    ])
}

fn summary_line(m: &RunMetrics, tel: &Telemetry) -> Json {
    obj(vec![
        ("type", Json::Str("summary".into())),
        ("cycles", num(m.cycles)),
        ("instructions", num(m.instructions)),
        ("ipc", Json::Num(m.ipc())),
        ("migrations", num(m.migrations)),
        ("migrated_bytes", num(m.migrated_bytes)),
        ("shootdowns", num(m.shootdowns)),
        ("mig_lat_p50", num(m.mig_lat_p50)),
        ("mig_lat_p95", num(m.mig_lat_p95)),
        ("mig_lat_p99", num(m.mig_lat_p99)),
        ("ptw_lat_p50", num(m.ptw_lat_p50)),
        ("ptw_lat_p95", num(m.ptw_lat_p95)),
        ("ptw_lat_p99", num(m.ptw_lat_p99)),
        ("events_total", num(tel.events_held() as u64
            + tel.events_dropped())),
        ("epochs", num(tel.epochs())),
    ])
}

/// Render a complete trace: meta, epochs, events, summary — one
/// compact JSON document per line.
pub fn render_trace(meta: &TraceMeta, metrics: &RunMetrics,
                    tel: &Telemetry) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str(&meta_line(meta, tel).compact());
    out.push('\n');
    for s in tel.series() {
        out.push_str(&epoch_line(s).compact());
        out.push('\n');
    }
    for e in tel.events() {
        out.push_str(&event_line(e).compact());
        out.push('\n');
    }
    out.push_str(&summary_line(metrics, tel).compact());
    out.push('\n');
    out
}

/// Everything a strict read of a trace file yields.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    pub meta: TraceMeta,
    pub epochs: Vec<EpochSample>,
    pub events: Vec<Event>,
    /// Event counts indexed like [`EventKind::ALL`].
    pub event_counts: [u64; EventKind::ALL.len()],
    pub cycles: u64,
    pub run_instructions: u64,
    pub ipc: f64,
    pub migrations: u64,
    pub mig_lat_p99: u64,
    pub ptw_lat_p99: u64,
}

fn req_u64(j: &Json, key: &str, line: usize) -> Result<u64, String> {
    j.get(key).and_then(Json::as_u64).ok_or_else(|| {
        format!("trace line {line}: missing or non-integer {key:?}")
    })
}

fn req_str(j: &Json, key: &str, line: usize) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| {
            format!("trace line {line}: missing or non-string {key:?}")
        })
}

/// Strict parse of a JSON-lines trace: every line must be valid JSON,
/// every record type known with all required fields present and typed,
/// the `meta` record first (with a matching `traceversion`) and the
/// `summary` record last. This is the locked-schema validation the CI
/// `trace-smoke` job runs over emitted traces.
pub fn read_trace(text: &str) -> Result<TraceSummary, String> {
    let mut out = TraceSummary::default();
    let mut saw_meta = false;
    let mut saw_summary = false;
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        if line.trim().is_empty() {
            return Err(format!("trace line {lineno}: blank line"));
        }
        let j = crate::util::json::parse(line)
            .map_err(|e| format!("trace line {lineno}: {e}"))?;
        if saw_summary {
            return Err(format!(
                "trace line {lineno}: records after the summary"));
        }
        let ty = req_str(&j, "type", lineno)?;
        match ty.as_str() {
            "meta" => {
                if saw_meta {
                    return Err(format!(
                        "trace line {lineno}: duplicate meta record"));
                }
                if lineno != 1 {
                    return Err(format!(
                        "trace line {lineno}: meta must be the first line"));
                }
                let v = req_u64(&j, "traceversion", lineno)?;
                if v != TRACE_VERSION {
                    return Err(format!(
                        "trace version {v} unsupported \
                         (expected {TRACE_VERSION})"));
                }
                out.meta = TraceMeta {
                    workload: req_str(&j, "workload", lineno)?,
                    policy: req_str(&j, "policy", lineno)?,
                    fingerprint: req_str(&j, "fingerprint", lineno)?,
                    interval_cycles: req_u64(&j, "interval_cycles", lineno)?,
                    instructions: req_u64(&j, "instructions", lineno)?,
                };
                saw_meta = true;
            }
            "epoch" => {
                if !saw_meta {
                    return Err(format!(
                        "trace line {lineno}: epoch before meta"));
                }
                out.epochs.push(EpochSample {
                    epoch: req_u64(&j, "epoch", lineno)?,
                    cycle: req_u64(&j, "cycle", lineno)?,
                    instructions: req_u64(&j, "instructions", lineno)?,
                    tlb_misses: req_u64(&j, "tlb_misses", lineno)?,
                    migrated_bytes: req_u64(&j, "migrated_bytes", lineno)?,
                    dram_row_hits: req_u64(&j, "dram_row_hits", lineno)?,
                    dram_row_misses: req_u64(&j, "dram_row_misses", lineno)?,
                    nvm_row_hits: req_u64(&j, "nvm_row_hits", lineno)?,
                    nvm_row_misses: req_u64(&j, "nvm_row_misses", lineno)?,
                    dram_util_bp: req_u64(&j, "dram_util_bp", lineno)?,
                });
            }
            "event" => {
                if !saw_meta {
                    return Err(format!(
                        "trace line {lineno}: event before meta"));
                }
                let kind_name = req_str(&j, "kind", lineno)?;
                let kind =
                    EventKind::from_name(&kind_name).ok_or_else(|| {
                        format!("trace line {lineno}: unknown event kind \
                                 {kind_name:?}")
                    })?;
                let idx = EventKind::ALL
                    .iter()
                    .position(|k| *k == kind)
                    .expect("kind came from ALL");
                out.event_counts[idx] += 1;
                out.events.push(Event {
                    cycle: req_u64(&j, "cycle", lineno)?,
                    kind,
                    a: req_u64(&j, "a", lineno)?,
                    b: req_u64(&j, "b", lineno)?,
                });
            }
            "summary" => {
                out.cycles = req_u64(&j, "cycles", lineno)?;
                out.run_instructions = req_u64(&j, "instructions", lineno)?;
                out.ipc = j
                    .get("ipc")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!(
                        "trace line {lineno}: missing or non-number \"ipc\""))?;
                out.migrations = req_u64(&j, "migrations", lineno)?;
                out.mig_lat_p99 = req_u64(&j, "mig_lat_p99", lineno)?;
                out.ptw_lat_p99 = req_u64(&j, "ptw_lat_p99", lineno)?;
                saw_summary = true;
            }
            other => {
                return Err(format!(
                    "trace line {lineno}: unknown record type {other:?}"));
            }
        }
    }
    if !saw_meta {
        return Err("trace: no meta record (empty file?)".to_string());
    }
    if !saw_summary {
        return Err("trace: missing summary record (truncated?)".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::CumStats;

    fn sample_trace() -> String {
        let mut tel = Telemetry::default();
        tel.enable(16, 16);
        tel.event(5, EventKind::MigrationStart, 9, 2);
        tel.event(11, EventKind::MigrationDone, 2, 6);
        tel.event(40, EventKind::Shootdown, 77, 3);
        tel.epoch_roll(100, 9, CumStats {
            instructions: 50, tlb_misses: 4, migrated_bytes: 4096,
            ..Default::default()
        }, 1234);
        let m = RunMetrics {
            instructions: 50,
            cycles: 109,
            migrations: 1,
            migrated_bytes: 4096,
            mig_lat_p50: 7,
            mig_lat_p95: 7,
            mig_lat_p99: 7,
            ptw_lat_p50: 31,
            ptw_lat_p95: 63,
            ptw_lat_p99: 63,
            ..Default::default()
        };
        let meta = TraceMeta {
            workload: "DICT".into(),
            policy: "rainbow".into(),
            fingerprint: "deadbeef".into(),
            interval_cycles: 100,
            instructions: 50,
        };
        render_trace(&meta, &m, &tel)
    }

    #[test]
    fn render_and_read_round_trip() {
        let text = sample_trace();
        let s = read_trace(&text).unwrap();
        assert_eq!(s.meta.workload, "DICT");
        assert_eq!(s.meta.policy, "rainbow");
        assert_eq!(s.epochs.len(), 1);
        assert_eq!(s.epochs[0].tlb_misses, 4);
        assert_eq!(s.epochs[0].dram_util_bp, 1234);
        // 3 explicit events + the epoch_roll stamped by epoch_roll().
        assert_eq!(s.events.len(), 4);
        assert_eq!(s.mig_lat_p99, 7);
        assert_eq!(s.ptw_lat_p99, 63);
        assert!((s.ipc - 50.0 / 109.0).abs() < 1e-12);
    }

    #[test]
    fn rendering_is_deterministic() {
        assert_eq!(sample_trace(), sample_trace());
    }

    #[test]
    fn reader_rejects_malformed_traces() {
        let text = sample_trace();
        // Truncation (summary lost).
        let no_summary: String = text
            .lines()
            .take(text.lines().count() - 1)
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(read_trace(&no_summary).unwrap_err().contains("summary"));
        // Unknown record type.
        let bad = text.replace("\"type\":\"epoch\"", "\"type\":\"wat\"");
        assert!(read_trace(&bad).unwrap_err().contains("unknown record"));
        // Unknown event kind.
        let bad = text.replace("\"kind\":\"shootdown\"",
                               "\"kind\":\"teleport\"");
        assert!(read_trace(&bad).unwrap_err().contains("unknown event kind"));
        // Missing required field.
        let bad = text.replace("\"tlb_misses\":4,", "");
        assert!(read_trace(&bad).unwrap_err().contains("tlb_misses"));
        // Wrong version.
        let bad = text.replace(
            &format!("\"traceversion\":{TRACE_VERSION}"),
            "\"traceversion\":999");
        assert!(read_trace(&bad).unwrap_err().contains("unsupported"));
        // Not JSON at all.
        assert!(read_trace("nope\n").is_err());
        // Empty.
        assert!(read_trace("").unwrap_err().contains("no meta"));
    }
}
