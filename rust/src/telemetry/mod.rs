//! Deterministic simulation telemetry (DESIGN.md §14).
//!
//! A [`Telemetry`] sink lives in every [`Machine`](crate::sim::machine::Machine)
//! and observes the run without perturbing it: recording is keyed off
//! the simulated cycle clock only (never wall time, so traces are
//! byte-identical across reruns and compatible with the `nondet-clock`
//! lint), and the sink never feeds back into timing — metrics from a
//! traced run equal metrics from an untraced run bit-for-bit, which
//! `rust/tests/sweep_determinism.rs` pins.
//!
//! Two cost classes:
//! * **Always-on**: the migration- and page-walk-latency [`Hist`]s.
//!   Recording is a leading-zeros count and two adds per (rare)
//!   migration or walk; their p50/p95/p99 land in `RunMetrics`.
//! * **Off-by-default**: cycle-stamped [`Event`]s and per-epoch
//!   [`EpochSample`]s into fixed-capacity ring buffers, pre-allocated
//!   once by [`Telemetry::enable`] — the hot path never allocates, and
//!   when disabled every record call is a single branch (measured by
//!   the `telemetry.record_off` perf stage, budgeted <2%).

pub mod hist;
pub mod trace;

pub use hist::Hist;

/// Version of the JSON-lines trace record format emitted by
/// `run --trace-out` and read back by `rainbow trace-summary`. Bump on
/// any incompatible change ([`Event`], [`EpochSample`], and
/// [`TraceMeta`] are schema-locked against it in `rust/schemas.lock`).
pub const TRACE_VERSION: u64 = 1;

/// Default event ring capacity (per run).
pub const DEFAULT_EVENT_CAP: usize = 65_536;
/// Default epoch-series ring capacity (per run).
pub const DEFAULT_SERIES_CAP: usize = 8_192;

/// What happened, encoded small enough to record on hot paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Bulk page copy issued (`a` = source page number, `b` = dest
    /// page number). NVM→DRAM is a migration, DRAM→NVM a writeback.
    MigrationStart,
    /// Bulk page copy retired (`a` = dest page number, `b` = copy
    /// latency in cycles).
    MigrationDone,
    /// TLB shootdown broadcast (`a` = virtual page number, `b` = cores
    /// that actually held the entry).
    Shootdown,
    /// Two-stage counter rotation at an interval boundary (`a` = pages
    /// monitored next interval).
    CounterRotate,
    /// Sampling-interval boundary crossed (`a` = epoch index, `b` = OS
    /// cycles charged stop-the-world).
    EpochRoll,
}

impl EventKind {
    /// Stable wire name (the `kind` field of a trace `event` record).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::MigrationStart => "migration_start",
            EventKind::MigrationDone => "migration_done",
            EventKind::Shootdown => "shootdown",
            EventKind::CounterRotate => "counter_rotate",
            EventKind::EpochRoll => "epoch_roll",
        }
    }

    pub fn from_name(s: &str) -> Option<EventKind> {
        Some(match s {
            "migration_start" => EventKind::MigrationStart,
            "migration_done" => EventKind::MigrationDone,
            "shootdown" => EventKind::Shootdown,
            "counter_rotate" => EventKind::CounterRotate,
            "epoch_roll" => EventKind::EpochRoll,
            _ => return None,
        })
    }

    pub const ALL: [EventKind; 5] = [
        EventKind::MigrationStart,
        EventKind::MigrationDone,
        EventKind::Shootdown,
        EventKind::CounterRotate,
        EventKind::EpochRoll,
    ];
}

/// One cycle-stamped trace event. `a`/`b` are kind-specific arguments
/// (see [`EventKind`]); fixed-width so the ring is allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub cycle: u64,
    pub kind: EventKind,
    pub a: u64,
    pub b: u64,
}

/// Per-epoch time-series snapshot: deltas over one sampling interval,
/// taken at the interval boundary by the engine. Counters are raw
/// deltas (readers derive IPC/MPKI); `dram_util_bp` is the DRAM-tier
/// frame occupancy in basis points (0..=10000) at the boundary —
/// fixed-point so records carry no floats.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EpochSample {
    pub epoch: u64,
    /// Cycle of the interval boundary (before OS work).
    pub cycle: u64,
    pub instructions: u64,
    pub tlb_misses: u64,
    pub migrated_bytes: u64,
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
    pub nvm_row_hits: u64,
    pub nvm_row_misses: u64,
    pub dram_util_bp: u64,
}

/// Cumulative machine counters the engine hands to
/// [`Telemetry::epoch_roll`]; the sink differences them against the
/// previous boundary to produce an [`EpochSample`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CumStats {
    pub instructions: u64,
    pub tlb_misses: u64,
    pub migrated_bytes: u64,
    pub dram_row_hits: u64,
    pub dram_row_misses: u64,
    pub nvm_row_hits: u64,
    pub nvm_row_misses: u64,
}

/// Fixed-capacity overwrite-oldest ring. Deterministic: contents are a
/// pure function of the recorded sequence and the capacity.
#[derive(Clone, Debug)]
struct Ring<T> {
    buf: Vec<T>,
    head: usize,
    total: u64,
    cap: usize,
}

// Manual impl: the derive would demand `T: Default` even though an
// empty ring needs no element values.
impl<T> Default for Ring<T> {
    fn default() -> Ring<T> {
        Ring { buf: Vec::new(), head: 0, total: 0, cap: 0 }
    }
}

impl<T: Copy> Ring<T> {
    fn with_capacity(cap: usize) -> Ring<T> {
        Ring { buf: Vec::with_capacity(cap), head: 0, total: 0, cap }
    }

    #[inline]
    fn push(&mut self, v: T) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else if self.cap > 0 {
            self.buf[self.head] = v;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Oldest-to-newest iteration.
    fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    /// Records pushed but no longer held (overwritten by wraparound).
    fn dropped(&self) -> u64 {
        self.total - self.buf.len() as u64
    }
}

/// The per-run telemetry sink. One per [`Machine`]; see the module
/// docs for the always-on vs off-by-default split.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    enabled: bool,
    /// Migration/writeback bulk-copy latency (cycles), always-on.
    pub mig_hist: Hist,
    /// Page-table / superpage-table walk latency (cycles), always-on.
    pub ptw_hist: Hist,
    events: Ring<Event>,
    series: Ring<EpochSample>,
    epoch: u64,
    prev: CumStats,
}

impl Telemetry {
    /// Turn on event/series recording, pre-allocating the rings. The
    /// one allocation site — everything after this is ring writes.
    pub fn enable(&mut self, event_cap: usize, series_cap: usize) {
        self.enabled = true;
        self.events = Ring::with_capacity(event_cap);
        self.series = Ring::with_capacity(series_cap);
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record a cycle-stamped event. One branch when disabled.
    #[inline]
    pub fn event(&mut self, cycle: u64, kind: EventKind, a: u64, b: u64) {
        if !self.enabled {
            return;
        }
        self.events.push(Event { cycle, kind, a, b });
    }

    /// Interval-boundary hook (engine): stamps an `epoch_roll` event
    /// and differences `cum` against the previous boundary into an
    /// [`EpochSample`]. `cycle` is the boundary cycle, `os_cycles` the
    /// stop-the-world OS charge, `dram_util_bp` the policy's DRAM
    /// occupancy in basis points.
    pub fn epoch_roll(&mut self, cycle: u64, os_cycles: u64, cum: CumStats,
                      dram_util_bp: u64) {
        let epoch = self.epoch;
        self.epoch += 1;
        if !self.enabled {
            return;
        }
        let p = self.prev;
        self.series.push(EpochSample {
            epoch,
            cycle,
            instructions: cum.instructions - p.instructions,
            tlb_misses: cum.tlb_misses - p.tlb_misses,
            migrated_bytes: cum.migrated_bytes - p.migrated_bytes,
            dram_row_hits: cum.dram_row_hits - p.dram_row_hits,
            dram_row_misses: cum.dram_row_misses - p.dram_row_misses,
            nvm_row_hits: cum.nvm_row_hits - p.nvm_row_hits,
            nvm_row_misses: cum.nvm_row_misses - p.nvm_row_misses,
            dram_util_bp,
        });
        self.prev = cum;
        self.events.push(Event {
            cycle,
            kind: EventKind::EpochRoll,
            a: epoch,
            b: os_cycles,
        });
    }

    /// Epochs completed so far (counted even when disabled, so traced
    /// and untraced runs tick identically).
    pub fn epochs(&self) -> u64 {
        self.epoch
    }

    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    pub fn series(&self) -> impl Iterator<Item = &EpochSample> {
        self.series.iter()
    }

    pub fn events_held(&self) -> usize {
        self.events.len()
    }

    pub fn events_dropped(&self) -> u64 {
        self.events.dropped()
    }

    pub fn series_dropped(&self) -> u64 {
        self.series.dropped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing_but_counts_epochs() {
        let mut t = Telemetry::default();
        assert!(!t.enabled());
        t.event(10, EventKind::Shootdown, 1, 2);
        t.epoch_roll(100, 5, CumStats::default(), 0);
        assert_eq!(t.events_held(), 0);
        assert_eq!(t.series().count(), 0);
        assert_eq!(t.epochs(), 1);
    }

    #[test]
    fn enabled_sink_stamps_events_in_order() {
        let mut t = Telemetry::default();
        t.enable(8, 8);
        t.event(5, EventKind::MigrationStart, 100, 7);
        t.event(9, EventKind::MigrationDone, 7, 4);
        let ev: Vec<Event> = t.events().copied().collect();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].cycle, 5);
        assert_eq!(ev[0].kind, EventKind::MigrationStart);
        assert_eq!(ev[1].b, 4);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut t = Telemetry::default();
        t.enable(4, 4);
        for i in 0..10u64 {
            t.event(i, EventKind::Shootdown, i, 0);
        }
        assert_eq!(t.events_held(), 4);
        assert_eq!(t.events_dropped(), 6);
        let cycles: Vec<u64> = t.events().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![6, 7, 8, 9], "oldest-to-newest survivors");
    }

    #[test]
    fn epoch_roll_differences_cumulative_counters() {
        let mut t = Telemetry::default();
        t.enable(16, 16);
        t.epoch_roll(1000, 50, CumStats {
            instructions: 500, tlb_misses: 10, migrated_bytes: 4096,
            ..Default::default()
        }, 2500);
        t.epoch_roll(2000, 60, CumStats {
            instructions: 900, tlb_misses: 25, migrated_bytes: 4096,
            ..Default::default()
        }, 5000);
        let s: Vec<EpochSample> = t.series().copied().collect();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].epoch, 0);
        assert_eq!(s[0].instructions, 500);
        assert_eq!(s[1].instructions, 400, "second epoch is a delta");
        assert_eq!(s[1].tlb_misses, 15);
        assert_eq!(s[1].migrated_bytes, 0);
        assert_eq!(s[1].dram_util_bp, 5000);
        // Each roll also stamps an epoch_roll event.
        assert_eq!(
            t.events().filter(|e| e.kind == EventKind::EpochRoll).count(), 2);
    }

    #[test]
    fn event_kind_names_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_name(k.name()), Some(k));
        }
        assert_eq!(EventKind::from_name("nope"), None);
    }
}
