//! Power-of-two-bucket latency histogram with mergeable quantiles.
//!
//! Bucket `i` (i >= 1) holds values in `[2^(i-1), 2^i)`; bucket 0 holds
//! zero. 65 buckets cover the full `u64` range, so `record` is a
//! leading-zeros count plus one array increment — cheap enough to stay
//! always-on in the simulator's migration and page-walk paths. Merging
//! is element-wise addition, which makes quantiles associative across
//! shards/workers: `quantile(merge(a, b)) == quantile(merge(b, a))` and
//! grouping does not matter (property-tested below).
//!
//! Quantiles are reported as the *upper bound* of the bucket containing
//! the requested rank, so for any true value `v` the reported quantile
//! `q` satisfies `v <= q <= 2v + 1` — a bounded, deterministic
//! overestimate that never invents precision the buckets don't have.

/// Number of buckets: one for zero plus one per bit position.
pub const BUCKETS: usize = 65;

#[derive(Clone, Debug)]
pub struct Hist {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist { buckets: [0; BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Bucket index of `v`: its significant-bit count (0 for zero).
    #[inline]
    fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Upper bound of bucket `i` (the value a quantile in it reports).
    fn bound_of(i: usize) -> u64 {
        match i {
            0 => 0,
            64 => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Element-wise merge (shard/worker aggregation).
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// The `pct`-th percentile (1..=100) as the upper bound of the
    /// bucket holding that rank; 0 when the histogram is empty.
    /// Integer math throughout so shards agree bit-for-bit.
    pub fn quantile(&self, pct: u64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let pct = pct.clamp(1, 100);
        // Nearest-rank: the smallest rank r with r >= count * pct / 100.
        let rank = (self.count * pct).div_ceil(100).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bound_of(i);
            }
        }
        Self::bound_of(BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    fn hist_of(vals: &[u64]) -> Hist {
        let mut h = Hist::new();
        for &v in vals {
            h.record(v);
        }
        h
    }

    /// Sorted-vec reference model: nearest-rank percentile.
    fn model_quantile(vals: &[u64], pct: u64) -> u64 {
        if vals.is_empty() {
            return 0;
        }
        let mut s = vals.to_vec();
        s.sort_unstable();
        let rank = ((vals.len() as u64 * pct).div_ceil(100)).max(1);
        s[(rank - 1) as usize]
    }

    fn gen_vals(rng: &mut Rng) -> Vec<u64> {
        let n = (rng.next_u64() % 64) as usize;
        (0..n)
            .map(|_| {
                let bits = rng.next_u64() % 40;
                rng.next_u64() & ((1u64 << bits.max(1)) - 1)
            })
            .collect()
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(50), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn single_value_quantiles() {
        let h = hist_of(&[100]);
        for pct in [1, 50, 99, 100] {
            let q = h.quantile(pct);
            assert!((100..=201).contains(&q), "pct {pct}: q={q}");
        }
    }

    #[test]
    fn bucket_bounds_are_powers_of_two() {
        assert_eq!(Hist::bound_of(0), 0);
        assert_eq!(Hist::bound_of(1), 1);
        assert_eq!(Hist::bound_of(10), 1023);
        assert_eq!(Hist::bound_of(64), u64::MAX);
        assert_eq!(Hist::bucket_of(0), 0);
        assert_eq!(Hist::bucket_of(1), 1);
        assert_eq!(Hist::bucket_of(1023), 10);
        assert_eq!(Hist::bucket_of(1024), 11);
    }

    #[test]
    fn prop_quantile_bounded_by_sorted_vec_model() {
        forall("hist quantile vs model", 0x51ab, 300, gen_vals, |vals| {
            let h = hist_of(vals);
            for pct in [50, 95, 99] {
                let q = h.quantile(pct);
                let m = model_quantile(vals, pct);
                // Upper-bound-of-bucket reporting: m <= q <= 2m + 1.
                if q < m || q > m.saturating_mul(2).saturating_add(1) {
                    return Err(format!(
                        "pct {pct}: hist {q} outside [{m}, {}]",
                        m.saturating_mul(2).saturating_add(1)));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_merge_associative_and_commutative() {
        let gen = |rng: &mut Rng| {
            (gen_vals(rng), gen_vals(rng), gen_vals(rng))
        };
        forall("hist merge assoc", 0x9e37, 300, gen, |(a, b, c)| {
            let (ha, hb, hc) = (hist_of(a), hist_of(b), hist_of(c));
            // (a + b) + c
            let mut left = ha.clone();
            left.merge(&hb);
            left.merge(&hc);
            // a + (b + c)
            let mut bc = hb.clone();
            bc.merge(&hc);
            let mut right = ha.clone();
            right.merge(&bc);
            // b + a + c (commuted)
            let mut comm = hb.clone();
            comm.merge(&ha);
            comm.merge(&hc);
            for pct in [50, 95, 99, 100] {
                if left.quantile(pct) != right.quantile(pct)
                    || left.quantile(pct) != comm.quantile(pct)
                {
                    return Err(format!("pct {pct} differs across groupings"));
                }
            }
            if left.count() != right.count() || left.count() != comm.count() {
                return Err("counts differ".to_string());
            }
            // Merged hist == hist of concatenated samples.
            let mut all = a.clone();
            all.extend_from_slice(b);
            all.extend_from_slice(c);
            let whole = hist_of(&all);
            if whole.quantile(95) != left.quantile(95)
                || whole.sum() != left.sum()
                || whole.max() != left.max()
            {
                return Err("merge != concat".to_string());
            }
            Ok(())
        });
    }
}
