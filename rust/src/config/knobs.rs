//! The experiment-knob registry: every `Config` field an experiment may
//! override, each with a stable dotted key (`rainbow.migration_threshold`,
//! `nvm.read_cycles`, ...), a declared type, and an apply function. This
//! is the SINGLE validated apply path shared by the tomlite config
//! loader (`Config::apply_doc`), the CLI `--set key=value` surface, the
//! on-disk spec-file format, and `RunSpec` overrides — so every consumer
//! rejects unknown keys and ill-typed values identically, before any
//! sweep fans out to worker threads.
//!
//! [`Overrides`] is the ordered (BTreeMap-canonical) collection of set
//! knobs a [`crate::report::RunSpec`] carries; its [`Overrides::canonical`]
//! serialization is order-independent, which keeps spec fingerprints
//! stable however call sites build their specs.

use std::collections::BTreeMap;
use std::fmt;

use super::Config;
use crate::util::cli::parse_u64;
use crate::util::tomlite::{Doc, Value};

/// Declared type of a knob's value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobKind {
    U64,
    F64,
}

impl KnobKind {
    pub fn name(self) -> &'static str {
        match self {
            KnobKind::U64 => "u64",
            KnobKind::F64 => "f64",
        }
    }
}

/// A typed override value in canonical form (always matches the knob's
/// [`KnobKind`] once it has passed [`Knob::coerce`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KnobValue {
    U64(u64),
    F64(f64),
}

impl KnobValue {
    pub fn as_u64(self) -> u64 {
        match self {
            KnobValue::U64(v) => v,
            KnobValue::F64(v) => v as u64,
        }
    }

    pub fn as_f64(self) -> f64 {
        match self {
            KnobValue::U64(v) => v as f64,
            KnobValue::F64(v) => v,
        }
    }
}

impl fmt::Display for KnobValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KnobValue::U64(v) => write!(f, "{v}"),
            KnobValue::F64(v) => write!(f, "{v}"),
        }
    }
}

impl From<u64> for KnobValue {
    fn from(v: u64) -> KnobValue {
        KnobValue::U64(v)
    }
}

impl From<usize> for KnobValue {
    fn from(v: usize) -> KnobValue {
        KnobValue::U64(v as u64)
    }
}

impl From<f64> for KnobValue {
    fn from(v: f64) -> KnobValue {
        KnobValue::F64(v)
    }
}

/// One overridable config field.
pub struct Knob {
    pub key: &'static str,
    pub kind: KnobKind,
    pub help: &'static str,
    apply: fn(&mut Config, KnobValue),
}

/// Knobs where a zero (or non-positive) value is degenerate — a divisor,
/// an empty hardware structure, or the sampling interval whose zero
/// would hang the engine's interval loop. Rejected at parse/coerce time
/// so bad values fail CLI/spec validation, not a worker thread.
const POSITIVE_KEYS: &[&str] = &[
    "cpu.cores", "cpu.ghz", "tlb.l1_4k_entries", "tlb.l1_2m_entries",
    "tlb.l2_4k_entries", "tlb.l2_2m_entries", "cache.l1_size",
    "cache.l2_size", "cache.l3_size", "dram.size", "nvm.size",
    "rainbow.interval_cycles", "rainbow.top_n",
    "rainbow.bitmap_cache_entries", "rainbow.bitmap_cache_assoc",
    "mem.dram_ratio",
];

impl Knob {
    /// Parse a textual value (CLI `--set`, spec file) into this knob's
    /// type. u64 knobs accept `_` separators and k/m/g/e suffixes, same
    /// as the tomlite loader.
    pub fn parse(&self, raw: &str) -> Result<KnobValue, String> {
        let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
        let v = match self.kind {
            KnobKind::U64 => parse_u64(&cleaned)
                .map(KnobValue::U64)
                .ok_or_else(|| {
                    format!("knob {}: expected integer, got {raw:?}", self.key)
                })?,
            KnobKind::F64 => cleaned
                .parse::<f64>()
                .map(KnobValue::F64)
                .map_err(|_| {
                    format!("knob {}: expected number, got {raw:?}", self.key)
                })?,
        };
        self.validate(v)
    }

    /// Coerce a typed value to this knob's kind (lossless only).
    pub fn coerce(&self, v: KnobValue) -> Result<KnobValue, String> {
        let v = match (self.kind, v) {
            (KnobKind::U64, KnobValue::U64(_)) => v,
            (KnobKind::U64, KnobValue::F64(f)) => {
                if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                    KnobValue::U64(f as u64)
                } else {
                    return Err(format!(
                        "knob {}: expected integer, got {f}", self.key));
                }
            }
            (KnobKind::F64, KnobValue::F64(_)) => v,
            (KnobKind::F64, KnobValue::U64(u)) => KnobValue::F64(u as f64),
        };
        self.validate(v)
    }

    /// Range checks shared by both input paths: f64 values must be
    /// finite (NaN would silently disable every threshold comparison),
    /// and [`POSITIVE_KEYS`] must be > 0.
    fn validate(&self, v: KnobValue) -> Result<KnobValue, String> {
        if let KnobValue::F64(f) = v {
            if !f.is_finite() {
                return Err(format!(
                    "knob {}: value must be finite, got {f}", self.key));
            }
        }
        if POSITIVE_KEYS.contains(&self.key) {
            let bad = match v {
                KnobValue::U64(u) => u == 0,
                KnobValue::F64(f) => f <= 0.0,
            };
            if bad {
                return Err(format!(
                    "knob {}: value must be positive, got {v}", self.key));
            }
        }
        Ok(v)
    }
}

/// The registry. Declaration order is APPLY order (deterministic and
/// independent of how an `Overrides` map was built); derived knobs like
/// `mem.dram_ratio` are declared last so they see the final base values.
static KNOBS: &[Knob] = &[
    Knob { key: "cpu.cores", kind: KnobKind::U64,
           help: "simulated cores",
           apply: |c, v| c.cores = v.as_u64() as usize },
    Knob { key: "cpu.ghz", kind: KnobKind::F64,
           help: "core clock (GHz)",
           apply: |c, v| c.cpu_ghz = v.as_f64() },
    Knob { key: "tlb.l1_4k_entries", kind: KnobKind::U64,
           help: "L1 4KB TLB entries",
           apply: |c, v| c.l1_tlb_4k.entries = v.as_u64() as usize },
    Knob { key: "tlb.l1_2m_entries", kind: KnobKind::U64,
           help: "L1 2MB TLB entries",
           apply: |c, v| c.l1_tlb_2m.entries = v.as_u64() as usize },
    Knob { key: "tlb.l2_4k_entries", kind: KnobKind::U64,
           help: "L2 4KB TLB entries",
           apply: |c, v| c.l2_tlb_4k.entries = v.as_u64() as usize },
    Knob { key: "tlb.l2_2m_entries", kind: KnobKind::U64,
           help: "L2 2MB TLB entries",
           apply: |c, v| c.l2_tlb_2m.entries = v.as_u64() as usize },
    Knob { key: "cache.l1_size", kind: KnobKind::U64,
           help: "L1 cache bytes",
           apply: |c, v| c.l1_cache.size = v.as_u64() },
    Knob { key: "cache.l2_size", kind: KnobKind::U64,
           help: "L2 cache bytes",
           apply: |c, v| c.l2_cache.size = v.as_u64() },
    Knob { key: "cache.l3_size", kind: KnobKind::U64,
           help: "LLC bytes",
           apply: |c, v| c.l3_cache.size = v.as_u64() },
    Knob { key: "dram.size", kind: KnobKind::U64,
           help: "DRAM capacity bytes",
           apply: |c, v| c.dram.size = v.as_u64() },
    Knob { key: "dram.read_cycles", kind: KnobKind::U64,
           help: "DRAM array read latency (cycles)",
           apply: |c, v| c.dram.read_cycles = v.as_u64() },
    Knob { key: "dram.write_cycles", kind: KnobKind::U64,
           help: "DRAM array write latency (cycles)",
           apply: |c, v| c.dram.write_cycles = v.as_u64() },
    Knob { key: "dram.t_cas", kind: KnobKind::U64,
           help: "DRAM tCAS (controller cycles)",
           apply: |c, v| c.dram.t_cas = v.as_u64() },
    Knob { key: "dram.t_rcd", kind: KnobKind::U64,
           help: "DRAM tRCD",
           apply: |c, v| c.dram.t_rcd = v.as_u64() },
    Knob { key: "dram.t_rp", kind: KnobKind::U64,
           help: "DRAM tRP",
           apply: |c, v| c.dram.t_rp = v.as_u64() },
    Knob { key: "dram.t_ras", kind: KnobKind::U64,
           help: "DRAM tRAS",
           apply: |c, v| c.dram.t_ras = v.as_u64() },
    Knob { key: "nvm.size", kind: KnobKind::U64,
           help: "NVM capacity bytes",
           apply: |c, v| c.nvm.size = v.as_u64() },
    Knob { key: "nvm.read_cycles", kind: KnobKind::U64,
           help: "NVM array read latency (cycles)",
           apply: |c, v| c.nvm.read_cycles = v.as_u64() },
    Knob { key: "nvm.write_cycles", kind: KnobKind::U64,
           help: "NVM array write latency (cycles)",
           apply: |c, v| c.nvm.write_cycles = v.as_u64() },
    Knob { key: "nvm.t_cas", kind: KnobKind::U64,
           help: "NVM tCAS",
           apply: |c, v| c.nvm.t_cas = v.as_u64() },
    Knob { key: "nvm.t_rcd", kind: KnobKind::U64,
           help: "NVM tRCD",
           apply: |c, v| c.nvm.t_rcd = v.as_u64() },
    Knob { key: "nvm.t_rp", kind: KnobKind::U64,
           help: "NVM tRP",
           apply: |c, v| c.nvm.t_rp = v.as_u64() },
    Knob { key: "nvm.t_ras", kind: KnobKind::U64,
           help: "NVM tRAS",
           apply: |c, v| c.nvm.t_ras = v.as_u64() },
    Knob { key: "rainbow.interval_cycles", kind: KnobKind::U64,
           help: "hot-page sampling interval (cycles)",
           apply: |c, v| c.interval_cycles = v.as_u64() },
    Knob { key: "rainbow.top_n", kind: KnobKind::U64,
           help: "top-N monitored hot superpages",
           apply: |c, v| c.top_n = v.as_u64() as usize },
    Knob { key: "rainbow.write_weight", kind: KnobKind::F64,
           help: "write weighting in superpage scoring",
           apply: |c, v| c.write_weight = v.as_f64() },
    Knob { key: "rainbow.migration_threshold", kind: KnobKind::F64,
           help: "base migration-benefit threshold (cycles, Eq. 1)",
           apply: |c, v| c.migration_threshold = v.as_f64() },
    Knob { key: "rainbow.bitmap_cache_entries", kind: KnobKind::U64,
           help: "migration-bitmap cache entries",
           apply: |c, v| c.bitmap_cache_entries = v.as_u64() as usize },
    Knob { key: "rainbow.bitmap_cache_assoc", kind: KnobKind::U64,
           help: "migration-bitmap cache associativity",
           apply: |c, v| c.bitmap_cache_assoc = v.as_u64() as usize },
    Knob { key: "rainbow.bitmap_cache_latency", kind: KnobKind::U64,
           help: "migration-bitmap cache latency (cycles)",
           apply: |c, v| c.bitmap_cache_latency = v.as_u64() },
    Knob { key: "cost.t_mig_4k", kind: KnobKind::U64,
           help: "4KB migration cost (cycles)",
           apply: |c, v| c.t_mig_4k = v.as_u64() },
    Knob { key: "cost.t_mig_2m", kind: KnobKind::U64,
           help: "2MB migration cost (cycles)",
           apply: |c, v| c.t_mig_2m = v.as_u64() },
    Knob { key: "cost.t_writeback_4k", kind: KnobKind::U64,
           help: "4KB writeback cost (cycles)",
           apply: |c, v| c.t_writeback_4k = v.as_u64() },
    Knob { key: "cost.t_shootdown", kind: KnobKind::U64,
           help: "TLB shootdown cost (cycles)",
           apply: |c, v| c.t_shootdown = v.as_u64() },
    Knob { key: "cost.t_clflush_line", kind: KnobKind::U64,
           help: "clflush cost per line (cycles)",
           apply: |c, v| c.t_clflush_line = v.as_u64() },
    // Derived knob, declared LAST so it sees the final nvm.size.
    Knob { key: "mem.dram_ratio", kind: KnobKind::U64,
           help: "NVM:DRAM capacity ratio (sets dram.size = nvm.size / r)",
           apply: |c, v| c.dram.size = c.nvm.size / v.as_u64().max(1) },
];

/// Every registered knob, in apply order.
pub fn all() -> &'static [Knob] {
    KNOBS
}

/// Look a knob up by its dotted key.
pub fn by_key(key: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.key == key)
}

/// An ordered (canonically sorted) map of knob overrides. The map keys
/// are the registry's `&'static str`s, so an `Overrides` can only ever
/// hold registered knobs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Overrides {
    map: BTreeMap<&'static str, KnobValue>,
}

impl Overrides {
    pub fn new() -> Overrides {
        Overrides::default()
    }

    /// Set a knob from a typed value. Rejects unknown keys and values
    /// that don't (losslessly) fit the knob's declared type.
    pub fn set(&mut self, key: &str, value: KnobValue) -> Result<(), String> {
        let knob = by_key(key)
            .ok_or_else(|| format!(
                "unknown config knob {key:?}; `rainbow list` shows them"))?;
        self.map.insert(knob.key, knob.coerce(value)?);
        Ok(())
    }

    /// Set a knob from its textual form (CLI `--set`, spec files).
    pub fn set_raw(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let knob = by_key(key)
            .ok_or_else(|| format!(
                "unknown config knob {key:?}; `rainbow list` shows them"))?;
        self.map.insert(knob.key, knob.parse(raw)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<KnobValue> {
        self.map.get(key).copied()
    }

    /// Drop a knob (no-op if unset), restoring the config's base value.
    pub fn remove(&mut self, key: &str) {
        self.map.remove(key);
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Knobs in canonical (key-sorted) order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, KnobValue)> + '_ {
        self.map.iter().map(|(k, v)| (*k, *v))
    }

    /// Apply every set knob onto `cfg`, in registry order (NOT map
    /// order), so derived knobs are deterministic.
    pub fn apply_to(&self, cfg: &mut Config) {
        for knob in KNOBS {
            if let Some(v) = self.map.get(knob.key) {
                (knob.apply)(cfg, *v);
            }
        }
    }

    /// Canonical `key=value\n` serialization: sorted by key, values in
    /// canonical textual form — identical however the map was built.
    pub fn canonical(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.map {
            s.push_str(k);
            s.push('=');
            s.push_str(&v.to_string());
            s.push('\n');
        }
        s
    }

    /// Build from a tomlite document, rejecting unknown keys and
    /// non-numeric values (the validated half of `Config::apply_doc`).
    pub fn from_doc(doc: &Doc) -> Result<Overrides, String> {
        let mut ov = Overrides::new();
        for key in doc.keys() {
            let knob = by_key(key).ok_or_else(|| {
                format!("unknown config knob {key:?} in config file")
            })?;
            let v = match doc.get(key) {
                Some(Value::Int(u)) => KnobValue::U64(*u),
                Some(Value::Float(f)) => KnobValue::F64(*f),
                _ => return Err(format!(
                    "knob {key}: expected a number")),
            };
            ov.map.insert(knob.key, knob.coerce(v)?);
        }
        Ok(ov)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_keys_are_unique_and_resolvable() {
        for (i, k) in KNOBS.iter().enumerate() {
            assert!(by_key(k.key).is_some());
            for other in &KNOBS[i + 1..] {
                assert_ne!(k.key, other.key, "duplicate knob key");
            }
        }
    }

    #[test]
    fn set_rejects_unknown_and_ill_typed() {
        let mut ov = Overrides::new();
        assert!(ov.set("rainbow.nope", KnobValue::U64(1)).is_err());
        assert!(ov.set_raw("nvm.read_cycles", "fast").is_err());
        assert!(ov
            .set("rainbow.top_n", KnobValue::F64(1.5))
            .is_err(), "fractional value must not fit a u64 knob");
        assert!(ov.set("rainbow.top_n", KnobValue::F64(32.0)).is_ok());
        assert_eq!(ov.get("rainbow.top_n"), Some(KnobValue::U64(32)));
    }

    #[test]
    fn apply_changes_config() {
        let mut ov = Overrides::new();
        ov.set("rainbow.migration_threshold", KnobValue::F64(123.5))
            .unwrap();
        ov.set_raw("nvm.read_cycles", "124").unwrap();
        ov.set_raw("tlb.l2_4k_entries", "64").unwrap();
        let mut c = Config::scaled(8);
        ov.apply_to(&mut c);
        assert_eq!(c.migration_threshold, 123.5);
        assert_eq!(c.nvm.read_cycles, 124);
        assert_eq!(c.l2_tlb_4k.entries, 64);
    }

    #[test]
    fn dram_ratio_applies_after_nvm_size() {
        let mut ov = Overrides::new();
        // Insertion order is the OPPOSITE of the dependency order; the
        // registry-ordered apply must still see the final nvm.size.
        ov.set_raw("mem.dram_ratio", "4").unwrap();
        ov.set_raw("nvm.size", "1g").unwrap();
        let mut c = Config::scaled(8);
        ov.apply_to(&mut c);
        assert_eq!(c.nvm.size, 1 << 30);
        assert_eq!(c.dram.size, (1 << 30) / 4);
    }

    #[test]
    fn canonical_is_insertion_order_independent() {
        let mut a = Overrides::new();
        a.set_raw("rainbow.top_n", "32").unwrap();
        a.set_raw("dram.read_cycles", "50").unwrap();
        let mut b = Overrides::new();
        b.set_raw("dram.read_cycles", "50").unwrap();
        b.set_raw("rainbow.top_n", "32").unwrap();
        assert_eq!(a.canonical(), b.canonical());
        assert_eq!(a, b);
        assert_eq!(a.canonical(), "dram.read_cycles=50\nrainbow.top_n=32\n");
    }

    #[test]
    fn u64_knob_accepts_suffixes() {
        let mut ov = Overrides::new();
        ov.set_raw("dram.size", "256m").unwrap();
        assert_eq!(ov.get("dram.size"), Some(KnobValue::U64(256 << 20)));
    }

    #[test]
    fn positive_keys_are_all_registered() {
        for k in POSITIVE_KEYS {
            assert!(by_key(k).is_some(), "POSITIVE_KEYS has stale key {k}");
        }
    }

    #[test]
    fn degenerate_values_rejected_before_any_fanout() {
        let mut ov = Overrides::new();
        // Zero divisors / empty structures / hang-inducing interval.
        assert!(ov.set_raw("cpu.cores", "0").is_err());
        assert!(ov.set_raw("rainbow.interval_cycles", "0").is_err());
        assert!(ov.set_raw("dram.size", "0").is_err());
        assert!(ov.set("rainbow.top_n", KnobValue::U64(0)).is_err());
        assert!(ov.set_raw("cpu.ghz", "-3.2").is_err());
        // Non-finite floats (NaN disables threshold comparisons).
        assert!(ov.set_raw("rainbow.migration_threshold", "nan").is_err());
        assert!(ov.set_raw("rainbow.migration_threshold", "inf").is_err());
        // Zero stays legal where it is meaningful.
        assert!(ov.set_raw("rainbow.write_weight", "0").is_ok());
        assert!(ov.set_raw("cost.t_shootdown", "0").is_ok());
    }
}
