//! The experiment-knob registry: every `Config` field an experiment may
//! override, each with a stable dotted key (`rainbow.migration_threshold`,
//! `nvm.read_cycles`, ...), a declared type, and an apply function. This
//! is the SINGLE validated apply path shared by the tomlite config
//! loader (`Config::apply_doc`), the CLI `--set key=value` surface, the
//! on-disk spec-file format, and `RunSpec` overrides — so every consumer
//! rejects unknown keys and ill-typed values identically, before any
//! sweep fans out to worker threads.
//!
//! [`Overrides`] is the ordered (BTreeMap-canonical) collection of set
//! knobs a [`crate::report::RunSpec`] carries; its [`Overrides::canonical`]
//! serialization is order-independent, which keeps spec fingerprints
//! stable however call sites build their specs.

use std::collections::BTreeMap;
use std::fmt;

use super::{profiles, Config, MemConfig};
use crate::util::cli::parse_u64;
use crate::util::tomlite::{Doc, Value};

/// Declared type of a knob's value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobKind {
    U64,
    F64,
    /// A device-profile name from [`crate::config::profiles`]; the value
    /// is interned to the catalog's canonical `&'static str`.
    Profile,
}

impl KnobKind {
    pub fn name(self) -> &'static str {
        match self {
            KnobKind::U64 => "u64",
            KnobKind::F64 => "f64",
            KnobKind::Profile => "prof",
        }
    }
}

/// A typed override value in canonical form (always matches the knob's
/// [`KnobKind`] once it has passed [`Knob::coerce`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KnobValue {
    U64(u64),
    F64(f64),
    /// A validated device-profile name (the catalog's canonical str, so
    /// the value stays `Copy` and serializes as itself).
    Str(&'static str),
}

impl KnobValue {
    pub fn as_u64(self) -> u64 {
        match self {
            KnobValue::U64(v) => v,
            KnobValue::F64(v) => v as u64,
            KnobValue::Str(_) => panic!("string knob value has no u64 form"),
        }
    }

    pub fn as_f64(self) -> f64 {
        match self {
            KnobValue::U64(v) => v as f64,
            KnobValue::F64(v) => v,
            KnobValue::Str(_) => panic!("string knob value has no f64 form"),
        }
    }

    pub fn as_str(self) -> Option<&'static str> {
        match self {
            KnobValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl fmt::Display for KnobValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KnobValue::U64(v) => write!(f, "{v}"),
            KnobValue::F64(v) => write!(f, "{v}"),
            KnobValue::Str(v) => write!(f, "{v}"),
        }
    }
}

impl From<u64> for KnobValue {
    fn from(v: u64) -> KnobValue {
        KnobValue::U64(v)
    }
}

impl From<usize> for KnobValue {
    fn from(v: usize) -> KnobValue {
        KnobValue::U64(v as u64)
    }
}

impl From<f64> for KnobValue {
    fn from(v: f64) -> KnobValue {
        KnobValue::F64(v)
    }
}

/// Sugar for statically-named profiles (`.with("nvm.profile",
/// "optane-dcpmm")`); [`Knob::coerce`] still validates the name against
/// the catalog.
impl From<&'static str> for KnobValue {
    fn from(v: &'static str) -> KnobValue {
        KnobValue::Str(v)
    }
}

/// One overridable config field.
pub struct Knob {
    pub key: &'static str,
    pub kind: KnobKind,
    pub help: &'static str,
    apply: fn(&mut Config, KnobValue),
}

/// Knobs where a zero (or non-positive) value is degenerate — a divisor,
/// an empty hardware structure, or the sampling interval whose zero
/// would hang the engine's interval loop. Rejected at parse/coerce time
/// so bad values fail CLI/spec validation, not a worker thread.
const POSITIVE_KEYS: &[&str] = &[
    "cpu.cores", "cpu.ghz", "tlb.l1_4k_entries", "tlb.l1_2m_entries",
    "tlb.l2_4k_entries", "tlb.l2_2m_entries", "cache.l1_size",
    "cache.l2_size", "cache.l3_size", "dram.size", "nvm.size",
    "dram.channels", "dram.ranks_per_channel", "dram.banks_per_rank",
    "dram.rows_per_bank", "nvm.channels", "nvm.ranks_per_channel",
    "nvm.banks_per_rank", "nvm.rows_per_bank",
    "rainbow.interval_cycles", "rainbow.top_n",
    "rainbow.bitmap_cache_entries", "rainbow.bitmap_cache_assoc",
    "mem.dram_ratio",
];

/// Energy/power knobs: zero is meaningful (PCM's standby draw), negative
/// values would silently corrupt every Fig. 12 rollup.
const NONNEGATIVE_KEYS: &[&str] = &[
    "dram.e_read_hit_pj_bit", "dram.e_write_hit_pj_bit",
    "dram.e_read_miss_pj_bit", "dram.e_write_miss_pj_bit",
    "dram.background_w_per_gb",
    "nvm.e_read_hit_pj_bit", "nvm.e_write_hit_pj_bit",
    "nvm.e_read_miss_pj_bit", "nvm.e_write_miss_pj_bit",
    "nvm.background_w_per_gb",
];

/// Row-buffer sizes below one 64 B line make the column count in
/// `bank::decode` zero — another divide-by-zero, rejected up front.
const ROW_SIZE_KEYS: &[&str] = &["dram.row_size", "nvm.row_size"];

impl Knob {
    /// Parse a textual value (CLI `--set`, spec file) into this knob's
    /// type. u64 knobs accept `_` separators and k/m/g/e suffixes, same
    /// as the tomlite loader; profile knobs resolve catalog names.
    pub fn parse(&self, raw: &str) -> Result<KnobValue, String> {
        let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
        let v = match self.kind {
            KnobKind::U64 => parse_u64(&cleaned)
                .map(KnobValue::U64)
                .ok_or_else(|| {
                    format!("knob {}: expected integer, got {raw:?}", self.key)
                })?,
            KnobKind::F64 => cleaned
                .parse::<f64>()
                .map(KnobValue::F64)
                .map_err(|_| {
                    format!("knob {}: expected number, got {raw:?}", self.key)
                })?,
            KnobKind::Profile => KnobValue::Str(intern_profile(
                self.key, raw.trim())?),
        };
        self.validate(v)
    }

    /// Coerce a typed value to this knob's kind (lossless only).
    pub fn coerce(&self, v: KnobValue) -> Result<KnobValue, String> {
        let v = match (self.kind, v) {
            (KnobKind::U64, KnobValue::U64(_)) => v,
            (KnobKind::U64, KnobValue::F64(f)) => {
                if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                    KnobValue::U64(f as u64)
                } else {
                    return Err(format!(
                        "knob {}: expected integer, got {f}", self.key));
                }
            }
            (KnobKind::F64, KnobValue::F64(_)) => v,
            (KnobKind::F64, KnobValue::U64(u)) => KnobValue::F64(u as f64),
            (KnobKind::Profile, KnobValue::Str(s)) => {
                KnobValue::Str(intern_profile(self.key, s)?)
            }
            (KnobKind::Profile, other) => {
                return Err(format!(
                    "knob {}: expected a device profile name, got {other}",
                    self.key))
            }
            (_, KnobValue::Str(s)) => {
                return Err(format!(
                    "knob {}: expected {}, got string {s:?}",
                    self.key, self.kind.name()))
            }
        };
        self.validate(v)
    }

    /// Range checks shared by both input paths: f64 values must be
    /// finite (NaN would silently disable every threshold comparison),
    /// [`POSITIVE_KEYS`] must be > 0, [`NONNEGATIVE_KEYS`] must be ≥ 0,
    /// and [`ROW_SIZE_KEYS`] must hold at least one cache line.
    fn validate(&self, v: KnobValue) -> Result<KnobValue, String> {
        if let KnobValue::F64(f) = v {
            if !f.is_finite() {
                return Err(format!(
                    "knob {}: value must be finite, got {f}", self.key));
            }
            if f < 0.0 && NONNEGATIVE_KEYS.contains(&self.key) {
                return Err(format!(
                    "knob {}: value must be non-negative, got {f}", self.key));
            }
        }
        if POSITIVE_KEYS.contains(&self.key) {
            let bad = match v {
                KnobValue::U64(u) => u == 0,
                KnobValue::F64(f) => f <= 0.0,
                KnobValue::Str(_) => false,
            };
            if bad {
                return Err(format!(
                    "knob {}: value must be positive, got {v}", self.key));
            }
        }
        if ROW_SIZE_KEYS.contains(&self.key) && v.as_u64() < 64 {
            return Err(format!(
                "knob {}: row size must be at least one 64 B line, got {v}",
                self.key));
        }
        Ok(v)
    }
}

/// Resolve a profile name to its canonical catalog str.
fn intern_profile(key: &str, name: &str) -> Result<&'static str, String> {
    profiles::by_name(name).map(|p| p.name).ok_or_else(|| {
        format!("knob {key}: unknown device profile {name:?} (available: {})",
                profiles::names().join(", "))
    })
}

/// Expand a (coerce-validated) profile name into the scaled device
/// bundle for one controller slot — the apply half of the profile knobs.
fn expand_profile(v: KnobValue, scale_factor: u64) -> MemConfig {
    let name = v.as_str().expect("profile knob holds a name");
    profiles::by_name(name)
        .expect("coerce validated the profile name")
        .mem_scaled(scale_factor.max(1))
}

/// The registry. Declaration order is APPLY order (deterministic and
/// independent of how an `Overrides` map was built): the device-profile
/// knobs come FIRST so a profile expands its whole `MemConfig` slot
/// before any explicit `dram.*`/`nvm.*` field override lands on top,
/// and derived knobs like `mem.dram_ratio` are declared last so they
/// see the final base values.
static KNOBS: &[Knob] = &[
    Knob { key: "dram.profile", kind: KnobKind::Profile,
           help: "named DRAM-slot device profile (expands first; \
                  dram.* overrides layer on top)",
           apply: |c, v| c.dram = expand_profile(v, c.scale_factor) },
    Knob { key: "nvm.profile", kind: KnobKind::Profile,
           help: "named NVM-slot device profile (expands first; \
                  nvm.* overrides layer on top)",
           apply: |c, v| c.nvm = expand_profile(v, c.scale_factor) },
    Knob { key: "cpu.cores", kind: KnobKind::U64,
           help: "simulated cores",
           apply: |c, v| c.cores = v.as_u64() as usize },
    Knob { key: "cpu.ghz", kind: KnobKind::F64,
           help: "core clock (GHz)",
           apply: |c, v| c.cpu_ghz = v.as_f64() },
    Knob { key: "tlb.l1_4k_entries", kind: KnobKind::U64,
           help: "L1 4KB TLB entries",
           apply: |c, v| c.l1_tlb_4k.entries = v.as_u64() as usize },
    Knob { key: "tlb.l1_2m_entries", kind: KnobKind::U64,
           help: "L1 2MB TLB entries",
           apply: |c, v| c.l1_tlb_2m.entries = v.as_u64() as usize },
    Knob { key: "tlb.l2_4k_entries", kind: KnobKind::U64,
           help: "L2 4KB TLB entries",
           apply: |c, v| c.l2_tlb_4k.entries = v.as_u64() as usize },
    Knob { key: "tlb.l2_2m_entries", kind: KnobKind::U64,
           help: "L2 2MB TLB entries",
           apply: |c, v| c.l2_tlb_2m.entries = v.as_u64() as usize },
    Knob { key: "cache.l1_size", kind: KnobKind::U64,
           help: "L1 cache bytes",
           apply: |c, v| c.l1_cache.size = v.as_u64() },
    Knob { key: "cache.l2_size", kind: KnobKind::U64,
           help: "L2 cache bytes",
           apply: |c, v| c.l2_cache.size = v.as_u64() },
    Knob { key: "cache.l3_size", kind: KnobKind::U64,
           help: "LLC bytes",
           apply: |c, v| c.l3_cache.size = v.as_u64() },
    Knob { key: "dram.size", kind: KnobKind::U64,
           help: "DRAM capacity bytes",
           apply: |c, v| c.dram.size = v.as_u64() },
    Knob { key: "dram.channels", kind: KnobKind::U64,
           help: "DRAM channels",
           apply: |c, v| c.dram.channels = v.as_u64() as usize },
    Knob { key: "dram.ranks_per_channel", kind: KnobKind::U64,
           help: "DRAM ranks per channel",
           apply: |c, v| c.dram.ranks_per_channel = v.as_u64() as usize },
    Knob { key: "dram.banks_per_rank", kind: KnobKind::U64,
           help: "DRAM banks per rank",
           apply: |c, v| c.dram.banks_per_rank = v.as_u64() as usize },
    Knob { key: "dram.rows_per_bank", kind: KnobKind::U64,
           help: "DRAM rows per bank",
           apply: |c, v| c.dram.rows_per_bank = v.as_u64() },
    Knob { key: "dram.row_size", kind: KnobKind::U64,
           help: "DRAM row-buffer bytes per bank",
           apply: |c, v| c.dram.row_size = v.as_u64() },
    Knob { key: "dram.read_cycles", kind: KnobKind::U64,
           help: "DRAM array read latency (cycles)",
           apply: |c, v| c.dram.read_cycles = v.as_u64() },
    Knob { key: "dram.write_cycles", kind: KnobKind::U64,
           help: "DRAM array write latency (cycles)",
           apply: |c, v| c.dram.write_cycles = v.as_u64() },
    Knob { key: "dram.t_cas", kind: KnobKind::U64,
           help: "DRAM tCAS (controller cycles)",
           apply: |c, v| c.dram.t_cas = v.as_u64() },
    Knob { key: "dram.t_rcd", kind: KnobKind::U64,
           help: "DRAM tRCD",
           apply: |c, v| c.dram.t_rcd = v.as_u64() },
    Knob { key: "dram.t_rp", kind: KnobKind::U64,
           help: "DRAM tRP",
           apply: |c, v| c.dram.t_rp = v.as_u64() },
    Knob { key: "dram.t_ras", kind: KnobKind::U64,
           help: "DRAM tRAS",
           apply: |c, v| c.dram.t_ras = v.as_u64() },
    Knob { key: "dram.e_read_hit_pj_bit", kind: KnobKind::F64,
           help: "DRAM read energy, row-buffer hit (pJ/bit)",
           apply: |c, v| c.dram.e_read_hit_pj_bit = v.as_f64() },
    Knob { key: "dram.e_write_hit_pj_bit", kind: KnobKind::F64,
           help: "DRAM write energy, row-buffer hit (pJ/bit)",
           apply: |c, v| c.dram.e_write_hit_pj_bit = v.as_f64() },
    Knob { key: "dram.e_read_miss_pj_bit", kind: KnobKind::F64,
           help: "DRAM read energy, row-buffer miss (pJ/bit)",
           apply: |c, v| c.dram.e_read_miss_pj_bit = v.as_f64() },
    Knob { key: "dram.e_write_miss_pj_bit", kind: KnobKind::F64,
           help: "DRAM write energy, row-buffer miss (pJ/bit)",
           apply: |c, v| c.dram.e_write_miss_pj_bit = v.as_f64() },
    Knob { key: "dram.background_w_per_gb", kind: KnobKind::F64,
           help: "DRAM standby+refresh power (W per GB)",
           apply: |c, v| c.dram.background_w_per_gb = v.as_f64() },
    Knob { key: "nvm.size", kind: KnobKind::U64,
           help: "NVM capacity bytes",
           apply: |c, v| c.nvm.size = v.as_u64() },
    Knob { key: "nvm.channels", kind: KnobKind::U64,
           help: "NVM channels",
           apply: |c, v| c.nvm.channels = v.as_u64() as usize },
    Knob { key: "nvm.ranks_per_channel", kind: KnobKind::U64,
           help: "NVM ranks per channel",
           apply: |c, v| c.nvm.ranks_per_channel = v.as_u64() as usize },
    Knob { key: "nvm.banks_per_rank", kind: KnobKind::U64,
           help: "NVM banks per rank",
           apply: |c, v| c.nvm.banks_per_rank = v.as_u64() as usize },
    Knob { key: "nvm.rows_per_bank", kind: KnobKind::U64,
           help: "NVM rows per bank",
           apply: |c, v| c.nvm.rows_per_bank = v.as_u64() },
    Knob { key: "nvm.row_size", kind: KnobKind::U64,
           help: "NVM row-buffer bytes per bank",
           apply: |c, v| c.nvm.row_size = v.as_u64() },
    Knob { key: "nvm.read_cycles", kind: KnobKind::U64,
           help: "NVM array read latency (cycles)",
           apply: |c, v| c.nvm.read_cycles = v.as_u64() },
    Knob { key: "nvm.write_cycles", kind: KnobKind::U64,
           help: "NVM array write latency (cycles)",
           apply: |c, v| c.nvm.write_cycles = v.as_u64() },
    Knob { key: "nvm.t_cas", kind: KnobKind::U64,
           help: "NVM tCAS",
           apply: |c, v| c.nvm.t_cas = v.as_u64() },
    Knob { key: "nvm.t_rcd", kind: KnobKind::U64,
           help: "NVM tRCD",
           apply: |c, v| c.nvm.t_rcd = v.as_u64() },
    Knob { key: "nvm.t_rp", kind: KnobKind::U64,
           help: "NVM tRP",
           apply: |c, v| c.nvm.t_rp = v.as_u64() },
    Knob { key: "nvm.t_ras", kind: KnobKind::U64,
           help: "NVM tRAS",
           apply: |c, v| c.nvm.t_ras = v.as_u64() },
    Knob { key: "nvm.e_read_hit_pj_bit", kind: KnobKind::F64,
           help: "NVM read energy, row-buffer hit (pJ/bit)",
           apply: |c, v| c.nvm.e_read_hit_pj_bit = v.as_f64() },
    Knob { key: "nvm.e_write_hit_pj_bit", kind: KnobKind::F64,
           help: "NVM write energy, row-buffer hit (pJ/bit)",
           apply: |c, v| c.nvm.e_write_hit_pj_bit = v.as_f64() },
    Knob { key: "nvm.e_read_miss_pj_bit", kind: KnobKind::F64,
           help: "NVM read energy, row-buffer miss (pJ/bit)",
           apply: |c, v| c.nvm.e_read_miss_pj_bit = v.as_f64() },
    Knob { key: "nvm.e_write_miss_pj_bit", kind: KnobKind::F64,
           help: "NVM write energy, row-buffer miss (pJ/bit)",
           apply: |c, v| c.nvm.e_write_miss_pj_bit = v.as_f64() },
    Knob { key: "nvm.background_w_per_gb", kind: KnobKind::F64,
           help: "NVM standby power (W per GB; 0 for PCM)",
           apply: |c, v| c.nvm.background_w_per_gb = v.as_f64() },
    Knob { key: "rainbow.interval_cycles", kind: KnobKind::U64,
           help: "hot-page sampling interval (cycles)",
           apply: |c, v| c.interval_cycles = v.as_u64() },
    Knob { key: "rainbow.top_n", kind: KnobKind::U64,
           help: "top-N monitored hot superpages",
           apply: |c, v| c.top_n = v.as_u64() as usize },
    Knob { key: "rainbow.write_weight", kind: KnobKind::F64,
           help: "write weighting in superpage scoring",
           apply: |c, v| c.write_weight = v.as_f64() },
    Knob { key: "rainbow.migration_threshold", kind: KnobKind::F64,
           help: "base migration-benefit threshold (cycles, Eq. 1)",
           apply: |c, v| c.migration_threshold = v.as_f64() },
    Knob { key: "rainbow.bitmap_cache_entries", kind: KnobKind::U64,
           help: "migration-bitmap cache entries",
           apply: |c, v| c.bitmap_cache_entries = v.as_u64() as usize },
    Knob { key: "rainbow.bitmap_cache_assoc", kind: KnobKind::U64,
           help: "migration-bitmap cache associativity",
           apply: |c, v| c.bitmap_cache_assoc = v.as_u64() as usize },
    Knob { key: "rainbow.bitmap_cache_latency", kind: KnobKind::U64,
           help: "migration-bitmap cache latency (cycles)",
           apply: |c, v| c.bitmap_cache_latency = v.as_u64() },
    Knob { key: "cost.t_mig_4k", kind: KnobKind::U64,
           help: "4KB migration cost (cycles)",
           apply: |c, v| c.t_mig_4k = v.as_u64() },
    Knob { key: "cost.t_mig_2m", kind: KnobKind::U64,
           help: "2MB migration cost (cycles)",
           apply: |c, v| c.t_mig_2m = v.as_u64() },
    Knob { key: "cost.t_writeback_4k", kind: KnobKind::U64,
           help: "4KB writeback cost (cycles)",
           apply: |c, v| c.t_writeback_4k = v.as_u64() },
    Knob { key: "cost.t_shootdown", kind: KnobKind::U64,
           help: "TLB shootdown cost (cycles)",
           apply: |c, v| c.t_shootdown = v.as_u64() },
    Knob { key: "cost.t_clflush_line", kind: KnobKind::U64,
           help: "clflush cost per line (cycles)",
           apply: |c, v| c.t_clflush_line = v.as_u64() },
    // Derived knob, declared LAST so it sees the final nvm.size.
    Knob { key: "mem.dram_ratio", kind: KnobKind::U64,
           help: "NVM:DRAM capacity ratio (sets dram.size = nvm.size / r)",
           apply: |c, v| c.dram.size = c.nvm.size / v.as_u64().max(1) },
];

/// Every registered knob, in apply order.
pub fn all() -> &'static [Knob] {
    KNOBS
}

/// Look a knob up by its dotted key.
pub fn by_key(key: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.key == key)
}

/// An ordered (canonically sorted) map of knob overrides. The map keys
/// are the registry's `&'static str`s, so an `Overrides` can only ever
/// hold registered knobs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Overrides {
    map: BTreeMap<&'static str, KnobValue>,
}

impl Overrides {
    pub fn new() -> Overrides {
        Overrides::default()
    }

    /// Set a knob from a typed value. Rejects unknown keys and values
    /// that don't (losslessly) fit the knob's declared type.
    pub fn set(&mut self, key: &str, value: KnobValue) -> Result<(), String> {
        let knob = by_key(key)
            .ok_or_else(|| format!(
                "unknown config knob {key:?}; `rainbow list` shows them"))?;
        self.map.insert(knob.key, knob.coerce(value)?);
        Ok(())
    }

    /// Set a knob from its textual form (CLI `--set`, spec files).
    pub fn set_raw(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let knob = by_key(key)
            .ok_or_else(|| format!(
                "unknown config knob {key:?}; `rainbow list` shows them"))?;
        self.map.insert(knob.key, knob.parse(raw)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<KnobValue> {
        self.map.get(key).copied()
    }

    /// Drop a knob (no-op if unset), restoring the config's base value.
    pub fn remove(&mut self, key: &str) {
        self.map.remove(key);
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Knobs in canonical (key-sorted) order.
    pub fn iter(&self) -> impl Iterator<Item = (&'static str, KnobValue)> + '_ {
        self.map.iter().map(|(k, v)| (*k, *v))
    }

    /// Apply every set knob onto `cfg`, in registry order (NOT map
    /// order), so derived knobs are deterministic.
    pub fn apply_to(&self, cfg: &mut Config) {
        for knob in KNOBS {
            if let Some(v) = self.map.get(knob.key) {
                (knob.apply)(cfg, *v);
            }
        }
    }

    /// Canonical `key=value\n` serialization: sorted by key, values in
    /// canonical textual form — identical however the map was built.
    pub fn canonical(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.map {
            s.push_str(k);
            s.push('=');
            s.push_str(&v.to_string());
            s.push('\n');
        }
        s
    }

    /// Build from a tomlite document, rejecting unknown keys and
    /// ill-typed values (the validated half of `Config::apply_doc`).
    /// Quoted strings route through [`Knob::parse`], so profile knobs
    /// work from config files too (`profile = "optane-dcpmm"`).
    pub fn from_doc(doc: &Doc) -> Result<Overrides, String> {
        let mut ov = Overrides::new();
        for key in doc.keys() {
            let knob = by_key(key).ok_or_else(|| {
                format!("unknown config knob {key:?} in config file")
            })?;
            let v = match doc.get(key) {
                Some(Value::Int(u)) => knob.coerce(KnobValue::U64(*u))?,
                Some(Value::Float(f)) => knob.coerce(KnobValue::F64(*f))?,
                Some(Value::Str(s)) => knob.parse(s)?,
                _ => return Err(format!(
                    "knob {key}: expected a number or string")),
            };
            ov.map.insert(knob.key, v);
        }
        Ok(ov)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_keys_are_unique_and_resolvable() {
        for (i, k) in KNOBS.iter().enumerate() {
            assert!(by_key(k.key).is_some());
            for other in &KNOBS[i + 1..] {
                assert_ne!(k.key, other.key, "duplicate knob key");
            }
        }
    }

    #[test]
    fn set_rejects_unknown_and_ill_typed() {
        let mut ov = Overrides::new();
        assert!(ov.set("rainbow.nope", KnobValue::U64(1)).is_err());
        assert!(ov.set_raw("nvm.read_cycles", "fast").is_err());
        assert!(ov
            .set("rainbow.top_n", KnobValue::F64(1.5))
            .is_err(), "fractional value must not fit a u64 knob");
        assert!(ov.set("rainbow.top_n", KnobValue::F64(32.0)).is_ok());
        assert_eq!(ov.get("rainbow.top_n"), Some(KnobValue::U64(32)));
    }

    #[test]
    fn apply_changes_config() {
        let mut ov = Overrides::new();
        ov.set("rainbow.migration_threshold", KnobValue::F64(123.5))
            .unwrap();
        ov.set_raw("nvm.read_cycles", "124").unwrap();
        ov.set_raw("tlb.l2_4k_entries", "64").unwrap();
        let mut c = Config::scaled(8);
        ov.apply_to(&mut c);
        assert_eq!(c.migration_threshold, 123.5);
        assert_eq!(c.nvm.read_cycles, 124);
        assert_eq!(c.l2_tlb_4k.entries, 64);
    }

    #[test]
    fn dram_ratio_applies_after_nvm_size() {
        let mut ov = Overrides::new();
        // Insertion order is the OPPOSITE of the dependency order; the
        // registry-ordered apply must still see the final nvm.size.
        ov.set_raw("mem.dram_ratio", "4").unwrap();
        ov.set_raw("nvm.size", "1g").unwrap();
        let mut c = Config::scaled(8);
        ov.apply_to(&mut c);
        assert_eq!(c.nvm.size, 1 << 30);
        assert_eq!(c.dram.size, (1 << 30) / 4);
    }

    #[test]
    fn canonical_is_insertion_order_independent() {
        let mut a = Overrides::new();
        a.set_raw("rainbow.top_n", "32").unwrap();
        a.set_raw("dram.read_cycles", "50").unwrap();
        let mut b = Overrides::new();
        b.set_raw("dram.read_cycles", "50").unwrap();
        b.set_raw("rainbow.top_n", "32").unwrap();
        assert_eq!(a.canonical(), b.canonical());
        assert_eq!(a, b);
        assert_eq!(a.canonical(), "dram.read_cycles=50\nrainbow.top_n=32\n");
    }

    #[test]
    fn u64_knob_accepts_suffixes() {
        let mut ov = Overrides::new();
        ov.set_raw("dram.size", "256m").unwrap();
        assert_eq!(ov.get("dram.size"), Some(KnobValue::U64(256 << 20)));
    }

    #[test]
    fn positive_keys_are_all_registered() {
        for k in POSITIVE_KEYS.iter().chain(NONNEGATIVE_KEYS)
            .chain(ROW_SIZE_KEYS)
        {
            assert!(by_key(k).is_some(), "validation list has stale key {k}");
        }
    }

    /// Registry-completeness guard: every public `MemConfig` field must
    /// be reachable through some knob. The exhaustive destructure makes
    /// this test FAIL TO COMPILE when `MemConfig` gains a field, forcing
    /// the registry (and this list) to keep up.
    #[test]
    fn every_mem_config_field_is_knob_reachable() {
        use crate::config::{MemConfig, MemTech};

        let field_values: &[(&str, &str)] = &[
            ("size", "128m"), ("channels", "3"),
            ("ranks_per_channel", "5"), ("banks_per_rank", "6"),
            ("rows_per_bank", "1234"), ("row_size", "512"),
            ("read_cycles", "111"), ("write_cycles", "222"),
            ("t_cas", "21"), ("t_rcd", "22"), ("t_rp", "23"),
            ("t_ras", "24"),
            ("e_read_hit_pj_bit", "0.5"), ("e_write_hit_pj_bit", "0.625"),
            ("e_read_miss_pj_bit", "0.75"), ("e_write_miss_pj_bit", "0.875"),
            ("background_w_per_gb", "0.125"),
        ];
        for prefix in ["dram", "nvm"] {
            let mut ov = Overrides::new();
            for (field, value) in field_values {
                ov.set_raw(&format!("{prefix}.{field}"), value)
                    .unwrap_or_else(|e| panic!("{prefix}.{field}: {e}"));
            }
            // `tech` is reachable through the bundle-expanding profile
            // knob (it has no standalone field knob by design).
            ov.set_raw(&format!("{prefix}.profile"), "stt-ram").unwrap();
            let mut c = Config::paper();
            ov.apply_to(&mut c);
            let dev = if prefix == "dram" { c.dram } else { c.nvm };
            let MemConfig {
                tech, size, channels, ranks_per_channel, banks_per_rank,
                rows_per_bank, row_size, read_cycles, write_cycles,
                t_cas, t_rcd, t_rp, t_ras, e_read_hit_pj_bit,
                e_write_hit_pj_bit, e_read_miss_pj_bit,
                e_write_miss_pj_bit, background_w_per_gb,
            } = dev;
            assert_eq!(tech, MemTech::SttRam, "{prefix}.profile");
            assert_eq!(size, 128 << 20, "{prefix}.size");
            assert_eq!(channels, 3, "{prefix}.channels");
            assert_eq!(ranks_per_channel, 5, "{prefix}.ranks_per_channel");
            assert_eq!(banks_per_rank, 6, "{prefix}.banks_per_rank");
            assert_eq!(rows_per_bank, 1234, "{prefix}.rows_per_bank");
            assert_eq!(row_size, 512, "{prefix}.row_size");
            assert_eq!(read_cycles, 111, "{prefix}.read_cycles");
            assert_eq!(write_cycles, 222, "{prefix}.write_cycles");
            assert_eq!(t_cas, 21, "{prefix}.t_cas");
            assert_eq!(t_rcd, 22, "{prefix}.t_rcd");
            assert_eq!(t_rp, 23, "{prefix}.t_rp");
            assert_eq!(t_ras, 24, "{prefix}.t_ras");
            assert_eq!(e_read_hit_pj_bit, 0.5, "{prefix}.e_read_hit");
            assert_eq!(e_write_hit_pj_bit, 0.625, "{prefix}.e_write_hit");
            assert_eq!(e_read_miss_pj_bit, 0.75, "{prefix}.e_read_miss");
            assert_eq!(e_write_miss_pj_bit, 0.875, "{prefix}.e_write_miss");
            assert_eq!(background_w_per_gb, 0.125, "{prefix}.background");
        }
    }

    #[test]
    fn profile_expands_before_field_overrides() {
        // Whatever order the map was built in, the profile knob applies
        // first (registry order), so the explicit field override wins.
        let mut ov = Overrides::new();
        ov.set_raw("nvm.read_cycles", "9999").unwrap();
        ov.set_raw("nvm.profile", "optane-dcpmm").unwrap();
        let mut c = Config::paper();
        ov.apply_to(&mut c);
        assert_eq!(c.nvm.tech, crate::config::MemTech::Optane);
        assert_eq!(c.nvm.read_cycles, 9999, "field override must win");
        let optane = profiles::by_name("optane-dcpmm").unwrap().mem();
        assert_eq!(c.nvm.write_cycles, optane.write_cycles);
    }

    #[test]
    fn profile_expansion_tracks_scale_factor() {
        let mut ov = Overrides::new();
        ov.set_raw("nvm.profile", "pcm-paper").unwrap();
        let mut c = Config::scaled(8);
        let expect = c.nvm; // pcm-paper IS the scaled baseline NVM
        ov.apply_to(&mut c);
        assert_eq!(c.nvm, expect);
    }

    #[test]
    fn profile_knob_rejects_bad_input() {
        let mut ov = Overrides::new();
        let e = ov.set_raw("nvm.profile", "sdram-9000").unwrap_err();
        assert!(e.contains("unknown device profile"), "got: {e}");
        assert!(e.contains("pcm-paper"), "error must list the catalog: {e}");
        // Numbers don't fit a profile knob; names don't fit numeric ones.
        assert!(ov.set("nvm.profile", KnobValue::U64(3)).is_err());
        assert!(ov.set("nvm.read_cycles", KnobValue::Str("pcm-paper"))
            .is_err());
        // Case-insensitive lookup interns the canonical name.
        ov.set_raw("nvm.profile", "PCM-Paper").unwrap();
        assert_eq!(ov.get("nvm.profile"), Some(KnobValue::Str("pcm-paper")));
        assert_eq!(ov.canonical(), "nvm.profile=pcm-paper\n");
    }

    #[test]
    fn degenerate_device_geometry_rejected() {
        let mut ov = Overrides::new();
        // Zero channels/ranks/banks/rows are bank-decode divide-by-zero.
        for key in ["dram.channels", "dram.rows_per_bank", "nvm.channels",
                    "nvm.banks_per_rank", "nvm.ranks_per_channel"] {
            assert!(ov.set_raw(key, "0").is_err(), "{key}=0 must fail");
        }
        // Sub-line row buffers zero the column count.
        assert!(ov.set_raw("nvm.row_size", "32").is_err());
        assert!(ov.set_raw("nvm.row_size", "64").is_ok());
        // Negative energy corrupts the Fig. 12 rollup; zero is fine.
        assert!(ov.set_raw("nvm.e_write_miss_pj_bit", "-1.0").is_err());
        assert!(ov.set_raw("dram.background_w_per_gb", "0").is_ok());
    }

    #[test]
    fn degenerate_values_rejected_before_any_fanout() {
        let mut ov = Overrides::new();
        // Zero divisors / empty structures / hang-inducing interval.
        assert!(ov.set_raw("cpu.cores", "0").is_err());
        assert!(ov.set_raw("rainbow.interval_cycles", "0").is_err());
        assert!(ov.set_raw("dram.size", "0").is_err());
        assert!(ov.set("rainbow.top_n", KnobValue::U64(0)).is_err());
        assert!(ov.set_raw("cpu.ghz", "-3.2").is_err());
        // Non-finite floats (NaN disables threshold comparisons).
        assert!(ov.set_raw("rainbow.migration_threshold", "nan").is_err());
        assert!(ov.set_raw("rainbow.migration_threshold", "inf").is_err());
        // Zero stays legal where it is meaningful.
        assert!(ov.set_raw("rainbow.write_weight", "0").is_ok());
        assert!(ov.set_raw("cost.t_shootdown", "0").is_ok());
    }
}
