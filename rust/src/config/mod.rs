//! System configuration — the paper's Table IV, plus experiment scaling.
//!
//! All latencies are in CPU cycles at `cpu_ghz` (3.2 GHz in the paper, so
//! 13.5 ns DRAM read = 43 cycles, 171 ns PCM write = 547 cycles).
//! `Config::paper()` reproduces Table IV exactly; `Config::scaled()` keeps
//! every ratio (DRAM:NVM = 1:8, latency ratios, TLB geometry) while
//! shrinking capacities so a full experiment suite runs in minutes.

use crate::util::tomlite::Doc;

pub mod knobs;
pub mod profiles;

pub const PAGE_SHIFT: u32 = 12;
pub const PAGE_SIZE: u64 = 1 << PAGE_SHIFT; // 4 KB
pub const SP_SHIFT: u32 = 21;
pub const SP_SIZE: u64 = 1 << SP_SHIFT; // 2 MB
pub const PAGES_PER_SP: u64 = SP_SIZE / PAGE_SIZE; // 512
pub const LINE_SIZE: u64 = 64;

/// TLB geometry (per level, per page size).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TlbConfig {
    pub entries: usize,
    pub assoc: usize,
    pub latency: u64,
}

/// Cache geometry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheConfig {
    pub size: u64,
    pub assoc: usize,
    pub latency: u64,
}

/// Memory technology behind a device — the identity a [`MemConfig`]
/// bundle (and hence a [`profiles::DeviceProfile`]) carries, so nothing
/// downstream has to infer "DRAM-ness" from which controller slot a
/// device sits in. The *slots* stay `dram`/`nvm` (fast tier / slow
/// tier); the *technology* in each slot is whatever the profile says.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemTech {
    /// Conventional DDR-class DRAM.
    Dram,
    /// High-bandwidth, many-channel DRAM (HBM-class).
    Hbm,
    /// Spin-transfer-torque MRAM.
    SttRam,
    /// Phase-change memory (the paper's NVM).
    Pcm,
    /// 3D-XPoint-class persistent memory (Optane DCPMM).
    Optane,
    /// DRAM reached over a CXL-style link (volatile but far).
    CxlDram,
}

impl MemTech {
    pub fn name(self) -> &'static str {
        match self {
            MemTech::Dram => "dram",
            MemTech::Hbm => "hbm",
            MemTech::SttRam => "stt-ram",
            MemTech::Pcm => "pcm",
            MemTech::Optane => "optane",
            MemTech::CxlDram => "cxl-dram",
        }
    }

    /// Whether writes survive power loss (drives the paper's clflush
    /// persistence reasoning; CXL-attached DRAM is far but volatile).
    pub fn is_nonvolatile(self) -> bool {
        matches!(self, MemTech::SttRam | MemTech::Pcm | MemTech::Optane)
    }
}

/// Memory-device timing/energy (one technology bundle; see
/// [`profiles`] for the named catalog).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemConfig {
    /// Technology identity of this bundle (reporting + persistence
    /// semantics); set by `Config::paper()` or a device profile.
    pub tech: MemTech,
    pub size: u64,
    pub channels: usize,
    pub ranks_per_channel: usize,
    pub banks_per_rank: usize,
    pub rows_per_bank: u64,
    /// Row-buffer (page) size per bank in bytes.
    pub row_size: u64,
    /// Array access latencies in cycles (row-buffer MISS adds tRCD+tRP).
    pub read_cycles: u64,
    pub write_cycles: u64,
    /// tCAS-tRCD-tRP-tRAS in memory-controller cycles (Table IV).
    pub t_cas: u64,
    pub t_rcd: u64,
    pub t_rp: u64,
    pub t_ras: u64,
    /// Energy: pJ per bit for row-buffer hit/miss reads and writes.
    pub e_read_hit_pj_bit: f64,
    pub e_write_hit_pj_bit: f64,
    pub e_read_miss_pj_bit: f64,
    pub e_write_miss_pj_bit: f64,
    /// Background power (refresh + standby) in watts per GB of capacity;
    /// 0 for PCM (near-zero standby, §I). Total draw scales with size.
    pub background_w_per_gb: f64,
}

/// Full system configuration (Table IV).
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub cores: usize,
    pub cpu_ghz: f64,
    /// L1 split TLBs (per core): one for 4 KB, one for 2 MB.
    pub l1_tlb_4k: TlbConfig,
    pub l1_tlb_2m: TlbConfig,
    /// L2 unified-per-size TLBs.
    pub l2_tlb_4k: TlbConfig,
    pub l2_tlb_2m: TlbConfig,
    pub l1_cache: CacheConfig,
    pub l2_cache: CacheConfig,
    pub l3_cache: CacheConfig,
    /// Migration-bitmap cache (Fig. 5): entries × 8-way, 9-cycle latency.
    pub bitmap_cache_entries: usize,
    pub bitmap_cache_assoc: usize,
    pub bitmap_cache_latency: u64,
    pub dram: MemConfig,
    pub nvm: MemConfig,
    /// Sampling interval for hot-page identification (cycles).
    pub interval_cycles: u64,
    /// Top-N hot superpages monitored at 4 KB granularity in stage 2.
    pub top_n: usize,
    /// Write weighting in superpage access counting.
    pub write_weight: f64,
    /// Base migration-benefit threshold (cycles; Eq. 1).
    pub migration_threshold: f64,
    /// Cost models (cycles).
    pub t_mig_4k: u64,
    pub t_mig_2m: u64,
    pub t_writeback_4k: u64,
    pub t_shootdown: u64,
    pub t_clflush_line: u64,
    /// TLB miss page-table walk memory references (x86-64: 4 for 4 KB,
    /// 3 for 2 MB superpages).
    pub ptw_levels_4k: u64,
    pub ptw_levels_2m: u64,
    /// Capacity scale divisor vs Table IV (1 = paper scale).
    pub scale_factor: u64,
}

impl Config {
    /// Exact Table IV configuration (4 GB DRAM + 32 GB PCM).
    pub fn paper() -> Config {
        let dram = MemConfig {
            tech: MemTech::Dram,
            size: 4 << 30,
            channels: 1,
            ranks_per_channel: 4,
            banks_per_rank: 8, // 32 banks total over 4 ranks
            rows_per_bank: 32768,
            row_size: 64 * 64, // 64 cols x 64B
            read_cycles: ns_to_cycles(13.5, 3.2),
            write_cycles: ns_to_cycles(28.5, 3.2),
            t_cas: 7,
            t_rcd: 7,
            t_rp: 7,
            t_ras: 18,
            // Derived from Table IV currents (1.5 V, tBurst):
            // hit ~ 120/125 mA, miss ~ 237/242 mA over the access window.
            e_read_hit_pj_bit: 1.1,
            e_write_hit_pj_bit: 1.2,
            e_read_miss_pj_bit: 2.2,
            e_write_miss_pj_bit: 2.3,
            // Standby 77 mA + refresh 160 mA at 1.5 V over 4 GB, derated:
            // ~0.9 W for the 4 GB device = 0.225 W/GB.
            background_w_per_gb: 0.225,
        };
        let nvm = MemConfig {
            tech: MemTech::Pcm,
            size: 32 << 30,
            channels: 4,
            ranks_per_channel: 8,
            banks_per_rank: 8,
            rows_per_bank: 65536,
            row_size: 32 * 64, // 32 cols x 64B
            read_cycles: ns_to_cycles(19.5, 3.2),
            write_cycles: ns_to_cycles(171.0, 3.2),
            t_cas: 9,
            t_rcd: 37,
            t_rp: 100,
            t_ras: 53,
            e_read_hit_pj_bit: 1.616,
            e_write_hit_pj_bit: 1.616,
            e_read_miss_pj_bit: 81.2,
            e_write_miss_pj_bit: 1684.8,
            background_w_per_gb: 0.0, // near-zero standby (paper §I)
        };
        Config {
            cores: 8,
            cpu_ghz: 3.2,
            l1_tlb_4k: TlbConfig { entries: 32, assoc: 4, latency: 1 },
            l1_tlb_2m: TlbConfig { entries: 32, assoc: 4, latency: 1 },
            l2_tlb_4k: TlbConfig { entries: 512, assoc: 8, latency: 8 },
            l2_tlb_2m: TlbConfig { entries: 512, assoc: 8, latency: 8 },
            l1_cache: CacheConfig { size: 64 << 10, assoc: 4, latency: 3 },
            l2_cache: CacheConfig { size: 256 << 10, assoc: 8, latency: 10 },
            l3_cache: CacheConfig { size: 8 << 20, assoc: 16, latency: 34 },
            bitmap_cache_entries: 4000,
            bitmap_cache_assoc: 8,
            bitmap_cache_latency: 9,
            dram,
            nvm,
            interval_cycles: 100_000_000,
            top_n: 100,
            write_weight: 3.0,
            migration_threshold: 2000.0,
            // 4 KB over ~10.7 GB/s shared bus + controller overhead.
            t_mig_4k: 4096,
            t_mig_2m: 4096 * 512,
            t_writeback_4k: 4096,
            t_shootdown: 4000, // IPI + invalidation across 8 cores
            t_clflush_line: 10,
            ptw_levels_4k: 4,
            ptw_levels_2m: 3,
            scale_factor: 1,
        }
    }

    /// Scaled-down config: capacities / `factor`, identical ratios and
    /// latencies. Default experiments use `factor = 8` (512 MB DRAM,
    /// 4 GB NVM) with a 1e7-cycle interval. Panics on an invalid
    /// factor; validated input paths (CLI, spec files) go through
    /// [`Config::try_scaled`] first.
    pub fn scaled(factor: u64) -> Config {
        Config::try_scaled(factor)
            .unwrap_or_else(|e| panic!("Config::scaled: {e}"))
    }

    /// [`Config::scaled`] with the degenerate factors as errors instead
    /// of panics: zero / non-power-of-two factors, and factors so large
    /// the DRAM tier would shrink below 32 MB (the machine parks a
    /// 16 MB page-table region at the top of DRAM, and rows-per-bank
    /// would degenerate toward the `.max(1)` clamp).
    pub fn try_scaled(factor: u64) -> Result<Config, String> {
        if factor == 0 || !factor.is_power_of_two() {
            return Err(format!(
                "scale factor must be a power of two, got {factor}"));
        }
        let mut c = Config::paper();
        if c.dram.size / factor < 32 << 20 {
            return Err(format!(
                "scale factor {factor} too large: DRAM would shrink to \
                 {} bytes (< 32 MB)", c.dram.size / factor));
        }
        c.dram.size /= factor;
        c.nvm.size /= factor;
        // Clamped so absurd factors (or sparse profile bundles) can
        // never drive the row count to 0 — a zero modulus in
        // `bank::decode` is a divide-by-zero panic.
        c.dram.rows_per_bank = (c.dram.rows_per_bank / factor).max(1);
        c.nvm.rows_per_bank = (c.nvm.rows_per_bank / factor).max(1);
        // Shrink caches/TLBs less aggressively (sqrt-ish) so hit rates keep
        // the paper's regime relative to the shrunk footprints.
        // Scale the *coverage* structures (TLBs, caches) by the same
        // factor as the footprints so hit rates stay in the paper's
        // regime (hot sets larger than the LLC, TLB coverage comparable
        // to working sets). Private L1/L2 scale less aggressively.
        let f = factor as usize;
        c.l2_tlb_4k.entries = (c.l2_tlb_4k.entries / f).max(16);
        c.l2_tlb_2m.entries = (c.l2_tlb_2m.entries / f).max(16);
        c.l1_cache.size = (c.l1_cache.size / 2).max(8 << 10);
        c.l2_cache.size = (c.l2_cache.size / 4).max(16 << 10);
        c.l3_cache.size = (c.l3_cache.size / factor).max(128 << 10);
        c.bitmap_cache_entries = ((c.bitmap_cache_entries / f).max(256)
            / c.bitmap_cache_assoc) * c.bitmap_cache_assoc;
        c.interval_cycles /= factor;
        c.top_n = (c.top_n / (factor as f64).sqrt() as usize).max(16);
        // Per-interval-amortized OS cost constants scale with the
        // interval so Eq. 1/2 decisions (counts vs T_mig) and the charged
        // stop-the-world costs keep the paper's per-interval ratios.
        c.t_mig_4k = (c.t_mig_4k / factor).max(256);
        c.t_mig_2m = (c.t_mig_2m / factor).max(256 * 512);
        c.t_writeback_4k = (c.t_writeback_4k / factor).max(256);
        c.t_shootdown = (c.t_shootdown / factor).max(500);
        c.scale_factor = factor;
        // Dynamic energy per access is scale-invariant but capacity (and
        // hence refresh/standby power) shrank by `factor`; keep the
        // paper's background:dynamic energy balance by scaling the
        // per-GB draw back up (Fig. 12 depends on this balance). Applied
        // to BOTH slots — a no-op for the baseline PCM (0 W/GB) but it
        // keeps `DeviceProfile::mem_scaled` an exact per-device mirror
        // for NVM-slot profiles with real standby draw (Optane, CXL).
        c.dram.background_w_per_gb *= factor as f64;
        c.nvm.background_w_per_gb *= factor as f64;
        Ok(c)
    }

    /// Total physical space (DRAM then NVM in the flat layouts).
    pub fn total_mem(&self) -> u64 {
        self.dram.size + self.nvm.size
    }

    pub fn nvm_superpages(&self) -> u64 {
        self.nvm.size / SP_SIZE
    }

    pub fn dram_pages(&self) -> u64 {
        self.dram.size / PAGE_SIZE
    }

    /// Load overrides from a tomlite document (flat `section.key` keys)
    /// through the knob registry: unknown keys and ill-typed values are
    /// rejected, the same as CLI `--set` and spec files.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<(), String> {
        let ov = knobs::Overrides::from_doc(doc)?;
        ov.apply_to(self);
        Ok(())
    }
}

/// ns at `ghz` → CPU cycles (rounded).
pub fn ns_to_cycles(ns: f64, ghz: f64) -> u64 {
    (ns * ghz).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_latencies_match_table_iv() {
        let c = Config::paper();
        assert_eq!(c.dram.read_cycles, 43); // 13.5 ns @ 3.2 GHz
        assert_eq!(c.dram.write_cycles, 91); // 28.5 ns
        assert_eq!(c.nvm.read_cycles, 62); // 19.5 ns
        assert_eq!(c.nvm.write_cycles, 547); // 171 ns
        assert_eq!(c.dram.size, 4 << 30);
        assert_eq!(c.nvm.size, 32 << 30);
        assert_eq!(c.cores, 8);
        assert_eq!(c.nvm_superpages(), 16384);
    }

    #[test]
    fn nvm_write_asymmetry() {
        // Paper §II-B: NVM writes 5-10x slower than DRAM.
        let c = Config::paper();
        let ratio = c.nvm.write_cycles as f64 / c.dram.write_cycles as f64;
        assert!((5.0..=10.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn scaled_preserves_ratio() {
        let c = Config::scaled(8);
        assert_eq!(c.nvm.size / c.dram.size, 8);
        assert_eq!(c.dram.size, 512 << 20);
        assert_eq!(c.dram.read_cycles, Config::paper().dram.read_cycles);
        assert_eq!(c.nvm_superpages(), 2048);
    }

    #[test]
    fn constants() {
        assert_eq!(PAGES_PER_SP, 512);
        assert_eq!(SP_SIZE, 2 << 20);
        assert_eq!(ns_to_cycles(13.5, 3.2), 43);
    }

    #[test]
    fn doc_overrides() {
        let doc = Doc::parse(
            "[rainbow]\ntop_n = 50\ninterval_cycles = 1_000_000\n\
             [dram]\nsize = 256m\n",
        )
        .unwrap();
        let mut c = Config::paper();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.top_n, 50);
        assert_eq!(c.interval_cycles, 1_000_000);
        assert_eq!(c.dram.size, 256 << 20);
    }

    #[test]
    fn doc_with_unknown_knob_rejected() {
        let doc = Doc::parse("[rainbow]\nnot_a_knob = 1\n").unwrap();
        let mut c = Config::paper();
        assert!(c.apply_doc(&doc).is_err());
    }

    #[test]
    fn doc_profile_strings_expand() {
        let doc =
            Doc::parse("[nvm]\nprofile = \"optane-dcpmm\"\n").unwrap();
        let mut c = Config::paper();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.nvm.tech, MemTech::Optane);
        let bad = Doc::parse("[nvm]\nprofile = \"sdram-9000\"\n").unwrap();
        assert!(Config::paper().apply_doc(&bad).is_err());
    }

    #[test]
    fn try_scaled_rejects_degenerate_factors() {
        assert!(Config::try_scaled(0).unwrap_err().contains("power of two"));
        assert!(Config::try_scaled(3).unwrap_err().contains("power of two"));
        // 4 GB / 512 = 8 MB DRAM: smaller than the page-table region.
        assert!(Config::try_scaled(512).unwrap_err().contains("too large"));
        assert!(Config::try_scaled(128).is_ok());
        // Rows-per-bank never reaches the bank-decode divide-by-zero.
        let c = Config::try_scaled(128).unwrap();
        assert!(c.dram.rows_per_bank >= 1 && c.nvm.rows_per_bank >= 1);
    }
}
